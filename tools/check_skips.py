#!/usr/bin/env python
"""Audit a pytest junitxml report against the registered-skip table.

Usage:  python tools/check_skips.py .pytest-report.xml

Exits non-zero — listing the offenders — if the report contains any
skipped test that is not in ``tests.skip_registry.REGISTERED_SKIPS`` with
one of its registered reason prefixes (or an environment-wide prefix such
as the no-jax CI leg's collection skips).  This is what turns a silently
perma-skipped test into a build failure instead of a green checkmark.
"""

import sys
import xml.etree.ElementTree as ET
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from tests.skip_registry import (ENVIRONMENT_REASON_PREFIXES,  # noqa: E402
                                 REGISTERED_SKIPS)


def _nodeid(case) -> str:
    """junitxml (classname='tests.test_ilp', name='test_x[param]') →
    'tests/test_ilp.py::test_x'.  Module-level collection skips carry the
    file path in ``name`` and an empty classname — passed through as-is."""
    cls = case.get("classname") or ""
    name = (case.get("name") or "").split("[")[0]
    if not cls:
        return name
    return cls.replace(".", "/") + ".py::" + name


def audit(path):
    """Return (offenders, n_skipped) for the junitxml at ``path``."""
    tree = ET.parse(path)
    offenders, n_skipped = [], 0
    for case in tree.iter("testcase"):
        sk = case.find("skipped")
        if sk is None:
            continue
        n_skipped += 1
        nodeid = _nodeid(case)
        msg = sk.get("message") or ""
        allowed = REGISTERED_SKIPS.get(nodeid, ())
        if any(msg.startswith(a) for a in allowed):
            continue
        if any(msg.startswith(p) for p in ENVIRONMENT_REASON_PREFIXES):
            continue
        offenders.append((nodeid, msg))
    return offenders, n_skipped


def main(argv):
    if len(argv) != 2:
        print(__doc__)
        return 2
    report = Path(argv[1])
    if not report.exists():
        print(f"check_skips: report {report} not found — run pytest with "
              f"--junitxml={report} first")
        return 2
    offenders, n_skipped = audit(report)
    if offenders:
        print("check_skips: UNREGISTERED skips (register in "
              "tests/skip_registry.py or fix the test):")
        for nodeid, msg in offenders:
            print(f"  {nodeid}: {msg!r}")
        return 1
    print(f"check_skips: ok — {n_skipped} skip(s), all registered")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
