"""Docs gate (`make docs-check`): keep README and DESIGN.md honest.

1. Extracts every ```bash fenced block from README.md and smoke-runs each
   command line, so the quickstart can never rot.  A block immediately
   preceded by an HTML comment containing ``docs-check: skip`` is listed
   but not executed (slow full sweeps, commands that would recurse into
   this check).
2. Collects every ``DESIGN.md §N`` reference in README.md and the Python
   sources and fails on references to sections that don't exist — DESIGN
   section numbering is a stable public contract (DESIGN.md header).

Exit code 0 iff every command succeeds and no reference dangles.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(REPO, "README.md")
DESIGN = os.path.join(REPO, "DESIGN.md")
SKIP_MARK = "docs-check: skip"
TIMEOUT_S = 600

FENCE_RE = re.compile(
    r"(?P<pre>^[^\n]*\n)?^```bash\n(?P<body>.*?)^```", re.M | re.S)
SECTION_REF_RE = re.compile(r"DESIGN\.md\s*§\s*(\d+)")
SECTION_DEF_RE = re.compile(r"^##\s*§(\d+)\b", re.M)


def extract_bash_blocks(text: str):
    """Yield (skipped, [command lines]) per fenced bash block."""
    for m in FENCE_RE.finditer(text):
        pre = m.group("pre") or ""
        skipped = SKIP_MARK in pre
        lines = [ln.strip() for ln in m.group("body").splitlines()]
        cmds = [ln for ln in lines if ln and not ln.startswith("#")]
        yield skipped, cmds


def check_quickstart() -> int:
    failures = 0
    with open(README) as f:
        text = f.read()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for skipped, cmds in extract_bash_blocks(text):
        for cmd in cmds:
            if skipped:
                print(f"docs-check: SKIP  {cmd}")
                continue
            print(f"docs-check: RUN   {cmd}")
            try:
                proc = subprocess.run(cmd, shell=True, cwd=REPO, env=env,
                                      capture_output=True, text=True,
                                      timeout=TIMEOUT_S)
            except subprocess.TimeoutExpired:
                failures += 1
                print(f"docs-check: FAIL  {cmd} (timeout {TIMEOUT_S}s)")
                continue
            if proc.returncode != 0:
                failures += 1
                print(f"docs-check: FAIL  {cmd} (exit {proc.returncode})")
                sys.stderr.write(proc.stderr[-2000:] + "\n")
    return failures


def check_design_refs() -> int:
    with open(DESIGN) as f:
        defined = set(SECTION_DEF_RE.findall(f.read()))
    failures = 0
    sources = [README]
    for root in ("src", "benchmarks", "examples", "tests", "tools"):
        for dirpath, _, names in os.walk(os.path.join(REPO, root)):
            sources += [os.path.join(dirpath, n) for n in names
                        if n.endswith(".py")]
    for path in sources:
        with open(path) as f:
            text = f.read()
        for sec in SECTION_REF_RE.findall(text):
            if sec not in defined:
                failures += 1
                print(f"docs-check: DANGLING reference DESIGN.md §{sec} "
                      f"in {os.path.relpath(path, REPO)}")
    print(f"docs-check: DESIGN.md sections defined: "
          f"{sorted(defined, key=int)}")
    return failures


def main() -> int:
    failures = check_design_refs()
    failures += check_quickstart()
    if failures:
        print(f"docs-check: {failures} failure(s)")
        return 1
    print("docs-check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
