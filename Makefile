# Developer entry points.  PYTHONPATH=src is the only environment the repo
# needs (ROADMAP.md "Tier-1 verify").

PY := PYTHONPATH=src python

.PHONY: verify test bench bench-solver

## tier-1 gate: full test suite + a smoke pass of the solver microbenchmark
verify:
	$(PY) -m pytest -x -q
	$(PY) -m benchmarks.bench_solver --smoke --json ""

test:
	$(PY) -m pytest -q

## full paper figure/table sweep (slow; compiles dry-run cells)
bench:
	$(PY) -m benchmarks.run

## solver microbenchmark at all market sizes; refreshes BENCH_solver.json
bench-solver:
	$(PY) -m benchmarks.bench_solver --json BENCH_solver.json
