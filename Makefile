# Developer entry points.  PYTHONPATH=src is the only environment the repo
# needs (ROADMAP.md "Tier-1 verify").

PY := PYTHONPATH=src python

.PHONY: verify test bench bench-solver bench-backend bench-risk bench-fleet \
        bench-scale bench-serve bench-chaos bench-region perf-gate docs-check \
        check-skips

## tier-1 gate: full test suite (junitxml-audited: every skip must be in
## tests/skip_registry.py) + a smoke pass of the solver microbenchmark
## + the docs gate (README quickstart runs, DESIGN.md refs resolve)
verify:
	$(PY) -m pytest -x -q --junitxml=.pytest-report.xml
	$(PY) tools/check_skips.py .pytest-report.xml
	$(PY) -m benchmarks.bench_solver --smoke --json ""
	$(PY) tools/docs_check.py

## audit the last test run's skips against the registered-skip table
check-skips:
	$(PY) tools/check_skips.py .pytest-report.xml

## smoke-run README quickstart code blocks; fail on dangling DESIGN.md §refs
docs-check:
	$(PY) tools/docs_check.py

test:
	$(PY) -m pytest -q

## full paper figure/table sweep (slow; compiles dry-run cells)
bench:
	$(PY) -m benchmarks.run

## solver microbenchmark at all market sizes; refreshes BENCH_solver.json
bench-solver:
	$(PY) -m benchmarks.bench_solver --json BENCH_solver.json

## decision-plane backend benchmark (PR 1 path vs batched numpy / per-
## dispatch jax / fused device-resident engines; compile vs steady-state
## split + catalog-size scaling column); refreshes BENCH_backend.json
bench-backend:
	$(PY) -m benchmarks.bench_backend --json BENCH_backend.json

## ReFrame-style perf regression gate: re-run the cheap fleet-tick config,
## compare ratio metrics against PERF_REFERENCE.json within tolerance
## bands, append to PERF_trajectory.jsonl; `--update` refreshes references
perf-gate:
	$(PY) -m benchmarks.perf_gate

## risk-subsystem backtest (kubepacs_risk vs kubepacs + forecast
## calibration); refreshes BENCH_risk.json
bench-risk:
	$(PY) -m benchmarks.bench_risk --json BENCH_risk.json

## fleet-engine throughput (FleetSim vs per-seed run_replicas at R=256,
## decision-memo effectiveness); refreshes BENCH_fleet.json
bench-fleet:
	$(PY) -m benchmarks.bench_fleet --json BENCH_fleet.json

## demand-scale sweep 5k → 1M pods (coarsening ladder; in-bench
## coarse≡exact verification at overlapping scales); refreshes
## BENCH_scale.json
bench-scale:
	$(PY) -m benchmarks.bench_scale --json BENCH_scale.json

## serving co-simulation (serving_slo vs karpenter_like/kubepacs/… on
## diurnal/bursty/flash; in-bench determinism + zero-infeasibility
## verification); refreshes BENCH_serve.json
bench-serve:
	$(PY) -m benchmarks.bench_serve --json BENCH_serve.json

## chaos fault-storm sweep (hardened degradation ladder vs naive plane on
## feed/ice/solver/combined storms; in-bench determinism + inertness
## verification); refreshes BENCH_chaos.json
bench-chaos:
	$(PY) -m benchmarks.bench_chaos --json BENCH_chaos.json

## multi-region failover sweep (hardened failover rung vs region-pinned
## strawman through the correlated regional storm; in-bench determinism +
## single-region/identity-config inertness verification); refreshes
## BENCH_region.json
bench-region:
	$(PY) -m benchmarks.bench_region --json BENCH_region.json
