"""ILP solver: exactness (vs PuLP/CBC and brute force), invariants."""

import itertools

import numpy as np
import pytest

from tests._optional import given, settings, st

from repro.core import CandidateItem, Offering, objective_coefficients, solve_ilp
from repro.core.ilp import solve_ilp_pulp


def _mk_item(i, pods, bs, sp, t3):
    o = Offering(offering_id=f"t{i}@az", instance_type=f"t{i}", family="m",
                 generation=6, vendor="i", specialization="general",
                 size="large", region="r", az="az", vcpus=2, mem_gib=8.0,
                 od_price=sp * 3, spot_price=sp, bs_core=bs, sps_single=3,
                 t3=t3, interruption_freq=1)
    return CandidateItem(offering=o, pods=pods, bs=bs, spot_price=sp, t3=t3)


item_strategy = st.builds(
    lambda i, pods, bs, sp, t3: _mk_item(i, pods, bs, sp, t3),
    st.integers(0, 10_000), st.integers(1, 8),
    st.floats(1e3, 1e5), st.floats(0.01, 3.0), st.integers(0, 6))


def _brute_force(items, req, alpha):
    coef = objective_coefficients(items, alpha)
    best, best_x = None, None
    ranges = [range(it.t3 + 1) for it in items]
    for xs in itertools.product(*ranges):
        if sum(x * it.pods for x, it in zip(xs, items)) < req:
            continue
        c = float(np.dot(coef, xs))
        if best is None or c < best - 1e-12:
            best, best_x = c, xs
    return best


@settings(max_examples=40, deadline=None)
@given(st.lists(item_strategy, min_size=1, max_size=4),
       st.integers(0, 12), st.floats(0.0, 1.0))
def test_dp_matches_brute_force(items, req, alpha):
    counts = solve_ilp(items, req, alpha)
    expected = _brute_force(items, req, alpha)
    if expected is None:
        assert counts is None
        return
    assert counts is not None
    coef = objective_coefficients(items, alpha)
    got = float(np.dot(coef, counts))
    assert got <= expected + 1e-9
    assert sum(c * it.pods for c, it in zip(counts, items)) >= req


@settings(max_examples=15, deadline=None)
@given(st.lists(item_strategy, min_size=2, max_size=12),
       st.integers(1, 60), st.floats(0.0, 1.0))
def test_dp_matches_pulp(items, req, alpha):
    pytest.importorskip("pulp")
    counts = solve_ilp(items, req, alpha)
    pulp_counts = solve_ilp_pulp(items, req, alpha)
    coef = objective_coefficients(items, alpha)
    if counts is None:
        # CBC reports infeasible too (no feasible integral point)
        cap = sum(it.pods * it.t3 for it in items)
        assert cap < req
        return
    assert pulp_counts is not None
    assert float(np.dot(coef, counts)) == pytest.approx(
        float(np.dot(coef, pulp_counts)), abs=1e-6)


def test_bounds_respected(items_100):
    counts = solve_ilp(items_100[:200], 500, 0.4)
    for c, it in zip(counts, items_100[:200]):
        assert 0 <= c <= it.t3


def test_alpha_one_saturates(items_100):
    """α=1: every positive-perf item has a negative coefficient and is taken
    at its T3 bound — the Table 2 over-provisioning collapse."""
    items = items_100[:100]
    counts = solve_ilp(items, 10, 1.0)
    for c, it in zip(counts, items):
        if it.perf > 0 and it.t3 > 0:
            assert c == it.t3


def test_alpha_zero_minimizes_cost(items_100):
    pytest.importorskip("pulp")
    items = items_100[:60]
    counts = solve_ilp(items, 40, 0.0)
    cost = sum(c * it.spot_price for c, it in zip(counts, items))
    pulp_counts = solve_ilp_pulp(items, 40, 0.0)
    pulp_cost = sum(c * it.spot_price for c, it in zip(pulp_counts, items))
    assert cost == pytest.approx(pulp_cost, rel=1e-6)


def test_infeasible_returns_none():
    items = [_mk_item(0, pods=1, bs=1e4, sp=0.1, t3=3)]
    assert solve_ilp(items, 10, 0.5) is None


def test_empty_items():
    assert solve_ilp([], 5, 0.5) is None
    assert solve_ilp([], 0, 0.5) == []
