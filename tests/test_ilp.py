"""ILP solver: exactness (vs PuLP/CBC and brute force), invariants."""

import itertools

import numpy as np
import pytest

from tests._optional import given, settings, st
from tests.strategies import item_strategy, mk_item

from repro.core import objective_coefficients, solve_ilp
from repro.core.ilp import solve_ilp_pulp


def _brute_force(items, req, alpha):
    coef = objective_coefficients(items, alpha)
    best, best_x = None, None
    ranges = [range(it.t3 + 1) for it in items]
    for xs in itertools.product(*ranges):
        if sum(x * it.pods for x, it in zip(xs, items)) < req:
            continue
        c = float(np.dot(coef, xs))
        if best is None or c < best - 1e-12:
            best, best_x = c, xs
    return best


@settings(max_examples=40, deadline=None)
@given(st.lists(item_strategy, min_size=1, max_size=4),
       st.integers(0, 12), st.floats(0.0, 1.0))
def test_dp_matches_brute_force(items, req, alpha):
    counts = solve_ilp(items, req, alpha)
    expected = _brute_force(items, req, alpha)
    if expected is None:
        assert counts is None
        return
    assert counts is not None
    coef = objective_coefficients(items, alpha)
    got = float(np.dot(coef, counts))
    assert got <= expected + 1e-9
    assert sum(c * it.pods for c, it in zip(counts, items)) >= req


def test_dp_matches_brute_force_deterministic():
    """Seeded twin of the hypothesis property above: always runs, so the
    brute-force exactness check never rides on an optional dependency."""
    rng = np.random.default_rng(101)
    n_feasible = n_infeasible = 0
    for _ in range(60):
        items = [mk_item(i, int(rng.integers(1, 9)),
                         float(rng.uniform(1e3, 1e5)),
                         float(rng.uniform(0.01, 3.0)),
                         int(rng.integers(0, 7)))
                 for i in range(int(rng.integers(1, 5)))]
        req = int(rng.integers(0, 13))
        alpha = float(rng.choice([0.0, 1.0, rng.uniform(0, 1)]))
        counts = solve_ilp(items, req, alpha)
        expected = _brute_force(items, req, alpha)
        if expected is None:
            assert counts is None
            n_infeasible += 1
            continue
        n_feasible += 1
        coef = objective_coefficients(items, alpha)
        assert float(np.dot(coef, counts)) <= expected + 1e-9
        assert sum(c * it.pods for c, it in zip(counts, items)) >= req
    assert n_feasible >= 20 and n_infeasible >= 1


@settings(max_examples=15, deadline=None)
@given(st.lists(item_strategy, min_size=2, max_size=12),
       st.integers(1, 60), st.floats(0.0, 1.0))
def test_dp_matches_pulp(items, req, alpha):
    pytest.importorskip("pulp")
    counts = solve_ilp(items, req, alpha)
    pulp_counts = solve_ilp_pulp(items, req, alpha)
    coef = objective_coefficients(items, alpha)
    if counts is None:
        # CBC reports infeasible too (no feasible integral point)
        cap = sum(it.pods * it.t3 for it in items)
        assert cap < req
        return
    assert pulp_counts is not None
    assert float(np.dot(coef, counts)) == pytest.approx(
        float(np.dot(coef, pulp_counts)), abs=1e-6)


def test_bounds_respected(items_100):
    counts = solve_ilp(items_100[:200], 500, 0.4)
    for c, it in zip(counts, items_100[:200]):
        assert 0 <= c <= it.t3


def test_alpha_one_saturates(items_100):
    """α=1: every positive-perf item has a negative coefficient and is taken
    at its T3 bound — the Table 2 over-provisioning collapse."""
    items = items_100[:100]
    counts = solve_ilp(items, 10, 1.0)
    for c, it in zip(counts, items):
        if it.perf > 0 and it.t3 > 0:
            assert c == it.t3


def test_alpha_zero_minimizes_cost(items_100):
    pytest.importorskip("pulp")
    items = items_100[:60]
    counts = solve_ilp(items, 40, 0.0)
    cost = sum(c * it.spot_price for c, it in zip(counts, items))
    pulp_counts = solve_ilp_pulp(items, 40, 0.0)
    pulp_cost = sum(c * it.spot_price for c, it in zip(pulp_counts, items))
    assert cost == pytest.approx(pulp_cost, rel=1e-6)


def test_infeasible_returns_none():
    items = [mk_item(0, pods=1, bs=1e4, sp=0.1, t3=3)]
    assert solve_ilp(items, 10, 0.5) is None


def test_empty_items():
    assert solve_ilp([], 5, 0.5) is None
    assert solve_ilp([], 0, 0.5) == []
