"""HLO roofline analyzer: exactness on synthetic modules, loop awareness,
collective wire formulas."""

import numpy as np
import pytest
jax = pytest.importorskip("jax")  # jax-native module: skip wholesale without jax
import jax.numpy as jnp

from repro.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, Roofline,
                            analyze_hlo, model_flops, parse_collectives,
                            xla_cost_analysis)
from repro.configs import SHAPES, get_config
from repro.models.transformer import active_params


def test_loop_free_matches_cost_analysis():
    g = jax.jit(lambda a, b: (a @ b).sum())
    comp = g.lower(jnp.ones((256, 512)), jnp.ones((512, 128))).compile()
    c = analyze_hlo(comp.as_text(), 1)
    assert c.flops == pytest.approx(2 * 256 * 512 * 128, rel=0.05)
    assert c.bytes == pytest.approx(
        float(xla_cost_analysis(comp)["bytes accessed"]), rel=0.2)


def test_scan_trip_counts_multiplied():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=9)
        return y.sum()
    comp = jax.jit(f).lower(jnp.ones((8, 64)), jnp.ones((64, 64))).compile()
    c = analyze_hlo(comp.as_text(), 1)
    assert c.flops == pytest.approx(9 * 2 * 8 * 64 * 64, rel=0.05)
    # cost_analysis counts the body once — document the gap this fixes
    xla = float(xla_cost_analysis(comp)["flops"])
    assert xla < c.flops / 4


def test_nested_scan_trip_counts():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()
    comp = jax.jit(f).lower(jnp.ones((8, 64)), jnp.ones((64, 64))).compile()
    c = analyze_hlo(comp.as_text(), 1)
    assert c.flops == pytest.approx(15 * 2 * 8 * 64 * 64, rel=0.05)


def test_collective_formulas():
    hlo = """
HloModule m

ENTRY %main (p0: bf16[1024,512]) -> bf16[1024,512] {
  %p0 = bf16[1024,512]{1,0} parameter(0)
  %ar = bf16[1024,512]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = bf16[1024,512]{1,0} all-gather(%ar), replica_groups=[2,8]<=[16], dimensions={0}
  ROOT %cp = bf16[1024,512]{1,0} collective-permute(%ag), source_target_pairs={{0,1}}
}
"""
    stats = parse_collectives(hlo, 16)
    buf = 1024 * 512 * 2
    assert stats.by_op["all-reduce"] == pytest.approx(2 * 3 / 4 * buf)
    assert stats.by_op["all-gather"] == pytest.approx(7 / 8 * buf)
    assert stats.by_op["collective-permute"] == pytest.approx(buf)
    assert stats.count == 3


def test_roofline_terms_and_bounds():
    rl = Roofline(flops_per_device=1.97e13,     # 0.1 s of compute
                  bytes_per_device=819e9,       # 1.0 s of HBM
                  wire_bytes_per_device=5e9,    # 0.1 s of ICI
                  n_devices=256,
                  model_flops_global=1.97e13 * 256 * 0.5)
    assert rl.compute_s == pytest.approx(0.1)
    assert rl.memory_s == pytest.approx(1.0)
    assert rl.collective_s == pytest.approx(0.1)
    assert rl.bound == "memory"
    assert rl.roofline_fraction == pytest.approx(0.1)
    assert rl.model_flops_ratio == pytest.approx(0.5)


def test_model_flops_kinds():
    cfg = get_config("qwen3-moe-30b-a3b")
    n = active_params(cfg)
    tr = model_flops(cfg, SHAPES["train_4k"], n)
    pf = model_flops(cfg, SHAPES["prefill_32k"], n)
    de = model_flops(cfg, SHAPES["decode_32k"], n)
    assert tr == pytest.approx(6 * n * 4096 * 256)
    assert pf == pytest.approx(2 * n * 32768 * 32)
    assert de == pytest.approx(2 * n * 128)
