"""Perf-gate harness behavior (benchmarks/perf_gate.py): the gate must
fail loudly — not silently refresh and pass — when the checked-in
reference file is absent, and only write references under an explicit
``--update``."""

import json
import os

import pytest

from benchmarks import perf_gate


def test_gate_mode_fails_fast_when_reference_missing(tmp_path, monkeypatch,
                                                     capsys):
    """A deleted or unshipped PERF_REFERENCE.json in CI must be a gate
    failure (before any measurement runs), never a no-op pass."""
    monkeypatch.setattr(perf_gate, "REFERENCE_PATH",
                        str(tmp_path / "PERF_REFERENCE.json"))
    monkeypatch.setattr(perf_gate, "TRAJECTORY_PATH",
                        str(tmp_path / "PERF_trajectory.jsonl"))
    monkeypatch.setattr(
        perf_gate, "measure",
        lambda *a, **k: pytest.fail("gate mode must not measure without "
                                    "a reference file"))
    assert perf_gate.run(update=False, smoke=True) == 1
    out = capsys.readouterr().out
    assert "reference file missing" in out and "--update" in out
    assert not os.path.exists(perf_gate.REFERENCE_PATH)   # not auto-created
    assert not os.path.exists(perf_gate.TRAJECTORY_PATH)  # nothing appended


def test_update_mode_writes_reference(tmp_path, monkeypatch):
    """--update is the only path that (re)creates the reference file."""
    monkeypatch.setattr(perf_gate, "REFERENCE_PATH",
                        str(tmp_path / "PERF_REFERENCE.json"))
    monkeypatch.setattr(perf_gate, "TRAJECTORY_PATH",
                        str(tmp_path / "PERF_trajectory.jsonl"))
    fake = {"config": {"n_items": 1, "base_pods": 1, "n_decisions": 1},
            "metrics": {"batched_numpy_speedup_vs_pr1": 2.0},
            "checks": {"pr1_equality": True}, "raw": {}}
    monkeypatch.setattr(perf_gate, "measure", lambda *a, **k: fake)
    assert perf_gate.run(update=True, smoke=True) == 0
    with open(perf_gate.REFERENCE_PATH) as f:
        ref = json.load(f)
    assert ref["metrics"]["batched_numpy_speedup_vs_pr1"]["value"] == 2.0
    assert os.path.exists(perf_gate.TRAJECTORY_PATH)
    # and the freshly written reference gates a repeat measurement green
    assert perf_gate.run(update=False, smoke=True) == 0
