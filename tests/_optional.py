"""Optional test dependencies: a drop-in shim for ``hypothesis``.

The property-based tests are a bonus layer on top of the deterministic
suite; when ``hypothesis`` is missing they should *skip*, not take their
whole module down at collection time.  Importing ``given``/``settings``/
``st`` from here instead of from ``hypothesis`` makes each ``@given`` test
an individual skip while every deterministic test in the module still runs.
"""

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Accepts any strategy-building call at module import time."""

        def __getattr__(self, _name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
