"""Optional test dependencies: drop-in shims for ``hypothesis`` and ``jax``.

The property-based tests are a bonus layer on top of the deterministic
suite; when ``hypothesis`` is missing they should *skip*, not take their
whole module down at collection time.  Importing ``given``/``settings``/
``st`` from here instead of from ``hypothesis`` makes each ``@given`` test
an individual skip while every deterministic test in the module still runs.

``jax`` is likewise optional for the *solver* path (the control plane's
only hard dependency is numpy — ``repro.core.backend`` falls back with a
warning).  ``HAVE_JAX`` / ``requires_jax`` let backend-equivalence tests
skip individually, and modules that are jax-native (kernels, models,
roofline) use ``pytest.importorskip("jax")`` to skip at collection on the
no-jax CI leg.
"""

import pytest

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:                                    # pragma: no cover
    jax = None
    HAVE_JAX = False

requires_jax = pytest.mark.skipif(not HAVE_JAX,
                                  reason="jax not installed")

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Accepts any strategy-building call at module import time."""

        def __getattr__(self, _name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "HAVE_JAX", "given", "jax", "requires_jax",
           "settings", "st"]
