"""Fleet engine: per-seed fleet ≡ run_replicas ≡ standalone equality, the
cross-replica decision memo, cache counters, and the vectorized pressure
sampler's RNG-stream contract (DESIGN.md §11)."""

import dataclasses

import numpy as np
import pytest

from repro.core import (DecisionMemo, generate_catalog,
                        pressure_interrupt_probability,
                        pressure_interrupt_probability_batch)
from repro.risk import backtest
from repro.sim import (ClusterSim, FleetSim, PressureInterruptModel,
                       run_fleet, run_replicas)
from repro.sim.events import InterruptNotice

SEEDS = [0, 1, 2]

#: the three standard stress scenarios, shrunk for unit-test runtimes
#: (the storm keeps 36 h so its 2 h-lead rebalance notices actually mature
#: into reclaims and the interrupt → re-provision path is exercised)
_SMALL = dict(duration_hours=24.0, max_offerings=100)
SCENARIOS = {
    "storm": lambda **kw: backtest.interrupt_storm_scenario(
        **{**_SMALL, "duration_hours": 36.0, **kw}),
    "price_shock": lambda **kw: backtest.price_shock_scenario(
        **{**_SMALL, **kw}),
    "pressure_crunch": lambda **kw: backtest.pressure_crunch_scenario(
        **{**_SMALL, **kw}),
}


def _standalone(scenario, seed, clock=None):
    sc = dataclasses.replace(scenario, interrupt_seed=int(seed))
    kwargs = {} if clock is None else {"clock": clock}
    return ClusterSim(sc, **kwargs).run()


def _assert_result_equal(a, b):
    """Field-by-field SimResult equality — floats bit-for-bit."""
    assert a.total_cost == b.total_cost
    assert a.total_perf_hours == b.total_perf_hours
    assert a.lost_perf_total == b.lost_perf_total
    assert a.interrupted_nodes == b.interrupted_nodes
    assert a.pool.as_dict() == b.pool.as_dict()
    assert len(a.rounds) == len(b.rounds)
    for ra, rb in zip(a.rounds, b.rounds):
        assert (ra.time, ra.notices, ra.effective, ra.lost_nodes,
                ra.lost_pods, ra.shortfall, ra.lost_perf) == \
               (rb.time, rb.notices, rb.effective, rb.lost_nodes,
                rb.lost_pods, rb.shortfall, rb.lost_perf)
        assert ra.pool.as_dict() == rb.pool.as_dict()
    assert [(r, d.pool.as_dict(), d.alpha, d.metrics)
            for r, d in a.decisions] == \
           [(r, d.pool.as_dict(), d.alpha, d.metrics)
            for r, d in b.decisions]


# ------------------------------------------------------ equality proof ----

@pytest.mark.parametrize("scenario_name", sorted(SCENARIOS))
@pytest.mark.parametrize("policy", ["kubepacs", "kubepacs_risk:12"])
def test_fleet_matches_standalone_and_run_replicas(scenario_name, policy):
    """The acceptance contract: every fleet replica is identical — rounds,
    decisions, float totals, and the JSONL trace byte-for-byte — to a
    standalone ClusterSim run and to run_replicas at the same seed."""
    sc = SCENARIOS[scenario_name](policy=policy)
    fleet = run_fleet(sc, SEEDS, record_traces=True)
    per_seed = run_replicas(sc, SEEDS)
    assert len(fleet) == len(per_seed) == len(SEEDS)
    for seed, f, p in zip(SEEDS, fleet, per_seed):
        single = _standalone(sc, seed)
        assert f.scenario.interrupt_seed == seed
        _assert_result_equal(f, single)
        _assert_result_equal(f, p)
        assert f.recorder.dumps() == single.recorder.dumps()
        assert f.decision_records() == p.decision_records()


@pytest.mark.parametrize("policy", ["karpenter_like", "fixed_alpha:0.5"])
def test_fleet_matches_standalone_baseline_policies(policy):
    sc = SCENARIOS["storm"](policy=policy)
    fleet = run_fleet(sc, SEEDS, record_traces=True)
    for seed, f in zip(SEEDS, fleet):
        single = _standalone(sc, seed)
        _assert_result_equal(f, single)
        assert f.recorder.dumps() == single.recorder.dumps()


def test_fleet_full_decision_equality_with_injected_clock():
    """With an injected wall clock even the diagnostic wall_seconds agrees,
    so whole ProvisioningDecision dataclasses compare equal — including
    across memo hits (decision provenance is compare=False)."""
    fake = lambda: 0.0                                     # noqa: E731
    sc = SCENARIOS["pressure_crunch"](policy="kubepacs")
    fleet = run_fleet(sc, SEEDS, clock=fake)
    for seed, f in zip(SEEDS, fleet):
        single = _standalone(sc, seed, clock=fake)
        assert [r for r, _ in f.decisions] == [r for r, _ in single.decisions]
        for (_, da), (_, db) in zip(f.decisions, single.decisions):
            assert da == db


def test_fleet_memo_disabled_equality():
    """Memoization is a pure optimization: memo on/off produce identical
    traces, and only the memoized fleet reports memo counters."""
    sc = SCENARIOS["pressure_crunch"]()
    on = FleetSim(sc, SEEDS, record_traces=True)
    off = FleetSim(sc, SEEDS, record_traces=True, memoize=False)
    res_on, res_off = on.run(), off.run()
    for a, b in zip(res_on, res_off):
        assert a.recorder.dumps() == b.recorder.dumps()
    assert "memo_hits" in on.stats() and on.stats()["memo_hits"] > 0
    assert "memo_hits" not in off.stats()


def test_fleet_empty_seed_list_matches_run_replicas():
    sc = SCENARIOS["storm"]()
    assert run_fleet(sc, []) == run_replicas(sc, [])


def test_fleet_rejects_fulfillment_scenarios():
    sc = SCENARIOS["storm"](apply_fulfillment=True)
    with pytest.raises(ValueError, match="apply_fulfillment"):
        FleetSim(sc, SEEDS)


def test_fleet_run_is_single_shot():
    sim = FleetSim(SCENARIOS["storm"](duration_hours=6.0), [0])
    sim.run()
    with pytest.raises(RuntimeError, match="once"):
        sim.run()


# ------------------------------------------------------- cache counters ----

def test_fleet_cache_counters_assert_effectiveness():
    """Cache effectiveness is asserted from counters, not timing.

    On the deterministic (bid-crossing) storm all replicas coincide, so
    every replica beyond the first hits the memo on every decision; on the
    stochastic crunch replicas genuinely diverge, yet coinciding
    (state, demand, exclusion) keys still collapse."""
    sim = FleetSim(SCENARIOS["storm"](), list(range(8)))
    sim.run()
    stats = sim.stats()
    assert stats["replicas"] == 8
    # identical replicas -> unique solves per decision event, not replica
    assert stats["memo_unique_solves"] == stats["memo_misses"]
    assert stats["memo_hits"] == 7 * stats["memo_misses"]

    sim = FleetSim(SCENARIOS["pressure_crunch"](), list(range(8)))
    results = sim.run()
    stats = sim.stats()
    assert stats["memo_hits"] > 0
    assert stats["memo_unique_solves"] == stats["memo_misses"]
    # interrupt re-provisioning reuses the per-state compiled market
    assert stats["compile_misses"] >= 1
    assert stats["compile_hits"] > stats["compile_misses"]
    # every result carries the fleet-wide aggregate
    for r in results:
        assert r.cache_stats == stats
    # memo provenance is stamped on hit decisions (and never breaks
    # decision equality — ProvisioningDecision.cache is compare=False)
    flags = [d.cache.get("memo_hit") for r in results
             for _, d in r.decisions]
    assert flags.count(1.0) == stats["memo_hits"]


def test_run_replicas_compile_counters():
    """The PR 2 shared-compile path now reports its effectiveness too."""
    sc = SCENARIOS["storm"]()
    results = run_replicas(sc, SEEDS)
    assert results[0].cache_stats["compile_misses"] >= 1
    # later replicas reuse every compiled (state, shape) of the first
    assert results[1].cache_stats["compile_misses"] == 0
    assert results[1].cache_stats["compile_hits"] > 0


def test_decision_memo_disabled_without_context():
    """context=None (the standalone state) disables lookups entirely, so
    attaching a memo can never change single-run behavior."""
    memo = DecisionMemo()
    sc = SCENARIOS["storm"](duration_hours=12.0)
    sim = ClusterSim(sc)
    sim.policy.set_decision_memo(memo)
    sim.run()
    assert memo.hits == memo.misses == memo.unique_solves == 0


# ------------------------------------------- vectorized pressure sampler ----

def _reference_sample(rng, offerings, pool, hours, now):
    """The seed implementation's per-entry Python loop, kept as the RNG
    stream reference: one scalar binomial per qualifying pool entry."""
    notices = []
    for offering_id, count in pool.items():
        o = offerings.get(offering_id)
        if o is None or count <= 0:
            continue
        p = pressure_interrupt_probability(count, float(o.t3),
                                           o.interruption_freq, hours)
        lost = int(rng.binomial(count, p))
        if lost > 0:
            notices.append(InterruptNotice(
                time=now, offering_id=offering_id, count=lost))
    return notices


def test_vectorized_pressure_sampler_is_stream_identical(small_catalog):
    """One batched binomial call must consume the RNG byte-identically to
    the per-entry loop — same notices, same stream position after."""
    index = {o.offering_id: o for o in small_catalog}
    pool = {o.offering_id: max(1, o.t3 * k % 7) for k, o in
            enumerate(small_catalog[:25], start=1)}
    pool[small_catalog[30].offering_id] = 0          # skipped, draws nothing
    for seed in range(5):
        model = PressureInterruptModel()
        model.reset(small_catalog, seed)
        got = model.sample(index, pool, hours=6.0, now=3.0)
        ref_rng = np.random.default_rng(seed)
        want = _reference_sample(ref_rng, index, pool, 6.0, 3.0)
        assert got == want
        # identical stream position: the next draws coincide
        assert np.array_equal(model._rng.random(4), ref_rng.random(4))


def test_pressure_probability_batch_matches_scalar_bitwise():
    counts = np.array([0, 1, 3, 17, 120, 400])
    t3 = np.array([0.0, 0.4, 3.0, 17.0, 100.0, 80.0])
    if_band = np.array([0, 1, 2, 3, 2, 1])
    for hours in (0.5, 1.0, 6.0):
        batch = pressure_interrupt_probability_batch(counts, t3, if_band,
                                                     hours)
        scalar = [pressure_interrupt_probability(int(c), float(t), int(i),
                                                 hours)
                  for c, t, i in zip(counts, t3, if_band)]
        assert batch.tolist() == scalar


# ----------------------------------------------------- backtest rewiring ----

def test_compare_policies_rides_fleet_and_matches_standalone():
    sc = SCENARIOS["price_shock"]()
    comp = backtest.compare_policies(
        sc, policies=("kubepacs", "karpenter_like"), seeds=(0, 1))
    assert set(comp["runs"]) == {"kubepacs", "karpenter_like"}
    # fleet-backed rows equal the metrics of standalone runs
    for spec, rows in comp["runs"].items():
        for seed, row in zip((0, 1), rows):
            single = _standalone(dataclasses.replace(sc, policy=spec), seed)
            assert row == backtest._run_metrics(
                single, comp["recovery_overhead_hours"])


def test_fleet_calibration_matches_per_trace_reports():
    """Each fleet replica's calibration probe sees the identical stream a
    standalone trace replay feeds, so per-seed reports coincide and the
    pooled Brier is their term-weighted mean."""
    sc = SCENARIOS["pressure_crunch"]()
    rep = backtest.fleet_calibration(sc, seeds=SEEDS)
    assert rep["seeds"] == SEEDS
    assert len(rep["per_seed"]) == len(SEEDS)
    for seed, per in zip(SEEDS, rep["per_seed"]):
        trace = _standalone(sc, seed).records
        assert per == backtest.calibration_report(trace)
    n = rep["allocations_scored"]
    assert n == sum(p["allocations_scored"] for p in rep["per_seed"])
    assert rep["brier"] == pytest.approx(np.average(
        [p["brier"] for p in rep["per_seed"]],
        weights=[p["allocations_scored"] for p in rep["per_seed"]]))
