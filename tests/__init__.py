# Makes tests/ a package so `from tests.test_ilp import ...` and
# `from tests._optional import ...` resolve under a bare `pytest`
# invocation (pytest then puts the repo root, not tests/, on sys.path).
