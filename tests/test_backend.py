"""Solver-backend layer (DESIGN.md §12): numpy ≡ jax at the level of
*selected pools*, cross-decision batching ≡ per-decision solving, the
collect-then-solve fleet tick phase ≡ the sequential one, the NumPy
fallback when jax is absent, and the heterogeneous-demand jitter contract.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import (NumpyBackend, Request,
                        compile_market, preprocess, generate_catalog,
                        make_backend, objective_coefficients, solve_ilp,
                        solve_ilp_batch, solve_ilp_many)
from repro.core import backend as backend_mod
from repro.core.gss import bracketed_gss, bracketed_gss_many
from repro.sim import (ClusterSim, FleetSim, run_replicas,
                       heterogeneous_demand_scenario)

from ._optional import HAVE_JAX, requires_jax
from .strategies import mk_item as _mk_item
from .strategies import random_exclude as _random_exclude
from .strategies import random_market as _random_market

NUMPY = NumpyBackend()
JAX = make_backend("jax") if HAVE_JAX else None


# ---------------------------------------------------------- numpy ≡ jax ----

@requires_jax
def test_jax_equals_numpy_selected_pools_100_markets():
    """≥100 randomized markets × α grid incl. {0, 1} edges, with and
    without exclusion masks, empty and infeasible targets: the jax backend
    must return the *identical count vectors* (not merely equal
    objectives) as the numpy backend — the bit-identical-selection
    contract."""
    rng = np.random.default_rng(11)
    n_markets = 110
    n_infeasible = n_masked = 0
    for _ in range(n_markets):
        items = _random_market(rng)
        market = compile_market(items)
        req = int(rng.integers(0, 90))
        exclude = _random_exclude(rng, len(items))
        if exclude is not None:
            n_masked += 1
        alphas = [0.0, 1.0] + [float(a) for a in rng.uniform(0, 1, size=3)]
        got_n = solve_ilp_batch(items, req, alphas, market=market,
                                exclude=exclude, backend=NUMPY)
        got_j = solve_ilp_batch(items, req, alphas, market=market,
                                exclude=exclude, backend=JAX)
        assert got_n == got_j
        n_infeasible += sum(c is None for c in got_n)
    assert n_infeasible > 0 and n_masked > 10


@requires_jax
def test_jax_equals_numpy_empty_market():
    assert solve_ilp([], 0, 0.5, backend=JAX) == []
    assert solve_ilp([], 5, 0.5, backend=JAX) is None


@requires_jax
def test_jax_backend_on_real_catalog_cycle():
    """A full guarded-GSS cycle on a generated catalog returns the same
    pool and trace through either backend."""
    cat = generate_catalog(seed=3, max_offerings=150)
    items = preprocess(cat, Request(pods=800, cpu_per_pod=2, mem_per_pod=2))
    market = compile_market(items)
    fake = lambda: 0.0                                     # noqa: E731
    (pn, tn), = bracketed_gss_many(items, [800], market=market, timer=fake,
                                   backend=NUMPY)
    (pj, tj), = bracketed_gss_many(items, [800], market=market, timer=fake,
                                   backend=JAX)
    assert pn.as_dict() == pj.as_dict() and pn.alpha == pj.alpha
    assert tn.alphas == tj.alphas and tn.e_totals == tj.e_totals


@requires_jax
def test_pallas_flag_matches_plain_jax():
    """The Pallas step kernel (interpret mode on CPU) is bit-identical to
    the plain scan step."""
    pallas = make_backend("jax:pallas")
    rng = np.random.default_rng(5)
    bpods = rng.integers(1, 40, size=24).astype(np.int64)
    costs = rng.uniform(0, 3, size=24)
    costs[rng.random(24) < 0.2] = np.inf
    (dp_j, bits_j), = JAX.cover_bits([(bpods, costs, 120)])
    (dp_p, bits_p), = pallas.cover_bits([(bpods, costs, 120)])
    (dp_n, bits_n), = NUMPY.cover_bits([(bpods, costs, 120)])
    assert np.array_equal(dp_j, dp_n) and np.array_equal(dp_p, dp_n)
    assert np.array_equal(bits_j, bits_n) and np.array_equal(bits_p, bits_n)


@requires_jax
def test_jax_cover_values_matches_cover_bits_dp():
    rng = np.random.default_rng(9)
    groups = [(rng.integers(1, 30, size=17).astype(np.int64),
               rng.uniform(0, 2, size=17), int(rng.integers(1, 200)))
              for _ in range(5)]
    dps = JAX.cover_values(groups)
    full = JAX.cover_bits(groups)
    for dp, (dp2, _bits) in zip(dps, full):
        assert np.array_equal(dp, dp2)


# ------------------------------------------------- cross-decision batch ----

def test_solve_ilp_many_equals_per_decision_batches():
    """solve_ilp_many over heterogeneous (demand, α grid, mask) decisions
    returns exactly the per-decision solve_ilp_batch results."""
    rng = np.random.default_rng(23)
    for _ in range(25):
        items = _random_market(rng, max_items=10)
        market = compile_market(items)
        n_dec = int(rng.integers(1, 6))
        reqs = [int(rng.integers(0, 70)) for _ in range(n_dec)]
        grids = [[0.0, 1.0] + [float(a) for a in rng.uniform(0, 1, size=2)]
                 for _ in range(n_dec)]
        excludes = [_random_exclude(rng, len(items)) for _ in range(n_dec)]
        many = solve_ilp_many(items, reqs, grids, market=market,
                              excludes=excludes, backend=NUMPY)
        per = [solve_ilp_batch(items, r, g, market=market, exclude=e,
                               backend=NUMPY)
               for r, g, e in zip(reqs, grids, excludes)]
        assert many == per


def test_solve_ilp_many_shared_grid_and_stats():
    items = _random_market(np.random.default_rng(1), max_items=8)
    market = compile_market(items)
    many, stats = solve_ilp_many(items, [10, 25], [0.0, 0.5, 1.0],
                                 market=market, return_stats=True)
    assert len(many) == 2 and all(len(row) == 3 for row in many)
    for d, req in enumerate([10, 25]):
        for a, alpha in enumerate([0.0, 0.5, 1.0]):
            counts = many[d][a]
            if counts is None:
                assert not np.isfinite(stats[d][a].objective)
                continue
            obj = float(np.dot(objective_coefficients(items, alpha), counts))
            assert stats[d][a].objective == pytest.approx(obj, abs=1e-8)
            assert sum(c * it.pods for c, it in zip(counts, items)) >= req


def test_bracketed_gss_many_equals_sequential():
    """Lockstep batched GSS ≡ sequential bracketed_gss per decision:
    pools, α*, and full trace content."""
    cat = generate_catalog(seed=7, max_offerings=120)
    items = preprocess(cat, Request(pods=300, cpu_per_pod=2, mem_per_pod=2))
    market = compile_market(items)
    rng = np.random.default_rng(2)
    reqs = [int(300 * (1 + 0.2 * (2 * rng.random() - 1))) for _ in range(7)]
    excludes = [None, None, *(_random_exclude(rng, len(items))
                              for _ in range(5))]
    fake = lambda: 0.0                                     # noqa: E731
    seq = [bracketed_gss(items, r, market=market, exclude=e, timer=fake)
           for r, e in zip(reqs, excludes)]
    many = bracketed_gss_many(items, reqs, market=market, excludes=excludes,
                              timer=fake)
    for (p1, t1), (p2, t2) in zip(seq, many):
        assert (p1 is None) == (p2 is None)
        if p1 is not None:
            assert p1.as_dict() == p2.as_dict() and p1.alpha == p2.alpha
        assert t1.alphas == t2.alphas
        assert t1.e_totals == t2.e_totals
        assert t1.ilp_solves == t2.ilp_solves


# -------------------------------------------- collect-then-solve fleet ----

def test_fleet_batched_tick_phase_trace_equality():
    """FleetSim with the collect-then-solve batch on vs off: byte-identical
    JSONL traces on the heterogeneous-demand scenario (low memo-hit) and on
    a deterministic-storm scenario (high memo-hit)."""
    from repro.risk import backtest
    seeds = [0, 1, 2]
    for sc in (heterogeneous_demand_scenario(duration_hours=24.0,
                                             max_offerings=80),
               backtest.interrupt_storm_scenario(duration_hours=24.0,
                                                 max_offerings=80)):
        on = FleetSim(sc, seeds, record_traces=True).run()
        off = FleetSim(sc, seeds, record_traces=True,
                       batch_decisions=False).run()
        for a, b in zip(on, off):
            assert a.recorder.dumps() == b.recorder.dumps()


def test_fleet_batched_memo_counters_match_sequential():
    """Duplicate pending keys count as memo hits, so the PR 4 counter
    semantics survive batching (8 identical storm replicas → 1 miss +
    7 hits per decision event)."""
    from repro.risk import backtest
    sc = backtest.interrupt_storm_scenario(duration_hours=24.0,
                                           max_offerings=80)
    on = FleetSim(sc, list(range(8)))
    on.run()
    off = FleetSim(sc, list(range(8)), batch_decisions=False)
    off.run()
    s_on, s_off = on.stats(), off.stats()
    for k in ("memo_hits", "memo_misses", "memo_unique_solves"):
        assert s_on[k] == s_off[k]
    assert s_on["memo_hits"] == 7 * s_on["memo_misses"]


def test_fleet_hetero_matches_standalone_bit_for_bit():
    """Heterogeneous-demand: every fleet replica (batched) is identical to
    a standalone ClusterSim at the same seed — traces and float totals."""
    sc = heterogeneous_demand_scenario(duration_hours=24.0, max_offerings=80)
    seeds = [0, 1, 2]
    fleet = FleetSim(sc, seeds, record_traces=True).run()
    per_seed = run_replicas(sc, seeds)
    for seed, f, p in zip(seeds, fleet, per_seed):
        single = ClusterSim(
            dataclasses.replace(sc, interrupt_seed=seed)).run()
        assert f.recorder.dumps() == single.recorder.dumps()
        assert f.total_cost == single.total_cost
        assert f.total_perf_hours == single.total_perf_hours
        assert f.decision_records() == p.decision_records()


def test_fleet_hetero_defeats_memo():
    """The scenario does its job: per-replica jitter drives the memo hit
    rate below 50 % (the regime the batched tick phase targets)."""
    sc = heterogeneous_demand_scenario(duration_hours=24.0, max_offerings=80)
    sim = FleetSim(sc, list(range(8)))
    sim.run()
    stats = sim.stats()
    lookups = stats["memo_hits"] + stats["memo_misses"]
    assert lookups > 0
    assert stats["memo_hits"] / lookups < 0.5


# ------------------------------------------------ demand-jitter contract ----

def test_effective_pods_deterministic_and_seed_dependent():
    sc = heterogeneous_demand_scenario()
    a = sc.effective_pods(3, 6.0, 220)
    assert a == sc.effective_pods(3, 6.0, 220)         # pure function
    assert a != 220 or sc.effective_pods(4, 6.0, 220) != 220
    vals = {sc.effective_pods(s, 6.0, 220) for s in range(16)}
    assert len(vals) > 8                               # replicas diverge
    assert all(1 <= v <= 220 * 1.2 for v in vals)
    zero = dataclasses.replace(sc, demand_jitter=0.0)
    assert zero.effective_pods(3, 6.0, 220) == 220     # exact passthrough


def test_scenario_roundtrip_keeps_jitter():
    sc = heterogeneous_demand_scenario()
    from repro.sim import Scenario
    assert Scenario.from_dict(sc.to_dict()) == sc
    # pre-jitter trace headers (no key) still load
    d = sc.to_dict()
    del d["demand_jitter"]
    assert Scenario.from_dict(d).demand_jitter == 0.0


def test_jitter_replay_reproduces_decisions():
    """A recorded heterogeneous-demand trace replays to the identical
    decision sequence (jitter is re-derived from the header scenario)."""
    sc = heterogeneous_demand_scenario(duration_hours=18.0, max_offerings=60)
    res = ClusterSim(sc).run()
    replay = ClusterSim.replay(res.records).run()
    assert res.decision_records() == replay.decision_records()


# ---------------------------------------------------------- jax fallback ----

def test_backend_falls_back_to_numpy_with_warning(monkeypatch):
    """Requesting the jax backend without jax installed warns once and
    returns the numpy backend — core/ilp.py never imports jax itself."""
    import builtins
    real_import = builtins.__import__

    def no_jax(name, *args, **kwargs):
        if name == "jax" or name.startswith("jax."):
            raise ImportError("no jax in this environment")
        return real_import(name, *args, **kwargs)

    from repro.core import events_log
    monkeypatch.setattr(builtins, "__import__", no_jax)
    events_log.reset()                        # drop the warn-once latch
    with pytest.warns(RuntimeWarning, match="falling back"):
        be = backend_mod.make_backend("jax")
    assert isinstance(be, NumpyBackend)
    assert events_log.counters()["backend_numpy_fallback"] == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # second request: warn once
        assert isinstance(backend_mod.make_backend("jax"), NumpyBackend)
    # ... but every occurrence is still counted (DESIGN.md §16)
    assert events_log.counters()["backend_numpy_fallback"] == 2


def test_env_selects_default_backend(monkeypatch):
    monkeypatch.setenv("KUBEPACS_SOLVER_BACKEND", "numpy")
    backend_mod.set_backend(None)
    try:
        assert isinstance(backend_mod.get_backend(), NumpyBackend)
        with pytest.raises(ValueError, match="unknown solver backend"):
            backend_mod.make_backend("torch")
    finally:
        backend_mod.set_backend("numpy")


def test_solver_core_importable_without_jax(monkeypatch):
    """repro.core.ilp/gss must not import jax at module import time: their
    modules never hold a jax attribute."""
    import repro.core.ilp as ilp_mod
    import repro.core.gss as gss_mod
    for mod in (ilp_mod, gss_mod, backend_mod):
        assert not hasattr(mod, "jax")
        src = open(mod.__file__).read().splitlines()
        assert not any(line.startswith("import jax") for line in src)


@requires_jax
def test_x64_flip_env_opt_out_and_warning():
    """Constructing a jax backend enables jax_enable_x64 process-wide —
    announced by a one-time RuntimeWarning — and KUBEPACS_JAX_X64=0
    forbids the global-config mutation outright (fresh subprocess: this
    process flipped the flag long ago)."""
    import os
    import subprocess
    import sys
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(backend_mod.__file__))))
    code = (
        "import os, warnings\n"
        "os.environ['KUBEPACS_JAX_X64'] = '0'\n"
        "from repro.core import make_backend\n"
        "try:\n"
        "    make_backend('jax')\n"
        "    raise SystemExit('opt-out did not refuse')\n"
        "except RuntimeError as e:\n"
        "    assert 'jax_enable_x64' in str(e)\n"
        "    print('REFUSED')\n"
        "del os.environ['KUBEPACS_JAX_X64']\n"
        "with warnings.catch_warnings(record=True) as w:\n"
        "    warnings.simplefilter('always')\n"
        "    make_backend('jax')\n"
        "assert any('x64' in str(x.message) for x in w)\n"
        "print('WARNED')\n"
    )
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("KUBEPACS_JAX_X64", None)
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr
    assert "REFUSED" in res.stdout and "WARNED" in res.stdout


# ------------------------------------------------- fused decision plane ----

FUSED = make_backend("jax:fused") if HAVE_JAX else None


def _gss_summary(results):
    """(pool dict, alpha, trace alphas, trace e_totals) per decision —
    the full byte-comparable decision record."""
    return [((None if p is None else p.as_dict()),
             (None if p is None else p.alpha), t.alphas, t.e_totals)
            for p, t in results]


@requires_jax
def test_fused_equals_numpy_pools_110_markets():
    """The device-resident GSS (one jitted while_loop, counts read back
    once) selects the identical pools/alphas/traces as the host engine and
    the per-dispatch jax backend over 110 randomized markets with masks,
    infeasible and zero demands — and resolves every probe from the device
    record (zero host-fallback solves)."""
    rng = np.random.default_rng(11)
    fake = lambda: 0.0                                     # noqa: E731
    base_fb = FUSED.device_cache_info()["fallback_solves"]
    n_infeasible = n_masked = 0
    for _ in range(110):
        items = _random_market(rng)
        market = compile_market(items)
        reqs = [int(rng.integers(0, 90))
                for _ in range(int(rng.integers(1, 4)))]
        excludes = [_random_exclude(rng, len(items)) for _ in reqs]
        n_masked += sum(e is not None for e in excludes)
        got_n = bracketed_gss_many(items, reqs, market=market,
                                   excludes=excludes, timer=fake,
                                   backend=NUMPY)
        got_f = bracketed_gss_many(items, reqs, market=market,
                                   excludes=excludes, timer=fake,
                                   backend=FUSED)
        got_j = bracketed_gss_many(items, reqs, market=market,
                                   excludes=excludes, timer=fake,
                                   backend=JAX)
        sn = _gss_summary(got_n)
        assert sn == _gss_summary(got_f) == _gss_summary(got_j)
        n_infeasible += sum(p is None for p, _t in got_n)
    assert n_infeasible > 0 and n_masked > 10
    assert FUSED.device_cache_info()["fallback_solves"] == base_fb


@requires_jax
def test_fused_empty_market_and_zero_demand():
    fake = lambda: 0.0                                     # noqa: E731
    (p0, _t), = bracketed_gss_many([], [0], timer=fake, backend=FUSED)
    assert p0 is not None and p0.as_dict() == {}
    (p1, _t), = bracketed_gss_many([], [5], timer=fake, backend=FUSED)
    assert p1 is None


@requires_jax
def test_fused_pallas_spec_matches_numpy():
    """``jax:fused:pallas`` (real cover-DP + scoring kernels, interpret
    mode on CPU) selects the identical pools; small markets only — the
    interpreter is slow."""
    pallas = make_backend("jax:fused:pallas")
    rng = np.random.default_rng(23)
    fake = lambda: 0.0                                     # noqa: E731
    for _ in range(3):
        items = _random_market(rng, max_items=6, max_t3=4)
        market = compile_market(items)
        reqs = [int(rng.integers(0, 40))]
        got_n = bracketed_gss_many(items, reqs, market=market, timer=fake,
                                   backend=NUMPY)
        got_p = bracketed_gss_many(items, reqs, market=market, timer=fake,
                                   backend=pallas)
        assert _gss_summary(got_n) == _gss_summary(got_p)


@requires_jax
def test_fused_device_cache_hit_and_invalidation():
    """CompiledMarket arrays upload once per (digest, pad-shape): a repeat
    dispatch is a cache hit, a changed market (new digest) is a miss, and
    the LRU keeps serving the old entry if it returns."""
    be = make_backend("jax:fused")
    rng = np.random.default_rng(7)
    fake = lambda: 0.0                                     # noqa: E731
    items_a = _random_market(rng, max_items=6)
    items_b = _random_market(rng, max_items=6)
    market_a = compile_market(items_a)
    market_b = compile_market(items_b)
    assert market_a.digest != market_b.digest
    bracketed_gss_many(items_a, [20], market=market_a, timer=fake,
                       backend=be)
    info0 = be.device_cache_info()
    assert info0["misses"] >= 1
    bracketed_gss_many(items_a, [25], market=market_a, timer=fake,
                       backend=be)
    info1 = be.device_cache_info()
    assert info1["hits"] > info0["hits"]          # same digest: resident
    assert info1["misses"] == info0["misses"]
    bracketed_gss_many(items_b, [20], market=market_b, timer=fake,
                       backend=be)
    info2 = be.device_cache_info()
    assert info2["misses"] > info1["misses"]      # new digest: re-upload


@requires_jax
def test_pallas_cover_block_divisibility_guard():
    """A bundle pad that is not a multiple of the 32-wide kernel block
    must fail loudly at build time, not silently truncate the grid."""
    with pytest.raises(ValueError, match="multiple"):
        FUSED._pallas_cover_fn(129, 33, True)
    for rung in backend_mod.FusedJaxBackend._BF_STEPS:
        assert rung % 32 == 0 or rung < 32   # the invariant the guard pins


@requires_jax
def test_pallas_kernel_selfcheck_bitwise_on_live_lowering():
    """The cover kernel's sequential-grid accumulator idiom is only
    trusted after a bitwise dp+bits probe against the NumPy reference on
    the live lowering (interpret mode here); a failing probe silently
    drops the fused programs back to the lax.scan path — selections
    unchanged."""
    be = make_backend("jax:fused:pallas")
    assert be._run_pallas_check(interpret=True) is True
    assert be._fused_flags() == (True, True)

    # simulate a racy lowering (GPU/Triton parallel grid): the kernel is
    # refused and the scan path still selects numpy's pools
    be_bad = make_backend("jax:fused:pallas")
    be_bad._run_pallas_check = lambda interpret: False
    assert be_bad._fused_flags()[0] is False
    rng = np.random.default_rng(31)
    fake = lambda: 0.0                                     # noqa: E731
    items = _random_market(rng, max_items=6, max_t3=4)
    market = compile_market(items)
    got_n = bracketed_gss_many(items, [15], market=market, timer=fake,
                               backend=NUMPY)
    got_b = bracketed_gss_many(items, [15], market=market, timer=fake,
                               backend=be_bad)
    assert _gss_summary(got_n) == _gss_summary(got_b)


@requires_jax
def test_prescan_host_crosscheck_disables_fused_on_divergence():
    """Device prescan counts are never consumed unverified: each batch
    cross-checks one sampled (decision, α) row against the NumPy engine,
    and a mismatch warns, permanently disables the fused path, and leaves
    selections on the host engine — bit-identical, never corrupted."""
    be = make_backend("jax:fused")
    orig = be._run_prescan

    def corrupted(market, reqs, excludes, grid, **kw):
        counts, feas = orig(market, reqs, excludes, grid, **kw)
        counts = np.asarray(counts).copy()
        counts[..., 0] += 1                  # silent device-side corruption
        feas = np.ones_like(np.asarray(feas))
        return counts, feas

    be._run_prescan = corrupted
    rng = np.random.default_rng(41)
    fake = lambda: 0.0                                     # noqa: E731
    items = _random_market(rng, max_items=6)
    market = compile_market(items)
    got_n = bracketed_gss_many(items, [20], market=market, timer=fake,
                               backend=NUMPY)
    with pytest.warns(RuntimeWarning, match="diverged"):
        got_f = bracketed_gss_many(items, [20], market=market, timer=fake,
                                   backend=be)
    assert _gss_summary(got_n) == _gss_summary(got_f)
    assert be._fused_ok() is False           # disabled for the process
    # subsequent batches decline the fused path outright (no new warning,
    # no record) and stay correct
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        got_f2 = bracketed_gss_many(items, [25], market=market, timer=fake,
                                    backend=be)
    got_n2 = bracketed_gss_many(items, [25], market=market, timer=fake,
                                backend=NUMPY)
    assert _gss_summary(got_n2) == _gss_summary(got_f2)


@requires_jax
def test_fleet_fused_traces_byte_identical():
    """FleetSim with ``backend="jax:fused"`` (string spec resolved via
    make_backend) produces byte-identical traces, decisions and float
    totals to the default numpy plane, and surfaces the device-cache
    counters in cache_stats."""
    from repro.risk import backtest
    from repro.sim import run_fleet
    sc = backtest.price_shock_scenario(duration_hours=24.0,
                                       max_offerings=60)
    base = run_fleet(sc, [0, 1], record_traces=True)
    fused = run_fleet(sc, [0, 1], record_traces=True,
                      backend="jax:fused")
    for a, b in zip(base, fused):
        assert a.records == b.records
        assert a.total_cost == b.total_cost
        assert a.total_perf_hours == b.total_perf_hours
        assert [(r, d.pool.as_dict(), d.alpha, d.metrics)
                for r, d in a.decisions] == \
               [(r, d.pool.as_dict(), d.alpha, d.metrics)
                for r, d in b.decisions]
    stats = fused[0].cache_stats
    assert stats.get("device_cache_fallback_solves") == 0
    assert stats.get("device_cache_entries", 0) >= 1
