"""Numerical equivalence of the §Perf optimization paths against baselines:
causal-skip flash scheduling, shard_map expert parallelism, attention
parallelism modes (no-op on a 1×1 mesh)."""

import dataclasses

import numpy as np
import pytest
jax = pytest.importorskip("jax")  # jax-native module: skip wholesale without jax
import jax.numpy as jnp

from repro import sharding
from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import init_params, loss_fn
from repro.models import moe as moe_mod


def test_causal_skip_matches_naive():
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 96, 6, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    o_ref = ref.attention_naive(q, k, v, causal=True)
    o_skip, lse_s = ref.flash_fwd_chunked(q, k, v, causal=True, q_chunk=32,
                                          kv_chunk=32, causal_skip=True)
    o_full, lse_f = ref.flash_fwd_chunked(q, k, v, causal=True, q_chunk=32,
                                          kv_chunk=32)
    np.testing.assert_allclose(np.asarray(o_skip), np.asarray(o_ref),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(lse_s), np.asarray(lse_f),
                               atol=1e-6)


def test_causal_skip_grad_path():
    """custom_vjp with causal_skip forward: backward matches naive grads
    (lse is identical, so the standard flash backward applies)."""
    rng = np.random.default_rng(1)
    B, S, H, KV, hd = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)

    def loss_skip(q, k, v):
        o = ops.flash_attention(q, k, v, causal=True, impl="chunked",
                                q_chunk=16, kv_chunk=16, causal_skip=True)
        return jnp.sum(jnp.cos(o))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.cos(ref.attention_naive(q, k, v, causal=True)))

    g1 = jax.grad(loss_skip, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_ep_shard_map_matches_plain():
    """shard_map expert parallelism on a (1,1) mesh == plain path exactly
    (values and grads); E_local == E so drop semantics are identical."""
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", smoke=True),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(2))
    p0 = jax.tree.map(lambda a: a[0], params["body"]["0"]["ffn"])
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32) * 0.1

    y_plain, aux_plain = moe_mod.moe_apply(p0, x, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = dataclasses.replace(sharding.single_pod_rules(),
                                ep_shard_map=True)
    with sharding.mesh_context(mesh, rules):
        y_sm, aux_sm = moe_mod.moe_apply(p0, x, cfg)
        g_sm = jax.grad(lambda p: jnp.sum(
            jnp.sin(moe_mod.moe_apply(p, x, cfg)[0])))(p0)
    g_plain = jax.grad(lambda p: jnp.sum(
        jnp.sin(moe_mod.moe_apply(p, x, cfg)[0])))(p0)

    np.testing.assert_array_equal(np.asarray(y_plain), np.asarray(y_sm))
    assert float(aux_plain) == float(aux_sm)
    for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_sm)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_full_model_loss_invariant_under_mesh_flags():
    """End-to-end: loss on a trivial mesh with all perf flags on equals the
    no-mesh loss (constraints are layout-only, never semantic)."""
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", smoke=True),
                              dtype="float32", attn_causal_skip=True)
    params = init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16))),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)))}
    loss0, _ = loss_fn(params, cfg, batch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = dataclasses.replace(sharding.single_pod_rules(fsdp=True),
                                attn_mode="auto", ep_shard_map=True)
    with sharding.mesh_context(mesh, rules):
        loss1, _ = loss_fn(params, cfg, batch)
    assert float(loss0) == pytest.approx(float(loss1), rel=1e-6)
