"""The tier-1 suite's *registered* skips — the only ones allowed.

Every remaining skip in the suite is an optional-dependency gate, not a
disabled test: the six hypothesis properties have seeded deterministic
twins that always run (``*_deterministic``), and the two PuLP
cross-checks are redundant with the brute-force/reference cross-checks —
they only add the independent-CBC angle when ``pulp`` is installed (CI
installs both extras, so both gates are exercised there).

``tools/check_skips.py`` audits the junitxml produced by ``make verify``
against this table and fails the build on any skip that is not listed
here with its exact reason; ``tests/test_skip_registry.py`` asserts the
table itself stays truthful (the nodeids exist and the gated reasons are
byte-exact).
"""

#: nodeid → tuple of acceptable reason prefixes.  A test may have more
#: than one (``test_dp_matches_pulp`` is double-gated: without hypothesis
#: the @given shim skips it first; with hypothesis but no pulp the
#: importorskip does).
REGISTERED_SKIPS = {
    "tests/test_ilp.py::test_dp_matches_brute_force":
        ("hypothesis not installed",),
    "tests/test_ilp.py::test_dp_matches_pulp":
        ("hypothesis not installed", "could not import 'pulp'"),
    "tests/test_ilp.py::test_alpha_zero_minimizes_cost":
        ("could not import 'pulp'",),
    "tests/test_solver_engine.py::test_engine_matches_pulp":
        ("could not import 'pulp'",),
    "tests/test_gss_efficiency.py::test_e_metrics_invariants":
        ("hypothesis not installed",),
    "tests/test_chaos.py::test_backoff_schedule_property":
        ("hypothesis not installed",),
    "tests/test_kernels.py::test_flash_ref_property":
        ("hypothesis not installed",),
    "tests/test_region.py::test_region_shock_purity_property":
        ("hypothesis not installed",),
}

#: reason prefixes acceptable for *any* test: the reduced-dependency CI
#: legs (verify-nojax) legitimately skip whole jax-native modules at
#: collection time and every @requires_jax test individually
ENVIRONMENT_REASON_PREFIXES = (
    "jax not installed",
    "could not import 'jax'",
)

__all__ = ["ENVIRONMENT_REASON_PREFIXES", "REGISTERED_SKIPS"]
