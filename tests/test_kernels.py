"""Per-kernel validation: Pallas (interpret=True) and chunked refs vs the
pure-jnp oracles, swept over shapes and dtypes; gradients vs naive autodiff."""

import numpy as np
import pytest
jax = pytest.importorskip("jax")  # jax-native module: skip wholesale without jax
import jax.numpy as jnp

from tests._optional import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import selective_scan_pallas


def _qkv(rng, b, sq, skv, h, kv, hd, dtype):
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, kv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, kv, hd)), dtype)
    return q, k, v


ATTN_SHAPES = [
    # (b, sq, skv, h, kv, hd, qc, kc)
    (1, 32, 32, 4, 4, 16, 8, 8),        # MHA
    (2, 64, 64, 8, 2, 32, 16, 32),      # GQA 4:1
    (1, 128, 128, 6, 6, 64, 64, 32),    # wider head
    (2, 48, 48, 4, 1, 16, 16, 16),      # MQA
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_ref_vs_naive(shape, dtype):
    b, sq, skv, h, kv, hd, qc, kc = shape
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, b, sq, skv, h, kv, hd, dtype)
    ref_o = ref.attention_naive(q, k, v, causal=True)
    got = ref.flash_attention_ref(q, k, v, causal=True, q_chunk=qc,
                                  kv_chunk=kc)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_o, np.float32), atol=tol)


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_vs_naive(shape, dtype):
    b, sq, skv, h, kv, hd, qc, kc = shape
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, b, sq, skv, h, kv, hd, dtype)
    ref_o = ref.attention_naive(q, k, v, causal=True)
    got = flash_attention_pallas(q, k, v, causal=True, q_chunk=qc,
                                 kv_chunk=kc, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_o, np.float32), atol=tol)


def test_flash_pallas_noncausal_and_kvlen():
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, 2, 32, 64, 4, 2, 16, jnp.float32)
    for kwargs in ({"causal": False}, {"causal": True, "q_offset": 32},
                   {"causal": False, "kv_len": 40}):
        a = ref.attention_naive(q, k, v, **kwargs)
        b = flash_attention_pallas(q, k, v, q_chunk=16, kv_chunk=16,
                                   interpret=True, **kwargs)
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=2e-5)


def test_flash_backward_matches_naive_grad():
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, 2, 64, 64, 8, 4, 16, jnp.float32)

    def loss_flash(q, k, v):
        o = ops.flash_attention(q, k, v, causal=True, impl="chunked",
                                q_chunk=16, kv_chunk=16)
        return jnp.sum(jnp.sin(o))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(ref.attention_naive(q, k, v, causal=True)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([16, 32, 48]),
       st.sampled_from([1, 2, 4]), st.sampled_from([8, 16]))
def test_flash_ref_property(b, s, kvh, hd):
    h = kvh * 2
    rng = np.random.default_rng(s + b)
    q, k, v = _qkv(rng, b, s, s, h, kvh, hd, jnp.float32)
    a = ref.attention_naive(q, k, v, causal=True)
    o = ref.flash_attention_ref(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(o), np.asarray(a), atol=3e-5)


def test_flash_ref_property_deterministic():
    """Seeded twin of the hypothesis property above: a fixed lattice over
    the same (batch, seqlen, kv-heads, head-dim) space."""
    for b, s, kvh, hd in [(1, 16, 1, 8), (2, 32, 2, 16), (3, 48, 4, 8),
                          (1, 48, 2, 16), (2, 16, 4, 16), (3, 32, 1, 8)]:
        h = kvh * 2
        rng = np.random.default_rng(s + b)
        q, k, v = _qkv(rng, b, s, s, h, kvh, hd, jnp.float32)
        a = ref.attention_naive(q, k, v, causal=True)
        o = ref.flash_attention_ref(q, k, v, causal=True, q_chunk=16,
                                    kv_chunk=16)
        np.testing.assert_allclose(np.asarray(o), np.asarray(a), atol=3e-5)


# ------------------------------------------------------------- mamba ----

MAMBA_SHAPES = [
    (1, 32, 16, 4, 16, 16),     # (b, s, di, n, chunk, di_block)
    (2, 64, 32, 8, 16, 32),
    (2, 128, 64, 16, 32, 32),
]


@pytest.mark.parametrize("shape", MAMBA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_pallas_vs_ref(shape, dtype):
    b, s, di, n, chunk, dib = shape
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(b, s, di)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, s, di)), dtype)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(di, n)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, n)), dtype)
    C = jnp.asarray(rng.normal(size=(b, s, n)), dtype)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    y_ref, _ = ref.selective_scan_ref(x, dt, A, B, C, D)
    y_pl = selective_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                                 di_block=dib, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_pl, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_mamba_chunked_vs_ref(chunk):
    rng = np.random.default_rng(5)
    b, s, di, n = 2, 64, 24, 8
    x = jnp.asarray(rng.normal(size=(b, s, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, s, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(di, n)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    y_ref, h_ref = ref.selective_scan_ref(x, dt, A, B, C, D)
    y, h = ref.selective_scan_chunked(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


def test_mamba_chunked_with_initial_state():
    rng = np.random.default_rng(6)
    b, s, di, n = 1, 32, 16, 4
    mk = lambda *sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    x, B, C = mk(b, s, di), mk(b, s, n), mk(b, s, n)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, s, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(di, n)), jnp.float32)
    D = mk(di)
    # split the sequence: scanning halves with state handoff == full scan
    y_full, h_full = ref.selective_scan_chunked(x, dt, A, B, C, D, chunk=8)
    y1, h1 = ref.selective_scan_chunked(x[:, :16], dt[:, :16], A, B[:, :16],
                                        C[:, :16], D, chunk=8)
    y2, h2 = ref.selective_scan_chunked(x[:, 16:], dt[:, 16:], A, B[:, 16:],
                                        C[:, 16:], D, h0=h1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-5)
