"""The skip registry stays truthful: every registered nodeid exists,
gated reasons are byte-exact, every hypothesis-gated property has a
deterministic twin that always runs, and the junitxml audit tool flags
exactly the unregistered skips.
"""

import importlib
import sys
import xml.etree.ElementTree as ET
from pathlib import Path

from ._optional import HAVE_HYPOTHESIS
from .skip_registry import ENVIRONMENT_REASON_PREFIXES, REGISTERED_SKIPS

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))
check_skips = importlib.import_module("check_skips")


def _resolve(nodeid):
    path, name = nodeid.split("::")
    mod = importlib.import_module(path.replace("/", ".")[:-len(".py")])
    return getattr(mod, name, None)


def test_registered_nodeids_exist():
    """A registry entry whose test was renamed or deleted is stale —
    every nodeid must resolve to a real test function."""
    for nodeid in REGISTERED_SKIPS:
        if "test_kernels" in nodeid and "jax" not in sys.modules:
            continue                      # jax-native module, no-jax leg
        assert _resolve(nodeid) is not None, f"stale registry: {nodeid}"


def test_hypothesis_gated_reasons_are_exact():
    """Without hypothesis, the @given shim must attach a skip mark whose
    reason is byte-identical to the registered string (the audit tool
    matches on it)."""
    if HAVE_HYPOTHESIS:                   # pragma: no cover - extras leg
        return
    for nodeid, reasons in REGISTERED_SKIPS.items():
        if "hypothesis not installed" not in reasons:
            continue
        if "test_kernels" in nodeid and "jax" not in sys.modules:
            continue
        fn = _resolve(nodeid)
        marks = getattr(fn, "pytestmark", [])
        assert any(m.name == "skip"
                   and m.kwargs.get("reason") == "hypothesis not installed"
                   for m in marks), nodeid


def test_every_hypothesis_skip_has_deterministic_twin():
    """The registered hypothesis properties may skip, but their seeded
    twins (same module, ``_deterministic`` suffix) must exist and be
    plain callables that pytest always collects."""
    for nodeid, reasons in REGISTERED_SKIPS.items():
        if "hypothesis not installed" not in reasons:
            continue
        if "pulp" in str(reasons):        # double-gated: pulp is the twin gap
            continue
        if "test_kernels" in nodeid and "jax" not in sys.modules:
            continue
        twin = _resolve(nodeid + "_deterministic")
        assert callable(twin), f"missing deterministic twin for {nodeid}"
        assert not getattr(twin, "pytestmark", []), \
            f"twin for {nodeid} must not carry skip marks"


def _report(cases):
    tcs = "\n".join(
        f'<testcase classname="{c}" name="{n}">'
        + (f'<skipped message="{m}"/>' if m is not None else "")
        + "</testcase>"
        for c, n, m in cases)
    return f'<testsuites><testsuite>{tcs}</testsuite></testsuites>'


def test_check_skips_audit(tmp_path):
    """The audit accepts registered + environment skips and flags
    everything else, including module-level collection skips."""
    report = tmp_path / "r.xml"
    report.write_text(_report([
        ("tests.test_ilp", "test_dp_matches_brute_force",
         "hypothesis not installed"),
        ("tests.test_ilp", "test_alpha_zero_minimizes_cost",
         "could not import 'pulp': No module named 'pulp'"),
        ("tests.test_backend", "test_jax_equals_numpy_empty_market",
         "jax not installed"),
        ("", "tests/test_kernels.py",
         "could not import 'jax': No module named 'jax'"),
        ("tests.test_ilp", "test_empty_items", None),
    ]))
    offenders, n_skipped = check_skips.audit(report)
    assert offenders == [] and n_skipped == 4

    report.write_text(_report([
        ("tests.test_ilp", "test_empty_items", "lazily disabled"),
    ]))
    offenders, n_skipped = check_skips.audit(report)
    assert n_skipped == 1
    assert offenders == [("tests/test_ilp.py::test_empty_items",
                          "lazily disabled")]


def test_environment_prefixes_are_dependency_gates_only():
    """The blanket prefixes must stay narrow: only missing-jax shapes."""
    for p in ENVIRONMENT_REASON_PREFIXES:
        assert "jax" in p
