"""Shared randomized-market generators for the test suite.

One canonical copy of the ``CandidateItem`` factory and the random-market
samplers that ``test_ilp``, ``test_solver_engine``, ``test_backend``,
``test_gss_efficiency``, and ``test_coarsening`` previously each grew
privately.  Two layers:

* plain callables (``mk_item`` / ``random_market`` / ``random_exclude`` /
  ``gcd_market`` / ``big_market``) — deterministic given an
  ``np.random.Generator``, usable with or without hypothesis;
* hypothesis strategies (``item_strategy`` / ``items_strategy``) built on
  the same factory through :mod:`tests._optional`, so modules import them
  unconditionally and each ``@given`` test skips individually when
  hypothesis is absent.
"""

import numpy as np

from repro.core import CandidateItem, Offering

from ._optional import st


def mk_item(i, pods, bs, sp, t3):
    """One synthetic offering/candidate with the full Offering signature."""
    o = Offering(offering_id=f"t{i}@az", instance_type=f"t{i}", family="m",
                 generation=6, vendor="i", specialization="general",
                 size="large", region="r", az="az", vcpus=2, mem_gib=8.0,
                 od_price=sp * 3, spot_price=sp, bs_core=bs, sps_single=3,
                 t3=t3, interruption_freq=1)
    return CandidateItem(offering=o, pods=pods, bs=bs, spot_price=sp, t3=t3)


def random_market(rng, max_items=12, max_t3=9):
    """The suite's canonical small random market: 1..max_items items,
    pods 1..8, t3 0..max_t3-1 (zero-t3 rows exercise the structural
    mask)."""
    n = int(rng.integers(1, max_items + 1))
    return [mk_item(i, int(rng.integers(1, 9)),
                    float(rng.uniform(1e3, 1e5)),
                    float(rng.uniform(0.01, 3.0)),
                    int(rng.integers(0, max_t3)))
            for i in range(n)]


def random_exclude(rng, n):
    """A ~30% exclusion mask (or None) over an n-item market."""
    if n == 0 or rng.random() < 0.4:
        return None
    mask = rng.random(n) < 0.3
    return mask if mask.any() else None


def gcd_market(rng, n_items=80, pod_mult=8, t3_lo=20, t3_hi=120):
    """A market whose pod counts all share the factor ``pod_mult`` — the
    demand-coarsening gcd tier's natural habitat (DESIGN.md §14)."""
    return [mk_item(i, pod_mult * int(rng.integers(1, 9)),
                    float(rng.uniform(0.5, 4.0)),
                    float(rng.uniform(0.05, 2.5)),
                    int(rng.integers(t3_lo, t3_hi)))
            for i in range(n_items)]


def big_market(rng, n_items=600, t3_lo=200, t3_hi=3000):
    """A deep market (capacity in the millions of pods) for the approx
    coarsening tier and the scale benchmarks; gcd is almost surely 1."""
    return [mk_item(i, int(rng.integers(1, 9)),
                    float(rng.uniform(0.5, 4.0)),
                    float(rng.uniform(0.05, 2.5)),
                    int(rng.integers(t3_lo, t3_hi)))
            for i in range(n_items)]


#: hypothesis strategies over the same factory (no-ops without hypothesis —
#: the @given decorator from tests._optional skips those tests individually)
item_strategy = st.builds(
    lambda i, pods, bs, sp, t3: mk_item(i, pods, bs, sp, t3),
    st.integers(0, 10_000), st.integers(1, 8),
    st.floats(1e3, 1e5), st.floats(0.01, 3.0), st.integers(0, 6))


def items_strategy(min_size=1, max_size=8):
    return st.lists(item_strategy, min_size=min_size, max_size=max_size)


__all__ = ["big_market", "gcd_market", "item_strategy", "items_strategy",
           "mk_item", "random_exclude", "random_market"]
