"""Per-arch smoke tests (reduced configs, fwd+train step, no NaNs) and
decode-vs-forward parity for every architecture family."""

import dataclasses

import numpy as np
import pytest
jax = pytest.importorskip("jax")  # jax-native module: skip wholesale without jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, SHAPES, shape_applicable
from repro.models import (init_params, loss_fn, count_params, active_params,
                          prefill, decode_step, init_cache)
from repro.models.transformer import forward
from repro.models import moe as moe_mod

ALL_ARCHS = list_archs()


def _batch(cfg, rng, B=2, S=16):
    if cfg.input_mode == "audio_codes":
        return {"codes": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, cfg.n_codebooks, S))),
                "targets": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, cfg.n_codebooks, S)))}
    if cfg.input_mode == "vlm":
        st = S - cfg.vision_prefix
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, st))),
                "vision_embeds": jnp.asarray(
                    rng.normal(size=(B, cfg.vision_prefix, cfg.d_model)),
                    jnp.float32),
                "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, st)))}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}


def test_all_archs_registered():
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one forward/backward on CPU, shapes + no NaNs."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    logits, aux, _ = forward(params, cfg, batch, mode="train")
    if cfg.input_mode == "audio_codes":
        assert logits.shape == (2, 16, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    assert cfg.n_layers == len(cfg.layout)
    assert count_params(cfg) > 0
    assert active_params(cfg) <= count_params(cfg)


def test_param_counts_match_names():
    """Full configs land near their nameplate sizes."""
    expect = {"internlm2-1.8b": (1.7e9, 2.1e9),
              "qwen2.5-14b": (13e9, 16e9),
              "qwen2.5-32b": (31e9, 34e9),
              "falcon-mamba-7b": (6.5e9, 8e9),
              "jamba-1.5-large-398b": (380e9, 410e9),
              "kimi-k2-1t-a32b": (0.95e12, 1.1e12),
              "qwen3-moe-30b-a3b": (29e9, 32e9)}
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, (arch, n)
    # active params for the MoEs
    assert 30e9 <= active_params(get_config("kimi-k2-1t-a32b")) <= 36e9
    assert 2.5e9 <= active_params(get_config("qwen3-moe-30b-a3b")) <= 4e9


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "falcon-mamba-7b",
                                  "jamba-1.5-large-398b", "qwen3-moe-30b-a3b",
                                  "musicgen-large", "internvl2-1b",
                                  "kimi-k2-1t-a32b", "stablelm-3b"])
def test_decode_matches_forward(arch):
    """prefill(S) + decode(token S) == full forward at position S."""
    cfg = get_config(arch, smoke=True)
    over = {"dtype": "float32"}
    if cfg.n_experts:
        over["capacity_factor"] = float(cfg.n_experts)   # no token drops
    cfg = dataclasses.replace(cfg, **over)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S, ML = 2, 8, 16
    if cfg.input_mode == "audio_codes":
        codes = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                         (B, cfg.n_codebooks, S + 1)))
        full, _, _ = forward(params, cfg, {"codes": codes}, mode="train")
        _, caches = prefill(params, cfg, {"codes": codes[:, :, :S]}, max_len=ML)
        ld, _ = decode_step(params, cfg, caches,
                            {"codes": codes[:, :, S:S + 1]}, jnp.asarray(S))
        err = float(jnp.abs(full[:, S] - ld[:, 0]).max())
    elif cfg.input_mode == "vlm":
        P = cfg.vision_prefix
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)))
        ve = jnp.asarray(rng.normal(size=(B, P, cfg.d_model)), jnp.float32)
        full, _, _ = forward(params, cfg,
                             {"tokens": toks, "vision_embeds": ve},
                             mode="train")
        _, caches = prefill(params, cfg,
                            {"tokens": toks[:, :S], "vision_embeds": ve},
                            max_len=ML + P)
        ld, _ = decode_step(params, cfg, caches, {"tokens": toks[:, S:S + 1]},
                            jnp.asarray(P + S))
        err = float(jnp.abs(full[:, P + S] - ld[:, 0]).max())
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)))
        full, _, _ = forward(params, cfg, {"tokens": toks}, mode="train")
        _, caches = prefill(params, cfg, {"tokens": toks[:, :S]}, max_len=ML)
        ld, _ = decode_step(params, cfg, caches, {"tokens": toks[:, S:S + 1]},
                            jnp.asarray(S))
        err = float(jnp.abs(full[:, S] - ld[:, 0]).max())
    assert err < 2e-3, (arch, err)


def test_multi_step_decode_consistency():
    """Three sequential decode steps match the teacher-forced forward."""
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, S = 2, 6
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 3)))
    full, _, _ = forward(params, cfg, {"tokens": toks}, mode="train")
    _, caches = prefill(params, cfg, {"tokens": toks[:, :S]}, max_len=S + 3)
    for i in range(3):
        ld, caches = decode_step(params, cfg, caches,
                                 {"tokens": toks[:, S + i:S + i + 1]},
                                 jnp.asarray(S + i))
        np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                   np.asarray(full[:, S + i]), atol=2e-3)


def test_moe_conservation_and_aux():
    """Dispatch/combine bookkeeping: with huge capacity nothing drops, and
    the MoE output matches a dense per-token expert evaluation."""
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", smoke=True),
                              dtype="float32",
                              capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(2))
    p = params["body"]["0"]["ffn"]
    p0 = jax.tree.map(lambda a: a[0], p)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32) * 0.1
    y, aux = moe_mod.moe_apply(p0, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.isfinite(aux))
    # dense oracle
    logits = x @ p0["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, cfg.n_experts_active)
    vals = vals / vals.sum(-1, keepdims=True)
    def per_token(xt, it, wt):
        out = 0
        for j in range(cfg.n_experts_active):
            wg, wu, wd = (p0["wg"][it[j]], p0["wu"][it[j]], p0["wd"][it[j]])
            out = out + wt[j] * ((jax.nn.silu(xt @ wg) * (xt @ wu)) @ wd)
        return out
    oracle = jax.vmap(jax.vmap(per_token))(x, idx, vals)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle), atol=1e-4)


def test_long_500k_applicability():
    shape = SHAPES["long_500k"]
    runnable = [a for a in ALL_ARCHS
                if shape_applicable(get_config(a), shape)]
    assert sorted(runnable) == ["falcon-mamba-7b", "jamba-1.5-large-398b"]
