"""Demand-coarsening hierarchical DP (DESIGN.md §14): the gcd tier is
bit-identical to the exact engine, the approx tier honours its certified
bound, the fallback ladder degrades to exact, and every backend agrees
under coarsening.

All tests are seeded deterministic loops (no hypothesis dependency): the
100+-market gcd sweep is the property harness the tier's exactness claim
rests on.
"""

import numpy as np
import pytest

from repro.core import (CoarseningConfig, DEFAULT_COARSENING,
                        NumpyBackend, bracketed_gss_many, compile_market,
                        make_backend, solve_ilp, solve_ilp_many)

from ._optional import HAVE_JAX, requires_jax
from .strategies import big_market, gcd_market, random_market

NUMPY = NumpyBackend()


def _solve(market, demand, alpha, cfg, backend=None):
    return solve_ilp(market.items, demand, alpha, return_stats=True,
                     market=market, backend=backend, coarsening=cfg)


EXACT = CoarseningConfig(enabled=False)


# ------------------------------------------------------------ gcd tier ----

def test_gcd_coarse_equals_exact_bitwise_100_markets():
    """≥100 randomized GCD-sharing markets × demands above threshold ×
    α incl. both edges: the gcd tier must return the *identical count
    vector and objective* as the uncoarsened engine — the DESIGN.md §14
    exactness theorem, checked bit-for-bit."""
    rng = np.random.default_rng(1234)
    cfg = CoarseningConfig(threshold=512, max_rows=1_000_000)
    n_markets = 0
    n_coarse_rows = 0
    for trial in range(34):
        mult = int(rng.choice([2, 4, 8, 16, 64]))
        market = compile_market(gcd_market(rng, n_items=40, pod_mult=mult))
        assert market.pods_gcd % mult == 0
        n_markets += 1
        for demand in (int(rng.integers(600, 3000)),
                       int(rng.integers(3000, 12000)),
                       int(rng.integers(12000, 30000))):
            for alpha in (0.0, float(rng.uniform(0, 1)), 1.0):
                r_e, s_e = _solve(market, demand, alpha, EXACT)
                r_c, s_c = _solve(market, demand, alpha, cfg)
                assert r_e == r_c, (trial, demand, alpha)
                assert s_e.objective == s_c.objective
                if s_c.residual_demand > cfg.threshold and r_c is not None \
                        and s_c.residual_demand > 0:
                    assert s_c.coarse == "gcd"
                    assert s_c.granularity == market.pods_gcd
                    n_coarse_rows += 1
    assert n_markets >= 34 and n_coarse_rows >= 100


def test_gcd_tier_inert_below_threshold():
    rng = np.random.default_rng(5)
    market = compile_market(gcd_market(rng, n_items=30, pod_mult=8))
    r_d, s_d = _solve(market, 900, 0.0, DEFAULT_COARSENING)
    r_e, s_e = _solve(market, 900, 0.0, EXACT)
    assert r_d == r_e and s_d.coarse == "exact" and s_d.granularity == 1


# --------------------------------------------------------- approx tier ----

def test_approx_within_advertised_bound_at_50k():
    """~50k residual on a gcd-1 market: the greedy-prefix + boundary-window
    solve must (1) report mode approx with a finite certificate, (2) have
    a true gap vs the exact optimum no larger than the certificate, and
    (3) keep the certificate within the configured rel_gap."""
    rng = np.random.default_rng(11)
    market = compile_market(big_market(rng, n_items=600))
    assert market.pods_gcd == 1
    cfg = CoarseningConfig(threshold=8192)
    for demand in (30_000, 50_000, 80_000):
        r_e, s_e = _solve(market, demand, 0.0, EXACT)
        r_c, s_c = _solve(market, demand, 0.0, cfg)
        assert s_c.coarse == "approx"
        assert s_c.granularity == cfg.approx_rows
        true_gap = s_c.objective - s_e.objective
        assert -1e-9 <= true_gap <= s_c.gap_bound + 1e-9
        assert s_c.gap_bound <= cfg.rel_gap * abs(s_e.objective) + 1e-9
        # the selection is feasible and bound-respecting
        assert sum(c * it.pods for c, it in zip(r_c, market.items)) >= demand
        assert all(0 <= c <= it.t3 for c, it in zip(r_c, market.items))


def test_approx_fallback_when_certificate_violated():
    """rel_gap=0 makes every certificate fail: the row must be re-solved
    exactly (coarse == approx_fallback) and match the exact engine
    bit-for-bit."""
    rng = np.random.default_rng(11)
    market = compile_market(big_market(rng, n_items=600))
    strict = CoarseningConfig(threshold=8192, rel_gap=0.0)
    r_f, s_f = _solve(market, 50_000, 0.0, strict)
    r_e, s_e = _solve(market, 50_000, 0.0, EXACT)
    assert s_f.coarse == "approx_fallback" and s_f.gap_bound == 0.0
    assert r_f == r_e and s_f.objective == s_e.objective


def test_exact_fallback_below_threshold_and_disabled_ladder():
    """Below threshold → exact; allow_approx=False on a gcd-1 market →
    exact even far above threshold; enabled=False → exact everywhere."""
    rng = np.random.default_rng(11)
    market = compile_market(big_market(rng, n_items=600))
    cfg = CoarseningConfig(threshold=8192)
    r_e, s_e = _solve(market, 5000, 0.0, EXACT)
    r_b, s_b = _solve(market, 5000, 0.0, cfg)
    assert r_b == r_e and s_b.coarse == "exact" and s_b.granularity == 1
    noapx = CoarseningConfig(threshold=8192, allow_approx=False)
    r_n, s_n = _solve(market, 50_000, 0.0, noapx)
    r_x, _ = _solve(market, 50_000, 0.0, EXACT)
    assert s_n.coarse == "exact" and r_n == r_x


def test_alpha_grid_rows_share_coarse_work():
    """solve_ilp_many across mixed scales: per-row tier labels follow the
    ladder, and every row equals its single-row solve (sparse-saturation
    sharing must not change results)."""
    rng = np.random.default_rng(17)
    market = compile_market(big_market(rng, n_items=400))
    cfg = CoarseningConfig(threshold=8192)
    reqs = [5000, 30_000, 30_000, 120_000]
    grids = [[0.0, 0.5], [0.0, 0.5], [0.0], [0.0]]
    many, stats = solve_ilp_many(market.items, reqs, grids, market=market,
                                 return_stats=True, coarsening=cfg)
    for d, (req, grid) in enumerate(zip(reqs, grids)):
        for a, alpha in enumerate(grid):
            r1, s1 = _solve(market, req, alpha, cfg)
            assert many[d][a] == r1
            assert stats[d][a].objective == s1.objective
            assert stats[d][a].coarse == s1.coarse
    # identical (objective, residual) rows dedupe onto one plan: the two
    # 30k α=0.0 rows must agree exactly
    assert many[1][0] == many[2][0]


# ----------------------------------------------- backend equivalence ----

@requires_jax
def test_backends_agree_under_coarsening_zero_fallback():
    """numpy / jax / jax:pallas host engines return identical selections
    under coarsening, with zero fallback solves on the approx rows."""
    rng = np.random.default_rng(29)
    market = compile_market(big_market(rng, n_items=300))
    cfg = CoarseningConfig(threshold=8192)
    backends = [NUMPY, make_backend("jax"), make_backend("jax:pallas")]
    outs = []
    for be in backends:
        many, stats = solve_ilp_many(
            market.items, [20_000, 60_000], [[0.0], [0.0]], market=market,
            backend=be, return_stats=True, coarsening=cfg)
        for row in stats:
            for s in row:
                assert s.coarse in ("gcd", "approx", "exact"), s  # no fallback
        outs.append(many)
    assert outs[0] == outs[1] == outs[2]


@requires_jax
def test_fused_gss_agrees_with_numpy_under_gcd_coarsening():
    """bracketed_gss_many through the fused device plane ≡ the NumPy
    engine on a gcd-8 market with coarsening active above a lowered
    threshold — pools, α*, and counts all identical."""
    rng = np.random.default_rng(23)
    market = compile_market(gcd_market(rng, n_items=80, pod_mult=8))
    cfg = CoarseningConfig(threshold=1000, max_rows=100_000)
    reqs = [12_000, 16_000, 900, 14_444]
    fake = lambda: 0.0                                     # noqa: E731
    # the device plane must *accept* a gcd-regime batch (decline would
    # silently fall back to the host and prove nothing)
    rec = make_backend("jax:fused").fused_gss_record(
        market.items, market, reqs, [None] * len(reqs),
        [i / 8 for i in range(9)], 0.01, coarsening=cfg)
    assert rec is not None
    out_n = bracketed_gss_many(market.items, reqs, market=market,
                               timer=fake, backend=NUMPY, coarsening=cfg)
    out_j = bracketed_gss_many(market.items, reqs, market=market,
                               timer=fake,
                               backend=make_backend("jax:fused"),
                               coarsening=cfg)
    out_e = bracketed_gss_many(market.items, reqs, market=market,
                               timer=fake, backend=NUMPY, coarsening=EXACT)
    for (pn, tn), (pj, tj), (pe, te) in zip(out_n, out_j, out_e):
        if pn is None:
            assert pj is None and pe is None
            continue
        assert pn.counts == pj.counts == pe.counts
        assert pn.alpha == pj.alpha == pe.alpha
        assert tn.alphas == tj.alphas


@requires_jax
def test_fused_record_declines_approx_regime():
    """Above threshold on a gcd-1 market the fused device plane must
    decline (approx runs on the host), and the host paths still agree."""
    rng = np.random.default_rng(31)
    market = compile_market(big_market(rng, n_items=120, t3_lo=50,
                                       t3_hi=400))
    assert market.pods_gcd == 1
    cfg = CoarseningConfig(threshold=2000)
    jb = make_backend("jax:fused")
    rec = jb.fused_gss_record(market.items, market, [30_000], [None],
                              [i / 8 for i in range(9)], 0.01,
                              coarsening=cfg)
    assert rec is None
    fake = lambda: 0.0                                     # noqa: E731
    out_n = bracketed_gss_many(market.items, [30_000], market=market,
                               timer=fake, backend=NUMPY, coarsening=cfg)
    out_j = bracketed_gss_many(market.items, [30_000], market=market,
                               timer=fake, backend=jb, coarsening=cfg)
    (pn, _), (pj, _) = out_n[0], out_j[0]
    if pn is None:
        assert pj is None
    else:
        assert pn.counts == pj.counts and pn.alpha == pj.alpha


# -------------------------------------------------- sim scenario family ----

def test_high_demand_scenario_engages_coarse_tier():
    """The sim-layer stress family must actually land in the coarse
    regime: its generated catalog compiles to a gcd ≥ 8 market and a
    solve at the scenario's demand reports a coarse tier (not exact)."""
    from repro.core.provisioner import preprocess
    from repro.sim import high_demand_scenario

    sc = high_demand_scenario()
    market = compile_market(preprocess(sc.build_catalog(), sc.request()))
    assert market.pods_gcd >= 8
    pool, stats = _solve(market, sc.pods, 0.5, DEFAULT_COARSENING)
    assert pool is not None
    assert stats.coarse in ("gcd", "approx")
    # round-trippable spec (trace-header contract) with the family's knobs
    assert sc == type(sc).from_dict(sc.to_dict())
    small = high_demand_scenario(pods=40_000)
    assert small.pods == 40_000 and small.name == "high_demand_40000"
