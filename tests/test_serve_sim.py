"""ServeSim: workload determinism, perf-model caching, SLO masking,
recovery accounting, and the serving_slo policy loop (DESIGN.md §15)."""

import dataclasses
import types

import numpy as np
import pytest

from repro.core.efficiency import Request
from repro.core.market import generate_catalog
from repro.core.provisioner import preprocess
from repro.risk.estimators import RiskEstimators, RiskParams
from repro.risk.objective import risk_adjustment, serving_risk_adjustment
from repro.serve_sim import (DEFAULT_STAFFING_BETA, ServingProfile,
                             WorkloadSpec, analytic_token_s,
                             build_serve_scenario, cache_stats, clear_caches,
                             default_slo_ms, demand_schedule_from_trace,
                             evaluate_serving, reference_qps_per_pod,
                             run_serving, serving_table, staffed_pods,
                             trace_digest)
from repro.serve_sim.sim import PoolTimeline, ServeScenario
from repro.sim import ClusterSim, loads_trace, make_policy, serving_scenario

from ._optional import requires_jax

ANALYTIC = ServingProfile(mode="analytic")


# --------------------------------------------------------------------------
# workload traces
# --------------------------------------------------------------------------

def test_trace_byte_identical_per_seed():
    for kind in ("diurnal", "bursty", "flash"):
        a = WorkloadSpec(kind=kind, seed=42)
        b = WorkloadSpec(kind=kind, seed=42)
        assert a.trace().tobytes() == b.trace().tobytes()
        assert trace_digest(a) == trace_digest(b)
        assert trace_digest(a) != trace_digest(
            WorkloadSpec(kind=kind, seed=43))


def test_trace_kinds_on_disjoint_streams():
    digests = {kind: trace_digest(WorkloadSpec(kind=kind, seed=0))
               for kind in ("diurnal", "bursty", "flash")}
    assert len(set(digests.values())) == 3
    # flash actually spikes: max well above the pure-diurnal peak
    flash = WorkloadSpec(kind="flash", seed=0, noise=0.0)
    plain = WorkloadSpec(kind="diurnal", seed=0, noise=0.0)
    assert flash.trace().max() > 1.5 * plain.trace().max()


def test_diurnal_shape():
    spec = WorkloadSpec(kind="diurnal", base_qps=100.0, peak_factor=3.0,
                        noise=0.0)
    lam = spec.trace()
    assert lam.dtype == np.float64 and lam.shape == (24,)
    assert np.isclose(lam.min(), 100.0)            # trough at base_qps
    assert np.isclose(lam.max(), 300.0)            # peak at base·factor
    assert int(np.argmax(lam)) == 15               # mid-afternoon peak


def test_staffed_pods_sqrt_headroom():
    # bare floor at beta=0; sqrt headroom above it; monotone in lambda
    assert staffed_pods(100.0, 10.0, beta=0.0) == 10
    rho = 100.0 / 10.0
    expect = int(np.ceil(rho + DEFAULT_STAFFING_BETA * np.sqrt(rho) - 1e-9))
    assert staffed_pods(100.0, 10.0) == expect > 10
    staffs = [staffed_pods(lam, 10.0) for lam in (0.0, 1.0, 50.0, 500.0)]
    assert staffs == sorted(staffs) and staffs[0] == 1


def test_demand_schedule_merges_equal_levels():
    spec = WorkloadSpec(kind="diurnal", base_qps=40.0, noise=0.0)
    initial, schedule = demand_schedule_from_trace(spec, 10.0)
    assert initial == staffed_pods(float(spec.trace()[0]), 10.0)
    times = [t for t, _ in schedule]
    assert times == sorted(times) and all(t > 0 for t in times)
    # merged: consecutive entries always change the staffing level
    levels = [initial] + [p for _, p in schedule]
    assert all(a != b for a, b in zip(levels, levels[1:]))
    # and the schedule reproduces the per-interval staffing exactly
    lam = spec.trace()
    cur, k = initial, 0
    for step, t in enumerate(spec.times()):
        while k < len(schedule) and schedule[k][0] <= t:
            cur = schedule[k][1]
            k += 1
        assert cur == staffed_pods(float(lam[step]), 10.0)


# --------------------------------------------------------------------------
# perf model: caching + SLO mask
# --------------------------------------------------------------------------

def test_perf_model_cache_hit_and_digest_invalidation():
    offs = generate_catalog(seed=3, max_offerings=24)
    clear_caches()
    t1 = serving_table(ANALYTIC, offs)
    assert cache_stats() == {"step_hits": 0, "step_misses": 1,
                             "table_hits": 0, "table_misses": 1}
    t2 = serving_table(ANALYTIC, offs)
    assert t2 is t1
    assert cache_stats()["table_hits"] == 1
    # tokens_per_request changes the profile digest -> table rebuild, but
    # the step time does not depend on it -> step cache still hits
    longer = dataclasses.replace(ANALYTIC, tokens_per_request=256)
    assert longer.digest != ANALYTIC.digest
    t3 = serving_table(longer, offs)
    stats = cache_stats()
    assert stats["table_misses"] == 2 and stats["step_hits"] == 1
    assert np.allclose(t3.request_ms, 2.0 * t1.request_ms)
    # batch_per_pod changes the decode step itself -> step cache miss
    serving_table(dataclasses.replace(ANALYTIC, batch_per_pod=64), offs)
    assert cache_stats()["step_misses"] == 2
    # a different offering set is a different table key
    serving_table(ANALYTIC, offs[:10])
    assert cache_stats()["table_misses"] == 4


def test_slo_mask_is_speed_factor_threshold():
    offs = generate_catalog(seed=3, max_offerings=120)
    table = serving_table(ANALYTIC, offs)
    slack = 1.05
    slo = default_slo_ms(ANALYTIC, slack=slack)
    mask = table.slo_mask(slo)
    assert mask is not None and 0 < mask.sum() < len(offs)
    # infeasible <=> speed factor below 1/slack (float-tolerant boundary)
    np.testing.assert_array_equal(mask, table.request_ms > slo)
    expect = table.speed < 1.0 / slack
    boundary = np.isclose(table.speed, 1.0 / slack, rtol=1e-12)
    np.testing.assert_array_equal(mask[~boundary], expect[~boundary])
    # a lax SLO masks nothing -> None (provisioner convention)
    assert table.slo_mask(1e9) is None


def test_analytic_token_s_terms():
    # KV-dominated at the default 32k context: memory term governs
    token_s = analytic_token_s(ANALYTIC)
    n, b, d = (ANALYTIC.active_params, ANALYTIC.batch_per_pod,
               ANALYTIC.devices_per_pod)
    from repro import roofline
    kv = b * ANALYTIC.context_len * ANALYTIC.kv_bytes_per_token
    assert np.isclose(token_s, (2 * n + kv) / (roofline.HBM_BW * d))
    # qps/pod and request latency are consistent with it
    assert np.isclose(reference_qps_per_pod(ANALYTIC),
                      b / (ANALYTIC.tokens_per_request * token_s))


@requires_jax
def test_roofline_matches_analytic_ranking():
    """The jax leg: the compiled-HLO mode must agree with the analytic
    fallback on everything scale-invariant — offering ranking, SLO mask,
    relative latencies — differing only in the absolute step time."""
    offs = generate_catalog(seed=3, max_offerings=60)
    ana = serving_table(ANALYTIC, offs)
    roof = serving_table(ServingProfile(mode="roofline"), offs)
    assert roof.mode == "roofline" and roof.token_s_ref != ana.token_s_ref
    np.testing.assert_array_equal(np.argsort(-roof.qps_per_pod),
                                  np.argsort(-ana.qps_per_pod))
    np.testing.assert_array_equal(
        roof.slo_mask(default_slo_ms(ServingProfile(mode="roofline"))),
        ana.slo_mask(default_slo_ms(ANALYTIC)))
    np.testing.assert_allclose(roof.request_ms / roof.token_s_ref,
                               ana.request_ms / ana.token_s_ref)


def test_ranking_follows_speed_factor_deterministic():
    """Deterministic twin of the roofline ranking test: in any mode the
    table is one reference step time scaled by the CoreMark speed factor,
    so ranking == speed ranking by construction."""
    offs = generate_catalog(seed=3, max_offerings=60)
    table = serving_table(ANALYTIC, offs)
    np.testing.assert_array_equal(np.argsort(-table.qps_per_pod),
                                  np.argsort(table.request_ms))
    np.testing.assert_array_equal(np.argsort(-table.qps_per_pod),
                                  np.argsort(-table.speed))


# --------------------------------------------------------------------------
# recovery accounting
# --------------------------------------------------------------------------

def _flat_scenario(recovery_hours: float) -> ServeScenario:
    # constant lambda (no diurnal swing, no noise) so served QPS-hours are
    # hand-computable
    spec = WorkloadSpec(kind="diurnal", base_qps=100.0, peak_factor=1.0,
                        noise=0.0, duration_hours=12.0)
    scenario = serving_scenario("diurnal", base_qps=100.0,
                                duration_hours=12.0, profile=ANALYTIC)
    return ServeScenario(workload=spec, scenario=scenario, profile=ANALYTIC,
                         slo_ms=1e9, recovery_hours=recovery_hours)


def test_recovery_accounting_charges_warmup():
    offs = generate_catalog(seed=3, max_offerings=8)
    table = serving_table(ANALYTIC, offs)
    oid = table.offering_ids[0]
    pods = 4
    qps1 = pods * float(table.qps_per_pod[table.index[oid]])
    # capacity qps1 from t=0 (warm: initial provisioning exempt), doubled
    # at t=6 -> the added half warms up for recovery_hours
    result = types.SimpleNamespace(decisions=[], total_cost=10.0,
                                   interrupted_nodes=0)
    reports = {}
    for rec in (0.0, 0.5):
        timeline = PoolTimeline()
        timeline.events = [(0.0, "launch", ((oid, 1, pods),)),
                           (6.0, "launch", ((oid, 2, pods),))]
        reports[rec] = evaluate_serving(_flat_scenario(rec), table,
                                        timeline, result)
    base, charged = reports[0.0], reports[0.5]
    assert base.recovery_lost_qps_hours == 0.0
    assert np.isclose(base.offered_qps_hours, 100.0 * 12.0)
    lam = 100.0
    exp_base = min(lam, qps1) * 6.0 + min(lam, 2 * qps1) * 6.0
    assert np.isclose(base.served_qps_hours, exp_base)
    # during [6, 6.5) the added qps1 is warming: capacity reverts to qps1
    exp_lost = (min(lam, 2 * qps1) - min(lam, qps1)) * 0.5
    assert np.isclose(charged.recovery_lost_qps_hours, exp_lost)
    assert np.isclose(charged.served_qps_hours, exp_base - exp_lost)
    assert np.isclose(charged.nominal_served_qps_hours, exp_base)


def test_recovery_initial_provisioning_exempt():
    offs = generate_catalog(seed=3, max_offerings=8)
    table = serving_table(ANALYTIC, offs)
    oid = table.offering_ids[0]
    timeline = PoolTimeline()
    timeline.events = [(0.0, "launch", ((oid, 2, 4),))]
    result = types.SimpleNamespace(decisions=[], total_cost=1.0,
                                   interrupted_nodes=0)
    report = evaluate_serving(_flat_scenario(0.5), table, timeline, result)
    assert report.recovery_lost_qps_hours == 0.0
    assert report.served_qps_hours == report.nominal_served_qps_hours > 0


# --------------------------------------------------------------------------
# risk-objective substitution
# --------------------------------------------------------------------------

def test_serving_risk_adjustment_identity_at_zero_horizon():
    catalog = generate_catalog(seed=5, max_offerings=30)
    items = preprocess(catalog, Request(pods=50, cpu_per_pod=2.0,
                                        mem_per_pod=4.0))
    est = RiskEstimators(catalog, RiskParams())
    base_perf = np.array([float(it.perf) for it in items])
    serve_perf = np.linspace(1.0, 2.0, len(items))
    adj0 = risk_adjustment(items, est, 0.0)
    out = serving_risk_adjustment(adj0, serve_perf, base_perf)
    # horizon 0: no discount anywhere -> the serving vector passes through
    np.testing.assert_allclose(out.perf, serve_perf)
    np.testing.assert_array_equal(out.price, adj0.price)
    # positive horizon: discounted by exactly the base-perf risk factor
    adj = risk_adjustment(items, est, 12.0)
    out = serving_risk_adjustment(adj, serve_perf, base_perf)
    np.testing.assert_allclose(out.perf,
                               serve_perf * adj.perf / base_perf)


# --------------------------------------------------------------------------
# the serving_slo policy in the engine loop
# --------------------------------------------------------------------------

def _short_serve(policy: str = "serving_slo", **kw):
    return build_serve_scenario("diurnal", policy=policy, base_qps=400.0,
                                duration_hours=8.0, profile=ANALYTIC,
                                max_offerings=120, **kw)


def test_serving_slo_selects_only_feasible_offerings():
    ss = _short_serve()
    timeline = PoolTimeline()
    sim = ClusterSim(ss.scenario, observers=[timeline], clock=lambda: 0.0)
    result = sim.run()
    table = serving_table(ANALYTIC, sim.catalog)
    idx = table.index
    launched = {oid for _, _, alloc in timeline.events
                for oid, _, _ in alloc}
    assert launched, "no capacity was ever launched"
    assert all(float(table.request_ms[idx[oid]]) <= ss.slo_ms + 1e-9
               for oid in launched)
    masked = [d.metrics["serve_slo_masked"] for _, d in result.decisions]
    assert all(m > 0 for m in masked)          # the mask actually bites
    assert all(d.metrics["serve_infeasible"] == 0.0
               for _, d in result.decisions)
    assert all(d.metrics["serve_qps_capacity"] > 0
               for _, d in result.decisions)


def test_serving_slo_decisions_deterministic_and_replayable():
    a = run_serving(_short_serve(), clock=lambda: 0.0)
    b = run_serving(_short_serve(), clock=lambda: 0.0)
    assert a.as_dict() == b.as_dict()
    assert a.infeasible_decisions == 0
    # the underlying provisioning trace replays byte-identically (replay
    # is RNG-free: the serving policy adds no stream consumption)
    sim = ClusterSim(_short_serve().scenario, clock=lambda: 0.0)
    res = sim.run()
    blob = res.recorder.dumps()
    rep = ClusterSim.replay(loads_trace(blob)).run()
    assert rep.recorder.dumps() == blob
    assert rep.decision_records() == res.decision_records()


def test_serving_slo_beats_karpenter_on_slo_qps_per_dollar():
    slo = run_serving(_short_serve(), clock=lambda: 0.0)
    karp = run_serving(_short_serve(policy="karpenter_like"),
                       clock=lambda: 0.0)
    assert slo.perf_mode == "analytic"
    assert slo.slo_attainment >= karp.slo_attainment - 1e-9
    assert (slo.slo_qps_hours_per_dollar
            > karp.slo_qps_hours_per_dollar)


def test_serving_scenario_spec_roundtrip():
    sc = serving_scenario("bursty", base_qps=200.0, profile=ANALYTIC)
    from repro.sim import Scenario
    assert Scenario.from_dict(sc.to_dict()) == sc
    assert sc.policy == "serving_slo" and sc.name == "serving_bursty"
    assert sc.pods >= 1 and sc.step_hours == 1.0


def test_make_policy_serving_slo_specs():
    assert make_policy("serving_slo").name == "serving_slo"
    assert make_policy("serving_slo:12").name == "serving_slo:12"
    with pytest.raises(ValueError):
        make_policy("serving_slo:not_a_number")
