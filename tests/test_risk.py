"""Risk subsystem: estimator convergence, E_risk reductions, survival math,
determinism of the kubepacs_risk policy, and the backtest acceptance
comparison (DESIGN.md §10)."""

import numpy as np
import pytest

from repro.core import (Request, compile_market, generate_catalog, preprocess,
                        reweight_items, reweight_market, solve_ilp)
from repro.risk import (RiskEstimators, e_risk, expected_uptime_fraction,
                        interrupt_probability, replay_observations,
                        reweight_candidates, risk_adjustment, survival_curve)
from repro.risk import backtest
from repro.sim import ClusterSim, Scenario, make_policy
from repro.sim.events import InterruptNotice

from ._optional import HAVE_HYPOTHESIS, given, settings, st


def storm_scenario(**overrides) -> Scenario:
    base = dict(name="risk_test_storm", duration_hours=36.0, step_hours=6.0,
                pods=60, cpu_per_pod=2, mem_per_pod=2,
                interrupt_model="pressure", inject_if_idle=True,
                policy="kubepacs_risk:12", catalog_seed=1, max_offerings=150,
                market_seed=1, interrupt_seed=1)
    base.update(overrides)
    return Scenario(**base)


# ------------------------------------------------------------- survival ----

def test_survival_curve_and_limits():
    hazard = np.array([0.0, 0.05, 0.5])
    s = survival_curve(hazard, np.array([0.0, 1.0, 10.0]))
    assert s.shape == (3, 3)
    assert np.allclose(s[:, 0], 1.0)            # S(0) = 1
    assert np.allclose(s[0], 1.0)               # λ=0 never dies
    assert np.all(np.diff(s[1:], axis=1) < 0)   # strictly decreasing in h

    assert np.all(interrupt_probability(hazard, 0.0) == 0.0)
    assert np.all(expected_uptime_fraction(hazard, 0.0) == 1.0)
    u = expected_uptime_fraction(hazard, 24.0)
    assert u[0] == 1.0
    assert np.all((u > 0) & (u <= 1.0)) and u[1] > u[2]
    # closed form: U = (1 − e^{−λH})/(λH)
    assert u[2] == pytest.approx((1 - np.exp(-0.5 * 24)) / (0.5 * 24))


# ----------------------------------------------- estimator convergence ----
# Each property is a plain checker exercised two ways: always on a fixed
# parameter grid (the deterministic suite), and — when hypothesis is
# installed — under randomized @given search over the whole range.

def _check_hazard_convergence(lam: float) -> None:
    """On a stationary expected-count event stream the discounted-ratio
    estimator converges to the true hazard (prior mass decays away)."""
    catalog = generate_catalog(seed=3, max_offerings=10)
    est = RiskEstimators(catalog)
    oid = catalog[0].offering_id
    count, dt = 25, 1.0
    for k in range(400):
        notices = [InterruptNotice(time=k * dt, offering_id=oid,
                                   count=lam * count * dt)]
        est.on_interrupts(k * dt, dt, {oid: count}, notices)
    hazard = est.hazard()[est.index[oid]]
    assert hazard == pytest.approx(lam, rel=0.05)
    # offerings never exposed stay at their IF-band prior
    other = catalog[1]
    prior = 0.01 + 0.015 * other.interruption_freq
    assert est.hazard()[est.index[other.offering_id]] == \
        pytest.approx(prior, rel=1e-6)


def _check_drift_convergence(drift: float) -> None:
    """A constant-relative-growth price path yields exactly that per-hour
    drift at every step, so the EWMA converges to it."""
    catalog = generate_catalog(seed=3, max_offerings=5)
    est = RiskEstimators(catalog)
    spot = np.array([o.spot_price for o in catalog], dtype=np.float64)
    t3 = np.array([o.t3 for o in catalog])
    for k in range(200):
        est.on_market_state(float(k), spot, t3)
        spot = spot * (1.0 + drift)
    assert np.allclose(est.drift(), drift, atol=5e-4)


@pytest.mark.parametrize("lam", [0.002, 0.02, 0.12])
def test_hazard_estimator_converges_to_true_rate(lam):
    _check_hazard_convergence(lam)


@pytest.mark.parametrize("drift", [-0.04, 0.0, 0.03])
def test_price_drift_estimator_converges(drift):
    _check_drift_convergence(drift)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(lam=st.floats(min_value=1e-3, max_value=0.15))
    def test_hazard_estimator_converges_property(lam):
        _check_hazard_convergence(lam)

    @settings(max_examples=15, deadline=None)
    @given(drift=st.floats(min_value=-0.04, max_value=0.04))
    def test_price_drift_estimator_converges_property(drift):
        _check_drift_convergence(drift)


def test_replay_observations_matches_live_price_state():
    """The offline record walker reproduces the price/drift state a live
    observer built from the same market_state stream."""
    sc = storm_scenario(interrupt_model="none", inject_if_idle=False,
                        duration_hours=18.0)
    catalog = sc.build_catalog()
    sim = ClusterSim(sc, catalog=catalog)
    res = sim.run()
    live = sim.policy.estimators
    offline = replay_observations(RiskEstimators(catalog), res.records)
    assert np.allclose(offline.drift(), live.drift())
    assert offline._last_market_time == live._last_market_time


def test_shortfall_estimator_tracks_grant_rate():
    catalog = generate_catalog(seed=3, max_offerings=5)
    est = RiskEstimators(catalog)
    oid = catalog[0].offering_id
    for k in range(60):
        est.on_fulfillment(float(k), {oid: 10}, {oid: 4})   # 40% granted
    i = est.index[oid]
    assert est.shortfall()[i] == pytest.approx(0.6, abs=0.01)
    # never-requested offerings keep the zero-shortfall prior
    assert est.shortfall()[est.index[catalog[1].offering_id]] == 0.0


# ------------------------------------------------------ E_risk reductions ----

def _items(n=40):
    catalog = generate_catalog(seed=2, max_offerings=200)
    return preprocess(catalog, Request(pods=50, cpu_per_pod=2,
                                       mem_per_pod=2))[:n], catalog


def test_e_risk_identity_at_zero_horizon():
    """horizon → 0: the adjustment is the exact identity, so E_risk of any
    pool equals e_total bitwise."""
    items, catalog = _items()
    est = RiskEstimators(catalog)
    adj = risk_adjustment(items, est, horizon=0.0)
    assert adj.perf.tolist() == [it.perf for it in items]
    assert adj.price.tolist() == [it.spot_price for it in items]
    items_adj, _ = reweight_candidates(items, adj)
    counts = solve_ilp(items, 50, 0.4)
    from repro.core import NodePool, e_total
    pool = NodePool(items=list(items), counts=counts).nonzero()
    assert e_risk(pool, 50, items_adj) == e_total(pool, 50)


def test_e_risk_identity_at_zero_hazard():
    """hazard → 0 (with zero drift and shortfall): identity at any horizon."""
    items, catalog = _items()
    est = RiskEstimators(catalog)
    est._events[:] = 0.0               # force λ = 0 (white-box, prior off)
    adj = risk_adjustment(items, est, horizon=24.0)
    assert adj.perf.tolist() == [it.perf for it in items]
    assert adj.price.tolist() == [it.spot_price for it in items]


def test_e_risk_discounts_high_hazard_and_charges_price():
    items, catalog = _items()
    est = RiskEstimators(catalog)
    oid = items[0].offering.offering_id
    for k in range(20):                # hammer item 0 with interrupts
        est.on_interrupts(float(k), 1.0, {oid: 5},
                          [InterruptNotice(time=float(k), offering_id=oid,
                                           count=3)])
    adj = risk_adjustment(items, est, horizon=12.0)
    assert adj.perf[0] < items[0].perf          # uptime discount
    assert adj.price[0] > items[0].spot_price   # re-provision charge
    assert adj.hazard[0] > adj.hazard[1]


def test_reweight_market_matches_fresh_compile():
    """The O(n) reweighted CompiledMarket solves identically to compiling
    the adjusted items from scratch (bundle structure is objective-free)."""
    items, catalog = _items(60)
    est = RiskEstimators(catalog)
    adj = risk_adjustment(items, est, horizon=24.0)
    market = compile_market(items)
    items_adj = reweight_items(items, adj.perf, adj.price)
    fast = reweight_market(market, adj.perf, adj.price, items=items_adj)
    fresh = compile_market(items_adj)
    assert np.allclose(fast.perf_norm, fresh.perf_norm)
    assert np.allclose(fast.price_norm, fresh.price_norm)
    assert fast.b_pods.tolist() == fresh.b_pods.tolist()
    for alpha in (0.0, 0.3, 0.9):
        assert solve_ilp(items_adj, 120, alpha, market=fast) == \
            solve_ilp(items_adj, 120, alpha, market=fresh)


def test_reweight_market_validates_inputs():
    items, _ = _items(10)
    market = compile_market(items)
    with pytest.raises(ValueError, match="entries"):
        reweight_market(market, np.ones(3), np.ones(3))
    with pytest.raises(ValueError, match="positive"):
        reweight_market(market, np.ones(10), np.zeros(10))


# ------------------------------------------------- policy & determinism ----

def test_make_policy_risk_specs():
    assert make_policy("kubepacs_risk").horizon == 12.0
    p = make_policy("kubepacs_risk:36")
    assert p.horizon == 36.0 and p.name == "kubepacs_risk:36"
    with pytest.raises(ValueError):
        make_policy("kubepacs_risky")


def test_risk_policy_same_seed_byte_identical_and_replays():
    sc = storm_scenario()
    a = ClusterSim(sc).run()
    b = ClusterSim(sc).run()
    assert a.recorder.dumps() == b.recorder.dumps()
    replayed = ClusterSim.replay(a.records).run()
    assert replayed.decision_records() == a.decision_records()
    assert replayed.recorder.dumps() == a.recorder.dumps()
    assert any("e_risk" in r["metrics"] for r in a.decision_records())


def test_risk_policy_replay_needs_no_rng(monkeypatch):
    sc = storm_scenario()
    catalog = sc.build_catalog()
    live = ClusterSim(sc, catalog=catalog).run()

    def boom(*a, **k):
        raise AssertionError("replay consumed RNG")
    monkeypatch.setattr(np.random, "default_rng", boom)
    replayed = ClusterSim.replay(live.records, catalog=catalog).run()
    assert replayed.decision_records() == live.decision_records()


def test_risk_policy_estimators_follow_event_stream():
    sc = storm_scenario()
    sim = ClusterSim(sc)
    res = sim.run()
    est = sim.policy.estimators
    assert est is not None
    assert est._last_market_time == sc.duration_hours
    # the storm's interrupts (incl. injected ones) raised someone's hazard
    # above the cold-start prior
    assert np.any(est.hazard() > est._hazard_prior + 1e-9)
    assert res.interrupted_nodes > 0


def test_injectable_clock_full_decision_equality():
    """With a deterministic clock, two identical runs agree on the *entire*
    ProvisioningDecision — wall_seconds and GSS trace included — for every
    policy family (the wall stamp is diagnostic, not decision content)."""
    def fake_clock_factory():
        state = {"t": 0.0}

        def clock():
            state["t"] += 1.0
            return state["t"]
        return clock

    for policy in ("kubepacs", "kubepacs_risk:12", "fixed_alpha:0.5"):
        sc = storm_scenario(policy=policy, duration_hours=12.0)
        a = ClusterSim(sc, clock=fake_clock_factory()).run()
        b = ClusterSim(sc, clock=fake_clock_factory()).run()
        assert a.decisions == b.decisions      # full dataclass equality
        assert all(d.wall_seconds > 0 for _, d in a.decisions)


def test_run_replicas_supports_risk_policy():
    from repro.sim import run_replicas
    sc = storm_scenario(duration_hours=18.0)
    single = ClusterSim(sc).run()
    replicas = run_replicas(sc, [1, 2])
    assert replicas[0].decision_records() == single.decision_records()


# ------------------------------------------------------------- backtest ----

def test_engine_accrues_useful_perf_hours():
    """Useful work = perf_rate × min(1, req/alloc): over-provisioned pods
    earn nothing, so per-hour useful-ppd equals E_Total exactly."""
    sc = storm_scenario(interrupt_model="none", inject_if_idle=False,
                        duration_hours=12.0)
    res = ClusterSim(sc).run()
    pool = dict(res.decisions)["initial"].pool
    scale = min(1.0, sc.pods / pool.total_pods)
    assert res.total_perf_hours == pytest.approx(
        12.0 * pool.perf_rate * scale)
    assert res.lost_perf_total == 0.0
    # and the per-hour useful ppd is E_Total of the standing pool
    from repro.core import e_total
    assert res.total_perf_hours / res.total_cost == \
        pytest.approx(e_total(pool, sc.pods))


def test_interrupts_charge_half_tick_of_useful_work():
    """One 6 h tick ending in a fault-injected loss: delivered work is the
    pool's full-interval useful rate minus half a tick of the reclaimed
    rate (the expected mid-interval reclaim instant)."""
    sc = storm_scenario(duration_hours=6.0, interrupt_model="none",
                        inject_if_idle=True)
    res = ClusterSim(sc).run()
    pool = dict(res.decisions)["initial"].pool
    scale = min(1.0, sc.pods / pool.total_pods)
    rd = res.rounds[0]
    assert rd.lost_nodes > 0 and rd.lost_perf > 0
    assert res.total_perf_hours == pytest.approx(
        (6.0 * pool.perf_rate - 0.5 * 6.0 * rd.lost_perf) * scale)


def test_calibration_report_scores_forecast():
    sc = backtest.interrupt_storm_scenario(duration_hours=24.0,
                                           max_offerings=120)
    res = ClusterSim(sc).run()
    rep = backtest.calibration_report(res.records)
    assert rep["ticks"] == 4
    assert rep["allocations_scored"] > 0
    assert 0.0 <= rep["brier"] <= 1.0
    assert rep["predicted_interrupted_nodes"] >= 0.0
    # realized = every node named by a sampled notice (advisory included)
    assert rep["realized_interrupted_nodes"] == sum(
        n.count for rd in res.rounds for n in rd.notices)


def test_backtest_storm_risk_beats_static():
    """Acceptance: on the interrupt-storm scenario kubepacs_risk ≥ kubepacs
    on perf-per-dollar net of interruption losses (deterministic: crossing
    interrupts draw no RNG, so this is a stable comparison, not a coin
    flip)."""
    out = backtest.compare_policies(backtest.interrupt_storm_scenario(),
                                    policies=("kubepacs",
                                              "kubepacs_risk:12"),
                                    seeds=(0,))
    static = out["summary"]["kubepacs"]["mean_net_ppd"]
    risk = out["summary"]["kubepacs_risk:12"]["mean_net_ppd"]
    assert risk >= static
    assert out["summary"]["kubepacs_risk:12"]["mean_interrupted_nodes"] <= \
        out["summary"]["kubepacs"]["mean_interrupted_nodes"]
