"""Optimizer, checkpointing, data pipeline, elastic runtime integration."""

import dataclasses
import os
import tempfile

import numpy as np
import pytest
jax = pytest.importorskip("jax")  # jax-native module: skip wholesale without jax
import jax.numpy as jnp

from repro import optim
from repro.configs import get_config, SHAPES
from repro.core import Request, SpotMarketSimulator, generate_catalog
from repro.data.pipeline import DataConfig, batch_specs, make_batch
from repro.models import init_params
from repro.runtime import ElasticConfig, ElasticSpotTrainer
from repro.train import checkpoint as ckpt
from repro.train.loop import make_train_step


# ---------------------------------------------------------------- optim ----

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = optim.init_opt_state(params)
    cfg = optim.OptConfig(lr=0.2, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, clip_norm=10.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = optim.adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clipping():
    params = {"w": jnp.ones((4,))}
    state = optim.init_opt_state(params)
    cfg = optim.OptConfig(clip_norm=1.0, warmup_steps=0)
    _, _, m = optim.adamw_update(params, {"w": jnp.full((4,), 100.0)},
                                 state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_schedule_shape():
    cfg = optim.OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(optim.schedule(jnp.asarray(s), cfg)) for s in range(100)]
    assert lrs[0] < lrs[9] <= cfg.lr * 1.0001          # warmup rises
    assert max(lrs) <= cfg.lr * 1.0001
    assert lrs[-1] >= cfg.lr * cfg.min_lr_ratio * 0.99  # cosine floor


# ----------------------------------------------------------- checkpoint ----

def test_checkpoint_roundtrip_and_retention():
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = optim.init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3, 4, 5):
            ckpt.save_checkpoint(d, step, params, opt_state, keep=2)
        assert ckpt.latest_step(d) == 5
        assert len([n for n in os.listdir(d) if n.startswith("step_")]) == 2
        p2, o2, meta = ckpt.restore_checkpoint(d, params, opt_state)
        assert meta["step"] == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not any(n.startswith(".tmp") for n in os.listdir(d))


def test_checkpoint_no_partial_publish():
    with tempfile.TemporaryDirectory() as d:
        assert ckpt.latest_step(d) is None
        with pytest.raises(FileNotFoundError):
            ckpt.restore_checkpoint(d, {})


# ------------------------------------------------------------------ data ----

def test_data_determinism_and_resume():
    cfg = get_config("internlm2-1.8b", smoke=True)
    dcfg = DataConfig(seed=11)
    a = make_batch(cfg, dcfg, step=7, shard=2, world=4, batch=4, seq=32)
    b = make_batch(cfg, dcfg, step=7, shard=2, world=4, batch=4, seq=32)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])   # resumable
    c = make_batch(cfg, dcfg, step=8, shard=2, world=4, batch=4, seq=32)
    assert not np.array_equal(a["tokens"], c["tokens"])
    d = make_batch(cfg, dcfg, step=7, shard=3, world=4, batch=4, seq=32)
    assert not np.array_equal(a["tokens"], d["tokens"])       # shard-disjoint
    assert a["targets"].shape == a["tokens"].shape
    np.testing.assert_array_equal(a["targets"][:, :-1], a["tokens"][:, 1:])


@pytest.mark.parametrize("arch", ["musicgen-large", "internvl2-1b",
                                  "qwen2.5-14b"])
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k"])
def test_batch_specs_structure(arch, shape_name):
    """Dry-run stand-ins mirror the runtime batch structure."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs = batch_specs(cfg, shape)
    smoke = get_config(arch, smoke=True)
    if shape.kind != "decode":
        runtime = make_batch(smoke, DataConfig(), step=0, batch=2,
                             seq=64 if smoke.input_mode != "vlm" else 64)
        assert set(specs) == set(runtime)
    for v in specs.values():
        assert 0 not in v.shape


# --------------------------------------------------------------- elastic ----

def test_elastic_trainer_survives_interrupts():
    cfg = get_config("internlm2-1.8b", smoke=True)
    market = SpotMarketSimulator(generate_catalog(seed=3, max_offerings=300),
                                 seed=3)
    req = Request(pods=40, cpu_per_pod=2, mem_per_pod=4)
    with tempfile.TemporaryDirectory() as d:
        tr = ElasticSpotTrainer(cfg, req, market, d, ElasticConfig(
            total_steps=24, ckpt_every=6, market_check_every=3,
            market_hours_per_check=8.0, batch_rows=4, seq_len=64))
        out = tr.run()
    assert out["steps"] == 24
    assert np.isfinite(out["losses"]).all()
    assert np.mean(out["losses"][-6:]) < np.mean(out["losses"][:6])
    # pool recovered to cover the request after every event
    assert tr.pool.total_pods >= req.pods
    if out["interrupts_handled"]:
        assert out["recovery_times"] and max(out["recovery_times"]) < 30


def test_elastic_trainer_restart_resumes():
    cfg = get_config("internlm2-1.8b", smoke=True)
    req = Request(pods=20, cpu_per_pod=2, mem_per_pod=4)
    with tempfile.TemporaryDirectory() as d:
        market = SpotMarketSimulator(
            generate_catalog(seed=4, max_offerings=200), seed=4)
        tr1 = ElasticSpotTrainer(cfg, req, market, d, ElasticConfig(
            total_steps=10, ckpt_every=5, market_check_every=100,
            batch_rows=2, seq_len=32))
        tr1.run()
        # process "dies"; a fresh trainer on the same dir resumes at step 10
        tr2 = ElasticSpotTrainer(cfg, req, market, d, ElasticConfig(
            total_steps=14, ckpt_every=5, market_check_every=100,
            batch_rows=2, seq_len=32))
        out = tr2.run()
        assert any(e["event"] == "resume" and e["detail"]["from"] == 10
                   for e in out["events"])
        assert out["steps"] == 14


# ----------------------------------------------------------- train step ----

def test_train_step_improves_loss():
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = optim.init_opt_state(params)
    step = make_train_step(cfg, optim.OptConfig(lr=3e-3, warmup_steps=2,
                                                total_steps=100),
                           donate=False)
    dcfg = DataConfig(seed=0)
    losses = []
    for s in range(20):
        batch = make_batch(cfg, dcfg, step=s, batch=4, seq=64)
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
