"""RegionPlane: correlated regional markets + cross-region failover
(DESIGN.md §17).

Covers the PR-10 acceptance surface: shock draws are pure functions of
``(seed, region, t)`` so the §9 determinism contract holds verbatim with
correlation active (byte-identical traces, RNG-free replay, fleet ≡
standalone — proven under the full regional storm), single-region and
identity-config inertness hold bit-exactly, the hazard regime and egress
accounting agree between the standalone and fleet engines, the region
side-constraints (caps / min-spread / egress reweight) wrap the solver
without changing the unconstrained solve, and the hardened policy's
failover rung engages only when region faults are declared.
"""

import dataclasses
import json
import math

import numpy as np
import pytest

from repro.chaos import region_storm
from repro.chaos.guard import GuardConfig, HardenedPolicy, decision_available, \
    quarantine_mask
from repro.core import CandidateItem, Offering
from repro.core.gss import bracketed_gss
from repro.region import RegionConfig, region_pool_shares
from repro.region.market import (RegionalMarketOverlay, apply_hazard_scale,
                                 make_overlay, region_shock,
                                 regional_price_factors)
from repro.region.solver import solve_with_regions
from repro.sim import (ClusterSim, Scenario, loads_trace, run_fleet,
                       run_fleet_paths, run_replicas)

from tests._optional import given, settings, st

HOME = "us-east-1"
REGIONS = ("us-east-1", "us-west-2", "eu-west-1")


def region_scenario(policy="hardened", *, storm=True, shock_seed=11,
                    **overrides):
    """A compact 24 h / 3 h-step regional storm (the ``bench_region``
    shape scaled down): ``region_storm`` at scale 0.5 lands every window
    inside the horizon."""
    base = dict(
        name="region_test", duration_hours=24.0, step_hours=3.0, pods=60,
        demand_schedule=((6.0, 110), (12.0, 70)),
        interrupt_model="pressure", policy=policy,
        catalog_seed=7, max_offerings=80, market_seed=7, interrupt_seed=7,
        region=RegionConfig(regions=REGIONS, rho=0.7, vol=0.25,
                            shock_seed=shock_seed, home_region=HOME,
                            egress_per_pod_hour=0.002),
        faults=region_storm(HOME, 0.5) if storm else ())
    base.update(overrides)
    return Scenario(**base)


def strip_region_header(trace: str) -> str:
    """Normalize a trace header for inertness comparisons: the scenario
    dict's region/name/policy fields are declared config, not behavior."""
    lines = trace.splitlines()
    head = json.loads(lines[0])
    head["scenario"]["region"] = None
    head["scenario"]["name"] = ""
    head["scenario"]["policy"] = ""
    lines[0] = json.dumps(head, sort_keys=True)
    return "\n".join(lines)


def mk_ritem(i, region, sp, pods=4, bs=1e4, t3=10):
    """A synthetic candidate pinned to a region tag."""
    o = Offering(offering_id=f"t{i}@{region}", instance_type=f"t{i}",
                 family="m", generation=6, vendor="i",
                 specialization="general", size="large", region=region,
                 az=f"{region}a", vcpus=2, mem_gib=8.0, od_price=sp * 3,
                 spot_price=sp, bs_core=bs, sps_single=3, t3=t3,
                 interruption_freq=1)
    return CandidateItem(offering=o, pods=pods, bs=bs, spot_price=sp, t3=t3)


def region_items(per_region=4, base_sp=0.5, spread=0.1):
    """``per_region`` items in each of the three regions; the home region
    is cheapest (ascending ``spread`` per region index)."""
    items = []
    for r_i, region in enumerate(REGIONS):
        for j in range(per_region):
            items.append(mk_ritem(r_i * per_region + j, region,
                                  sp=base_sp + spread * r_i + 0.01 * j))
    return items


# -------------------------------------------------- coordinate-pure RNG ----

def test_region_shock_is_a_pure_function_of_coordinates():
    a = region_shock(11, "us-east-1", 6.0)
    assert a == region_shock(11, "us-east-1", 6.0)
    # draws never come from a consumed stream: interleaving other draws
    # cannot move them
    region_shock(11, "us-west-2", 6.0)
    region_shock(12, "us-east-1", 9.0)
    assert a == region_shock(11, "us-east-1", 6.0)
    # each coordinate axis matters
    assert a != region_shock(12, "us-east-1", 6.0)
    assert a != region_shock(11, "us-west-2", 6.0)
    assert a != region_shock(11, "us-east-1", 6.25)
    # the time coordinate is second-exact: sub-second float noise rounds
    # onto the same draw
    assert a == region_shock(11, "us-east-1", 6.0 + 1e-7)


def test_regional_price_factors_correlation_structure():
    cfg = dataclasses.replace(RegionConfig(regions=REGIONS), vol=0.25)
    # rho = 1: only the shared factor survives — every region moves
    # together, bit-exactly (the dangerous correlated regime)
    f1 = regional_price_factors(dataclasses.replace(cfg, rho=1.0),
                                REGIONS, 6.0)
    assert len(set(f1.values())) == 1
    # rho = 0: purely idiosyncratic — regions decouple
    f0 = regional_price_factors(dataclasses.replace(cfg, rho=0.0),
                                REGIONS, 6.0)
    assert len(set(f0.values())) == len(REGIONS)
    # vol = 0 is the identity, no draws at all
    assert regional_price_factors(dataclasses.replace(cfg, vol=0.0),
                                  REGIONS, 6.0) \
        == {r: 1.0 for r in REGIONS}
    # purity: the factor map is reproducible from coordinates alone
    assert f0 == regional_price_factors(dataclasses.replace(cfg, rho=0.0),
                                        REGIONS, 6.0)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 32 - 1), st.sampled_from(REGIONS),
       st.integers(0, 400), st.floats(0.0, 1.0, allow_nan=False))
def test_region_shock_purity_property(seed, tag, quarter_hours, rho):
    """Purity and correlation-structure properties over random
    coordinates (time on a quarter-hour grid so the second-exact time
    coordinate is unambiguous)."""
    t = quarter_hours * 0.25
    z = region_shock(seed, tag, t)
    assert math.isfinite(z)
    assert z == region_shock(seed, tag, t)
    cfg = RegionConfig(regions=REGIONS, rho=rho, vol=0.25, shock_seed=seed)
    f = regional_price_factors(cfg, REGIONS, t)
    assert f == regional_price_factors(cfg, REGIONS, t)
    assert all(v > 0.0 and math.isfinite(v) for v in f.values())
    if rho == 1.0:
        assert len(set(f.values())) == 1


def test_region_shock_purity_property_deterministic():
    """Seeded twin of the hypothesis property above."""
    rng = np.random.default_rng(41)
    for _ in range(40):
        seed = int(rng.integers(0, 2 ** 32))
        tag = REGIONS[int(rng.integers(0, len(REGIONS)))]
        t = int(rng.integers(0, 401)) * 0.25
        rho = float(rng.uniform(0.0, 1.0))
        z = region_shock(seed, tag, t)
        assert math.isfinite(z)
        assert z == region_shock(seed, tag, t)
        cfg = RegionConfig(regions=REGIONS, rho=rho, vol=0.25,
                           shock_seed=seed)
        f = regional_price_factors(cfg, REGIONS, t)
        assert f == regional_price_factors(cfg, REGIONS, t)
        assert all(v > 0.0 and math.isfinite(v) for v in f.values())


# ------------------------------------------------------- market overlay ----

def test_overlay_inert_case_returns_inputs_by_reference():
    items = region_items()
    catalog = [it.offering for it in items]
    ov = RegionalMarketOverlay(RegionConfig(regions=REGIONS), catalog)
    spot = np.array([o.spot_price for o in catalog])
    t3 = np.array([o.t3 for o in catalog])
    spot2, t32 = ov.apply(spot, t3, 6.0)
    assert spot2 is spot and t32 is t3     # the engine identity checks
    # and no overlay is built at all for a region-free scenario
    assert make_overlay(None, catalog, ()) is None
    assert make_overlay(RegionConfig(), catalog, ()) is not None


def test_overlay_brownout_thins_and_spikes_outage_blacks_out():
    items = region_items()
    catalog = [it.offering for it in items]
    faults = region_storm(HOME)            # brownout @6, outage @18
    ov = make_overlay(RegionConfig(regions=REGIONS, vol=0.0), catalog,
                      faults)
    spot = np.array([o.spot_price for o in catalog], dtype=np.float64)
    t3 = np.array([o.t3 for o in catalog])
    home = np.array([o.region == HOME for o in catalog])

    bs, bt3 = ov.apply(spot, t3, 6.0)      # brownout window
    assert (bt3[home] == np.floor(t3[home] * 0.4)).all()   # mag 0.6 thins
    od = np.array([o.od_price for o in catalog])
    assert (bs[home] == np.minimum(spot[home] * 1.6, od[home])).all()
    assert (bt3[~home] == t3[~home]).all() and (bs[~home]
                                                == spot[~home]).all()

    os_, ot3 = ov.apply(spot, t3, 18.0)    # outage window: region dark
    assert (ot3[home] == 0).all()
    assert (ot3[~home] == t3[~home]).all() and (os_[~home]
                                                == spot[~home]).all()
    # partition is observed-side (ChaosController): the TRUE overlay
    # leaves the world untouched in its window
    ps, pt3 = ov.apply(spot, t3, 33.0)
    assert ps is spot and pt3 is t3


# -------------------------------------------- determinism under regions ----

@pytest.mark.parametrize("policy", ["kubepacs", "hardened"])
def test_same_seed_byte_identical_trace_with_regions(policy):
    sc = region_scenario(policy)
    a = ClusterSim(sc, clock=lambda: 0.0).run()
    b = ClusterSim(sc, clock=lambda: 0.0).run()
    assert a.recorder.dumps() == b.recorder.dumps()


@pytest.mark.parametrize("policy", ["kubepacs", "hardened"])
def test_replay_rng_free_with_regions(policy):
    live = ClusterSim(region_scenario(policy), clock=lambda: 0.0).run()
    rep = ClusterSim.replay(loads_trace(live.recorder.dumps())).run()
    assert rep.recorder.dumps() == live.recorder.dumps()


def test_fleet_matches_standalone_with_regions():
    sc = region_scenario("hardened")
    seeds = [0, 1]
    fleet = run_fleet(sc, seeds, record_traces=True, clock=lambda: 0.0)
    per_seed = run_replicas(sc, seeds)
    for f, s in zip(fleet, per_seed):
        assert f.recorder.dumps() == s.recorder.dumps()
        assert f.total_egress == s.total_egress


def test_run_fleet_paths_sweeps_the_shock_seed():
    sc = region_scenario("kubepacs", storm=False)
    paths = run_fleet_paths(sc, [11, 23], [7], record_traces=True,
                            clock=lambda: 0.0)
    assert len(paths) == 2 and all(len(p) == 1 for p in paths)
    # different correlated market paths: different behavior...
    assert paths[0][0].recorder.dumps() != paths[1][0].recorder.dumps()
    # ...and each path is exactly run_fleet at that shock seed
    sc23 = dataclasses.replace(sc, region=dataclasses.replace(
        sc.region, shock_seed=23))
    direct = run_fleet(sc23, [7], record_traces=True, clock=lambda: 0.0)
    assert paths[1][0].recorder.dumps() == direct[0].recorder.dumps()
    with pytest.raises(ValueError):
        run_fleet_paths(dataclasses.replace(sc, region=None), [11], [7])


# ----------------------------------------------------------- inertness ----

def test_single_region_scenario_is_byte_inert():
    """K=1 RegionalCatalog ≡ the region-free scenario over the identical
    restricted catalog — every byte but the declared config header."""
    plain = region_scenario("kubepacs", storm=False, region=None,
                            name="plain")
    k1 = dataclasses.replace(plain,
                             region=RegionConfig(regions=(HOME,)))
    cat = k1.build_catalog()
    rk1 = ClusterSim(k1, clock=lambda: 0.0).run()
    rpl = ClusterSim(plain, catalog=cat, clock=lambda: 0.0).run()
    assert strip_region_header(rk1.recorder.dumps()) \
        == strip_region_header(rpl.recorder.dumps())
    assert rk1.total_egress == 0.0


def test_identity_region_config_is_byte_inert():
    """A solver-inert, price-inert, hazard-inert RegionConfig changes
    nothing: the failover rung is bit-inert when no region faults are
    declared (here: no faults at all), per the §17 contract."""
    bare = region_scenario("hardened", storm=False, region=None)
    ident = dataclasses.replace(
        bare, region=RegionConfig(regions=REGIONS,
                                  hazard_scale=((HOME, 1.0),)))
    cat = ident.build_catalog()
    rid = ClusterSim(ident, clock=lambda: 0.0).run()
    rbare = ClusterSim(bare, catalog=cat, clock=lambda: 0.0).run()
    assert strip_region_header(rid.recorder.dumps()) \
        == strip_region_header(rbare.recorder.dumps())
    assert not any(k.startswith("chaos_region")
                   for k in rid.cache_stats)


# ------------------------------------------------------- hazard regime ----

def test_apply_hazard_scale_law():
    p = np.array([0.0, 0.1, 0.5, 1.0])
    # scale 1 is the identity law; 2 compounds two independent trials
    assert np.allclose(apply_hazard_scale(p, np.ones(4)), p)
    assert np.allclose(apply_hazard_scale(p, np.full(4, 2.0)),
                       1.0 - (1.0 - p) ** 2)
    # scale 0 turns hazard off entirely
    assert (apply_hazard_scale(p, np.zeros(4)) == 0.0).all()


def test_hazard_scale_fleet_matches_standalone():
    """The per-region hazard regime must be applied identically by the
    standalone model (per-entry gather) and the fleet engine's batched
    matrix path — bitwise, via the one shared law."""
    sc = region_scenario(
        "kubepacs", storm=False,
        region=RegionConfig(regions=REGIONS,
                            hazard_scale=((HOME, 3.0),
                                          ("us-west-2", 0.5))))
    seeds = [0, 1]
    fleet = run_fleet(sc, seeds, record_traces=True, clock=lambda: 0.0)
    per_seed = run_replicas(sc, seeds)
    for f, s in zip(fleet, per_seed):
        assert f.recorder.dumps() == s.recorder.dumps()


# ---------------------------------------------------- egress accounting ----

def test_egress_accrues_into_billing_and_gates_on_its_knob():
    sc = region_scenario("kubepacs", storm=False)
    res = ClusterSim(sc, clock=lambda: 0.0).run()
    assert res.total_egress > 0.0
    assert res.total_cost > res.total_egress
    off = dataclasses.replace(sc, region=dataclasses.replace(
        sc.region, egress_per_pod_hour=0.0))
    assert ClusterSim(off, clock=lambda: 0.0).run().total_egress == 0.0


# ------------------------------------------------- region side-solves ----

def test_solver_inert_config_is_exactly_bracketed_gss():
    items = region_items()
    pool, _, info = solve_with_regions(items, 40, RegionConfig())
    ref, _ = bracketed_gss(items, 40, 0.01)
    assert pool.as_dict() == ref.as_dict()
    assert info == {"cap_repairs": 0, "spread_forced": 0,
                    "egress_reweighted": False}


def test_caps_trim_and_resolve_into_survivors():
    items = region_items()                 # home region strictly cheapest
    cfg = RegionConfig(regions=REGIONS, home_region=HOME,
                       caps=((HOME, 2),))
    pool, _, info = solve_with_regions(items, 40, cfg)
    shares = region_pool_shares(pool)
    assert shares.get(HOME, 0) <= 2
    assert pool.total_pods >= 40           # residual re-solved elsewhere
    assert info["cap_repairs"] >= 1


def test_min_spread_forces_n_plus_one_redundancy():
    items = region_items()
    pool, _, info = solve_with_regions(
        items, 40, RegionConfig(regions=REGIONS, min_spread=3))
    assert len(region_pool_shares(pool)) >= 3
    assert info["spread_forced"] >= 1


def test_egress_reweight_prefers_home_but_bills_true_prices():
    # identical spot everywhere: only data gravity separates the regions
    items = [mk_ritem(i, r, sp=0.5) for i, r in
             ((0, HOME), (1, "us-west-2"), (2, "eu-west-1"))]
    cfg = RegionConfig(regions=REGIONS, home_region=HOME,
                       egress_per_pod_hour=0.1)
    pool, _, info = solve_with_regions(items, 4, cfg)
    assert info["egress_reweighted"]
    assert set(region_pool_shares(pool)) == {HOME}
    # counts map back onto TRUE-priced items (the reweight never leaks
    # into billing)
    assert all(it.spot_price == 0.5 for it in pool.items)


# ------------------------------------------------- failover + learned band -

def test_failover_rung_fires_only_under_region_faults():
    res = ClusterSim(region_scenario("hardened"), clock=lambda: 0.0).run()
    assert res.cache_stats.get("chaos_region_failover", 0) > 0
    assert all(decision_available(d) for _, d in res.decisions)
    # failover decisions sit above the ladder (rung -1) and carry the
    # quarantined-region count
    failover = [d for _, d in res.decisions
                if d.metrics.get("chaos_rung") == -1.0]
    assert failover
    assert all(d.metrics["chaos_region_failover"] >= 1.0 for d in failover)


def test_hazard_quarantine_band_defaults_off():
    items = region_items()
    hazard = np.full(len(items), 0.9)
    # rate 0 (the default): the learned band is bit-inert — the mask is
    # exactly the fixed-bands mask however hot the estimate runs
    assert quarantine_mask(items, GuardConfig(), hazard=hazard) is None
    # enabled: rows whose estimated rate exceeds the band join the mask
    cfg = GuardConfig(hazard_quarantine_rate=0.5)
    mask = quarantine_mask(items, cfg, hazard=hazard)
    assert mask is not None and mask.all()
    hazard[0] = 0.1
    assert not quarantine_mask(items, cfg, hazard=hazard)[0]


def test_hazard_band_estimators_gate_on_the_knob():
    catalog = [it.offering for it in region_items()]
    off = HardenedPolicy(clock=lambda: 0.0)
    off.bind(catalog)
    assert off.estimators is None          # default: fixed bands only
    on = HardenedPolicy(clock=lambda: 0.0,
                        config=GuardConfig(hazard_quarantine_rate=0.2))
    on.bind(catalog)
    assert on.estimators is not None
    # the learned band joins the decision identity: memo keys must not
    # collide across estimator states (None stays None pre-chaos)
    assert off.memo_digest() is None
    assert on.memo_digest() is not None
