"""Scenario engine: determinism/replay contract, interrupt-model semantics,
multi-seed sharing, and scenario-derived benchmark consistency (DESIGN.md §9)."""

import dataclasses

import numpy as np
import pytest

from repro.core import Request, generate_catalog
from repro.sim import (ClusterSim, PriceCrossingInterruptModel,
                       RebalanceRecommendationModel, Scenario, Shock,
                       loads_trace, make_interrupt_model, run_replicas)


def storm_scenario(**overrides) -> Scenario:
    """A 6-round interrupt storm small enough for unit tests."""
    base = dict(name="test_storm", duration_hours=36.0, step_hours=6.0,
                pods=60, cpu_per_pod=2, mem_per_pod=2,
                interrupt_model="pressure", inject_if_idle=True,
                policy="kubepacs", catalog_seed=1, max_offerings=150,
                market_seed=1, interrupt_seed=1)
    base.update(overrides)
    return Scenario(**base)


# ---------------------------------------------------------------- traces ----

def test_same_seed_byte_identical_trace():
    sc = storm_scenario()
    a = ClusterSim(sc).run().recorder.dumps()
    b = ClusterSim(sc).run().recorder.dumps()
    assert a == b


def test_different_interrupt_seed_changes_trace_not_market():
    sc = storm_scenario()
    a = ClusterSim(sc).run()
    b = ClusterSim(dataclasses.replace(sc, interrupt_seed=99)).run()
    assert a.recorder.dumps() != b.recorder.dumps()
    # market evolution is independently seeded: identical price records
    states_a = [r for r in a.records if r["type"] == "market_state"]
    states_b = [r for r in b.records if r["type"] == "market_state"]
    assert [s["spot"] for s in states_a] == [s["spot"] for s in states_b]


def test_replay_reproduces_decisions_and_costs():
    """Acceptance: a recorded 6-round interrupt scenario replays to an
    identical ProvisioningDecision sequence — and an identical trace."""
    sc = storm_scenario()
    live = ClusterSim(sc).run()
    assert len(live.rounds) == 6
    assert any(rd.effective for rd in live.rounds)

    text = live.recorder.dumps()           # JSONL round trip
    replayed = ClusterSim.replay(loads_trace(text)).run()

    assert replayed.decision_records() == live.decision_records()
    assert [(r, d.pool.as_dict(), d.alpha, d.metrics)
            for r, d in replayed.decisions] == \
           [(r, d.pool.as_dict(), d.alpha, d.metrics)
            for r, d in live.decisions]
    assert replayed.total_cost == live.total_cost
    assert replayed.recorder.dumps() == text


def test_replay_with_fulfillment_and_shocks():
    sc = storm_scenario(apply_fulfillment=True, pods=120,
                        demand_schedule=((15.0, 40),),
                        shocks=(Shock(time=9.0, kind="capacity", factor=0.3),
                                Shock(time=21.0, kind="price", factor=2.0,
                                      selector="us-east-1")))
    live = ClusterSim(sc).run()
    replayed = ClusterSim.replay(live.records).run()
    assert replayed.recorder.dumps() == live.recorder.dumps()


def test_replay_needs_no_rng(monkeypatch):
    """Replaying a trace must never draw randomness: the static catalog is
    rebuilt from its seed up front, after which the run is RNG-free."""
    sc = storm_scenario()
    catalog = sc.build_catalog()
    live = ClusterSim(sc, catalog=catalog).run()

    def boom(*a, **k):
        raise AssertionError("replay consumed RNG")
    monkeypatch.setattr(np.random, "default_rng", boom)
    replayed = ClusterSim.replay(live.records, catalog=catalog).run()
    assert replayed.decision_records() == live.decision_records()


def test_replay_rejects_mismatched_catalog():
    """A trace recorded against an explicit catalog must not be silently
    replayed against the catalog regenerated from the Scenario seeds."""
    sc = storm_scenario(duration_hours=6.0)
    other = generate_catalog(seed=42, max_offerings=sc.max_offerings)
    live = ClusterSim(sc, catalog=other).run()   # catalog ≠ scenario seeds
    with pytest.raises(ValueError, match="catalog mismatch"):
        ClusterSim.replay(live.records)          # would rebuild from seeds
    # passing the recording catalog explicitly replays exactly
    rep = ClusterSim.replay(live.records, catalog=other).run()
    assert rep.recorder.dumps() == live.recorder.dumps()


def test_decision_metrics_schema_uniform_across_policies():
    """Every policy (and the infeasible path) emits the same metric keys;
    kubepacs_risk adds exactly its optimized risk score on top."""
    keys = {"e_total", "e_perf_cost", "e_over_pods", "hourly_cost",
            "nodes", "pods"}
    for policy in ("kubepacs", "karpenter_like", "fixed_alpha:0.5"):
        sc = storm_scenario(duration_hours=0.0, policy=policy)
        res = ClusterSim(sc).run()
        assert set(res.decision_records()[0]["metrics"]) == keys
    sc = storm_scenario(duration_hours=0.0, policy="kubepacs_risk:12")
    res = ClusterSim(sc).run()
    assert set(res.decision_records()[0]["metrics"]) == keys | {"e_risk"}
    # infeasible demand: empty pool, same schema, zero scores
    sc = storm_scenario(duration_hours=0.0, pods=10**7)
    rec = ClusterSim(sc).run().decision_records()[0]
    assert set(rec["metrics"]) == keys
    assert rec["metrics"]["e_total"] == 0.0 and rec["pool"] == {}


def test_scenario_workload_order_normalized():
    a = Scenario(name="w", workload=("network", "disk"))
    b = Scenario(name="w", workload=("disk", "network"))
    assert a == b == Scenario.from_dict(a.to_dict())


def test_integer_schedule_times_replay_byte_identical():
    """Scenario numerics are normalized at construction, so int-typed
    demand/shock times can't break the byte-identity contract."""
    sc = storm_scenario(duration_hours=12, interrupt_model="none",
                        inject_if_idle=False,
                        demand_schedule=((9, 80),),
                        shocks=(Shock(time=6, kind="price", factor=2),))
    res = ClusterSim(sc).run()
    assert ClusterSim.replay(res.records).run().recorder.dumps() == \
        res.recorder.dumps()


def test_run_refuses_after_event_stream_use():
    """Mixing the probe/event-stream API with run() would desynchronize
    the recorded market-state sequence — refused loudly."""
    sc = storm_scenario(duration_hours=6.0)
    sim = ClusterSim(sc)
    sim.current_snapshot()
    with pytest.raises(RuntimeError, match="fresh ClusterSim"):
        sim.run()


def test_t0_shock_visible_to_initial_decision():
    """DESIGN.md §9: a shock is visible to the same instant's decision —
    including the initial provisioning at t=0."""
    base = storm_scenario(duration_hours=6.0, interrupt_model="none",
                          inject_if_idle=False)
    shocked = storm_scenario(duration_hours=6.0, interrupt_model="none",
                             inject_if_idle=False,
                             shocks=(Shock(time=0.0, kind="price",
                                           factor=10.0),))
    cost_base = dict(ClusterSim(base).run().decisions)["initial"] \
        .metrics["hourly_cost"]
    cost_shocked = dict(ClusterSim(shocked).run().decisions)["initial"] \
        .metrics["hourly_cost"]
    assert cost_shocked > cost_base * 2
    # the scripted replica path orders the t=0 shock identically
    r = run_replicas(shocked, [shocked.interrupt_seed])[0]
    assert r.decision_records() == \
        ClusterSim(shocked).run().decision_records()


def test_trace_header_versioned():
    sc = storm_scenario(duration_hours=0.0)
    res = ClusterSim(sc).run()
    header = res.records[0]
    assert header["type"] == "header" and header["version"] == 1
    assert Scenario.from_dict(header["scenario"]) == sc
    bad = [dict(header, version=99)] + res.records[1:]
    with pytest.raises(ValueError):
        ClusterSim.replay(bad)


# ------------------------------------------------------- interrupt models ----

def _snapshot_index(catalog):
    return {o.offering_id: o for o in catalog}


def test_price_crossing_fires_iff_spot_above_bid():
    catalog = generate_catalog(seed=2, max_offerings=20)
    model = PriceCrossingInterruptModel(bid_factor=1.5)
    model.reset(catalog, seed=0)
    over = dataclasses.replace(catalog[0],
                               spot_price=catalog[0].spot_price * 1.6)
    under = dataclasses.replace(catalog[1],
                                spot_price=catalog[1].spot_price * 1.4)
    index = {over.offering_id: over, under.offering_id: under}
    pool = {over.offering_id: 4, under.offering_id: 3}
    notices = model.sample(index, pool, hours=1.0, now=5.0)
    assert [(n.offering_id, n.count, n.reason) for n in notices] == \
        [(over.offering_id, 4, "price-crossing")]


def test_price_crossing_at_bid_does_not_fire():
    catalog = generate_catalog(seed=2, max_offerings=5)
    model = PriceCrossingInterruptModel(bid_factor=1.0)
    model.reset(catalog, seed=0)
    # spot exactly at bid: strictly-greater semantics, no interrupt
    notices = model.sample(_snapshot_index(catalog),
                           {catalog[0].offering_id: 2}, 1.0, 0.0)
    assert notices == []


def test_rebalance_model_stamps_lead_time():
    catalog = generate_catalog(seed=2, max_offerings=10)
    inner = PriceCrossingInterruptModel(bid_factor=0.0)  # always fires
    model = RebalanceRecommendationModel(inner, lead_hours=2.5)
    model.reset(catalog, seed=0)
    notices = model.sample(_snapshot_index(catalog),
                           {catalog[0].offering_id: 3}, 1.0, now=4.0)
    assert len(notices) == 1
    n = notices[0]
    assert n.lead_hours == 2.5 and n.effective_time == 6.5
    assert n.reason.startswith("rebalance-recommendation")


def test_rebalance_lead_time_honored_by_engine():
    """A warning issued at tick t reclaims capacity only at t + lead."""
    sc = storm_scenario(
        interrupt_model="rebalance:6:price_crossing:0.0",  # fire every tick
        inject_if_idle=False, duration_hours=18.0)
    res = ClusterSim(sc).run()
    first = res.rounds[0]
    assert first.notices and not first.effective   # advisory only at t=6
    assert first.lost_nodes == 0
    second = res.rounds[1]                          # matured at t=12
    assert second.effective and second.lost_nodes > 0
    # every reclaimed notice waited out its full lead time
    for rd in res.rounds:
        for n in rd.effective:
            assert n.effective_time <= rd.time + 1e-9
            assert rd.time - n.time >= n.lead_hours - 1e-9


def test_make_interrupt_model_specs():
    assert make_interrupt_model("none").sample({}, {}, 1.0, 0.0) == []
    assert make_interrupt_model("price_crossing:2.5").bid_factor == 2.5
    m = make_interrupt_model("rebalance:4:price_crossing:1.1")
    assert m.lead_hours == 4.0 and m.inner.bid_factor == 1.1
    with pytest.raises(ValueError):
        make_interrupt_model("martian")


def test_pressure_model_matches_simulator_law(small_catalog):
    """Same probability law as the market's built-in sampler: under heavy
    pressure the dedicated-stream model also loses nodes."""
    model = make_interrupt_model("pressure")
    model.reset(small_catalog, seed=3)
    index = _snapshot_index(small_catalog)
    o = max(small_catalog, key=lambda o: o.t3)
    lost = sum(sum(n.count for n in model.sample(index,
                                                 {o.offering_id: o.t3 * 4},
                                                 4.0, 0.0))
               for _ in range(20))
    assert lost > 0


# ----------------------------------------------------------- engine shape ----

def test_demand_scale_up_merges_shortfall():
    sc = storm_scenario(interrupt_model="none", inject_if_idle=False,
                        pods=30, demand_schedule=((15.0, 90),))
    res = ClusterSim(sc).run()
    reasons = [r for r, _ in res.decisions]
    assert reasons[0] == "initial" and "demand" in reasons
    initial = dict(res.decisions)["initial"]
    demand_decision = dict(res.decisions)["demand"]
    # only the shortfall is provisioned; running capacity is kept, not
    # discarded — the merged pool covers the new demand
    assert demand_decision.pool.total_pods < 90
    assert (initial.pool.total_pods + demand_decision.pool.total_pods) >= 90
    assert res.pool.total_pods >= 90


def test_demand_scale_down_keeps_pool():
    sc = storm_scenario(interrupt_model="none", inject_if_idle=False,
                        pods=90, demand_schedule=((15.0, 20),))
    res = ClusterSim(sc).run()
    assert [r for r, _ in res.decisions] == ["initial"]   # no new decision
    initial = dict(res.decisions)["initial"]
    assert res.pool.as_dict() == initial.pool.as_dict()


def test_injection_skipped_when_advisory_matures():
    """Fault injection only fires on genuinely calm rounds: a maturing
    rebalance recommendation counts as this round's interrupt."""
    sc = storm_scenario(
        interrupt_model="rebalance:6:price_crossing:0.0",  # fire every tick
        inject_if_idle=True, duration_hours=18.0)
    res = ClusterSim(sc).run()
    matured_rounds = [rd for rd in res.rounds if rd.effective]
    assert matured_rounds
    for rd in matured_rounds:
        assert all(n.reason != "fault-injection" for n in rd.notices)


def test_lost_pods_use_per_item_capacity():
    """The Fig. 12 bugfix: losses count each item's actual Pod_i."""
    sc = storm_scenario()
    res = ClusterSim(sc).run()
    rounds = [rd for rd in res.rounds if rd.effective]
    assert rounds
    for rd in rounds:
        assert rd.lost_pods >= rd.lost_nodes   # every node hosts ≥ 1 pod
    # at least one loss involves a node hosting != 2 pods (the old hardcode)
    req = Request(pods=sc.pods, cpu_per_pod=sc.cpu_per_pod,
                  mem_per_pod=sc.mem_per_pod)
    assert any(rd.lost_pods != 2 * rd.lost_nodes for rd in rounds), \
        "catalog draw only produced 2-pod nodes; weaken ONLY if seeds change"


def test_kubepacs_policy_excludes_interrupted_offerings():
    sc = storm_scenario()
    res = ClusterSim(sc).run()
    for rd in res.rounds:
        if rd.decision is None or not rd.decision.pool.total_nodes:
            continue
        interrupted = {n.offering_id for n in rd.effective}
        chosen = {it.offering.offering_id for it in rd.decision.pool.items}
        assert not (interrupted & chosen)


def test_partial_final_tick_covers_horizon():
    """A duration that isn't a step multiple ends with a partial tick so
    the whole horizon is simulated and billed."""
    sc = storm_scenario(duration_hours=10.0, interrupt_model="none",
                        inject_if_idle=False)
    res = ClusterSim(sc).run()
    assert [rd.time for rd in res.rounds] == [6.0, 10.0]
    assert res.records[-1]["time"] == 10.0           # summary at horizon
    pool_rate = dict(res.decisions)["initial"].pool.hourly_cost
    assert res.total_cost == pytest.approx(10.0 * pool_rate)
    assert ClusterSim.replay(res.records).run().recorder.dumps() == \
        res.recorder.dumps()


def test_events_beyond_horizon_are_dropped():
    sc = storm_scenario(duration_hours=12.0, interrupt_model="none",
                        inject_if_idle=False,
                        demand_schedule=((20.0, 500),),
                        shocks=(Shock(time=30.0, kind="price", factor=9.0),))
    res = ClusterSim(sc).run()
    assert [r for r, _ in res.decisions] == ["initial"]
    assert res.records[-1]["time"] == 12.0
    assert not any(r["type"] in ("demand", "shock") for r in res.records)


def test_infeasible_replacement_decision_is_recorded():
    """An interrupt re-optimization that finds no feasible replacement
    still appears in the trace, like initial/demand decisions."""
    sc = storm_scenario(pods=40, duration_hours=18.0,
                        interrupt_model="none", inject_if_idle=True,
                        demand_schedule=((7.0, 10**7),))   # impossible demand
    res = ClusterSim(sc).run()
    recs = res.decision_records()
    demand_t = next(r["time"] for r in recs if r["reason"] == "demand")
    # the demand-change attempt and every re-optimization attempt after it
    # are infeasible — and every one of them is in the trace
    assert next(r for r in recs if r["reason"] == "demand")["pool"] == {}
    late_interrupts = [r for r in recs
                       if r["reason"] == "interrupt" and r["time"] > demand_t]
    assert late_interrupts, "injection should force a post-demand interrupt"
    assert all(r["pool"] == {} and r["metrics"]["e_total"] == 0.0
               for r in late_interrupts)
    # survivors were kept despite the infeasible replacement attempts
    assert ClusterSim.replay(res.records).run().recorder.dumps() == \
        res.recorder.dumps()


# ----------------------------------------------------- multi-seed runner ----

def test_run_replicas_matches_standalone_run():
    sc = storm_scenario()
    single = ClusterSim(sc).run()
    replicas = run_replicas(sc, [1, 2, 3])
    assert replicas[0].decision_records() == single.decision_records()
    assert replicas[0].total_cost == single.total_cost
    # different interruption seeds genuinely diverge
    assert any(r.decision_records() != single.decision_records()
               for r in replicas[1:])


def test_run_replicas_rejects_fulfillment_scenarios():
    """Live fulfillment consumes the market price RNG; a scripted shared
    path cannot reproduce it, so the combination is an explicit error."""
    sc = storm_scenario(apply_fulfillment=True)
    with pytest.raises(ValueError, match="apply_fulfillment"):
        run_replicas(sc, [0, 1])


def test_run_replicas_shares_compiled_market():
    sc = storm_scenario(interrupt_model="none", inject_if_idle=False,
                        duration_hours=12.0)
    replicas = run_replicas(sc, [0, 1, 2, 3])
    assert len(replicas) == 4
    # no interrupts -> identical decisions across replicas (pure sharing)
    first = replicas[0].decision_records()
    for r in replicas[1:]:
        assert r.decision_records() == first


# --------------------------------------- scenario-derived fig benchmarks ----

def test_fig9_via_engine_matches_direct_simulator():
    """The engine's fulfillment probes reproduce the pre-refactor driver,
    which called SpotMarketSimulator.fulfill directly."""
    from benchmarks import fig9_t3_fulfillment
    from repro.core import SpotMarketSimulator

    cat = generate_catalog(seed=0, max_offerings=400)
    out = fig9_t3_fulfillment.run(cat)
    assert out["monotone"]
    assert out["trace_records"] > 1

    sim = SpotMarketSimulator(cat, seed=0)
    snap = sim.snapshot()
    lo, hi = 0, 5
    offers = [o for o in snap if lo <= o.t3 < hi][:40]
    expect = float(np.mean([sim.fulfill(o.offering_id, 50)
                            for o in offers]))
    assert out["rows"][0]["mean_fulfilled"] == expect


def test_fig12_via_engine(small_catalog):
    from benchmarks import fig12_interrupts
    out = fig12_interrupts.run(small_catalog, rounds=3)
    assert out["recovery_s_ours"] < out["recovery_s_karpenter"]
    assert out["interrupted_nodes"] > 0
    assert np.isfinite(out["node_price_ours"])
