"""End-to-end behaviour tests for the paper's system (RQ-1/2/3 shapes) plus
one real multi-pod dry-run cell exercised in a subprocess (the 512-device
XLA override must not leak into this test process)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (KubePACSProvisioner, Request, SpotMarketSimulator,
                        e_total, generate_catalog, preprocess, solve_ilp)
from repro.core.efficiency import NodePool
from repro.core.gss import bracketed_gss

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_table2_fixed_alpha_collapse(catalog):
    """Table 2: fixed α ∈ {0.5, 1.0} collapse to ~0; GSS-optimized is best;
    α=0 lands within ~2x of it."""
    req = Request(pods=100, cpu_per_pod=2, mem_per_pod=2)
    items = preprocess(catalog, req)
    best, _ = bracketed_gss(items, req.pods, tolerance=0.01)
    e_best = e_total(best, req.pods)
    scores = {}
    for a in (0.0, 0.5, 1.0):
        counts = solve_ilp(items, req.pods, a)
        scores[a] = e_total(NodePool(items=items, counts=counts), req.pods)
    assert e_best >= max(scores.values()) - 1e-9
    assert scores[0.5] / e_best < 0.01
    assert scores[1.0] / e_best < 0.01
    assert scores[0.0] / e_best > 0.5


def test_gss_alpha_concave_shape(catalog):
    """Fig. 6: E_Total rises from α=0 to a peak then steps down toward 0."""
    req = Request(pods=50, cpu_per_pod=1, mem_per_pod=2)
    items = preprocess(catalog, req)
    grid = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.8, 1.0]
    es = []
    for a in grid:
        counts = solve_ilp(items, req.pods, a)
        es.append(e_total(NodePool(items=items, counts=counts), req.pods))
    peak = int(np.argmax(es))
    assert es[peak] > es[0] * 0.999          # peak at or above the α=0 value
    assert es[-1] < es[peak] * 0.05          # collapse at α→1


def test_workload_preference_selection(catalog):
    """Fig. 8: declaring an intent shifts selection to specialized types
    (aggregated over market snapshots — a single pool has 3–6 types)."""
    sim = SpotMarketSimulator(catalog, seed=5)
    prov = KubePACSProvisioner()

    def frac(kinds, workload, snaps=5):
        sim2 = SpotMarketSimulator(catalog, seed=5)
        hits = total = 0
        for _ in range(snaps):
            req = Request(pods=200, cpu_per_pod=2, mem_per_pod=2,
                          workload=workload)
            pool = prov.provision(req, sim2.snapshot()).pool
            total += pool.total_nodes
            hits += sum(c for it, c in zip(pool.items, pool.counts)
                        if it.offering.specialization in kinds)
            sim2.step(6.0)
        return hits / max(total, 1)

    general = frac(("network", "network+disk"), frozenset())
    network = frac(("network", "network+disk"), frozenset({"network"}))
    assert network > general + 0.2
    disk = frac(("disk", "network+disk"), frozenset({"disk"}))
    assert disk > 0.4


def test_interrupt_recovery_cycle(catalog):
    """§4.1 loop: interrupt → exclude → re-provision covers the request."""
    sim = SpotMarketSimulator(catalog, seed=0)
    prov = KubePACSProvisioner()
    req = Request(pods=80, cpu_per_pod=2, mem_per_pod=2)
    d = prov.provision(req, sim.snapshot())
    pool = d.pool
    for _ in range(5):
        sim.step(4.0)
        prov.clock = sim.time
        events = sim.interrupts_for_pool(pool.as_dict(), hours=4.0)
        if not events:
            continue
        prov.enqueue(events)
        lost = sum(e.count for e in events)
        survivors = max(0, pool.total_pods - lost * 2)
        repl = prov.handle_interrupts(req, sim.snapshot(),
                                      surviving_pods=survivors)
        assert repl is not None
        excluded = {e.offering_id for e in events}
        chosen = {it.offering.offering_id for it in repl.pool.items}
        assert not (excluded & chosen)
        assert repl.pool.total_pods + survivors >= req.pods
        return
    pytest.skip("market produced no interrupts in 5 windows")


def test_solver_overhead_budget(catalog):
    """§5.3: the full GSS×ILP cycle stays within interactive latency."""
    prov = KubePACSProvisioner()
    req = Request(pods=400, cpu_per_pod=2, mem_per_pod=2)
    d = prov.provision(req, catalog)
    assert d.wall_seconds < 30.0
    assert d.trace.ilp_solves <= 25


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real (arch × shape × multi-pod mesh) cell lowers and compiles on
    the 2×16×16 = 512-device production mesh."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "internvl2-1b", "--shape", "decode_32k", "--multi-pod",
         "--out", "/tmp/dryrun_test.jsonl"],
        env=env, capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(open("/tmp/dryrun_test.jsonl").readlines()[-1])
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 512
    assert rec["flops_per_device"] > 0
