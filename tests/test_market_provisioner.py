"""Market simulator properties (Fig. 2/9 shapes) + §4.1 interrupt loop."""

import numpy as np
import pytest

from repro.core import (InterruptEvent, KubePACSProvisioner, Request,
                        SpotMarketSimulator, e_total, generate_catalog,
                        kubepacs_greedy, spotverse, spotkube, karpenter_like,
                        preprocess)


def test_catalog_deterministic():
    a = generate_catalog(seed=7, max_offerings=100)
    b = generate_catalog(seed=7, max_offerings=100)
    assert [o.offering_id for o in a] == [o.offering_id for o in b]
    assert [o.spot_price for o in a] == [o.spot_price for o in b]


def test_catalog_marginals(catalog):
    """Fig. 1 qualitative shapes baked into the generator."""
    by_gen = {}
    for o in catalog:
        by_gen.setdefault(o.generation, []).append(o.bs_core)
    gens = sorted(by_gen)
    means = [np.mean(by_gen[g]) for g in gens]
    assert all(a < b for a, b in zip(means, means[1:]))   # newer = faster
    # specialization raises od price, not benchmark score
    base = [o for o in catalog if o.specialization == "general"]
    net = [o for o in catalog if o.specialization == "network"]
    assert np.mean([o.od_price / o.vcpus for o in net]) > \
        np.mean([o.od_price / o.vcpus for o in base])
    assert abs(np.mean([o.bs_core for o in net])
               - np.mean([o.bs_core for o in base])) / \
        np.mean([o.bs_core for o in base]) < 0.05


def test_fulfillment_tracks_t3(small_catalog):
    """Fig. 9: higher T3 → more of a 50-node request fulfilled."""
    sim = SpotMarketSimulator(small_catalog, seed=0)
    snap = sim.snapshot()
    lo = [o for o in snap if o.t3 <= 3]
    hi = [o for o in snap if o.t3 >= 20]
    assert lo and hi
    f_lo = np.mean([sim.fulfill(o.offering_id, 50) for o in lo[:20]])
    f_hi = np.mean([sim.fulfill(o.offering_id, 50) for o in hi[:20]])
    assert f_hi > f_lo + 5


def test_single_node_sps_misleading(small_catalog):
    """Fig. 2: high single-node SPS does not imply multi-node fulfillment."""
    sim = SpotMarketSimulator(small_catalog, seed=0)
    trap = [o for o in sim.snapshot() if o.sps_single == 3 and o.t3 <= 3]
    if not trap:
        pytest.skip("no trap offerings in this catalog draw")
    got = np.mean([sim.fulfill(o.offering_id, 50) for o in trap])
    assert got < 15


def test_interrupt_pressure(small_catalog):
    sim = SpotMarketSimulator(small_catalog, seed=0)
    snap = sim.snapshot()
    o = max(snap, key=lambda o: o.t3)
    calm = sim.interrupts_for_pool({o.offering_id: max(1, o.t3 // 4)}, hours=1)
    rng_events = [sim.interrupts_for_pool({o.offering_id: o.t3 * 4}, hours=4)
                  for _ in range(20)]
    stressed = sum(sum(e.count for e in evs) for evs in rng_events)
    assert stressed > sum(e.count for e in calm)


def test_provisioner_excludes_interrupted(catalog):
    prov = KubePACSProvisioner()
    req = Request(pods=60, cpu_per_pod=2, mem_per_pod=2)
    d1 = prov.provision(req, catalog)
    assert d1.pool.total_pods >= req.pods
    victim = d1.pool.items[0].offering.offering_id
    prov.enqueue([InterruptEvent(time=0.0, offering_id=victim, count=1)])
    d2 = prov.handle_interrupts(req, catalog, surviving_pods=0)
    assert d2 is not None
    assert victim in d2.excluded_offerings
    assert victim not in {it.offering.offering_id for it in d2.pool.items}
    assert d2.pool.total_pods >= req.pods


def test_cache_ttl(catalog):
    prov = KubePACSProvisioner(ttl_hours=1.0)
    prov.cache.add("x@y", now=0.0)
    assert "x@y" in prov.cache.excluded(0.5)
    assert "x@y" not in prov.cache.excluded(2.0)


def test_kubepacs_wins_scenarios(catalog):
    """RQ-1 (Fig. 5a): KubePACS ≥ every baseline on E_Total."""
    prov = KubePACSProvisioner()
    for pods, cpu, mem in [(10, 1, 2), (100, 2, 2), (400, 1, 4), (75, 3, 5)]:
        req = Request(pods=pods, cpu_per_pod=cpu, mem_per_pod=mem)
        items = preprocess(catalog, req)
        d = prov.provision(req, catalog)
        ours = d.metrics["e_total"]
        for fn in (kubepacs_greedy,
                   lambda it, r: spotverse(it, r, "node"),
                   lambda it, r: spotverse(it, r, "pod"),
                   karpenter_like):
            assert ours >= e_total(fn(items, pods), pods) - 1e-9


def test_spotkube_small_scale(catalog):
    """Fig. 5c setup: restricted pool, 4-per-type SpotKube vs KubePACS."""
    from repro.core import restrict
    types = sorted({o.instance_type for o in catalog})[:4]
    small = restrict(catalog, instance_types=types)
    req = Request(pods=20, cpu_per_pod=1, mem_per_pod=1)
    items = preprocess(small, req)
    if not items:
        pytest.skip("restricted pool infeasible for this draw")
    sk = spotkube(items, req.pods, seed=0, generations=30, population=24)
    prov = KubePACSProvisioner()
    d = prov.provision(req, small)
    if sk.total_pods >= req.pods:
        assert d.metrics["e_total"] >= e_total(sk, req.pods) - 1e-9
    # SpotKube's rigidity: every selected type has exactly 4 nodes
    for c in sk.counts:
        assert c == 4
