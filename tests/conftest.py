"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs exclusively to launch/dryrun.py)."""

import numpy as np
import pytest

from repro.core import Request, generate_catalog, preprocess


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end test (compiles XLA cells)")


@pytest.fixture(scope="session")
def catalog():
    return generate_catalog(seed=0, max_offerings=600)


@pytest.fixture(scope="session")
def small_catalog():
    return generate_catalog(seed=1, max_offerings=120)


@pytest.fixture()
def request_100(catalog):
    return Request(pods=100, cpu_per_pod=2, mem_per_pod=2)


@pytest.fixture()
def items_100(catalog, request_100):
    return preprocess(catalog, request_100)
