"""Batched multi-α ILP engine: cross-validation against the seed solver,
brute force, and the legacy GSS path (DESIGN.md §8).

The engine must be *exact*: every randomized market — including infeasible
demands and the α ∈ {0, 1} edges — has to produce the same objective value
and a feasible, bound-respecting count vector as the seed history-matrix
solver, and the rewired guarded GSS must return pools with identical
E_Total to the legacy per-α path.
"""

import itertools

import numpy as np
import pytest

from repro.core import (KubePACSProvisioner, Request,
                        compile_market, e_total, e_total_batch,
                        generate_catalog, objective_coefficients,
                        pool_metric_arrays, preprocess, solve_ilp,
                        solve_ilp_batch, solve_ilp_reference)
from repro.core.gss import bracketed_gss, golden_section_search
from repro.core.ilp import _lp_prune

from tests.strategies import mk_item as _mk_item
from tests.strategies import random_market as _random_market


def _objective(items, counts, alpha):
    return float(np.dot(objective_coefficients(items, alpha), counts))


def _check_solution(items, counts, req, alpha, ref_obj):
    assert counts is not None
    assert all(0 <= c <= it.t3 for c, it in zip(counts, items))
    assert sum(c * it.pods for c, it in zip(counts, items)) >= req
    assert _objective(items, counts, alpha) == pytest.approx(ref_obj, abs=1e-8)


# ------------------------------------------------- randomized equivalence ----

def test_batch_equals_single_equals_reference_100_markets():
    """≥100 randomized markets × α grid incl. the {0, 1} edges: the batched
    engine, the per-α engine, and the seed solver agree on feasibility and
    objective, and every returned count vector is feasible and in-bounds."""
    rng = np.random.default_rng(7)
    n_markets = 110
    n_infeasible = 0
    for _ in range(n_markets):
        items = _random_market(rng)
        req = int(rng.integers(0, 90))
        alphas = [0.0, 1.0] + [float(a) for a in rng.uniform(0, 1, size=3)]
        market = compile_market(items)
        batch = solve_ilp_batch(items, req, alphas, market=market)
        for alpha, counts_b in zip(alphas, batch):
            counts_s = solve_ilp(items, req, alpha, market=market)
            counts_r = solve_ilp_reference(items, req, alpha)
            if counts_r is None:
                n_infeasible += 1
                assert counts_b is None and counts_s is None
                continue
            ref_obj = _objective(items, counts_r, alpha)
            _check_solution(items, counts_b, req, alpha, ref_obj)
            _check_solution(items, counts_s, req, alpha, ref_obj)
    assert n_infeasible > 0   # the sweep must exercise the infeasible branch


def test_batch_stats_dp_objectives_match_decoded_counts():
    """return_stats objectives come from the vectorized (A × R+1) value DP;
    they must equal the objective of the independently decoded counts."""
    rng = np.random.default_rng(21)
    for _ in range(15):
        items = _random_market(rng)
        req = int(rng.integers(1, 80))
        alphas = [0.0, 0.04, 0.5, 1.0]
        counts_list, stats = solve_ilp_batch(items, req, alphas,
                                             return_stats=True)
        for alpha, counts, st_ in zip(alphas, counts_list, stats):
            if counts is None:
                assert not np.isfinite(st_.objective)
                continue
            assert st_.objective == pytest.approx(
                _objective(items, counts, alpha), abs=1e-8)


def test_engine_matches_brute_force_small():
    rng = np.random.default_rng(3)
    for _ in range(40):
        items = _random_market(rng, max_items=4, max_t3=6)
        req = int(rng.integers(0, 14))
        alpha = float(rng.uniform(0, 1))
        coef = objective_coefficients(items, alpha)
        best = None
        for xs in itertools.product(*[range(it.t3 + 1) for it in items]):
            if sum(x * it.pods for x, it in zip(xs, items)) < req:
                continue
            c = float(np.dot(coef, xs))
            if best is None or c < best - 1e-12:
                best = c
        counts = solve_ilp(items, req, alpha)
        if best is None:
            assert counts is None
            continue
        _check_solution(items, counts, req, alpha, best)


def test_engine_matches_pulp():
    pytest.importorskip("pulp")
    from repro.core.ilp import solve_ilp_pulp
    rng = np.random.default_rng(11)
    for _ in range(10):
        items = _random_market(rng, max_items=8)
        req = int(rng.integers(1, 50))
        alpha = float(rng.uniform(0, 1))
        counts = solve_ilp(items, req, alpha)
        pulp_counts = solve_ilp_pulp(items, req, alpha)
        assert (counts is None) == (pulp_counts is None)
        if counts is not None:
            assert _objective(items, counts, alpha) == pytest.approx(
                _objective(items, pulp_counts, alpha), abs=1e-6)


# ---------------------------------------------------------- GSS rewire ----

def test_bracketed_gss_identical_before_after_rewire(catalog):
    """The engine path must return pools with identical E_Total to the seed
    per-α path across the paper's scenario grid."""
    for pods, cpu, mem in [(10, 1, 2), (100, 2, 2), (400, 1, 4),
                           (1000, 1, 4), (287, 1, 6)]:
        req = Request(pods=pods, cpu_per_pod=cpu, mem_per_pod=mem)
        items = preprocess(catalog, req)
        engine_pool, engine_trace = bracketed_gss(items, pods, tolerance=0.01)
        legacy_pool, legacy_trace = bracketed_gss(items, pods, tolerance=0.01,
                                                  solver=solve_ilp_reference)
        assert engine_trace.ilp_solves == legacy_trace.ilp_solves
        assert e_total(engine_pool, pods) == pytest.approx(
            e_total(legacy_pool, pods), rel=1e-9)


def test_pure_gss_identical_before_after_rewire(catalog):
    req = Request(pods=150, cpu_per_pod=2, mem_per_pod=2)
    items = preprocess(catalog, req)
    engine_pool, _ = golden_section_search(items, 150, tolerance=0.01)
    legacy_pool, _ = golden_section_search(items, 150, tolerance=0.01,
                                           solver=solve_ilp_reference)
    assert e_total(engine_pool, 150) == pytest.approx(
        e_total(legacy_pool, 150), rel=1e-9)


def test_provision_identical_before_after_rewire(catalog):
    """KubePACSProvisioner.provision == seed pipeline (preprocess → legacy
    bracketed GSS) on E_Total."""
    prov = KubePACSProvisioner()
    for pods, cpu, mem in [(60, 2, 2), (400, 1, 4)]:
        req = Request(pods=pods, cpu_per_pod=cpu, mem_per_pod=mem)
        d = prov.provision(req, catalog)
        items = preprocess(catalog, req)
        legacy_pool, _ = bracketed_gss(items, pods, tolerance=0.01,
                                       solver=solve_ilp_reference)
        assert d.metrics["e_total"] == pytest.approx(
            e_total(legacy_pool, pods), rel=1e-9)


def test_compiled_market_cached_across_reoptimization(catalog):
    """§4.1 re-optimisation (same snapshot, shortfall demand) must reuse the
    compiled market instead of re-running preprocessing."""
    from repro.core import InterruptEvent
    prov = KubePACSProvisioner()
    req = Request(pods=80, cpu_per_pod=2, mem_per_pod=2)
    d1 = prov.provision(req, catalog)
    market_1 = prov._market
    assert market_1 is not None
    victim = d1.pool.items[0].offering.offering_id
    prov.enqueue([InterruptEvent(time=0.0, offering_id=victim, count=1)])
    d2 = prov.handle_interrupts(req, catalog, surviving_pods=30)
    assert d2 is not None
    assert prov._market is market_1          # cache hit: no recompilation
    assert victim not in {it.offering.offering_id for it in d2.pool.items}
    assert d2.pool.total_pods >= 50


def test_exclusion_mask_matches_rebuilt_market():
    """Solving with an exclude mask ≡ rebuilding the candidate set without
    the excluded offerings (incl. the Perf_min/SP_min renormalization)."""
    rng = np.random.default_rng(5)
    for _ in range(20):
        items = _random_market(rng, max_items=8)
        if len(items) < 2:
            continue
        excl = np.zeros(len(items), dtype=bool)
        excl[rng.integers(0, len(items))] = True
        survivors = [it for it, e in zip(items, excl) if not e]
        req = int(rng.integers(0, 30))
        alpha = float(rng.uniform(0, 1))
        masked = solve_ilp(items, req, alpha, market=compile_market(items),
                           exclude=excl)
        rebuilt = solve_ilp(survivors, req, alpha)
        if rebuilt is None:
            assert masked is None
            continue
        assert [c for c, e in zip(masked, excl) if not e] is not None
        assert _objective(survivors,
                          [c for c, e in zip(masked, excl) if not e],
                          alpha) == pytest.approx(
            _objective(survivors, rebuilt, alpha), abs=1e-8)
        assert all(c == 0 for c, e in zip(masked, excl) if e)


# ----------------------------------------------------- batch scoring ----

def test_e_total_batch_matches_scalar(items_100):
    from repro.core import NodePool
    items = items_100[:40]
    rng = np.random.default_rng(0)
    counts = rng.integers(0, 4, size=(16, len(items)))
    perf, price, pods = pool_metric_arrays(items)
    batch = e_total_batch(perf, price, pods, counts, 60)
    for row, score in zip(counts, batch):
        pool = NodePool(items=list(items), counts=[int(c) for c in row])
        assert score == pytest.approx(e_total(pool, 60), rel=1e-12)


# ----------------------------------------------------- memory flatness ----

def test_solver_memory_flat():
    """Peak solver allocation must no longer scale as bundles × demand: the
    seed history matrix alone is ≈ n_bundles × R × 8 bytes, while the
    engine's working set is O(bundles + R)."""
    import tracemalloc
    rng = np.random.default_rng(1)
    items = [_mk_item(i, int(rng.integers(1, 4)), float(rng.uniform(1e3, 1e5)),
                      float(rng.uniform(0.5, 3.0)), int(rng.integers(10, 50)))
             for i in range(150)]
    req = 4000
    market = compile_market(items)
    alpha = 0.02          # low α: the residual DP is the dominant phase
    solve_ilp(items, req, alpha, market=market)   # warm up

    tracemalloc.start()
    counts, stats = solve_ilp(items, req, alpha, market=market,
                              return_stats=True)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert counts is not None and stats.residual_demand > 0
    history_bytes = market.n_bundles * (stats.residual_demand + 1) * 8
    assert peak < history_bytes / 4   # far below the seed's history matrix


def test_lp_prune_preserves_optimum():
    """Pruned bundle sets must still contain an optimal solution."""
    rng = np.random.default_rng(9)
    for _ in range(30):
        B = int(rng.integers(3, 40))
        bpods = rng.integers(1, 12, size=B)
        bcosts = rng.uniform(0.0, 5.0, size=B)
        target = int(rng.integers(1, int(bpods.sum()) + 1))
        keep = _lp_prune(bpods, bcosts, target)
        from repro.core.ilp import _cover_dp
        full = _cover_dp(bpods, bcosts, target)[target]
        pruned = _cover_dp(bpods[keep], bcosts[keep], target)[target]
        assert pruned == pytest.approx(full, abs=1e-9)
