"""GSS (Alg. 1 / Eq. 6–7), efficiency metrics (Eq. 1–3), scaling (Eq. 8)."""

import math

import numpy as np
import pytest

from tests._optional import given, settings, st

from repro.core import (CandidateItem, NodePool, Offering, Request,
                        build_base_price_index, e_over_pods, e_perf_cost,
                        e_total, expected_iterations, generate_catalog,
                        golden_section_search, pods_per_instance,
                        scaled_benchmark_score, preprocess)
from repro.core.gss import PHI, bracketed_gss
from tests.strategies import mk_item as _mk_item


# ---------------------------------------------------------------- GSS ----

def test_expected_iterations_eq7():
    """Eq. 7: k ≈ 4.784·n + 1 for ε = 10⁻ⁿ."""
    for n in (1, 2, 3, 4):
        k = expected_iterations(10.0 ** -n)
        assert k == math.ceil(-n * math.log(10) / math.log(PHI)) + 1
        assert abs(k - (4.784 * n + 1)) <= 1.5


def test_gss_finds_unimodal_peak():
    """Mock solver: E_Total(α) peaked at α*=0.3; GSS must land within ε."""
    peak = 0.3

    def mock_solver(items, req, alpha):
        # one item; count encodes f(α) via perf-cost: pods exactly req
        score = 1000.0 * math.exp(-30 * (alpha - peak) ** 2)
        it = _mk_item(0, pods=req, bs=score, sp=1.0, t3=5)
        items[0] = it          # mutate the placeholder the pool will carry
        return [1]

    items = [_mk_item(0, pods=10, bs=1.0, sp=1.0, t3=5)]
    pool, trace = golden_section_search(items, 10, tolerance=0.005,
                                        solver=mock_solver)
    assert pool is not None
    assert abs(pool.alpha - peak) < 0.02
    # one ILP solve per iteration after the two initial points
    assert trace.ilp_solves <= expected_iterations(0.005) + 3


def test_gss_ilp_solve_count_scales_with_tolerance(items_100):
    items = items_100[:150]
    _, t1 = golden_section_search(items, 30, tolerance=0.1)
    _, t2 = golden_section_search(items, 30, tolerance=0.001)
    assert t2.ilp_solves > t1.ilp_solves
    assert t2.ilp_solves <= expected_iterations(0.001) + 3


def test_bracketed_not_worse_than_pure(items_100):
    items = items_100[:300]
    p1, _ = golden_section_search(items, 50, tolerance=0.01)
    p2, _ = bracketed_gss(items, 50, tolerance=0.01)
    assert e_total(p2, 50) >= e_total(p1, 50) - 1e-9


# ------------------------------------------------------- efficiency ----

def test_pods_per_instance_eq1():
    o = Offering("x@a", "x", "m", 6, "i", "general", "xlarge", "r", "a",
                 vcpus=4, mem_gib=16.0, od_price=0.2, spot_price=0.05,
                 bs_core=2e4, sps_single=3, t3=10, interruption_freq=0)
    assert pods_per_instance(o, Request(pods=1, cpu_per_pod=1, mem_per_pod=2)) == 4
    assert pods_per_instance(o, Request(pods=1, cpu_per_pod=2, mem_per_pod=2)) == 2
    assert pods_per_instance(o, Request(pods=1, cpu_per_pod=1, mem_per_pod=9)) == 1
    assert pods_per_instance(o, Request(pods=1, cpu_per_pod=8, mem_per_pod=1)) == 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 6), st.floats(1e3, 1e5),
                          st.floats(0.01, 2.0), st.integers(1, 10),
                          st.integers(0, 5)),
                min_size=1, max_size=6),
       st.integers(1, 40))
def test_e_metrics_invariants(raw, req):
    items = [_mk_item(i, p, bs, sp, t3) for i, (p, bs, sp, t3, _) in
             enumerate(raw)]
    counts = [min(t3, c) for (_, _, _, t3, c) in raw]
    pool = NodePool(items=items, counts=counts)
    if pool.total_pods >= req and pool.total_pods > 0:
        assert 0 < e_over_pods(pool, req) <= 1.0
        assert e_total(pool, req) == pytest.approx(
            e_perf_cost(pool) * e_over_pods(pool, req))
    else:
        assert e_total(pool, req) == 0.0


def test_e_metrics_invariants_deterministic():
    """Seeded twin of the hypothesis property above — the E-metric
    invariants (Eq. 2–3 ranges and the E_Total factorization) hold on
    every randomized pool, optional dependencies or not."""
    rng = np.random.default_rng(73)
    n_covered = n_short = 0
    for _ in range(60):
        raw = [(int(rng.integers(1, 7)), float(rng.uniform(1e3, 1e5)),
                float(rng.uniform(0.01, 2.0)), int(rng.integers(1, 11)),
                int(rng.integers(0, 6)))
               for _ in range(int(rng.integers(1, 7)))]
        req = int(rng.integers(1, 41))
        items = [_mk_item(i, p, bs, sp, t3) for i, (p, bs, sp, t3, _) in
                 enumerate(raw)]
        counts = [min(t3, c) for (_, _, _, t3, c) in raw]
        pool = NodePool(items=items, counts=counts)
        if pool.total_pods >= req and pool.total_pods > 0:
            n_covered += 1
            assert 0 < e_over_pods(pool, req) <= 1.0
            assert e_total(pool, req) == pytest.approx(
                e_perf_cost(pool) * e_over_pods(pool, req))
        else:
            n_short += 1
            assert e_total(pool, req) == 0.0
    assert n_covered >= 10 and n_short >= 10


def test_e_total_scale_free_for_single_type():
    """Aggregate/aggregate reading: duplicating a homogeneous pool must not
    change E_PerfCost (and only over-pods penalizes it)."""
    it = _mk_item(0, pods=2, bs=2e4, sp=0.5, t3=50)
    p1 = NodePool(items=[it], counts=[5])
    p2 = NodePool(items=[it], counts=[10])
    assert e_perf_cost(p1) == pytest.approx(e_perf_cost(p2))


# ------------------------------------------------------- Eq. 8 scaling ----

def test_workload_scaling_eq8(catalog):
    idx = build_base_price_index(catalog)
    net = next(o for o in catalog if o.specialization == "network"
               and o.base_instance_type in idx)
    disk = next(o for o in catalog if o.specialization == "disk"
                and o.base_instance_type in idx)
    gen = next(o for o in catalog if o.specialization == "general")

    # network intent: network instances scaled by OP_i/OP_base, disk NOT
    scaled = scaled_benchmark_score(net, {"network"}, idx)
    assert scaled == pytest.approx(
        net.bs_core * net.od_price / idx[net.base_instance_type])
    assert scaled > net.bs_core                       # price premium > 1
    assert scaled_benchmark_score(disk, {"network"}, idx) == disk.bs_core
    assert scaled_benchmark_score(gen, {"network"}, idx) == gen.bs_core
    # no intent: nothing scales
    assert scaled_benchmark_score(net, set(), idx) == net.bs_core
    # dual-intent instances match either
    nd = next((o for o in catalog if o.specialization == "network+disk"
               and o.base_instance_type in idx), None)
    if nd is not None:
        assert scaled_benchmark_score(nd, {"disk"}, idx) > nd.bs_core


def test_preprocess_filters(catalog):
    req = Request(pods=10, cpu_per_pod=2, mem_per_pod=2)
    items = preprocess(catalog, req, excluded={catalog[0].offering_id})
    ids = {it.offering.offering_id for it in items}
    assert catalog[0].offering_id not in ids
    for it in items:
        assert it.pods >= 1 and it.t3 >= 1 and it.spot_price > 0
