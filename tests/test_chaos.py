"""ChaosPlane: deterministic fault injection + the hardened degradation
ladder (DESIGN.md §16).

Covers the PR-9 acceptance surface: the determinism contract survives
fault injection (byte-identical traces, RNG-free replay, fleet ≡
standalone), the hardening layer is bit-inert when no faults are
declared, backend rungs agree (descending the ladder is safe), the
backoff schedule is a pure function of its coordinates, and ICE
accounting matches hand-computed caps and stays idempotent under
replay's re-clipping.
"""

import dataclasses
import math

import numpy as np
import pytest

from repro.chaos import ChaosController, Fault, fault_storm
from repro.chaos.guard import (GuardConfig, HardenedPolicy,
                               backoff_schedule, decision_available,
                               quarantine_mask)
from repro.core.efficiency import NodePool, decision_metrics
from repro.core.provisioner import ProvisioningDecision
from repro.sim import ClusterSim, Scenario, run_fleet, run_replicas

from tests._optional import given, settings, st
from tests.strategies import mk_item


def chaos_scenario(storm="combined", policy="hardened", **overrides):
    """A compact 24 h / 3 h-step storm: the ``fault_storm`` presets at
    scale 0.5 land every window inside the horizon."""
    base = dict(name="chaos_test", duration_hours=24.0, step_hours=3.0,
                pods=60, cpu_per_pod=2, mem_per_pod=2,
                demand_schedule=((6.0, 110), (12.0, 70), (18.0, 115)),
                interrupt_model="pressure", policy=policy,
                catalog_seed=7, max_offerings=80, market_seed=7,
                interrupt_seed=7,
                faults=fault_storm(storm, 0.5) if storm else ())
    base.update(overrides)
    return Scenario(**base)


# ------------------------------------------------- determinism contract ----

@pytest.mark.parametrize("policy", ["kubepacs", "hardened"])
def test_same_seed_byte_identical_trace_under_faults(policy):
    sc = chaos_scenario(policy=policy)
    a = ClusterSim(sc, clock=lambda: 0.0).run()
    b = ClusterSim(sc, clock=lambda: 0.0).run()
    assert a.recorder.dumps() == b.recorder.dumps()
    # the fault plane is part of the trace: activation transitions are
    # recorded, and begin/end phases pair up per fault index
    faults = [r for r in a.records if r["type"] == "fault"]
    assert faults
    begins = {r["fault_index"] for r in faults if r["phase"] == "begin"}
    ends = {r["fault_index"] for r in faults if r["phase"] == "end"}
    assert ends <= begins


@pytest.mark.parametrize("policy", ["kubepacs", "hardened"])
def test_replay_rng_free_under_faults(policy):
    """Replay consumes recorded market/interrupt/fulfillment records and
    re-derives the identical trace — fault effects included — with zero
    RNG (every fault is a pure function of trace coordinates)."""
    sc = chaos_scenario(policy=policy)
    live = ClusterSim(sc, clock=lambda: 0.0).run()
    rep = ClusterSim.replay(live.records).run()
    assert rep.recorder.dumps() == live.recorder.dumps()


@pytest.mark.parametrize("policy", ["kubepacs", "hardened"])
def test_fleet_matches_standalone_under_faults(policy):
    sc = chaos_scenario(policy=policy)
    seeds = [0, 1]
    fleet = run_fleet(sc, seeds, record_traces=True, clock=lambda: 0.0)
    per_seed = run_replicas(sc, seeds)
    for f, s in zip(fleet, per_seed):
        assert f.recorder.dumps() == s.recorder.dumps()


def test_hardened_inert_without_faults():
    """Selection safety: with no faults declared the hardened policy is
    byte-identical to plain kubepacs (the healthy path literally
    delegates — the only trace difference is the policy name in the
    scenario header)."""
    h = ClusterSim(chaos_scenario(None, "hardened"),
                   clock=lambda: 0.0).run()
    k = ClusterSim(chaos_scenario(None, "kubepacs"),
                   clock=lambda: 0.0).run()
    assert h.recorder.dumps().replace(
        '"policy": "hardened"', '"policy": "kubepacs"') \
        == k.recorder.dumps()
    assert not any(key.startswith("chaos_") for key in h.cache_stats)


# ------------------------------------------------- solver-fault gating ----

def test_solver_fault_fails_naive_but_not_hardened():
    """Under an active solver fault the engine fails unhardened policies'
    decision cycles outright; the hardened ladder absorbs the same fault
    (injected errors burn attempts, then a later attempt/rung solves)."""
    naive = ClusterSim(chaos_scenario("solver", "kubepacs"),
                       clock=lambda: 0.0).run()
    failed = [d for _, d in naive.decisions
              if d is not None and d.metrics.get("decision_failed")]
    assert failed
    assert all(not decision_available(d) for d in failed)

    hard = ClusterSim(chaos_scenario("solver", "hardened"),
                      clock=lambda: 0.0).run()
    assert all(decision_available(d) for _, d in hard.decisions)
    assert hard.cache_stats.get("chaos_solve_errors", 0) > 0


def test_rung_descends_to_equal_decision():
    """Rung N ≡ rung N+1 when the upper rung is healthy: the DESIGN §12
    backend bit-identity contract is what makes descending the ladder
    safe, so a degraded solve must pick the same pool on every rung."""
    sc = chaos_scenario("feed", "hardened")
    catalog = sc.build_catalog()
    chaos = ChaosController(sc.faults, catalog)
    spot = np.array([o.spot_price for o in catalog], dtype=np.float64)
    t3 = np.array([o.t3 for o in catalog])
    chaos.observe(0, 4.5, spot, t3)       # inside the corrupt window
    assert chaos.snapshot_tainted
    pools = []
    for ladder in (("default",), ("numpy",)):
        hp = HardenedPolicy(clock=lambda: 0.0, ladder=ladder)
        hp.bind(catalog)
        hp.bind_chaos(chaos)
        d = hp.provision(sc.request(), catalog, 4.5)
        assert isinstance(d, ProvisioningDecision)
        assert d.metrics["chaos_rung"] == 0.0
        pools.append(d.pool.as_dict())
    assert pools[0] == pools[1]


# ------------------------------------------------------ backoff ladder ----

def test_backoff_schedule_deterministic_under_injected_clock():
    a = backoff_schedule(0, 12.0, 6)
    assert a == backoff_schedule(0, 12.0, 6)
    assert a[0] == 0.0
    assert all(0.05 <= d <= 1.0 for d in a[1:])
    # the schedule is keyed on the *decision time*: a different tick
    # draws a different (still deterministic) jitter sequence
    assert a != backoff_schedule(0, 15.0, 6)
    assert a[:3] == backoff_schedule(0, 12.0, 3)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 32 - 1),
       st.floats(0.0, 1e5, allow_nan=False),
       st.integers(1, 12))
def test_backoff_schedule_property(seed, now, attempts):
    sched = backoff_schedule(seed, now, attempts)
    assert sched == backoff_schedule(seed, now, attempts)
    assert len(sched) == attempts
    assert sched[0] == 0.0
    assert all(0.05 <= d <= 1.0 for d in sched[1:])


def test_backoff_schedule_property_deterministic():
    """Seeded twin of the hypothesis property above — purity, length,
    zero first delay, and [base, cap] bounds hold on every draw."""
    rng = np.random.default_rng(41)
    for _ in range(40):
        seed = int(rng.integers(0, 2 ** 32))
        now = float(rng.uniform(0.0, 1e5))
        attempts = int(rng.integers(1, 13))
        sched = backoff_schedule(seed, now, attempts)
        assert sched == backoff_schedule(seed, now, attempts)
        assert len(sched) == attempts
        assert sched[0] == 0.0
        assert all(0.05 <= d <= 1.0 for d in sched[1:])


# ------------------------------------------------------ ICE accounting ----

def test_ice_caps_match_hand_computed_and_are_idempotent():
    f = Fault(kind="ice", time=6.0, duration=6.0, magnitude=0.7, seed=1)
    chaos = ChaosController((f,), [])
    assert chaos.ice_caps(3.0, {"a@az": 10}) is None      # window closed
    requested = {"a@az": 10, "b@az": 4, "c@az": 0}
    caps = chaos.ice_caps(6.0, requested)
    assert caps == {"a@az": math.floor(10 * 0.3),          # 3
                    "b@az": math.floor(4 * 0.3),           # 1
                    "c@az": 0}
    # replay re-derives caps from the same (time, requested) coordinates
    # and re-clips the recorded grants: min(grants, caps) must be identity
    grants = {oid: min(c, caps[oid]) for oid, c in requested.items()}
    assert {oid: min(g, caps[oid]) for oid, g in grants.items()} == grants


def test_observe_fulfillment_market_wide_vs_offering_specific():
    items = [mk_item(0, pods=4, bs=1e4, sp=0.5, t3=5),
             mk_item(1, pods=4, bs=1e4, sp=0.6, t3=2)]
    catalog = [it.offering for it in items]
    f = Fault(kind="ice", time=0.0, duration=6.0, magnitude=0.7, seed=1)
    hp = HardenedPolicy(clock=lambda: 0.0)
    hp.bind(catalog)
    hp.bind_chaos(ChaosController((f,), catalog))
    a, b = items[0].offering.offering_id, items[1].offering.offering_id

    # every offering short: market-wide pressure — no exclusions, the
    # grant ratio arms the over-request compensation instead
    hp.observe_fulfillment(1.0, {a: 10, b: 4}, {a: 3, b: 1})
    assert hp.provisioner.cache.excluded(1.0) == set()
    assert hp._grant_ratio == pytest.approx(4 / 14)
    assert hp.counters["ice_market_wide"] == 1

    # compensation: counts scale by 1/ratio, clipped to each item's T3
    pool = NodePool(items=items, counts=[3, 1])
    decision = ProvisioningDecision(
        pool=pool, trace=None, alpha=None, wall_seconds=0.0,
        excluded_offerings=set(),
        metrics=decision_metrics(pool, 40))
    request = chaos_scenario().request()
    inflated = hp._inflate(request, decision)
    assert inflated.pool.counts == [5, 2]      # ceil(3·3.5)→11→T3=5; 4→2
    assert inflated.metrics["chaos_ice_inflate"] == pytest.approx(3.5)
    assert hp.counters["ice_inflated"] == 1

    # one offering granted in full: the shortfall is offering-specific —
    # diversify away from the short one, disarm the compensation
    hp.observe_fulfillment(2.0, {a: 10, b: 4}, {a: 10, b: 0})
    assert hp.provisioner.cache.excluded(2.0) == {b}
    assert hp._grant_ratio == 1.0
    assert hp.counters["ice_excluded"] == 1


# --------------------------------------------------- invariant monitor ----

def test_quarantine_mask_bands():
    cfg = GuardConfig()
    clean = mk_item(0, pods=4, bs=1e4, sp=0.5, t3=5)
    nan = mk_item(1, pods=4, bs=1e4, sp=float("nan"), t3=5)
    low = mk_item(2, pods=4, bs=1e4, sp=0.01, t3=5)
    low = dataclasses.replace(
        low, offering=dataclasses.replace(low.offering, od_price=1.0))
    spike = mk_item(3, pods=4, bs=1e4, sp=1.2, t3=5)
    spike = dataclasses.replace(
        spike, offering=dataclasses.replace(spike.offering, od_price=1.0))
    bad_t3 = mk_item(4, pods=4, bs=1e4, sp=0.5, t3=60)
    mask = quarantine_mask([clean, nan, low, spike, bad_t3], cfg)
    assert mask.tolist() == [False, True, True, True, True]
    assert quarantine_mask([clean], cfg) is None


# ------------------------------------------------------- serialization ----

def test_scenario_faults_roundtrip():
    sc = chaos_scenario()
    assert sc.faults
    rebuilt = Scenario.from_dict(sc.to_dict())
    assert rebuilt == sc
    assert rebuilt.faults == sc.faults
    assert all(isinstance(f, Fault) for f in rebuilt.faults)
