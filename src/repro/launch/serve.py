"""Batched serving driver: continuous-batching loop over any assigned arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
        --requests 12 --batch 4 --prompt-len 32 --new-tokens 16

Requests arrive in a queue; the server packs them into fixed-size batches,
prefills, then decodes greedily with the KV/SSM caches. Reduced (smoke)
configs on CPU; the same code path lowers on the production meshes via
serving.make_sharded_prefill/decode (see launch/dryrun.py).
"""

import argparse
import collections
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, list_archs
from ..models import decode_step, init_params, prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B, S, N = args.batch, args.prompt_len, args.new_tokens
    vp = cfg.vision_prefix if cfg.input_mode == "vlm" else 0
    max_len = S + N + vp

    pre = jax.jit(lambda p, b: prefill(p, cfg, b, max_len=max_len))
    step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))

    queue = collections.deque(range(args.requests))
    served, t0 = 0, time.perf_counter()
    stats = []
    while queue:
        ids = [queue.popleft() for _ in range(min(B, len(queue) + 1))
               if queue or True][:B]
        n = len(ids)
        pad = B - n                                  # pad partial batches
        if cfg.input_mode == "audio_codes":
            batch = {"codes": jnp.asarray(rng.integers(
                0, cfg.vocab_size, (B, cfg.n_codebooks, S)))}
        elif cfg.input_mode == "vlm":
            batch = {"tokens": jnp.asarray(rng.integers(
                        0, cfg.vocab_size, (B, S))),
                     "vision_embeds": jnp.asarray(rng.normal(
                         size=(B, vp, cfg.d_model)), jnp.float32)}
        else:
            batch = {"tokens": jnp.asarray(rng.integers(
                0, cfg.vocab_size, (B, S)))}
        t_b = time.perf_counter()
        logits, caches = pre(params, batch)
        nxt = jnp.argmax(logits[:, -1:, ...], axis=-1)
        for i in range(N):
            if cfg.input_mode == "audio_codes":
                inp = {"codes": jnp.moveaxis(nxt, 2, 1)}
            else:
                inp = {"tokens": nxt.reshape(B, -1)[:, :1]}
            logits, caches = step(params, caches, inp,
                                  jnp.asarray(S + vp + i))
            nxt = jnp.argmax(logits[:, -1:, ...], axis=-1)
        dt = time.perf_counter() - t_b
        served += n
        stats.append({"batch": n, "padded": pad, "latency_s": round(dt, 3),
                      "tok_s": round(n * N / dt, 1)})
    wall = time.perf_counter() - t0
    print(json.dumps({"arch": cfg.name, "served": served,
                      "wall_s": round(wall, 2),
                      "throughput_tok_s": round(served * N / wall, 1),
                      "batches": stats}, indent=2))


if __name__ == "__main__":
    main()
