import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the production mesh (16×16 single-pod, 2×16×16
multi-pod) from 512 placeholder host devices, lowers the jitted step
(train_step / prefill / serve_step per the shape's kind) against
ShapeDtypeStruct stand-ins (zero allocation), compiles it, and records:

  * memory_analysis() / static per-device argument bytes (fits-check)
  * cost_analysis() FLOPs + bytes accessed (roofline compute/memory terms)
  * parsed collective wire bytes from the partitioned HLO (collective term)

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod --out f.jsonl
Exit code != 0 on any cell failure (sharding mismatch, compile error).
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .. import optim, roofline, serving, sharding
from ..configs import SHAPES, get_config, list_archs, shape_applicable
from ..data.pipeline import batch_pspecs, batch_specs
from ..models import transformer
from ..train.loop import make_sharded_train_step
from .mesh import make_production_mesh


def _abstract_opt(cfg):
    aparams = transformer.abstract_params(cfg)
    f32 = lambda sds: jax.ShapeDtypeStruct(sds.shape, jnp.float32)
    return {"m": jax.tree.map(f32, aparams),
            "v": jax.tree.map(f32, aparams),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _tree_device_bytes(tree, pspec_tree, mesh) -> float:
    """Per-device bytes of a sharded abstract tree."""
    leaves = jax.tree.leaves(tree)
    specs = jax.tree.leaves(pspec_tree,
                            is_leaf=lambda x: isinstance(x, P) or x is None)
    total = 0.0
    for leaf, spec in zip(leaves, specs):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        denom = 1
        if isinstance(spec, P):
            for ax in spec:
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    denom *= mesh.shape[a]
        total += n * jnp.dtype(leaf.dtype).itemsize / denom
    return total


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             fsdp: bool = False, seq_act: bool = True, attn_mode: str = "none",
             ep_shard_map: bool = False, causal_skip: bool = False,
             attn_chunk: int = None,
             remat: str = None, capacity_factor: float = None,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    overrides = {}
    if remat is not None:
        overrides["remat_policy"] = remat
    if capacity_factor is not None:
        overrides["capacity_factor"] = capacity_factor
    if fsdp:
        overrides["fsdp"] = True
    if causal_skip:
        overrides["attn_causal_skip"] = True
    if attn_chunk:
        overrides["attn_chunk_q"] = attn_chunk
        overrides["attn_chunk_kv"] = attn_chunk
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "fsdp": fsdp, "seq_act": seq_act, "attn_mode": attn_mode,
           "ep_shard_map": ep_shard_map,
           "remat": cfg.remat_policy, "capacity_factor": cfg.capacity_factor}

    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("long_500k requires sub-quadratic token mixing; "
                         f"{cfg.family} is full-attention (DESIGN.md §4)")
        return rec

    long_ctx = shape.name == "long_500k"
    mesh = make_production_mesh(multi_pod=multi_pod)
    mk_rules = (sharding.multi_pod_rules if multi_pod
                else sharding.single_pod_rules)
    rules = mk_rules(fsdp=cfg.fsdp, long_context=long_ctx)
    rules = dataclasses.replace(rules, seq_act=seq_act, attn_mode=attn_mode,
                                ep_shard_map=ep_shard_map)
    n_dev = mesh.devices.size

    t0 = time.perf_counter()
    with sharding.mesh_context(mesh, rules):
        aparams = transformer.abstract_params(cfg)
        bspecs = batch_specs(cfg, shape)
        bpspecs = batch_pspecs(cfg, shape, rules)

        if shape.kind == "train":
            step = make_sharded_train_step(cfg, optim.OptConfig(), rules,
                                           bpspecs, donate=False)
            lowered = step.lower(aparams, _abstract_opt(cfg), bspecs)
            arg_bytes = (_tree_device_bytes(aparams,
                                            transformer.param_pspecs(cfg, rules), mesh) * 3.0)
        elif shape.kind == "prefill":
            step = serving.make_sharded_prefill(cfg, rules, bpspecs,
                                                max_len=shape.seq_len)
            lowered = step.lower(aparams, bspecs)
            arg_bytes = _tree_device_bytes(
                aparams, transformer.param_pspecs(cfg, rules), mesh)
        else:                                     # decode
            acache = transformer.abstract_cache(cfg, shape.global_batch,
                                                shape.seq_len)
            step = serving.make_sharded_decode(cfg, rules, bpspecs,
                                               long_context=long_ctx,
                                               donate=False)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = step.lower(aparams, acache, bspecs, pos)
            arg_bytes = (_tree_device_bytes(
                aparams, transformer.param_pspecs(cfg, rules), mesh)
                + _tree_device_bytes(
                    acache, transformer.cache_pspecs(cfg, rules, long_ctx),
                    mesh))
        t_lower = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    cost = roofline.xla_cost_analysis(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
        }
    except Exception:
        mem_info = {}

    # loop-aware HLO walk (cost_analysis counts while bodies once — see
    # roofline.py docstring); cost_analysis kept as a cross-check floor
    hlo = compiled.as_text()
    hc = roofline.analyze_hlo(hlo, n_dev)

    from ..models.transformer import active_params
    rl = roofline.Roofline(
        flops_per_device=hc.flops,
        bytes_per_device=hc.bytes,
        wire_bytes_per_device=hc.wire_bytes,
        n_devices=n_dev,
        model_flops_global=roofline.model_flops(cfg, shape,
                                                active_params(cfg)))

    rec.update({
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "arg_bytes_per_device": arg_bytes,
        "memory_analysis": mem_info,
        "collectives": {k: round(v) for k, v in hc.wire_by_op.items()},
        "n_collectives": hc.n_collectives,
        "unknown_trip_counts": hc.unknown_trip_counts,
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
        **rl.as_dict(),
    })
    if verbose:
        fits = arg_bytes + (mem_info.get("temp_bytes") or 0)
        print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: "
              f"compile {t_compile:.1f}s  "
              f"flops/dev {rl.flops_per_device:.3e}  "
              f"bytes/dev {rl.bytes_per_device:.3e}  "
              f"wire/dev {rl.wire_bytes_per_device:.3e}  "
              f"bound={rl.bound}  frac={rl.roofline_fraction:.3f}  "
              f"args+temp/dev {fits/1e9:.2f} GB "
              f"({'fits' if fits <= roofline.HBM_BYTES else 'EXCEEDS'} 16GB)")
        print(f"[dryrun]   memory_analysis: {mem_info}")
        print(f"[dryrun]   cost_analysis: flops={cost.get('flops')}, "
              f"bytes accessed={cost.get('bytes accessed')}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--attn-mode", default="none",
                    choices=["none", "auto", "ulysses", "cp"])
    ap.add_argument("--ep-shard-map", action="store_true")
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--no-seq-act", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, multi_pod=mp, fsdp=args.fsdp,
                                   seq_act=not args.no_seq_act,
                                   attn_mode=args.attn_mode,
                                   ep_shard_map=args.ep_shard_map,
                                   causal_skip=args.causal_skip,
                                   attn_chunk=args.attn_chunk,
                                   remat=args.remat,
                                   capacity_factor=args.capacity_factor)
                except Exception as e:   # noqa: BLE001 — cell failure is a bug
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "failed", "error": repr(e)}
                    failures += 1
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
