"""Production meshes.  Functions, not module constants: importing this module
never touches jax device state (the dry-run sets XLA_FLAGS first)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16,16) over ("data","model").
    Multi-pod: 2 pods = 512 chips (2,16,16) over ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist locally (examples/tests)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
