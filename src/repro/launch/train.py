"""End-to-end elastic spot training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 100 --pods 64 [--smoke] [--ckpt-dir DIR] [--resume]

Runs the full stack: KubePACS provisions the pool from a simulated spot
market, the trainer runs real jitted train steps, interruptions trigger the
§4.1 recovery loop (emergency checkpoint → Unavailable-Offerings cache →
ILP×GSS re-optimization → restore).  On this CPU container use --smoke
(reduced configs); on a TPU fleet drop --smoke and point --ckpt-dir at
durable storage.
"""

import argparse
import json
import tempfile

from ..configs import get_config, list_archs
from ..core import Request, SpotMarketSimulator, generate_catalog
from ..data.pipeline import DataConfig
from ..optim import OptConfig
from ..runtime import ElasticConfig, ElasticSpotTrainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU container default)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--pods", type=int, default=64)
    ap.add_argument("--batch-rows", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--market-seed", type=int, default=7)
    ap.add_argument("--intent", default="none",
                    choices=["none", "network", "disk"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    intent = frozenset() if args.intent == "none" else frozenset({args.intent})
    request = Request(pods=args.pods, cpu_per_pod=4, mem_per_pod=8,
                      workload=intent)
    market = SpotMarketSimulator(generate_catalog(seed=args.market_seed),
                                 seed=args.market_seed)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix=f"ckpt_{args.arch}_")

    trainer = ElasticSpotTrainer(
        cfg, request, market, ckpt_dir,
        ElasticConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      market_check_every=10, batch_rows=args.batch_rows,
                      seq_len=args.seq_len),
        opt_cfg=OptConfig(lr=args.lr, warmup_steps=10,
                          total_steps=max(args.steps, 100)),
        dcfg=DataConfig(seed=args.seed), seed=args.seed)
    out = trainer.run()
    print(json.dumps({
        "arch": cfg.name, "steps": out["steps"],
        "first_loss": out["losses"][0], "final_loss": out["final_loss"],
        "interrupts_handled": out["interrupts_handled"],
        "recovery_times_s": out["recovery_times"],
        "ckpt_dir": ckpt_dir,
    }, indent=2))


if __name__ == "__main__":
    main()
