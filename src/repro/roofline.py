"""Three-term roofline from a compiled dry-run artifact (no hardware runs).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

Source of truth is the post-SPMD partitioned module (``compiled.as_text()``),
analyzed by :func:`analyze_hlo` — a loop-aware HLO cost walker.  We verified
empirically that ``compiled.cost_analysis()`` counts ``while``-loop bodies
exactly once (no trip-count multiplication), which under-reports scanned
transformer stacks by orders of magnitude; the walker instead:

  * builds the computation call graph (fusion ``calls=``, while ``body=`` /
    ``condition=``, ``to_apply=``) and propagates an execution-count
    multiplier, extracting static trip counts from loop conditions;
  * counts dot FLOPs exactly (2·|out|·K from contracting dims);
  * counts bytes at fusion boundaries (operands + results of top-level ops,
    skipping bookkeeping ops) — the same HBM-traffic proxy HloCostAnalysis
    uses on fused modules;
  * sums ring-algorithm wire bytes per collective:
        all-reduce        2·(N−1)/N · buf
        all-gather          (N−1)/N · result
        reduce-scatter      (N−1)   · result
        all-to-all          (N−1)/N · buf
        collective-permute            buf
    with N from ``replica_groups``.

``cost_analysis()`` is still recorded per cell as a cross-check floor.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
HBM_BYTES = 16 * 1024**3     # 16 GiB per chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,512,1024]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_TUPLE_RE = re.compile(
    r"=\s*\(\s*([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                       # per device
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0


def _collective_wire(op: str, buf: float, n: int) -> float:
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * buf
    if op == "all-gather":
        return (n - 1) / n * buf              # result shape printed
    if op == "reduce-scatter":
        return (n - 1) * buf                  # result = scattered piece
    if op == "all-to-all":
        return (n - 1) / n * buf
    return float(buf)                         # collective-permute


# ---------------------------------------------------------------------------
# Loop-aware HLO walker
# ---------------------------------------------------------------------------

# op line:  %name = dtype[dims]{layout} opkind(%a, %b, ...), attrs
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?\s*"
    r"([\w\-]+)\(([^)]*)\)(.*)$")
# tuple-result op line:  %name = (t1[..], t2[..]) opkind(...), attrs
_TUPLE_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"\(([^()]*)\)\s*"
    r"([\w\-]+)\(([^)]*)\)(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "iota", "reshape",
}


@dataclasses.dataclass
class _Op:
    name: str
    dtype: Optional[str]
    dims: Optional[str]
    kind: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    wire_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_collectives: int = 0
    unknown_trip_counts: int = 0


def _is_comp_header(line: str) -> Optional[str]:
    if not line.endswith("{") or " = " in line.split("(")[0]:
        return None
    m = _COMP_RE.match(line)
    return m.group(1) if m else None


def _parse_computations(hlo_text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    current: Optional[str] = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        header = _is_comp_header(line)
        if header is not None:
            current = header
            comps[current] = []
            continue
        if line == "}":
            current = None
            continue
        if current is None:
            continue
        lm = _LINE_RE.match(line)
        if lm:
            name, dtype, dims, kind, operands, attrs = lm.groups()
        else:
            tm = _TUPLE_LINE_RE.match(line)
            if not tm:
                continue
            name, _tuple_types, kind, operands, attrs = tm.groups()
            dtype, dims = None, None
        comps[current].append(_Op(
            name=name, dtype=dtype, dims=dims, kind=kind,
            operands=_OPERAND_RE.findall(operands or ""), attrs=attrs or ""))
    return comps


def _dims_list(dims: Optional[str]) -> List[int]:
    if not dims:
        return []
    return [int(d) for d in dims.split(",") if d]


def analyze_hlo(hlo_text: str, n_devices: int,
                max_trip: int = 10_000_000) -> HloCost:
    comps = _parse_computations(hlo_text)
    shapes: Dict[str, Dict[str, Tuple[Optional[str], Optional[str]]]] = {
        c: {op.name: (op.dtype, op.dims) for op in ops}
        for c, ops in comps.items()
    }

    # --- execution-count multipliers via the call graph -------------------
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    # entry computations: those never referenced by others
    referenced = set()
    for ops in comps.values():
        for op in ops:
            for r in _CALLS_RE.findall(op.attrs):
                referenced.add(r)
    entries = [c for c in comps if c not in referenced]
    for c in entries:
        mult[c] = 1.0

    # trip counts: static scan bounds appear as constant(N) ops inside the
    # loop-condition computation; reparse raw lines to capture the values
    const_vals: Dict[str, List[int]] = {c: [] for c in comps}
    current = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        header = _is_comp_header(line)
        if header is not None:
            current = header
            continue
        if line == "}":
            current = None
            continue
        if current is None:
            continue
        cm = _CONST_RE.search(line)
        if cm and "constant(" in line:
            v = int(cm.group(1))
            if 0 < v <= max_trip:
                const_vals[current].append(v)

    unknown_trips = 0
    # worklist propagation
    import collections as _c
    work = _c.deque(entries)
    seen_pairs = set()
    while work:
        c = work.popleft()
        for op in comps.get(c, []):
            if op.kind == "while":
                body = _BODY_RE.search(op.attrs)
                cond = _COND_RE.search(op.attrs)
                t = None
                if cond and const_vals.get(cond.group(1)):
                    t = max(const_vals[cond.group(1)])
                if t is None:
                    t = 1
                    unknown_trips += 1
                for target in ([body.group(1)] if body else []) + \
                        ([cond.group(1)] if cond else []):
                    mult[target] = mult.get(target, 0.0) + mult[c] * t
                    if (c, target) not in seen_pairs:
                        seen_pairs.add((c, target))
                        work.append(target)
            else:
                for target in _CALLS_RE.findall(op.attrs):
                    if target == c:
                        continue
                    mult[target] = mult.get(target, 0.0) + mult[c]
                    if (c, target) not in seen_pairs:
                        seen_pairs.add((c, target))
                        work.append(target)

    # --- cost accumulation -------------------------------------------------
    # byte counting happens at "top level" ops: inside fusion-called
    # computations we count FLOPs but not bytes (fusion boundary = HBM).
    fusion_called = set()
    for ops in comps.values():
        for op in ops:
            if op.kind == "fusion":
                for t in _CALLS_RE.findall(op.attrs):
                    fusion_called.add(t)

    cost = HloCost(unknown_trip_counts=unknown_trips)
    for c, ops in comps.items():
        m_c = mult.get(c, 0.0)
        if m_c <= 0:
            continue
        local_shapes = shapes[c]
        for op in ops:
            out_bytes = (_shape_bytes(op.dtype, op.dims)
                         if op.dtype is not None else 0)
            # FLOPs: dots anywhere (incl. inside fusions)
            if op.kind in ("dot", "dot_general") and op.dtype is not None:
                cm = _CONTRACT_RE.search(op.attrs)
                k = 1
                if cm and op.operands:
                    lhs = local_shapes.get(op.operands[0])
                    if lhs and lhs[1]:
                        ldims = _dims_list(lhs[1])
                        for ci in _dims_list(cm.group(1)):
                            if ci < len(ldims):
                                k *= ldims[ci]
                out_elems = 1
                for d in _dims_list(op.dims):
                    out_elems *= d
                cost.flops += m_c * 2.0 * out_elems * k
            elif op.kind == "convolution" and op.dtype is not None:
                out_elems = 1
                for d in _dims_list(op.dims):
                    out_elems *= d
                cost.flops += m_c * 2.0 * out_elems  # lower bound
            # collectives
            base = op.kind
            for coll in _COLLECTIVES:
                if base == coll or base == coll + "-start":
                    buf = out_bytes
                    if buf == 0 and op.operands:
                        o0 = local_shapes.get(op.operands[0])
                        if o0 and o0[1] is not None:
                            buf = _shape_bytes(o0[0], o0[1])
                    n = max(2, _group_size(op.attrs, n_devices))
                    wire = _collective_wire(coll, buf, n) * m_c
                    cost.wire_bytes += wire
                    cost.wire_by_op[coll] = cost.wire_by_op.get(coll, 0.0) + wire
                    cost.n_collectives += int(m_c)
                    break
            # bytes at fusion boundaries / top-level ops
            if c in fusion_called or op.kind in _SKIP_BYTES_OPS:
                continue
            operand_bytes = []
            for o in op.operands:
                sh = local_shapes.get(o)
                if sh and sh[1] is not None:
                    operand_bytes.append(_shape_bytes(sh[0], sh[1]))
            # loop-carried aliasing: slice ops, and while-body fusions with a
            # pass-through operand (same shape as the result) — XLA updates
            # these in place; per-iteration traffic is the touched region.
            slice_like = (op.kind in ("dynamic-slice", "dynamic-update-slice")
                          or (op.kind == "fusion"
                              and ("dynamic" in op.name
                                   or any(b == out_bytes
                                          for b in operand_bytes))))
            if slice_like and m_c > 1:
                # loop-carried buffer: XLA aliases it in place, so per
                # iteration only the touched slice moves.  Total traffic over
                # the loop ≈ 2·buffer (one full write + one full read across
                # all iterations) + per-iteration small operands.
                big = max(out_bytes, 1)
                small = sum(b for b in operand_bytes if b < 0.5 * big)
                cost.bytes += m_c * small + 2.0 * out_bytes
                continue
            cost.bytes += m_c * (out_bytes + sum(operand_bytes))
    return cost


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Loop-aware collective summary (kept as the public collective API)."""
    cost = analyze_hlo(hlo_text, n_devices)
    return CollectiveStats(wire_bytes=cost.wire_bytes, by_op=cost.wire_by_op,
                           count=cost.n_collectives)


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalised across JAX versions.

    Older JAX returns a dict; newer versions return a per-partition list of
    dicts (one per SPMD program — identical for our single-program modules).
    Always returns a plain dict so callers can ``.get("flops")`` safely.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    n_devices: int
    model_flops_global: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_device / LINK_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time lower bound (no overlap assumed)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak-FLOPs runtime if the step ran at its bound:
        1.0 when compute-dominated, <1 when memory/collectives dominate."""
        if self.step_s <= 0:
            return 0.0
        return self.compute_s / self.step_s

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled HLO FLOPs (global): 'useful compute' share
        — catches remat recompute, causal-mask waste, MoE capacity padding."""
        total = self.flops_per_device * self.n_devices
        if total <= 0:
            return 0.0
        return self.model_flops_global / total

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization upper bound at the roofline:
        useful FLOPs / (devices × peak × step_time)."""
        denom = self.n_devices * PEAK_FLOPS * self.step_s
        return self.model_flops_global / denom if denom > 0 else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "step_s": self.step_s,
            "roofline_fraction": self.roofline_fraction,
            "model_flops_global": self.model_flops_global,
            "model_flops_ratio": self.model_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops(cfg, shape, active_param_count: int) -> float:
    """MODEL_FLOPS = 6·N_active·D for training, 2·N_active·D for inference
    (D = tokens processed by the step)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active_param_count * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active_param_count * tokens
    tokens = shape.global_batch * 1          # decode: one token per row
    return 2.0 * active_param_count * tokens
