"""Logical-axis sharding rules (MaxText-style) for all assigned archs.

Weights carry *logical* axis names; :func:`logical_to_pspec` maps them to
mesh axes under a :class:`MeshRules`.  Activation/cache constraints are
config-aware (GQA head counts are not always divisible by the model axis, so
we shard heads when divisible and head_dim otherwise).

A contextvar holds the active (mesh, rules) so model code can call
:func:`constrain` unconditionally: it is a no-op outside a mesh context
(CPU smoke tests), and a `with_sharding_constraint` inside one (dry-run,
production lowering).
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """How logical axes map onto mesh axes."""

    batch: Tuple[str, ...]                   # data-parallel axes
    model: Optional[str] = "model"           # tensor/expert-parallel axis
    fsdp: Optional[Tuple[str, ...]] = None   # weight-shard axes (ZeRO-3 style)
    seq: Optional[Tuple[str, ...]] = None    # context-parallel axes (long decode)
    seq_act: bool = True                     # Megatron-SP: shard the sequence
    #                                          dim of inter-block activations
    #                                          over the model axis
    attn_mode: str = "none"                  # "none" | "auto" | "ulysses" | "cp"
    #                                          how self-attention internals are
    #                                          parallelized (see flash_mode)
    ep_shard_map: bool = False               # explicit shard_map expert
    #                                          parallelism: local-rows x
    #                                          local-experts + one psum/layer
    #                                          (vs GSPMD gather/scatter)


def single_pod_rules(fsdp: bool = False, long_context: bool = False) -> MeshRules:
    return MeshRules(batch=("data",),
                     fsdp=("data",) if fsdp else None,
                     seq=("data",) if long_context else None)


def multi_pod_rules(fsdp: bool = False, long_context: bool = False) -> MeshRules:
    return MeshRules(batch=("pod", "data"),
                     fsdp=("pod", "data") if fsdp else None,
                     seq=("pod", "data") if long_context else None)


# -- logical weight axes -> PartitionSpec -----------------------------------

#: logical axis names that live on the model (tensor-parallel) axis
_MODEL_AXES = {"vocab", "q", "kv", "ff", "inner"}
#: logical axis names that live on the fsdp axes when fsdp is enabled
_FSDP_AXES = {"embed", "expert_in"}


def logical_to_pspec(axes: Sequence[Optional[str]], rules: MeshRules,
                     expert_parallel: bool = True) -> P:
    out = []
    for ax in axes:
        if ax in _MODEL_AXES:
            out.append(rules.model)
        elif ax == "experts":
            out.append(rules.model if expert_parallel else None)
        elif ax == "expert_ff":
            out.append(None if expert_parallel else rules.model)
        elif ax in _FSDP_AXES:
            out.append(rules.fsdp)
        else:                       # None, "layers", "state", "convk", ...
            out.append(None)
    return P(*out)


# -- ambient mesh context -----------------------------------------------------

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_mesh", default=None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: MeshRules):
    """Install (mesh, rules) for `constrain` + enter the jax mesh context."""
    token = _ACTIVE.set((mesh, rules))
    try:
        with mesh:
            yield
    finally:
        _ACTIVE.reset(token)


def active() -> Optional[Tuple[Mesh, MeshRules]]:
    return _ACTIVE.get()


def constrain(x, spec: P):
    """with_sharding_constraint when a mesh is active, else identity."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, _ = ctx
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def rules_or_default() -> MeshRules:
    ctx = _ACTIVE.get()
    return ctx[1] if ctx is not None else MeshRules(batch=())


def as_shardings(pspec_tree):
    """PartitionSpec tree -> NamedSharding tree on the active mesh (jit's
    in/out_shardings want concrete Shardings in recent JAX)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return pspec_tree
    mesh, _ = ctx
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        pspec_tree, is_leaf=lambda s: isinstance(s, P))


# -- config-aware activation / cache specs -----------------------------------

def _axis_size(name: Optional[str]) -> int:
    ctx = _ACTIVE.get()
    if ctx is None or name is None:
        return 1
    mesh, _ = ctx
    if isinstance(name, tuple):
        size = 1
        for n in name:
            size *= mesh.shape[n]
        return size
    return mesh.shape[name]


def batch_axes() -> Optional[Tuple[str, ...]]:
    r = rules_or_default()
    return r.batch if r.batch else None


def act_spec_btd(seq_len: Optional[int] = None) -> P:
    """(batch, seq, d_model) activations: batch over data axes; with
    Megatron-style sequence parallelism the *inter-block* sequence dim also
    shards over the model axis (cuts the scan-carried remat residuals by the
    model-axis size) whenever it divides evenly."""
    r = rules_or_default()
    seq_ax = None
    if (r.seq_act and seq_len is not None and r.model is not None
            and _axis_size(r.model) > 1 and seq_len % _axis_size(r.model) == 0
            and seq_len > 1):
        seq_ax = r.model
    return P(batch_axes(), seq_ax, None)


def head_axis_spec(n_heads: int, head_dim: int) -> Tuple[Optional[str], Optional[str]]:
    """Which of (heads, head_dim) goes on the model axis for (B,S,H,hd)."""
    r = rules_or_default()
    m = r.model
    msize = _axis_size(m)
    if msize <= 1:
        return None, None
    if n_heads % msize == 0:
        return m, None
    if head_dim % msize == 0:
        return None, m
    return None, None


def attn_act_spec(n_heads: int, head_dim: int) -> P:
    h_ax, d_ax = head_axis_spec(n_heads, head_dim)
    return P(batch_axes(), None, h_ax, d_ax)


def kv_cache_spec(n_kv_heads: int, head_dim: int, long_context: bool) -> P:
    """(B, T, KV, hd) cache: batch over data unless long-context (then the
    sequence axis takes the data axes and batch stays replicated)."""
    r = rules_or_default()
    h_ax, d_ax = head_axis_spec(n_kv_heads, head_dim)
    if long_context and r.seq:
        return P(None, r.seq, h_ax, d_ax)
    return P(batch_axes(), None, h_ax, d_ax)


def mamba_state_spec() -> P:
    """(B, d_inner, state): d_inner on the model axis."""
    r = rules_or_default()
    return P(batch_axes(), r.model, None)


def mamba_conv_state_spec() -> P:
    """(B, convk-1, d_inner)."""
    r = rules_or_default()
    return P(batch_axes(), None, r.model)


def flash_mode(batch_size: int, seq_len: int) -> str:
    """How to parallelize flash self-attention on the active mesh.

    * "ulysses" — reshard batch over (data × model); attention is then fully
      device-local (no per-block collectives).  Needs B divisible by the
      whole mesh.
    * "cp" — context parallelism: shard the q sequence dim over the model
      axis; k/v are gathered once per layer, dk/dv partial-summed once after
      the block loop.  Needs S divisible by the model axis.
    * "none" — leave layout to GSPMD propagation (baseline).
    """
    ctx = _ACTIVE.get()
    if ctx is None:
        return "none"
    mesh, r = ctx
    if r.attn_mode == "none" or r.model is None:
        return "none"
    dm = _axis_size(r.batch) * _axis_size(r.model)
    msize = _axis_size(r.model)
    if (r.attn_mode in ("auto", "ulysses") and dm > 1
            and batch_size % dm == 0):
        return "ulysses"
    if (r.attn_mode in ("auto", "cp") and msize > 1
            and seq_len % msize == 0 and seq_len > msize):
        return "cp"
    return "none"


def ulysses_spec(rank: int) -> P:
    """(B, ...) with batch sharded over every mesh axis."""
    r = rules_or_default()
    axes = tuple(r.batch) + ((r.model,) if r.model else ())
    return P(axes, *([None] * (rank - 1)))


def cp_q_spec(rank: int) -> P:
    """(B, S, ...) with the q sequence dim on the model axis."""
    r = rules_or_default()
    return P(batch_axes(), r.model, *([None] * (rank - 2)))


def cp_kv_spec(rank: int) -> P:
    """(B, S, KV, hd) k/v replicated over model (gathered once per layer)."""
    return P(batch_axes(), *([None] * (rank - 1)))


def moe_group_spec() -> P:
    """(G, E, C, d) expert buffers: groups on data, experts on model."""
    r = rules_or_default()
    return P(batch_axes(), r.model, None, None)


def logits_spec() -> P:
    """(B, S, vocab) with vocab on the model axis."""
    r = rules_or_default()
    return P(batch_axes(), None, r.model)
