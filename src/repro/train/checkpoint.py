"""Fault-tolerant checkpointing: atomic, resumable, retention-managed.

Layout: ``<dir>/step_<k>/shard_<host>.npz`` + ``meta.json``; a step directory
is staged as ``.tmp-step_<k>`` and atomically renamed once fully written, so
a preemption mid-save can never corrupt the latest checkpoint (the 2-minute
spot interruption notice triggers an *emergency save* through the same path).
Trees are flattened to path-keyed arrays, so params/opt_state of any arch
round-trip without schema registration.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(template: PyTree, arrays: Dict[str, np.ndarray]) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = arrays[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for _, l in
                                                  zip(flat, leaves)])


def save_checkpoint(ckpt_dir: str, step: int, params: PyTree,
                    opt_state: Optional[PyTree] = None,
                    meta: Optional[Dict[str, Any]] = None,
                    keep: int = 3, host: int = 0) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp-step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, f"params_{host}.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(tmp, f"opt_{host}.npz"), **_flatten(opt_state))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "time": time.time(), **(meta or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            out.append(int(name.split("_")[1]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, params_template: PyTree,
                       opt_template: Optional[PyTree] = None,
                       step: Optional[int] = None, host: int = 0,
                       ) -> Tuple[PyTree, Optional[PyTree], Dict[str, Any]]:
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(d, f"params_{host}.npz")) as z:
        params = _unflatten(params_template, dict(z))
    opt_state = None
    if opt_template is not None:
        with np.load(os.path.join(d, f"opt_{host}.npz")) as z:
            opt_state = _unflatten(opt_template, dict(z))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    return params, opt_state, meta
