from .checkpoint import save_checkpoint, restore_checkpoint, latest_step
from .loop import make_train_step, TrainState

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "make_train_step", "TrainState"]
