"""Jitted train step factory + a minimal state container.

``make_train_step`` builds a single jitted function computing loss, grads,
clipping, and the AdamW update; with a mesh context active it is given
explicit in/out shardings (params/opt sharded per the logical rules, batch
sharded over the data axes) — the same function the multi-pod dry-run lowers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import optim, sharding
from ..configs.base import ModelConfig
from ..models import transformer

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: int = 0


def train_step_fn(params, opt_state, batch, *, cfg: ModelConfig,
                  opt_cfg: optim.OptConfig):
    (loss, metrics), grads = jax.value_and_grad(
        transformer.loss_fn, has_aux=True)(params, cfg, batch)
    params, opt_state, opt_metrics = optim.adamw_update(
        params, grads, opt_state, opt_cfg)
    metrics = {**metrics, **opt_metrics, "total_loss": loss}
    return params, opt_state, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: optim.OptConfig,
                    donate: bool = True) -> Callable:
    fn = functools.partial(train_step_fn, cfg=cfg, opt_cfg=opt_cfg)
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ())


def make_sharded_train_step(cfg: ModelConfig, opt_cfg: optim.OptConfig,
                            rules: sharding.MeshRules, batch_pspecs,
                            donate: bool = True):
    """Explicit in/out shardings — what dryrun.py lowers and compiles."""
    pspecs = transformer.param_pspecs(cfg, rules)
    opt_pspecs = {"m": pspecs, "v": pspecs,
                  "step": jax.sharding.PartitionSpec()}
    out_metrics = jax.sharding.PartitionSpec()
    fn = functools.partial(train_step_fn, cfg=cfg, opt_cfg=opt_cfg)
    return jax.jit(
        fn,
        in_shardings=sharding.as_shardings((pspecs, opt_pspecs, batch_pspecs)),
        out_shardings=sharding.as_shardings((pspecs, opt_pspecs, out_metrics)),
        donate_argnums=(0, 1) if donate else ())
