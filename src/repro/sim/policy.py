"""Pluggable provisioning policies for the scenario engine.

Every policy answers the same two questions the engine asks —

  * ``provision(request, snapshot, now)``: build a pool from scratch
    (initial provisioning, demand changes), and
  * ``on_interrupts(notices, request, snapshot, surviving_pods, now)``:
    react to capacity loss by provisioning the shortfall with the
    interrupted offerings excluded (the §4.1 loop)

— and returns the core :class:`ProvisioningDecision`, so KubePACS, a
Karpenter-like baseline, and fixed-α ablations all produce comparable,
trace-recordable decision sequences.  Policies must be deterministic
functions of their inputs (no RNG, no wall clock in the decision content):
that is what makes trace replay reproduce identical decisions.  The
diagnostic ``wall_seconds`` stamp goes through an injectable ``clock`` so
tests can assert *full* ``ProvisioningDecision`` equality.

Policies are also engine *observers* (DESIGN.md §10): the engine feeds
them the event stream (market refreshes, interrupt samples, fulfillment
grants) through the no-op ``observe_*`` hooks below.  Stateful policies —
``kubepacs_risk`` updates its online risk estimators this way — therefore
stay deterministic under replay: the recorded stream re-derives the
identical estimator state at every decision point.

Spec strings (``Scenario.policy``):

    "kubepacs"               guarded GSS × ILP (the paper's method)
    "kubepacs_unguarded"     pure Algorithm-1 GSS over α ∈ [0, 1]
    "kubepacs_risk[:H]"      risk-adjusted E_risk over an H-hour horizon
                             (default 12) — DESIGN.md §10
    "karpenter_like"         price-capacity-optimized baseline (§5.4)
    "fixed_alpha:<α>"        single ILP solve at a fixed α (Table 2)
    "serving_slo[:H]"        SLO-driven serving: QPS/pod objective from the
                             roofline perf model + latency-SLO feasibility
                             mask; optional H-hour risk discount
                             (DESIGN.md §15)
    "kubepacs_region"        KubePACS objective + the scenario RegionConfig's
                             side-constraints (caps / spread / egress)
                             through solve_with_regions (DESIGN.md §17)
    "region_pinned[:R]"      the single-market strawman: provision only in
                             region R (default: the config's home region) —
                             what bench_region measures hardened against

The optional ``precompiled=(items, CompiledMarket)`` argument lets the
multi-seed runner share one preprocessed market across N replica policies
(PR 1's batched engine is then reused instead of re-solving per replica).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.efficiency import (CandidateItem, NodePool, Request,
                               decision_metrics, pool_capacity_rate)
from ..core.gss import bracketed_gss
from ..core.ilp import CompiledMarket, compile_market, solve_ilp
from ..core.market import Offering
from ..core.baselines import karpenter_like
from ..core.provisioner import (DecisionMemo, KubePACSProvisioner,
                                ProvisioningDecision,
                                UnavailableOfferingsCache, exclusion_mask,
                                preprocess)
from ..risk.estimators import RiskEstimators, RiskParams
from ..risk.objective import (e_risk, reweight_candidates, risk_adjustment,
                              serving_risk_adjustment)
from ..serve_sim.perf_model import (ServingProfile, default_profile,
                                    default_slo_ms, serving_table)
from .events import InterruptNotice

Precompiled = Tuple[List[CandidateItem], CompiledMarket]

#: default forecasting horizon (hours) of "kubepacs_risk" without ":H"
DEFAULT_RISK_HORIZON = 12.0


class Policy:
    name = "abstract"
    decision_memo: Optional[DecisionMemo] = None

    def provision(self, request: Request, snapshot: Sequence[Offering],
                  now: float, precompiled: Optional[Precompiled] = None,
                  ) -> ProvisioningDecision:
        raise NotImplementedError

    def on_interrupts(self, notices: Sequence[InterruptNotice],
                      request: Request, snapshot: Sequence[Offering],
                      surviving_pods: int, now: float,
                      precompiled: Optional[Precompiled] = None,
                      ) -> Optional[ProvisioningDecision]:
        raise NotImplementedError

    # -- cross-replica memoization hooks (DESIGN.md §11) --------------------
    def set_decision_memo(self, memo: Optional[DecisionMemo]) -> None:
        """Attach the fleet engine's shared :class:`DecisionMemo` (None
        detaches).  Policies that route their solve through the memo must
        key it on *everything* decision-relevant; stateful policies
        additionally surface their internal state via :meth:`memo_digest`."""
        self.decision_memo = memo

    def memo_digest(self) -> Optional[str]:
        """Digest of internal decision-relevant state beyond the (snapshot,
        request, excluded-set) the memo key already covers.  ``None`` means
        the policy is stateless given those inputs (the KubePACS/baseline
        policies — their only state is the TTL exclusion cache, which the
        memo key captures as the resolved excluded frozenset)."""
        return None

    def set_solve_batch(self, batch) -> None:
        """Attach the fleet engine's collect-then-solve :class:`SolveBatch`
        (DESIGN.md §12).  Base implementation: no-op — policies without a
        batchable guarded-GSS solve path simply keep solving inline, which
        is always correct (batching changes execution, never content)."""

    # -- engine observer hooks (no-ops for stateless policies) --------------
    def bind(self, catalog: Sequence[Offering]) -> None:
        """Called once by the engine with the static offering universe."""

    def bind_chaos(self, chaos) -> None:
        """Attach the scenario's :class:`~repro.chaos.faults.ChaosController`
        (None when the scenario declares no faults).  Base implementation:
        no-op — unhardened policies decide on whatever (possibly corrupted)
        snapshot the engine hands them, which is exactly the naive control
        plane the chaos benchmark measures against (DESIGN.md §16)."""

    def observe_market(self, time: float, spot: np.ndarray,
                       t3: np.ndarray) -> None:
        """A market refresh (tick or shock) produced live (spot, t3)."""

    def observe_interrupts(self, time: float, dt: float,
                           pool: Dict[str, int],
                           notices: Sequence[InterruptNotice]) -> None:
        """A tick sampled ``notices`` for ``pool`` exposed over ``dt``."""

    def observe_fulfillment(self, time: float, requested: Dict[str, int],
                            grants: Dict[str, int]) -> None:
        """A launch's fulfillment round granted ``grants`` of ``requested``."""

    def observe_pool(self, time: float, pool: NodePool,
                     reason: str) -> None:
        """The engine's pool changed (launch merge or interruption losses)
        — the serving co-simulation's capacity-timeline hook (§15)."""


class KubePACSPolicy(Policy):
    """The paper's provisioner, including its UnavailableOfferingsCache."""

    name = "kubepacs"

    def __init__(self, tolerance: float = 0.01, ttl_hours: float = 2.0,
                 guarded: bool = True,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        self.provisioner = KubePACSProvisioner(tolerance=tolerance,
                                               ttl_hours=ttl_hours,
                                               guarded_gss=guarded,
                                               timer=clock)
        if not guarded:
            self.name = "kubepacs_unguarded"

    def set_decision_memo(self, memo):
        self.decision_memo = memo
        self.provisioner.decision_memo = memo

    def set_solve_batch(self, batch):
        # the provisioner's guarded path defers memo-miss solves into the
        # batch; the unguarded variant ignores it (provisioner-side check)
        self.provisioner.solve_batch = batch

    def provision(self, request, snapshot, now, precompiled=None):
        self.provisioner.clock = now
        return self.provisioner.provision(request, snapshot, precompiled)

    def on_interrupts(self, notices, request, snapshot, surviving_pods, now,
                      precompiled=None):
        self.provisioner.clock = now
        self.provisioner.enqueue([n.to_core() for n in notices])
        return self.provisioner.handle_interrupts(
            request, snapshot, surviving_pods=surviving_pods,
            precompiled=precompiled)


class _BaselinePolicy(Policy):
    """Shared §4.1 plumbing (TTL exclusion cache, shortfall requests) for
    baselines that are not the KubePACS provisioner."""

    def __init__(self, ttl_hours: float = 2.0,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        self.cache = UnavailableOfferingsCache(ttl_hours)
        self.clock = clock

    def _solve(self, items: List[CandidateItem], req_pods: int,
               exclude: Optional[np.ndarray],
               precompiled: Optional[Precompiled]) -> Tuple[NodePool, Optional[float]]:
        raise NotImplementedError

    def _extra_mask(self, items: List[CandidateItem]) -> Optional[np.ndarray]:
        """Optional per-candidate feasibility mask ORed into the §4.1
        exclusion path (None = no constraint — the default is bit-inert;
        ``exclusion_mask(…, extra=None)`` is the pre-existing call)."""
        return None

    def provision(self, request, snapshot, now, precompiled=None):
        t0 = self.clock()
        excluded = self.cache.excluded(now)
        memo = self.decision_memo
        mkey = memo.key(request, excluded) if memo is not None else None
        if mkey is not None:
            hit = memo.fetch(mkey, self.clock() - t0)
            if hit is not None:
                return hit
        items = precompiled[0] if precompiled is not None \
            else preprocess(snapshot, request)
        exclude = exclusion_mask(items, excluded,
                                 extra=self._extra_mask(items))
        pool, alpha = self._solve(items, request.pods, exclude, precompiled)
        pool.request = request
        pool.alpha = alpha
        decision = ProvisioningDecision(
            pool=pool, trace=None, alpha=alpha,
            wall_seconds=self.clock() - t0,
            excluded_offerings=excluded,
            metrics=decision_metrics(pool, request.pods))
        if mkey is not None:
            memo.store(mkey, decision)
        return decision

    def on_interrupts(self, notices, request, snapshot, surviving_pods, now,
                      precompiled=None):
        if not notices:
            return None
        for n in notices:
            self.cache.add(n.offering_id, now)
        shortfall = max(0, request.pods - surviving_pods)
        if shortfall == 0:
            return None
        repl = dataclasses.replace(request, pods=shortfall)
        return self.provision(repl, snapshot, now, precompiled)


class FixedAlphaPolicy(_BaselinePolicy):
    """Single ILP solve at a fixed α — the Table 2 ablation as a policy."""

    def __init__(self, alpha: float, ttl_hours: float = 2.0,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        super().__init__(ttl_hours, clock)
        self.alpha = float(alpha)
        self.name = f"fixed_alpha:{alpha:g}"

    def _solve(self, items, req_pods, exclude, precompiled):
        market = precompiled[1] if precompiled is not None else None
        counts = solve_ilp(items, req_pods, self.alpha, market=market,
                           exclude=exclude)
        if counts is None:
            return NodePool(items=[], counts=[]), self.alpha
        return NodePool(items=list(items), counts=list(counts)).nonzero(), \
            self.alpha


class KarpenterLikePolicy(_BaselinePolicy):
    """Price-capacity-optimized consolidation (no BS/T3 awareness, §5.4)."""

    name = "karpenter_like"

    def _solve(self, items, req_pods, exclude, precompiled):
        if exclude is not None:
            items = [it for it, ex in zip(items, exclude) if not ex]
        return karpenter_like(items, req_pods), None


class KubePACSRiskPolicy(_BaselinePolicy):
    """Risk-adjusted KubePACS: guarded GSS × ILP over E_risk (DESIGN.md §10).

    Decisions maximize the risk-adjusted efficiency of
    :mod:`repro.risk.objective` — Perf_i discounted by expected uptime and
    fulfillment rate, SP_i charged with drifted price and expected
    re-provision cost over ``horizon`` hours — by substituting adjusted
    (Perf̂, SP̂) vectors into the *unchanged* PR 1 solver stack.  The
    returned pool references the real items, so cost accrual and the
    canonical metrics stay in real dollars; the optimized risk score rides
    along as the extra ``e_risk`` metric.  The §4.1 exclusion/shortfall
    protocol is inherited from :class:`_BaselinePolicy`.

    Deterministic given (snapshot, estimator state): estimators update only
    through the engine's observe hooks, which replay feeds the identical
    recorded stream.
    """

    def __init__(self, horizon: float = DEFAULT_RISK_HORIZON,
                 tolerance: float = 0.01, ttl_hours: float = 2.0,
                 params: Optional[RiskParams] = None,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        super().__init__(ttl_hours, clock)
        self.horizon = float(horizon)
        self.name = f"kubepacs_risk:{self.horizon:g}"
        self.tolerance = tolerance
        self.params = params or RiskParams()
        self.estimators: Optional[RiskEstimators] = None
        # compiled-market cache mirroring KubePACSProvisioner._compiled:
        # preprocessing/bundle-splitting depends only on (snapshot identity,
        # per-pod request shape), so the same-tick §4.1 re-provision reuses
        # the initial decision's CompiledMarket and only the O(n)
        # reweighting runs per solve
        self._market_snapshot: Optional[Sequence[Offering]] = None
        self._market_shape: Optional[Tuple] = None
        self._market_items: List[CandidateItem] = []
        self._market: Optional[CompiledMarket] = None

    # -- estimator lifecycle -----------------------------------------------
    def bind(self, catalog):
        self.estimators = RiskEstimators(catalog, self.params)

    def _ensure_estimators(self, snapshot) -> RiskEstimators:
        # standalone use (no engine): bind lazily to the first snapshot —
        # offering order there matches the catalog (snapshot_with preserves
        # it), so indices line up with later observations
        if self.estimators is None:
            self.estimators = RiskEstimators(snapshot, self.params)
        return self.estimators

    def observe_market(self, time, spot, t3):
        if self.estimators is not None:
            self.estimators.on_market_state(time, spot, t3)

    def observe_interrupts(self, time, dt, pool, notices):
        if self.estimators is not None:
            self.estimators.on_interrupts(time, dt, pool, notices)

    def observe_fulfillment(self, time, requested, grants):
        if self.estimators is not None:
            self.estimators.on_fulfillment(time, requested, grants)

    # -- decisions ----------------------------------------------------------
    def _compiled(self, request, snapshot,
                  precompiled: Optional[Precompiled]) -> Precompiled:
        if precompiled is not None:
            return precompiled
        # the held snapshot reference keeps it alive, so the identity check
        # cannot alias a recycled object id
        shape = (request.cpu_per_pod, request.mem_per_pod, request.workload)
        if snapshot is not self._market_snapshot or \
                shape != self._market_shape:
            items = preprocess(snapshot, request)
            self._market_snapshot = snapshot
            self._market_shape = shape
            self._market_items = items
            self._market = compile_market(items)
        return self._market_items, self._market

    def memo_digest(self):
        # the estimator arrays are the only decision-relevant state beyond
        # the memo key's (snapshot, request, excluded) — two replicas with
        # identical observation histories share identical digests, so their
        # risk-adjusted solves coincide (DESIGN.md §11)
        if self.estimators is None:
            return None
        return self.estimators.digest()

    def provision(self, request, snapshot, now, precompiled=None):
        t0 = self.clock()
        est = self._ensure_estimators(snapshot)
        excluded = self.cache.excluded(now)
        memo = self.decision_memo
        mkey = memo.key(request, excluded) if memo is not None else None
        if mkey is not None:
            hit = memo.fetch(mkey, self.clock() - t0)
            if hit is not None:
                return hit
        items, market = self._compiled(request, snapshot, precompiled)
        exclude = exclusion_mask(items, excluded)
        adj = risk_adjustment(items, est, self.horizon)
        items_adj, market_adj = reweight_candidates(items, adj, market)
        pool_adj, trace = bracketed_gss(items_adj, request.pods,
                                        tolerance=self.tolerance,
                                        market=market_adj, exclude=exclude,
                                        timer=self.clock)
        if pool_adj is None:     # demand exceeds bounded capacity
            pool = NodePool(items=[], counts=[], request=request)
            alpha = None
            risk_score = 0.0
        else:
            # map the solved counts back onto the real items so downstream
            # cost/perf accounting uses live market numbers, not Perf̂/SP̂
            real = {it.offering.offering_id: it for it in items}
            pool = NodePool(
                items=[real[it.offering.offering_id]
                       for it in pool_adj.items],
                counts=list(pool_adj.counts), alpha=pool_adj.alpha,
                request=request)
            alpha = pool_adj.alpha
            risk_score = e_risk(pool, request.pods, items_adj)
        metrics = decision_metrics(pool, request.pods)
        metrics["e_risk"] = risk_score
        decision = ProvisioningDecision(pool=pool, trace=trace, alpha=alpha,
                                        wall_seconds=self.clock() - t0,
                                        excluded_offerings=excluded,
                                        metrics=metrics)
        if mkey is not None:
            memo.store(mkey, decision)
        return decision


class ServingSLOPolicy(KubePACSRiskPolicy):
    """SLO-driven serving provisioning (DESIGN.md §15): the decision plane
    connected to the ML stack's perf model.

    Two changes relative to the scalar-perf policies, both through
    existing solver entry points:

    * **objective** — Perf_i is replaced by the serving capacity rate
      ``QPS/pod_i · Pod_i`` from :mod:`repro.serve_sim.perf_model`
      (roofline-derived, analytic fallback without jax), so GSS × ILP
      maximizes *served QPS per dollar* instead of CoreMark per dollar;
    * **feasibility** — offerings whose per-request decode latency
      exceeds ``slo_ms`` are ORed into the §4.1 exclusion mask
      (``exclusion_mask(extra=)``), entering ``solve_ilp`` as hard
      infeasibility, exactly like TTL-cached interrupted offerings.

    Inherits the risk policy's machinery: the compiled-market cache, the
    §4.1 shortfall protocol, and the online estimators — with
    ``risk_horizon > 0`` the serving rate is additionally discounted by
    expected uptime × fulfillment via
    :func:`repro.risk.objective.serving_risk_adjustment` (at the default
    horizon 0 that reduces exactly to the pure serving objective).
    Deterministic given (snapshot, estimator state): the serving table is
    a pure function of (profile, offering set), cached by digest.
    """

    def __init__(self, profile: Optional[ServingProfile] = None,
                 slo_ms: Optional[float] = None, risk_horizon: float = 0.0,
                 tolerance: float = 0.01, ttl_hours: float = 2.0,
                 params: Optional[RiskParams] = None,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        super().__init__(horizon=risk_horizon, tolerance=tolerance,
                         ttl_hours=ttl_hours, params=params, clock=clock)
        self.profile = profile if profile is not None else default_profile()
        self.slo_ms = float(slo_ms) if slo_ms is not None \
            else default_slo_ms(self.profile)
        self.name = ("serving_slo" if risk_horizon <= 0
                     else f"serving_slo:{risk_horizon:g}")

    def memo_digest(self):
        # beyond the risk digest, decisions depend on the perf-model table
        # (profile digest pins mode/config/shape) and the SLO threshold
        base = super().memo_digest() or ""
        return f"{base}|{self.profile.digest}|{self.slo_ms!r}"

    def provision(self, request, snapshot, now, precompiled=None):
        t0 = self.clock()
        est = self._ensure_estimators(snapshot)
        excluded = self.cache.excluded(now)
        memo = self.decision_memo
        mkey = memo.key(request, excluded) if memo is not None else None
        if mkey is not None:
            hit = memo.fetch(mkey, self.clock() - t0)
            if hit is not None:
                return hit
        items, market = self._compiled(request, snapshot, precompiled)
        table = serving_table(self.profile,
                              [it.offering for it in items])
        slo_mask = table.slo_mask(self.slo_ms)
        exclude = exclusion_mask(items, excluded, extra=slo_mask)
        # serving capacity rate per node, risk-discounted when horizon > 0
        serve_perf = table.qps_per_pod * np.array(
            [it.pods for it in items], dtype=np.float64)
        base_perf = np.array([it.perf for it in items], dtype=np.float64)
        adj = serving_risk_adjustment(
            risk_adjustment(items, est, self.horizon), serve_perf, base_perf)
        items_adj, market_adj = reweight_candidates(items, adj, market)
        pool_adj, trace = bracketed_gss(items_adj, request.pods,
                                        tolerance=self.tolerance,
                                        market=market_adj, exclude=exclude,
                                        timer=self.clock)
        if pool_adj is None:     # demand exceeds SLO-feasible capacity
            pool = NodePool(items=[], counts=[], request=request)
            alpha = None
        else:
            real = {it.offering.offering_id: it for it in items}
            pool = NodePool(
                items=[real[it.offering.offering_id]
                       for it in pool_adj.items],
                counts=list(pool_adj.counts), alpha=pool_adj.alpha,
                request=request)
            alpha = pool_adj.alpha
        metrics = decision_metrics(pool, request.pods)
        qps = table.qps_map()
        metrics["serve_qps_capacity"] = pool_capacity_rate(pool, qps)
        metrics["serve_slo_masked"] = float(0 if slo_mask is None
                                            else int(slo_mask.sum()))
        metrics["serve_infeasible"] = float(pool_adj is None)
        decision = ProvisioningDecision(pool=pool, trace=trace, alpha=alpha,
                                        wall_seconds=self.clock() - t0,
                                        excluded_offerings=excluded,
                                        metrics=metrics)
        if mkey is not None:
            memo.store(mkey, decision)
        return decision


def make_policy(spec: str, tolerance: float = 0.01,
                ttl_hours: float = 2.0,
                clock: Callable[[], float] = time.perf_counter,
                region=None) -> Policy:
    """Parse a scenario's policy spec string (see module doc).

    ``region`` threads the scenario's :class:`~repro.region.RegionConfig`
    (or None) to the policies that honor side-constraints — the engines
    pass ``scenario.region`` so region-aware specs need no extra wiring."""
    if spec == "kubepacs":
        return KubePACSPolicy(tolerance=tolerance, ttl_hours=ttl_hours,
                              clock=clock)
    if spec == "kubepacs_unguarded":
        return KubePACSPolicy(tolerance=tolerance, ttl_hours=ttl_hours,
                              guarded=False, clock=clock)
    if spec == "kubepacs_risk" or spec.startswith("kubepacs_risk:"):
        horizon = (float(spec.split(":", 1)[1])
                   if ":" in spec else DEFAULT_RISK_HORIZON)
        return KubePACSRiskPolicy(horizon=horizon, tolerance=tolerance,
                                  ttl_hours=ttl_hours, clock=clock)
    if spec == "serving_slo" or spec.startswith("serving_slo:"):
        risk_horizon = float(spec.split(":", 1)[1]) if ":" in spec else 0.0
        return ServingSLOPolicy(risk_horizon=risk_horizon,
                                tolerance=tolerance, ttl_hours=ttl_hours,
                                clock=clock)
    if spec == "hardened":
        # lazy: repro.chaos.guard imports this module (the Policy base)
        from ..chaos.guard import HardenedPolicy
        return HardenedPolicy(tolerance=tolerance, ttl_hours=ttl_hours,
                              clock=clock, region=region)
    if spec == "kubepacs_region":
        # lazy: repro.region.policy imports this module (the base classes)
        from ..region.policy import RegionAwarePolicy
        return RegionAwarePolicy(region, tolerance=tolerance,
                                 ttl_hours=ttl_hours, clock=clock)
    if spec == "region_pinned" or spec.startswith("region_pinned:"):
        pin = spec.split(":", 1)[1] if ":" in spec else ""
        if not pin:
            if region is None or not region.regions:
                raise ValueError("region_pinned needs ':REGION' or a "
                                 "scenario RegionConfig to pick the home")
            pin = region.home
        from ..region.policy import RegionPinnedPolicy
        return RegionPinnedPolicy(pin, tolerance=tolerance,
                                  ttl_hours=ttl_hours, clock=clock)
    if spec == "karpenter_like":
        return KarpenterLikePolicy(ttl_hours=ttl_hours, clock=clock)
    if spec.startswith("fixed_alpha:"):
        return FixedAlphaPolicy(float(spec.split(":", 1)[1]),
                                ttl_hours=ttl_hours, clock=clock)
    raise ValueError(f"unknown policy spec {spec!r}")
