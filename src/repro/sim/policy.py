"""Pluggable provisioning policies for the scenario engine.

Every policy answers the same two questions the engine asks —

  * ``provision(request, snapshot, now)``: build a pool from scratch
    (initial provisioning, demand changes), and
  * ``on_interrupts(notices, request, snapshot, surviving_pods, now)``:
    react to capacity loss by provisioning the shortfall with the
    interrupted offerings excluded (the §4.1 loop)

— and returns the core :class:`ProvisioningDecision`, so KubePACS, a
Karpenter-like baseline, and fixed-α ablations all produce comparable,
trace-recordable decision sequences.  Policies must be deterministic
functions of their inputs (no RNG, no wall clock in the decision content):
that is what makes trace replay reproduce identical decisions.

Spec strings (``Scenario.policy``):

    "kubepacs"               guarded GSS × ILP (the paper's method)
    "kubepacs_unguarded"     pure Algorithm-1 GSS over α ∈ [0, 1]
    "karpenter_like"         price-capacity-optimized baseline (§5.4)
    "fixed_alpha:<α>"        single ILP solve at a fixed α (Table 2)

The optional ``precompiled=(items, CompiledMarket)`` argument lets the
multi-seed runner share one preprocessed market across N replica policies
(PR 1's batched engine is then reused instead of re-solving per replica).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.efficiency import (CandidateItem, NodePool, Request,
                               decision_metrics)
from ..core.ilp import CompiledMarket, solve_ilp
from ..core.market import Offering
from ..core.baselines import karpenter_like
from ..core.provisioner import (KubePACSProvisioner, ProvisioningDecision,
                                UnavailableOfferingsCache, exclusion_mask,
                                preprocess)
from .events import InterruptNotice

Precompiled = Tuple[List[CandidateItem], CompiledMarket]


class Policy:
    name = "abstract"

    def provision(self, request: Request, snapshot: Sequence[Offering],
                  now: float, precompiled: Optional[Precompiled] = None,
                  ) -> ProvisioningDecision:
        raise NotImplementedError

    def on_interrupts(self, notices: Sequence[InterruptNotice],
                      request: Request, snapshot: Sequence[Offering],
                      surviving_pods: int, now: float,
                      precompiled: Optional[Precompiled] = None,
                      ) -> Optional[ProvisioningDecision]:
        raise NotImplementedError


class KubePACSPolicy(Policy):
    """The paper's provisioner, including its UnavailableOfferingsCache."""

    name = "kubepacs"

    def __init__(self, tolerance: float = 0.01, ttl_hours: float = 2.0,
                 guarded: bool = True) -> None:
        self.provisioner = KubePACSProvisioner(tolerance=tolerance,
                                               ttl_hours=ttl_hours,
                                               guarded_gss=guarded)
        if not guarded:
            self.name = "kubepacs_unguarded"

    def provision(self, request, snapshot, now, precompiled=None):
        self.provisioner.clock = now
        return self.provisioner.provision(request, snapshot, precompiled)

    def on_interrupts(self, notices, request, snapshot, surviving_pods, now,
                      precompiled=None):
        self.provisioner.clock = now
        self.provisioner.enqueue([n.to_core() for n in notices])
        return self.provisioner.handle_interrupts(
            request, snapshot, surviving_pods=surviving_pods,
            precompiled=precompiled)


class _BaselinePolicy(Policy):
    """Shared §4.1 plumbing (TTL exclusion cache, shortfall requests) for
    baselines that are not the KubePACS provisioner."""

    def __init__(self, ttl_hours: float = 2.0) -> None:
        self.cache = UnavailableOfferingsCache(ttl_hours)

    def _solve(self, items: List[CandidateItem], req_pods: int,
               exclude: Optional[np.ndarray],
               precompiled: Optional[Precompiled]) -> Tuple[NodePool, Optional[float]]:
        raise NotImplementedError

    def provision(self, request, snapshot, now, precompiled=None):
        t0 = time.perf_counter()
        excluded = self.cache.excluded(now)
        items = precompiled[0] if precompiled is not None \
            else preprocess(snapshot, request)
        exclude = exclusion_mask(items, excluded)
        pool, alpha = self._solve(items, request.pods, exclude, precompiled)
        pool.request = request
        pool.alpha = alpha
        return ProvisioningDecision(
            pool=pool, trace=None, alpha=alpha,
            wall_seconds=time.perf_counter() - t0,
            excluded_offerings=excluded,
            metrics=decision_metrics(pool, request.pods))

    def on_interrupts(self, notices, request, snapshot, surviving_pods, now,
                      precompiled=None):
        if not notices:
            return None
        for n in notices:
            self.cache.add(n.offering_id, now)
        shortfall = max(0, request.pods - surviving_pods)
        if shortfall == 0:
            return None
        repl = dataclasses.replace(request, pods=shortfall)
        return self.provision(repl, snapshot, now, precompiled)


class FixedAlphaPolicy(_BaselinePolicy):
    """Single ILP solve at a fixed α — the Table 2 ablation as a policy."""

    def __init__(self, alpha: float, ttl_hours: float = 2.0) -> None:
        super().__init__(ttl_hours)
        self.alpha = float(alpha)
        self.name = f"fixed_alpha:{alpha:g}"

    def _solve(self, items, req_pods, exclude, precompiled):
        market = precompiled[1] if precompiled is not None else None
        counts = solve_ilp(items, req_pods, self.alpha, market=market,
                           exclude=exclude)
        if counts is None:
            return NodePool(items=[], counts=[]), self.alpha
        return NodePool(items=list(items), counts=list(counts)).nonzero(), \
            self.alpha


class KarpenterLikePolicy(_BaselinePolicy):
    """Price-capacity-optimized consolidation (no BS/T3 awareness, §5.4)."""

    name = "karpenter_like"

    def _solve(self, items, req_pods, exclude, precompiled):
        if exclude is not None:
            items = [it for it, ex in zip(items, exclude) if not ex]
        return karpenter_like(items, req_pods), None


def make_policy(spec: str, tolerance: float = 0.01,
                ttl_hours: float = 2.0) -> Policy:
    """Parse a scenario's policy spec string (see module doc)."""
    if spec == "kubepacs":
        return KubePACSPolicy(tolerance=tolerance, ttl_hours=ttl_hours)
    if spec == "kubepacs_unguarded":
        return KubePACSPolicy(tolerance=tolerance, ttl_hours=ttl_hours,
                              guarded=False)
    if spec == "karpenter_like":
        return KarpenterLikePolicy(ttl_hours=ttl_hours)
    if spec.startswith("fixed_alpha:"):
        return FixedAlphaPolicy(float(spec.split(":", 1)[1]),
                                ttl_hours=ttl_hours)
    raise ValueError(f"unknown policy spec {spec!r}")
