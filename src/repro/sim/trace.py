"""JSONL trace recording and loading (schema: ``repro.sim.events``).

A trace is a list of dict records, one JSON object per line, serialized
with ``sort_keys=True`` so that identical runs produce byte-identical
files (the determinism contract of DESIGN.md §9).  The recorder is
in-memory first — ``ClusterSim`` always records — and ``dump``/``dumps``
materialize the JSONL on demand.
"""

from __future__ import annotations

import json
from typing import Dict, List


class TraceRecorder:
    """Append-only in-memory record sink with JSONL (de)materialization."""

    def __init__(self) -> None:
        self.records: List[Dict] = []

    def write(self, record: Dict) -> None:
        self.records.append(record)

    def dumps(self) -> str:
        return "\n".join(json.dumps(r, sort_keys=True)
                         for r in self.records) + "\n"

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())


def loads_trace(text: str) -> List[Dict]:
    return [json.loads(line) for line in text.splitlines() if line.strip()]


def load_trace(path: str) -> List[Dict]:
    with open(path) as f:
        return loads_trace(f.read())
