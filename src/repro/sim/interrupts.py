"""Pluggable interruption models behind one interface (DESIGN.md §9).

The paper samples interruptions from pool pressure + the SpotLake IF band;
real spot markets also interrupt when the spot price crosses a user bid
(classic EC2 spot semantics) and issue advance *rebalance recommendations*
before reclaiming capacity.  The scenario engine treats all three as
interchangeable :class:`InterruptModel` implementations so a scenario picks
its interruption physics by spec string:

    "none"                           no interruptions
    "pressure"                       the pressure/IF sampler (own RNG stream)
    "price_crossing:<bid_factor>"    fire iff live spot > bid_factor × spot₀
    "rebalance:<lead_hours>:<inner>" wrap <inner>, stamping a warning lead
                                     time; capacity is reclaimed lead_hours
                                     after the (advisory) notice

Models see the *live snapshot* (offerings carry current SP_i/T3_i) and the
current pool; they never touch the market's price RNG, so the market path
and the interruption stream are independently seeded and a recorded trace
replays without any RNG at all.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from ..core.market import Offering, pressure_interrupt_probability
from .events import InterruptNotice


class InterruptModel:
    """Interface: seeded reset + a pure-given-RNG-state sampling step."""

    spec: str = "none"

    def reset(self, catalog: Sequence[Offering], seed: int) -> None:
        """Bind the model to a scenario run (catalog at t=0, RNG seed)."""

    def sample(self, offerings: Dict[str, Offering], pool: Dict[str, int],
               hours: float, now: float) -> List[InterruptNotice]:
        """Interrupt notices for ``pool`` over the last ``hours``.

        ``offerings`` maps offering_id → live Offering (current spot/t3).
        """
        raise NotImplementedError


class NullInterruptModel(InterruptModel):
    spec = "none"

    def sample(self, offerings, pool, hours, now):
        return []


class PressureInterruptModel(InterruptModel):
    """The paper's sampler: P(interrupt) rises with pool pressure and IF.

    Identical law to ``SpotMarketSimulator.interrupts_for_pool`` (shared
    via :func:`pressure_interrupt_probability`) but on a dedicated RNG
    stream keyed by the scenario's ``interrupt_seed``.
    """

    spec = "pressure"

    def __init__(self) -> None:
        self._rng = np.random.default_rng(0)

    def reset(self, catalog, seed):
        self._rng = np.random.default_rng(seed)

    def sample(self, offerings, pool, hours, now):
        notices: List[InterruptNotice] = []
        for offering_id, count in pool.items():
            o = offerings.get(offering_id)
            if o is None or count <= 0:
                continue
            p = pressure_interrupt_probability(count, float(o.t3),
                                               o.interruption_freq, hours)
            lost = int(self._rng.binomial(count, p))
            if lost > 0:
                notices.append(InterruptNotice(
                    time=now, offering_id=offering_id, count=lost))
        return notices


class PriceCrossingInterruptModel(InterruptModel):
    """EC2-classic bid semantics: all nodes of an offering are interrupted
    iff its live spot price exceeds the bid (bid_factor × the t=0 spot
    price).  Deterministic — no RNG."""

    def __init__(self, bid_factor: float = 1.25) -> None:
        self.bid_factor = float(bid_factor)
        self.spec = f"price_crossing:{bid_factor:g}"
        self._bids: Dict[str, float] = {}

    def reset(self, catalog, seed):
        self._bids = {o.offering_id: self.bid_factor * o.spot_price
                      for o in catalog}

    def sample(self, offerings, pool, hours, now):
        notices: List[InterruptNotice] = []
        for offering_id, count in pool.items():
            o = offerings.get(offering_id)
            if o is None or count <= 0:
                continue
            bid = self._bids.get(offering_id)
            if bid is not None and o.spot_price > bid:
                notices.append(InterruptNotice(
                    time=now, offering_id=offering_id, count=count,
                    reason="price-crossing"))
        return notices


class RebalanceRecommendationModel(InterruptModel):
    """Advance-warning wrapper: inner-model notices become advisory
    recommendations with a configurable lead time; the engine reclaims the
    capacity only once ``lead_hours`` have elapsed (effective_time)."""

    def __init__(self, inner: InterruptModel, lead_hours: float = 2.0) -> None:
        self.inner = inner
        self.lead_hours = float(lead_hours)
        self.spec = f"rebalance:{lead_hours:g}:{inner.spec}"

    def reset(self, catalog, seed):
        self.inner.reset(catalog, seed)

    def sample(self, offerings, pool, hours, now):
        return [InterruptNotice(time=n.time, offering_id=n.offering_id,
                                count=n.count,
                                reason=f"rebalance-recommendation:{n.reason}",
                                lead_hours=self.lead_hours)
                for n in self.inner.sample(offerings, pool, hours, now)]


def make_interrupt_model(spec: str) -> InterruptModel:
    """Parse a scenario's interrupt-model spec string (see module doc)."""
    if spec == "none":
        return NullInterruptModel()
    if spec == "pressure":
        return PressureInterruptModel()
    if spec.startswith("price_crossing"):
        parts = spec.split(":")
        return PriceCrossingInterruptModel(
            float(parts[1]) if len(parts) > 1 else 1.25)
    if spec.startswith("rebalance:"):
        _, lead, inner = spec.split(":", 2)
        return RebalanceRecommendationModel(make_interrupt_model(inner),
                                            lead_hours=float(lead))
    raise ValueError(f"unknown interrupt-model spec {spec!r}")
