"""Pluggable interruption models behind one interface (DESIGN.md §9).

The paper samples interruptions from pool pressure + the SpotLake IF band;
real spot markets also interrupt when the spot price crosses a user bid
(classic EC2 spot semantics) and issue advance *rebalance recommendations*
before reclaiming capacity.  The scenario engine treats all three as
interchangeable :class:`InterruptModel` implementations so a scenario picks
its interruption physics by spec string:

    "none"                           no interruptions
    "pressure"                       the pressure/IF sampler (own RNG stream)
    "price_crossing:<bid_factor>"    fire iff live spot > bid_factor × spot₀
    "rebalance:<lead_hours>:<inner>" wrap <inner>, stamping a warning lead
                                     time; capacity is reclaimed lead_hours
                                     after the (advisory) notice

Models see the *live snapshot* (offerings carry current SP_i/T3_i) and the
current pool; they never touch the market's price RNG, so the market path
and the interruption stream are independently seeded and a recorded trace
replays without any RNG at all.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from ..core.market import (Offering, pressure_interrupt_probability,
                           pressure_interrupt_probability_batch)
from .events import InterruptNotice


class InterruptModel:
    """Interface: seeded reset + a pure-given-RNG-state sampling step."""

    spec: str = "none"

    def reset(self, catalog: Sequence[Offering], seed: int) -> None:
        """Bind the model to a scenario run (catalog at t=0, RNG seed)."""

    def set_hazard_scale(self, scale_by_id: Dict[str, float]) -> None:
        """Install per-offering regional hazard scales (DESIGN.md §17).

        Base models ignore the regime — only the pressure sampler's law is
        hazard-shaped; deterministic models (price crossing) are not."""

    def sample(self, offerings: Dict[str, Offering], pool: Dict[str, int],
               hours: float, now: float) -> List[InterruptNotice]:
        """Interrupt notices for ``pool`` over the last ``hours``.

        ``offerings`` maps offering_id → live Offering (current spot/t3).
        """
        raise NotImplementedError


class NullInterruptModel(InterruptModel):
    spec = "none"

    def sample(self, offerings, pool, hours, now):
        return []


class PressureInterruptModel(InterruptModel):
    """The paper's sampler: P(interrupt) rises with pool pressure and IF.

    Identical law to ``SpotMarketSimulator.interrupts_for_pool`` (shared
    via :func:`pressure_interrupt_probability`) but on a dedicated RNG
    stream keyed by the scenario's ``interrupt_seed``.

    The per-tick draw is one vectorized binomial over the pool's live
    entries (DESIGN.md §11): numpy's ``Generator.binomial`` fills array
    outputs by iterating the scalar sampler in C order against the same
    bit stream, so the batched call consumes the RNG byte-identically to
    the seed implementation's per-entry Python loop — same seed, same
    trace, one RNG call per tick.  :meth:`draw_lost_counts` exposes the
    batched draw to the fleet engine, which gathers the probabilities
    from a fleet-wide hazard matrix instead of recomputing them per
    replica.
    """

    spec = "pressure"

    def __init__(self) -> None:
        self._rng = np.random.default_rng(0)
        self._hazard_scale: Dict[str, float] = {}

    def reset(self, catalog, seed):
        self._rng = np.random.default_rng(seed)

    def set_hazard_scale(self, scale_by_id):
        self._hazard_scale = dict(scale_by_id)

    def draw_lost_counts(self, counts: np.ndarray,
                         probs: np.ndarray) -> np.ndarray:
        """One batched binomial draw on this model's stream — ``probs``
        must come from the shared pressure law (scalar or batch; the two
        are bitwise-identical) evaluated in pool-entry order."""
        if len(counts) == 0:
            return np.zeros(0, dtype=np.int64)
        return self._rng.binomial(counts, probs)

    def sample(self, offerings, pool, hours, now):
        entries = [(offering_id, count, offerings.get(offering_id))
                   for offering_id, count in pool.items()]
        entries = [(oid, c, o) for oid, c, o in entries
                   if o is not None and c > 0]
        if not entries:
            return []
        probs = pressure_interrupt_probability_batch(
            np.array([c for _, c, _ in entries], dtype=np.int64),
            np.array([float(o.t3) for _, _, o in entries]),
            np.array([o.interruption_freq for _, _, o in entries]),
            hours)
        if self._hazard_scale:
            from ..region.market import apply_hazard_scale
            probs = apply_hazard_scale(
                probs, np.array([self._hazard_scale.get(oid, 1.0)
                                 for oid, _, _ in entries], dtype=np.float64))
        lost = self.draw_lost_counts(
            np.array([c for _, c, _ in entries], dtype=np.int64), probs)
        return [InterruptNotice(time=now, offering_id=oid, count=int(k))
                for (oid, _, _), k in zip(entries, lost) if k > 0]


class PriceCrossingInterruptModel(InterruptModel):
    """EC2-classic bid semantics: all nodes of an offering are interrupted
    iff its live spot price exceeds the bid (bid_factor × the t=0 spot
    price).  Deterministic — no RNG."""

    def __init__(self, bid_factor: float = 1.25) -> None:
        self.bid_factor = float(bid_factor)
        self.spec = f"price_crossing:{bid_factor:g}"
        self._bids: Dict[str, float] = {}

    def reset(self, catalog, seed):
        self._bids = {o.offering_id: self.bid_factor * o.spot_price
                      for o in catalog}

    def crossed_ids(self, offerings: Dict[str, Offering]) -> set:
        """The offerings whose live spot strictly exceeds their bid — the
        single definition of the crossing rule, shared by :meth:`sample`
        and the fleet engine's one-mask-per-tick batched path."""
        crossed = set()
        for oid, o in offerings.items():
            bid = self._bids.get(oid)
            if bid is not None and o.spot_price > bid:
                crossed.add(oid)
        return crossed

    def sample(self, offerings, pool, hours, now):
        crossed = self.crossed_ids(offerings)
        return [InterruptNotice(time=now, offering_id=offering_id,
                                count=count, reason="price-crossing")
                for offering_id, count in pool.items()
                if count > 0 and offering_id in crossed]


class RebalanceRecommendationModel(InterruptModel):
    """Advance-warning wrapper: inner-model notices become advisory
    recommendations with a configurable lead time; the engine reclaims the
    capacity only once ``lead_hours`` have elapsed (effective_time)."""

    def __init__(self, inner: InterruptModel, lead_hours: float = 2.0) -> None:
        self.inner = inner
        self.lead_hours = float(lead_hours)
        self.spec = f"rebalance:{lead_hours:g}:{inner.spec}"

    def reset(self, catalog, seed):
        self.inner.reset(catalog, seed)

    def set_hazard_scale(self, scale_by_id):
        self.inner.set_hazard_scale(scale_by_id)

    def wrap(self, notices: Sequence[InterruptNotice],
             ) -> List[InterruptNotice]:
        """Stamp the advisory lead onto inner-model notices — the single
        definition of the wrapper semantics, shared by :meth:`sample` and
        the fleet engine (which draws the inner notices batched)."""
        return [InterruptNotice(time=n.time, offering_id=n.offering_id,
                                count=n.count,
                                reason=f"rebalance-recommendation:{n.reason}",
                                lead_hours=self.lead_hours)
                for n in notices]

    def sample(self, offerings, pool, hours, now):
        return self.wrap(self.inner.sample(offerings, pool, hours, now))


def make_interrupt_model(spec: str) -> InterruptModel:
    """Parse a scenario's interrupt-model spec string (see module doc)."""
    if spec == "none":
        return NullInterruptModel()
    if spec == "pressure":
        return PressureInterruptModel()
    if spec.startswith("price_crossing"):
        parts = spec.split(":")
        return PriceCrossingInterruptModel(
            float(parts[1]) if len(parts) > 1 else 1.25)
    if spec.startswith("rebalance:"):
        _, lead, inner = spec.split(":", 2)
        return RebalanceRecommendationModel(make_interrupt_model(inner),
                                            lead_hours=float(lead))
    raise ValueError(f"unknown interrupt-model spec {spec!r}")
