"""Event taxonomy + JSONL trace record schema of the scenario engine.

The engine (``repro.sim.engine.ClusterSim``) is a discrete-event simulation;
everything that happens is one of a small set of timestamped events, and
every event is serializable to a one-line JSON record so a run can be
recorded to a JSONL trace and replayed bit-exactly (DESIGN.md §9).

Record types (``TRACE_VERSION = 1``):

==============  ============================================================
``header``      first line: schema version + the full Scenario spec
``tick``        the market is about to advance by ``hours``
``market_state``  live (spot, t3) vectors after a tick or shock — together
                with the seeded catalog these fully determine a snapshot
``shock``       a deterministic scheduled price/capacity shock was applied
``demand``      the demand schedule changed the requested pod count
``interrupts``  the interrupt notices sampled this tick (possibly empty),
                including fault-injected and rebalance-advisory notices
``fault``       a chaos fault window opened or closed (DESIGN.md §16) —
                diagnostic only; replay re-derives fault effects from the
                scenario spec, never from these records
``fulfillment`` per-offering granted node counts for a decision's pool
``probe``       a one-off fulfillment probe (Fig. 9 driver)
``decision``    a provisioning decision (pool, α*, metrics — wall time is
                deliberately excluded: records must be deterministic)
``summary``     last line: totals for quick inspection
==============  ============================================================

Determinism contract: floats round-trip exactly through ``json`` (CPython
serializes ``repr`` shortest-roundtrip), record key order is fixed by
``sort_keys=True``, and no wall-clock or RNG-state material is recorded.
Same seed ⇒ byte-identical trace; replay consumes ``market_state`` /
``interrupts`` / ``fulfillment`` records instead of RNG draws.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Sequence

from ..core.market import InterruptEvent

TRACE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class InterruptNotice:
    """An interruption notice from a pluggable interrupt model.

    Generalizes the core :class:`InterruptEvent` with a warning lead time:
    the notice is *advisory* at ``time`` and capacity is actually reclaimed
    at ``time + lead_hours`` (the rebalance-recommendation model; the
    classic 2-minute warning is ``lead_hours == 0`` at simulation scale).
    """

    time: float
    offering_id: str
    count: int
    reason: str = "capacity-reclaim"
    lead_hours: float = 0.0

    @property
    def effective_time(self) -> float:
        return self.time + self.lead_hours

    def to_core(self) -> InterruptEvent:
        """The core event the §4.1 provisioner loop consumes."""
        return InterruptEvent(time=self.time, offering_id=self.offering_id,
                              count=self.count, reason=self.reason)

    def to_record(self) -> Dict:
        return {"time": self.time, "offering_id": self.offering_id,
                "count": self.count, "reason": self.reason,
                "lead_hours": self.lead_hours}

    @classmethod
    def from_record(cls, rec: Dict) -> "InterruptNotice":
        return cls(time=rec["time"], offering_id=rec["offering_id"],
                   count=rec["count"], reason=rec["reason"],
                   lead_hours=rec["lead_hours"])


# ---------------------------------------------------------------------------
# Record constructors — one per trace record type
# ---------------------------------------------------------------------------

def catalog_digest(catalog) -> str:
    """Deterministic fingerprint of the offering universe a trace was
    recorded against.  Replay validates it so a trace can never be
    silently paired with a different catalog (same seed ⇒ same digest).
    Hashes every decision-relevant field — prices and capacity, the
    Eq. 1 resource dims, and the hazard inputs — so two catalogs that
    could produce different decisions can never share a digest."""
    h = hashlib.sha256()
    for o in catalog:
        h.update(f"{o.offering_id}|{o.spot_price}|{o.od_price}|{o.t3}|"
                 f"{o.bs_core}|{o.vcpus}|{o.mem_gib}|{o.sps_single}|"
                 f"{o.interruption_freq}|{o.specialization}\n".encode())
    return h.hexdigest()[:16]


def header_record(scenario_dict: Dict, n_offerings: int,
                  digest: str) -> Dict:
    return {"type": "header", "version": TRACE_VERSION,
            "scenario": scenario_dict, "n_offerings": n_offerings,
            "catalog_digest": digest}


def tick_record(time: float, hours: float) -> Dict:
    return {"type": "tick", "time": time, "hours": hours}


def market_state_record(time: float, spot, t3) -> Dict:
    return {"type": "market_state", "time": time,
            "spot": [float(x) for x in spot], "t3": [int(x) for x in t3]}


def shock_record(time: float, kind: str, selector: str, factor: float,
                 affected: int) -> Dict:
    return {"type": "shock", "time": time, "kind": kind,
            "selector": selector, "factor": factor, "affected": affected}


def demand_record(time: float, pods: int) -> Dict:
    return {"type": "demand", "time": time, "pods": pods}


def interrupts_record(time: float,
                      notices: Sequence[InterruptNotice]) -> Dict:
    return {"type": "interrupts", "time": time,
            "notices": [n.to_record() for n in notices]}


def fault_record(time: float, kind: str, phase: str,
                 fault_index: int) -> Dict:
    """A fault window transition ("begin"/"end").  The scenario spec in
    the header already fully determines every fault effect (the chaos
    controller is a pure function of spec + market state), so these lines
    are human-readable provenance, not replay inputs."""
    return {"type": "fault", "time": time, "kind": kind, "phase": phase,
            "fault_index": int(fault_index)}


def fulfillment_record(time: float, grants: Dict[str, int]) -> Dict:
    return {"type": "fulfillment", "time": time,
            "grants": {k: int(v) for k, v in sorted(grants.items())}}


def probe_record(time: float, offering_id: str, requested: int,
                 granted: int) -> Dict:
    return {"type": "probe", "time": time, "offering_id": offering_id,
            "requested": requested, "granted": granted}


def decision_record(time: float, reason: str, policy: str, pool_counts: Dict[str, int],
                    alpha, metrics: Dict[str, float]) -> Dict:
    return {"type": "decision", "time": time, "reason": reason,
            "policy": policy,
            "pool": {k: int(v) for k, v in sorted(pool_counts.items())},
            "alpha": None if alpha is None else float(alpha),
            "metrics": {k: float(v) for k, v in sorted(metrics.items())}}


def summary_record(time: float, total_cost: float, interrupted_nodes: int,
                   decisions: int, final_pool: Dict[str, int]) -> Dict:
    return {"type": "summary", "time": time, "total_cost": total_cost,
            "interrupted_nodes": interrupted_nodes, "decisions": decisions,
            "final_pool": {k: int(v) for k, v in sorted(final_pool.items())}}
