"""Trace-driven scenario engine for spot-cluster simulation (DESIGN.md §9).

Scenarios are declarative specs (``Scenario``); ``ClusterSim`` runs them
through a discrete-event loop over market ticks, shocks, demand changes,
and pluggable interruption models, recording a replayable JSONL trace.
"""

from .events import InterruptNotice, TRACE_VERSION
from .interrupts import (InterruptModel, NullInterruptModel,
                         PressureInterruptModel, PriceCrossingInterruptModel,
                         RebalanceRecommendationModel, make_interrupt_model)
from .policy import (FixedAlphaPolicy, KarpenterLikePolicy, KubePACSPolicy,
                     KubePACSRiskPolicy, Policy, ServingSLOPolicy,
                     make_policy)
from .scenario import (Scenario, Shock, heterogeneous_demand_scenario,
                       high_demand_scenario, serving_scenario)
from .trace import TraceRecorder, load_trace, loads_trace
from .engine import (ClusterSim, LiveMarketSource, ReplaySource,
                     ScriptedMarketSource, SimResult, SimRound, run_replicas,
                     script_market_states)
from .fleet import FleetSim, run_fleet, run_fleet_paths

__all__ = [
    "InterruptNotice", "TRACE_VERSION", "InterruptModel",
    "NullInterruptModel", "PressureInterruptModel",
    "PriceCrossingInterruptModel", "RebalanceRecommendationModel",
    "make_interrupt_model", "Policy", "KubePACSPolicy", "KubePACSRiskPolicy",
    "KarpenterLikePolicy",
    "FixedAlphaPolicy", "ServingSLOPolicy", "make_policy", "Scenario",
    "Shock",
    "heterogeneous_demand_scenario", "high_demand_scenario",
    "serving_scenario",
    "TraceRecorder",
    "load_trace", "loads_trace", "ClusterSim", "LiveMarketSource",
    "ReplaySource", "ScriptedMarketSource", "SimResult", "SimRound",
    "run_replicas", "script_market_states", "FleetSim", "run_fleet",
    "run_fleet_paths",
]
