"""ClusterSim: the discrete-event scenario engine (DESIGN.md §9).

One reproducible harness unifying market evolution, interruption modeling,
and provisioning.  A :class:`ClusterSim` advances a ``SpotMarketSimulator``
+ a pluggable policy through a time-ordered event queue of price ticks,
scheduled shocks, demand changes, and interrupt notices, recording every
event to a JSONL trace (``repro.sim.trace``).  The same loop runs in three
modes, differing only in the :class:`MarketSource` behind it:

* **live** — ``LiveMarketSource``: seeded ``SpotMarketSimulator`` RNG for
  prices, a separately-seeded ``InterruptModel`` for notices;
* **replay** — ``ReplaySource``: market states / notices / fulfillment
  grants are popped from a recorded trace, no RNG anywhere — same policy
  code re-derives bit-identical decisions (the determinism contract);
* **scripted** — ``ScriptedMarketSource``: one precomputed market path
  shared by N replicas of :func:`run_replicas`, which also share one
  preprocessed ``CompiledMarket`` per (market state, request shape) so
  multi-seed sweeps reuse PR 1's batched solver instead of re-solving
  the identical candidate universe per replica.

The engine also exposes an incremental event-stream API
(:meth:`ClusterSim.advance` / :meth:`ClusterSim.current_snapshot`) used by
``repro.runtime.elastic.ElasticSpotTrainer``, which owns its own training
loop but sources market time, interrupts, and the trace from the engine —
and an *observer* fan-out (DESIGN.md §10): the policy and any
``observers=`` passed to the constructor receive every market refresh,
interrupt sample, and fulfillment round, which is how the risk
subsystem's online estimators (and the backtest's calibration probe)
learn from the same stream live and under replay.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..chaos.faults import ChaosController
from ..core import events_log
from ..core.efficiency import NodePool, Request, decision_metrics
from ..core.ilp import compile_market
from ..core.market import (InterruptEvent, Offering, SpotMarketSimulator,
                           snapshot_with)
from ..core.provisioner import (ProvisioningDecision, merge_pools, preprocess)
from ..region.market import (hazard_scale_rows, make_overlay,
                             pool_egress_rate)
from .events import (InterruptNotice, catalog_digest, decision_record,
                     demand_record, fault_record, fulfillment_record,
                     header_record, interrupts_record, market_state_record,
                     probe_record, shock_record, summary_record, tick_record,
                     TRACE_VERSION)
from .interrupts import InterruptModel, make_interrupt_model
from .policy import make_policy
from .scenario import Scenario, Shock
from .trace import TraceRecorder

_EPS = 1e-9

#: sentinel payload for the initial provisioning event — scheduled at
#: (t=0, demand priority) so a t=0 shock (priority 0) is applied first and
#: the same-instant-visibility rule of DESIGN.md §9 holds at t=0 too
_INITIAL = object()


# ---------------------------------------------------------------------------
# Market sources
# ---------------------------------------------------------------------------

class LiveMarketSource:
    """Seeded simulator RNG for prices + a separate model RNG for notices."""

    def __init__(self, catalog: Sequence[Offering], scenario: Scenario,
                 model: InterruptModel,
                 market: Optional[SpotMarketSimulator] = None,
                 overlay=None):
        self.market = market or SpotMarketSimulator(
            catalog, seed=scenario.market_seed,
            price_vol=scenario.price_vol, t3_vol=scenario.t3_vol)
        self.model = model
        #: optional RegionalMarketOverlay (DESIGN.md §17): a pure per-
        #: refresh view transform — the simulator's own state (and its OU
        #: dynamics) never see the regional factor
        self.overlay = overlay
        model.reset(catalog, scenario.interrupt_seed)

    def advance(self, hours: float) -> None:
        self.market.step(hours)

    def apply_shock(self, shock: Shock) -> None:
        price_factor, t3_factor = shock.factors()
        self.market.apply_shock(selector=shock.selector,
                                price_factor=price_factor,
                                t3_factor=t3_factor)

    def state(self, now: float = 0.0) -> Tuple[np.ndarray, np.ndarray]:
        # the engine passes its own clock: shock-triggered refreshes do
        # not advance market.time, but the overlay must be evaluated at
        # the refresh time in the live and scripted paths identically
        spot, t3 = self.market.state_arrays()
        if self.overlay is not None:
            spot, t3 = self.overlay.apply(spot, t3, now)
        return spot, t3

    def interrupts(self, offerings: Dict[str, Offering],
                   pool: Dict[str, int], hours: float,
                   now: float) -> List[InterruptNotice]:
        return self.model.sample(offerings, pool, hours, now)

    def fulfill(self, offering_id: str, count: int, now: float) -> int:
        return self.market.fulfill(offering_id, count)

    def fulfill_pool(self, requests: Dict[str, int],
                     now: float) -> Dict[str, int]:
        return {oid: self.market.fulfill(oid, c)
                for oid, c in requests.items()}


class ScriptedMarketSource:
    """A precomputed market path (see :func:`script_market_states`) shared
    read-only across replicas; interrupts still come from a live per-replica
    model.  Fulfillment is the deterministic T3 clip (no RNG) so replica
    sweeps stay reproducible without a market RNG stream."""

    def __init__(self, catalog: Sequence[Offering],
                 states: Sequence[Tuple[np.ndarray, np.ndarray]],
                 model: InterruptModel, seed: int):
        self._states = states
        self._idx = 0
        self._index = {o.offering_id: i for i, o in enumerate(catalog)}
        self.model = model
        model.reset(catalog, seed)

    def advance(self, hours: float) -> None:
        pass

    def apply_shock(self, shock: Shock) -> None:
        pass

    def state(self, now: float = 0.0) -> Tuple[np.ndarray, np.ndarray]:
        # scripted states were pre-overlaid by script_market_states; the
        # time argument exists only for protocol uniformity
        spot, t3 = self._states[self._idx]
        self._idx += 1
        return spot, t3

    def interrupts(self, offerings, pool, hours, now):
        return self.model.sample(offerings, pool, hours, now)

    def _capacity(self, offering_id: str) -> int:
        # before the first pop the "current" state is the t=0 state, not a
        # [-1] wraparound into the end-of-horizon vector
        _, t3 = self._states[max(self._idx - 1, 0)]
        return int(t3[self._index[offering_id]])

    def fulfill(self, offering_id, count, now):
        return min(count, self._capacity(offering_id))

    def fulfill_pool(self, requests, now):
        return {oid: min(c, self._capacity(oid))
                for oid, c in requests.items()}


class ReplaySource:
    """Serve market states, notices, and grants from a recorded trace.

    Replay needs no RNG: everything stochastic was recorded; everything
    else (policy decisions) is recomputed deterministically."""

    def __init__(self, records: Sequence[Dict]):
        self._records = list(records)
        self._pos = 0

    def _next(self, *rtypes: str) -> Dict:
        while self._pos < len(self._records):
            rec = self._records[self._pos]
            self._pos += 1
            if rec["type"] in rtypes:
                return rec
        raise ValueError(f"trace exhausted while looking for {rtypes}")

    def advance(self, hours: float) -> None:
        pass

    def apply_shock(self, shock: Shock) -> None:
        pass

    def state(self, now: float = 0.0) -> Tuple[np.ndarray, np.ndarray]:
        # recorded states already carry any regional overlay (the trace
        # records TRUE post-overlay state), so replay stays RNG-free
        rec = self._next("market_state")
        return (np.array(rec["spot"], dtype=np.float64),
                np.array(rec["t3"], dtype=np.int64))

    def interrupts(self, offerings, pool, hours, now):
        rec = self._next("interrupts")
        return [InterruptNotice.from_record(n) for n in rec["notices"]]

    def fulfill(self, offering_id, count, now):
        return int(self._next("probe")["granted"])

    def fulfill_pool(self, requests, now):
        return {k: int(v)
                for k, v in self._next("fulfillment")["grants"].items()}


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimRound:
    """One tick's outcome: what was sampled, lost, and re-provisioned."""

    time: float
    notices: List[InterruptNotice]           # sampled this tick (incl. advisory)
    effective: List[InterruptNotice]         # capacity actually reclaimed now
    lost_nodes: int
    lost_pods: int                           # per-item Pod_i accounting
    shortfall: int
    decision: Optional[ProvisioningDecision]
    pool: NodePool                           # post-round pool
    snapshot: Optional[List[Offering]] = None
    lost_perf: float = 0.0                   # Σ Perf_i over reclaimed nodes


@dataclasses.dataclass
class SimResult:
    scenario: Scenario
    decisions: List[Tuple[str, ProvisioningDecision]]   # (reason, decision)
    rounds: List[SimRound]
    total_cost: float
    interrupted_nodes: int
    pool: NodePool
    recorder: TraceRecorder
    total_perf_hours: float = 0.0     # ∫ pool perf_rate dt (delivered work)
    #: data-gravity spend (DESIGN.md §17): the egress component already
    #: included in ``total_cost`` — 0.0 whenever the scenario has no
    #: RegionConfig or a zero egress rate (the accrual is skipped, not
    #: added as 0, so legacy float sequences are untouched)
    total_egress: float = 0.0
    #: cache-effectiveness counters (DESIGN.md §11): ``compile_hits`` /
    #: ``compile_misses`` of the shared CompiledMarket cache, plus
    #: ``memo_hits`` / ``memo_misses`` / ``memo_unique_solves`` of the
    #: cross-replica decision memo under the fleet engine (fleet results
    #: carry the fleet-wide aggregate).  Deliberately NOT part of decision
    #: metrics or the trace: cache provenance must never break the
    #: fleet ≡ standalone equality contract.
    cache_stats: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def lost_perf_total(self) -> float:
        """Σ Perf_i of every reclaimed node — the backtest charges each
        interruption ``reprovision_hours`` of this rate (DESIGN.md §10)."""
        return float(sum(rd.lost_perf for rd in self.rounds))

    @property
    def records(self) -> List[Dict]:
        return self.recorder.records

    def decision_records(self) -> List[Dict]:
        return [r for r in self.records if r["type"] == "decision"]


def useful_scale(pool: NodePool, req_pods: int) -> float:
    """Fraction of a pool's perf rate doing *useful* work: pods beyond the
    requested demand contribute nothing (the E_OverPods principle, Eq. 2),
    an underfilled pool is fully utilized.  One definition shared by
    ClusterSim and FleetSim — the value enters the delivered-work accrual,
    so the float sequence must be identical in both engines."""
    alloc = pool.total_pods
    return min(1.0, req_pods / alloc) if alloc > 0 else 0.0


def accrual_increments(pool: NodePool, req_pods: int,
                       dt: float) -> Tuple[float, float]:
    """(cost, useful perf-hours) one interval adds to the running totals —
    the single definition of the accrual float sequence (DESIGN.md §11:
    fleet totals must match standalone totals bit-for-bit, so both engines
    add exactly these products in exactly this order)."""
    return (pool.hourly_cost * dt,
            pool.perf_rate * useful_scale(pool, req_pods) * dt)


def shock_affected(catalog: Sequence[Offering], shock: Shock) -> int:
    """Offerings a shock's selector matches — the trace-record count."""
    return sum(shock.selector in o.offering_id for o in catalog)


def _split_pending(pending: Sequence[InterruptNotice],
                   sampled: Sequence[InterruptNotice], now: float,
                   ) -> Tuple[List[InterruptNotice], List[InterruptNotice]]:
    """Advisory-lead split shared by ClusterSim and FleetSim: matured
    pending notices plus zero-lead fresh ones are effective *now*; the
    rest wait out their lead time.  Determinism-critical (it decides which
    tick reclaims capacity), hence one definition."""
    effective: List[InterruptNotice] = []
    still_pending: List[InterruptNotice] = []
    for n in pending:
        (effective if n.effective_time <= now + _EPS
         else still_pending).append(n)
    for n in sampled:
        (still_pending if n.lead_hours > 0 else effective).append(n)
    return effective, still_pending


def shared_precompile(cache: Dict, stats: Dict[str, int], state_idx: int,
                      snapshot: Sequence[Offering], request: Request):
    """The (market state, request shape)-keyed preprocess+compile cache
    shared by ClusterSim replicas and the fleet engine, with hit/miss
    counters (``SimResult.cache_stats``)."""
    key = (state_idx, request.cpu_per_pod, request.mem_per_pod,
           request.workload)
    if key not in cache:
        stats["compile_misses"] += 1
        items = preprocess(snapshot, request)
        cache[key] = (items, compile_market(items))
    else:
        stats["compile_hits"] += 1
    return cache[key]


def billable_pool(chaos: Optional[ChaosController],
                  snap_index: Dict[str, Offering],
                  pool: NodePool) -> NodePool:
    """Map a decision's pool (solved over the *observed* snapshot) onto
    TRUE market rows for billing/capacity accounting: feed corruption can
    change what the controller believes, never what the market charges —
    which is exactly how trusting a corrupted feed costs real money
    (DESIGN.md §16).  ``Pod_i``/``BS_i`` derive from static offering
    fields, so only offering/spot/t3 swap.  Identity when no chaos is
    configured, keeping healthy runs byte-identical — and one definition
    shared by ClusterSim and FleetSim (the fleet ≡ standalone contract)."""
    if chaos is None or not pool.items:
        return pool
    items = []
    for it in pool.items:
        o = snap_index[it.offering.offering_id]
        items.append(dataclasses.replace(it, offering=o,
                                         spot_price=o.spot_price, t3=o.t3))
    return NodePool(items=items, counts=list(pool.counts),
                    alpha=pool.alpha, request=pool.request)


def failed_decision(request: Request) -> ProvisioningDecision:
    """The record of a decision cycle the control plane could not run
    (solver fault, unhardened policy): an empty pool with
    ``decision_failed`` stamped — deterministic, so it traces and replays
    like any other decision.  Shared by both engines."""
    pool = NodePool(items=[], counts=[], request=request)
    metrics = decision_metrics(pool, request.pods)
    metrics["decision_failed"] = 1.0
    return ProvisioningDecision(pool=pool, trace=None, alpha=None,
                                wall_seconds=0.0, excluded_offerings=set(),
                                metrics=metrics)


def solver_down(chaos: Optional[ChaosController], policy,
                now: float) -> bool:
    """An active solver fault takes out *unhardened* decision cycles
    entirely — they have no retry/ladder machinery to ride it out.
    Hardened policies (``chaos_hardened``) still get called and absorb
    the fault themselves (DESIGN.md §16)."""
    return (chaos is not None
            and not getattr(policy, "chaos_hardened", False)
            and chaos.solver_faulted(now) is not None)


def _apply_losses(pool: NodePool, notices: Sequence[InterruptNotice],
                  ) -> Tuple[NodePool, int, int, float]:
    """Remove interrupted nodes; lost pods use each item's actual Pod_i
    (not a hardcoded per-node pod count — large-instance interrupts count
    fully).  Also totals the reclaimed Perf_i rate for loss accounting."""
    lost: Dict[str, int] = {}
    for n in notices:
        lost[n.offering_id] = lost.get(n.offering_id, 0) + n.count
    items, counts, lost_nodes, lost_pods = [], [], 0, 0
    lost_perf = 0.0
    for it, c in zip(pool.items, pool.counts):
        take = min(c, lost.get(it.offering.offering_id, 0))
        lost_nodes += take
        lost_pods += take * it.pods
        lost_perf += take * it.perf
        if c - take > 0:
            items.append(it)
            counts.append(c - take)
    return (NodePool(items=items, counts=counts, alpha=pool.alpha,
                     request=pool.request), lost_nodes, lost_pods, lost_perf)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

def _schedule(scenario: Scenario) -> List[Tuple[float, int, object]]:
    """Time-ordered event queue: shocks (0) < demand changes (1) < ticks (2)
    at equal timestamps, so a shock is visible to the same tick's decision.
    A tick's payload is its dt; a duration that is not a step multiple gets
    a final partial tick so the whole horizon is simulated and billed.
    Shocks/demand changes beyond the horizon are dropped — the scenario
    declares its world ends at ``duration_hours``.  The initial
    provisioning itself is the ``_INITIAL`` event at (0, demand priority),
    so a t=0 shock is visible to it like at any other timestamp."""
    horizon = scenario.duration_hours
    events: List[Tuple[float, int, object]] = [(0.0, 1, _INITIAL)]
    for s in scenario.shocks:
        if s.time <= horizon + _EPS:
            events.append((s.time, 0, s))
    for t, pods in scenario.demand_schedule:
        if t <= horizon + _EPS:
            events.append((t, 1, int(pods)))
    if scenario.step_hours > 0:
        n_ticks = int(math.floor(horizon / scenario.step_hours + _EPS))
        for k in range(1, n_ticks + 1):
            events.append((k * scenario.step_hours, 2,
                           scenario.step_hours))
        covered = n_ticks * scenario.step_hours
        if horizon - covered > _EPS:
            events.append((horizon, 2, horizon - covered))
    return sorted(events, key=lambda e: (e[0], e[1]))


def script_market_states(scenario: Scenario, catalog: Sequence[Offering],
                         ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Precompute every market state a run will observe (initial + one per
    tick/shock), in the exact refresh order ``ClusterSim.run`` uses."""
    market = SpotMarketSimulator(catalog, seed=scenario.market_seed,
                                 price_vol=scenario.price_vol,
                                 t3_vol=scenario.t3_vol)
    # Regional overlay: the scripted path must record the same TRUE states
    # the live source produces, so the overlay applies at the same times
    # the engine would pass to ``source.state(now)``.
    overlay = make_overlay(scenario.region, catalog, scenario.faults)

    def _state(t: float) -> Tuple[np.ndarray, np.ndarray]:
        spot, t3 = market.state_arrays()
        if overlay is not None:
            spot, t3 = overlay.apply(spot, t3, t)
        return spot, t3

    states = []
    last_t = 0.0
    for t, prio, payload in _schedule(scenario):
        if payload is _INITIAL:             # initial refresh at t=0
            states.append(_state(0.0))
        elif prio == 2:                     # tick
            market.step(t - last_t)
            last_t = t
            states.append(_state(t))
        elif prio == 0:                     # shock
            shock: Shock = payload
            price_factor, t3_factor = shock.factors()
            market.apply_shock(selector=shock.selector,
                               price_factor=price_factor,
                               t3_factor=t3_factor)
            states.append(_state(t))
    return states


class ClusterSim:
    """Event-queue simulation of one scenario (see module docstring)."""

    def __init__(self, scenario: Scenario, *,
                 catalog: Optional[Sequence[Offering]] = None,
                 source=None, recorder: Optional[TraceRecorder] = None,
                 keep_snapshots: bool = False,
                 compile_cache: Optional[Dict] = None,
                 observers: Sequence = (), clock=None):
        self.scenario = scenario
        self.catalog = (list(catalog) if catalog is not None
                        else scenario.build_catalog())
        if source is None:
            source = LiveMarketSource(self.catalog, scenario,
                                      make_interrupt_model(
                                          scenario.interrupt_model),
                                      overlay=make_overlay(
                                          scenario.region, self.catalog,
                                          scenario.faults))
        self.source = source
        # regional hazard regimes (DESIGN.md §17): scale the pressure
        # model's per-node law; skipped entirely (None) for unit scales so
        # the law stays bitwise untouched
        scale_rows = hazard_scale_rows(scenario.region, self.catalog)
        model = getattr(self.source, "model", None)
        if model is not None and scale_rows is not None:
            model.set_hazard_scale(
                dict(zip((o.offering_id for o in self.catalog),
                         scale_rows.tolist())))
        policy_kwargs = {} if clock is None else {"clock": clock}
        self.policy = make_policy(scenario.policy,
                                  tolerance=scenario.tolerance,
                                  ttl_hours=scenario.ttl_hours,
                                  region=scenario.region,
                                  **policy_kwargs)
        # event-stream observer fan-out (DESIGN.md §10): the policy always
        # observes (risk policies learn online), plus any caller-supplied
        # observers (e.g. the backtest's calibration probe) — each owns its
        # own state, so fan-out order is not decision-relevant
        self.policy.bind(self.catalog)
        # chaos controller (DESIGN.md §16): derived purely from the
        # scenario spec + catalog, so live/replay/fleet all rebuild the
        # identical fault view.  None when the scenario declares no faults
        # — every chaos branch below is then skipped, keeping healthy runs
        # byte-identical to the pre-chaos engine.
        self.chaos = (ChaosController(scenario.faults, self.catalog)
                      if scenario.faults else None)
        self.policy.bind_chaos(self.chaos)
        self._observers = [self.policy, *observers]
        self._events_snap = events_log.snapshot()
        self.recorder = recorder or TraceRecorder()
        self.recorder.write(header_record(scenario.to_dict(),
                                          len(self.catalog),
                                          catalog_digest(self.catalog)))
        self.keep_snapshots = keep_snapshots
        self.compile_cache = compile_cache
        self.cache_stats: Dict[str, int] = {"compile_hits": 0,
                                            "compile_misses": 0}

        self.request = scenario.request()
        self.pool = NodePool(items=[], counts=[])
        self.pending: List[InterruptNotice] = []
        self.time = 0.0
        self.total_cost = 0.0
        self.total_perf_hours = 0.0
        self.total_egress = 0.0
        # egress accrual is armed only by a non-zero rate: the off case
        # must not even add 0.0 to the running totals (bit-inertness)
        self._egress_cfg = (scenario.region
                            if scenario.region is not None and
                            scenario.region.egress_per_pod_hour > 0.0
                            else None)
        self._cost_accrued_to = 0.0
        self.interrupted_nodes = 0
        self.decisions: List[Tuple[str, ProvisioningDecision]] = []
        self.rounds: List[SimRound] = []
        self._snapshot: Optional[List[Offering]] = None
        self._snap_index: Dict[str, Offering] = {}
        self._state_idx = -1

    # -- construction helpers ---------------------------------------------
    @classmethod
    def replay(cls, records: Sequence[Dict], *,
               catalog: Optional[Sequence[Offering]] = None,
               keep_snapshots: bool = False,
               observers: Sequence = ()) -> "ClusterSim":
        """Rebuild a sim from a recorded trace; running it re-derives the
        identical decision sequence without any RNG (DESIGN.md §9)."""
        records = list(records)
        header = records[0]
        if header.get("type") != "header":
            raise ValueError("trace does not start with a header record")
        if header.get("version") != TRACE_VERSION:
            raise ValueError(f"trace version {header.get('version')!r} != "
                             f"supported {TRACE_VERSION}")
        scenario = Scenario.from_dict(header["scenario"])
        catalog = (list(catalog) if catalog is not None
                   else scenario.build_catalog())
        # a trace is only meaningful against the exact offering universe it
        # was recorded on; refuse to pair it with a different catalog
        # (e.g. the recording run was handed an explicit catalog whose
        # seeds don't match the Scenario's) instead of silently diverging
        digest = catalog_digest(catalog)
        if digest != header.get("catalog_digest"):
            raise ValueError(
                "catalog mismatch: trace was recorded against digest "
                f"{header.get('catalog_digest')!r} but replay catalog has "
                f"{digest!r}; pass the recording run's catalog= explicitly")
        return cls(scenario, catalog=catalog,
                   source=ReplaySource(records),
                   keep_snapshots=keep_snapshots, observers=observers)

    @classmethod
    def from_market(cls, market: SpotMarketSimulator,
                    interrupt_model: str = "pressure",
                    interrupt_seed: int = 0, name: str = "live",
                    recorder: Optional[TraceRecorder] = None) -> "ClusterSim":
        """Wrap an existing market for event-stream consumers (the elastic
        trainer): the engine owns time, interrupts, and the trace while the
        caller drives its own loop via :meth:`advance`."""
        catalog = market.catalog
        scenario = Scenario(name=name, duration_hours=0.0,
                            interrupt_model=interrupt_model,
                            interrupt_seed=interrupt_seed,
                            max_offerings=len(catalog))
        model = make_interrupt_model(interrupt_model)
        sim = cls(scenario, catalog=catalog,
                  source=LiveMarketSource(catalog, scenario, model,
                                          market=market),
                  recorder=recorder)
        sim.time = market.time
        return sim

    @property
    def market(self) -> Optional[SpotMarketSimulator]:
        """The underlying simulator of a live source (None on replay)."""
        return getattr(self.source, "market", None)

    # -- internals ---------------------------------------------------------
    def _record(self, rec: Dict) -> None:
        self.recorder.write(rec)

    def _useful_scale(self) -> float:
        """See :func:`useful_scale` (per hour, useful perf / cost is then
        exactly E_Total)."""
        return useful_scale(self.pool, self.request.pods)

    def _accrue_cost(self, now: float) -> None:
        """Charge the current pool for the interval since the last accrual —
        called before any event mutates the pool or the demand, so
        mid-interval changes (demand merges, interrupts) are billed at the
        rate that actually ran.  Useful perf-hours accrue on the same
        schedule, so cost and work integrals cover identical pool
        histories."""
        dt = now - self._cost_accrued_to
        cost, perf = accrual_increments(self.pool, self.request.pods, dt)
        self.total_cost += cost
        self.total_perf_hours += perf
        if self._egress_cfg is not None:
            egress = pool_egress_rate(self._egress_cfg, self.pool) * dt
            self.total_cost += egress
            self.total_egress += egress
        self._cost_accrued_to = now

    def _refresh(self) -> None:
        """TRUE/OBSERVED split (DESIGN.md §16): the trace records the TRUE
        market state (so the header + records replay regardless of faults);
        the chaos controller then derives the *observed* view the policy
        decides on.  ``_snap_index`` stays TRUE — interrupt hazards and
        billing live in reality even when the feed lies."""
        spot, t3 = self.source.state(self.time)
        self._record(market_state_record(self.time, spot, t3))
        self._state_idx += 1
        if self.chaos is not None:
            spot_obs, t3_obs, transitions = self.chaos.observe(
                self._state_idx, self.time, spot, t3)
            for kind, phase, idx in transitions:
                self._record(fault_record(self.time, kind, phase, idx))
            self._true_snapshot = snapshot_with(self.catalog, spot, t3)
            self._snapshot = (self._true_snapshot
                              if spot_obs is spot and t3_obs is t3
                              else snapshot_with(self.catalog, spot_obs,
                                                 t3_obs))
        else:
            spot_obs, t3_obs = spot, t3
            self._snapshot = snapshot_with(self.catalog, spot, t3)
            self._true_snapshot = self._snapshot
        self._snap_index = {o.offering_id: o for o in self._true_snapshot}
        for obs in self._observers:
            obs.observe_market(self.time, spot_obs, t3_obs)

    def _notify_pool(self, reason: str) -> None:
        """Pool-change fan-out: fired whenever ``self.pool`` changes (a
        launch, or interruption losses with no re-provision decision).
        ``observe_pool`` is part of the formal observer protocol (no-op on
        the :class:`~repro.sim.policy.Policy` base) — serving co-sim
        timelines integrate capacity between exactly these events
        (DESIGN.md §15)."""
        for obs in self._observers:
            obs.observe_pool(self.time, self.pool, reason)

    def _solver_down(self) -> bool:
        return solver_down(self.chaos, self.policy, self.time)

    def _provision(self, request: Request) -> ProvisioningDecision:
        if self._solver_down():
            return failed_decision(request)
        return self.policy.provision(request, self._snapshot, self.time,
                                     precompiled=self._precompiled(request))

    def _precompiled(self, request: Request):
        """Shared-compile hook: replicas keyed on (market state, request
        shape) reuse one preprocessed candidate set + CompiledMarket."""
        if self.compile_cache is None:
            return None
        return shared_precompile(self.compile_cache, self.cache_stats,
                                 self._state_idx, self._snapshot, request)

    def _launch(self, decision: ProvisioningDecision, reason: str,
                base_pool: Optional[NodePool] = None) -> None:
        """Apply a decision: optional fulfillment clip, trace record, merge."""
        new_pool = billable_pool(self.chaos, self._snap_index,
                                 decision.pool)
        # ICE-style partial fulfillment (DESIGN.md §16): active ice faults
        # cap per-offering grants as a pure function of the REQUESTED
        # counts, so replay re-deriving the caps and re-clipping recorded
        # grants is the identity
        caps = (self.chaos.ice_caps(self.time, new_pool.as_dict())
                if self.chaos is not None and new_pool.total_nodes else None)
        if new_pool.total_nodes and (self.scenario.apply_fulfillment
                                     or caps is not None):
            requested = new_pool.as_dict()
            if self.scenario.apply_fulfillment:
                grants = self.source.fulfill_pool(requested, self.time)
            else:
                grants = dict(requested)
            if caps is not None:
                grants = {oid: min(g, caps.get(oid, g))
                          for oid, g in grants.items()}
            self._record(fulfillment_record(self.time, grants))
            for obs in self._observers:
                obs.observe_fulfillment(self.time, requested, grants)
            items, counts = [], []
            for it, c in zip(new_pool.items, new_pool.counts):
                g = min(c, grants.get(it.offering.offering_id, 0))
                if g > 0:
                    items.append(it)
                    counts.append(g)
            new_pool = NodePool(items=items, counts=counts,
                                alpha=new_pool.alpha,
                                request=new_pool.request)
        self._record(decision_record(self.time, reason, self.policy.name,
                                     decision.pool.as_dict(), decision.alpha,
                                     decision.metrics))
        self.decisions.append((reason, decision))
        if base_pool is not None and base_pool.total_nodes:
            self.pool = merge_pools(base_pool, new_pool)
        else:
            self.pool = new_pool
        self._notify_pool(reason)

    def _split_notices(self, sampled: Sequence[InterruptNotice],
                       now: float) -> List[InterruptNotice]:
        """Advisory notices wait out their lead time in the pending queue;
        returns the notices whose capacity is reclaimed *now*."""
        effective, self.pending = _split_pending(self.pending, sampled, now)
        return effective

    def _tick_events(self, t: float, dt: float, pool: Dict[str, int],
                     ) -> Tuple[List[InterruptNotice],
                                List[InterruptNotice]]:
        """The tick protocol shared by :meth:`run` and :meth:`advance`:
        record tick → advance market → refresh state → sample notices
        (with §5.4.3 fault injection on genuinely calm rounds: nothing
        sampled AND no advisory notice maturing now) → record → split into
        (sampled, effective-now)."""
        self._record(tick_record(t, dt))
        self.source.advance(dt)
        self.time = t
        self._refresh()
        sampled = self.source.interrupts(self._snap_index, pool, dt, t)
        matured = any(n.effective_time <= t + _EPS for n in self.pending)
        if (self.scenario.inject_if_idle and not sampled and not matured
                and any(c > 0 for c in pool.values())):
            # deterministically kill the largest allocation so
            # interrupt-handling is exercised every round
            oid, c = max(pool.items(), key=lambda kv: kv[1])
            sampled = [InterruptNotice(time=t, offering_id=oid, count=c,
                                       reason="fault-injection")]
        self._record(interrupts_record(t, sampled))
        for obs in self._observers:
            obs.observe_interrupts(t, dt, pool, sampled)
        return sampled, self._split_notices(sampled, t)

    def _on_tick(self, t: float, dt: float) -> None:
        scale = self._useful_scale()        # utilization of the interval's pool
        self._accrue_cost(t)                # interval just run, old pool
        sampled, effective = self._tick_events(t, dt, self.pool.as_dict())

        survivors, lost_nodes, lost_pods, lost_perf = _apply_losses(
            self.pool, effective)
        # a notice sampled over this tick reclaimed its capacity at an
        # unknown instant within it, but the accrual above credited the
        # full interval — charge the expected half-tick of undelivered
        # useful work (cost is NOT rebated: reclaimed capacity was still
        # billed, which is exactly why interruptions hurt perf-per-dollar)
        self.total_perf_hours -= 0.5 * dt * lost_perf * scale
        self.interrupted_nodes += lost_nodes
        decision, shortfall = None, 0
        if effective:
            shortfall = max(0, self.request.pods - survivors.total_pods)
            if self._solver_down():
                # the unhardened reactive loop is down with the solver:
                # exclusions don't update and the shortfall goes unfilled
                decision = (failed_decision(dataclasses.replace(
                    self.request, pods=shortfall)) if shortfall > 0
                    else None)
            else:
                decision = self.policy.on_interrupts(
                    effective, self.request, self._snapshot,
                    survivors.total_pods, t,
                    precompiled=self._precompiled(self.request))
            self.pool = survivors
            if decision is not None:
                # recorded even when the replacement pool is empty
                # (infeasible shortfall) so the trace shows every
                # re-optimization attempt, exactly like initial/demand
                self._launch(decision, "interrupt", base_pool=survivors)
            else:
                self._notify_pool("losses")
        self.rounds.append(SimRound(
            time=t, notices=list(sampled), effective=effective,
            lost_nodes=lost_nodes, lost_pods=lost_pods, shortfall=shortfall,
            decision=decision, pool=self.pool,
            snapshot=self._snapshot if self.keep_snapshots else None,
            lost_perf=lost_perf))

    def _on_shock(self, shock: Shock) -> None:
        self.source.apply_shock(shock)
        self._record(shock_record(self.time, shock.kind, shock.selector,
                                  shock.factor,
                                  shock_affected(self.catalog, shock)))
        self._refresh()

    def _on_demand(self, pods: int) -> None:
        """Demand change: scale-ups provision only the shortfall and merge
        with the running pool (capacity is never discarded for free);
        scale-downs keep the pool over-provisioned — consolidation is a
        billing optimization the paper leaves to Karpenter's own path.
        ``Scenario.demand_jitter`` perturbs the scheduled demand per
        interruption seed (stream-free; identical across engines)."""
        self._accrue_cost(self.time)
        pods = self.scenario.effective_pods(self.scenario.interrupt_seed,
                                            self.time, pods)
        self.request = dataclasses.replace(self.request, pods=pods)
        self._record(demand_record(self.time, pods))
        shortfall = pods - self.pool.total_pods
        if shortfall <= 0 and self.pool.total_nodes:
            return
        repl_request = (dataclasses.replace(self.request, pods=shortfall)
                        if self.pool.total_nodes else self.request)
        decision = self._provision(repl_request)
        self._launch(decision, "demand",
                     base_pool=self.pool if self.pool.total_nodes else None)

    # -- scenario run ------------------------------------------------------
    def _on_initial(self) -> None:
        if self.scenario.demand_jitter:
            self.request = dataclasses.replace(
                self.request, pods=self.scenario.effective_pods(
                    self.scenario.interrupt_seed, 0.0, self.scenario.pods))
        self._refresh()
        decision = self._provision(self.request)
        self._launch(decision, "initial")

    def run(self) -> SimResult:
        if self._state_idx != -1:
            # current_snapshot()/advance()/probe_fulfillment() already
            # consumed market state: a run() on top would desynchronize
            # the recorded state sequence (and a scripted source's state
            # queue), silently breaking the byte-identical-trace contract
            raise RuntimeError(
                "run() must drive a fresh ClusterSim; this instance "
                "already served the event-stream/probe API — construct a "
                "new ClusterSim for the scenario run")
        for t, prio, payload in _schedule(self.scenario):
            self.time = t
            if payload is _INITIAL:
                self._on_initial()
            elif prio == 0:
                self._on_shock(payload)
            elif prio == 1:
                self._on_demand(payload)
            else:
                self._on_tick(t, payload)
        self._record(summary_record(self.time, self.total_cost,
                                    self.interrupted_nodes,
                                    len(self.decisions),
                                    self.pool.as_dict()))
        return SimResult(scenario=self.scenario, decisions=self.decisions,
                         rounds=self.rounds, total_cost=self.total_cost,
                         interrupted_nodes=self.interrupted_nodes,
                         pool=self.pool, recorder=self.recorder,
                         total_perf_hours=self.total_perf_hours,
                         total_egress=self.total_egress,
                         cache_stats=self._final_stats())

    def _final_stats(self) -> Dict[str, int]:
        """cache_stats + the run's one-time-warning counter deltas
        (``event_*``, repro.core.events_log) + the hardened policy's
        degradation-ladder counters (``chaos_*``).  Diagnostic only —
        never part of decisions, records, or metrics (DESIGN.md §16)."""
        stats = dict(self.cache_stats)
        for k, v in events_log.delta_since(self._events_snap).items():
            stats[f"event_{k}"] = stats.get(f"event_{k}", 0) + v
        chaos_stats = getattr(self.policy, "chaos_stats", None)
        if chaos_stats is not None:
            for k, v in chaos_stats().items():
                stats[f"chaos_{k}"] = v
        return stats

    # -- incremental event-stream API (elastic trainer) --------------------
    def current_snapshot(self) -> List[Offering]:
        if self._snapshot is None:
            self._refresh()
        return self._snapshot

    def advance(self, hours: float,
                pool: Dict[str, int]) -> List[InterruptEvent]:
        """Advance the market by ``hours`` and return the interrupt events
        effective *now* for ``pool`` (advisory notices queue until their
        lead time elapses; ``inject_if_idle`` scenarios fault-inject on
        calm ticks here too).  Records tick/state/notices to the trace."""
        t = self.time + hours
        _, effective = self._tick_events(t, hours, pool)
        # clip to the caller's live pool (a matured advisory may refer to
        # capacity the caller already replaced), mirroring _apply_losses
        remaining = dict(pool)
        events: List[InterruptEvent] = []
        for n in effective:
            take = min(n.count, remaining.get(n.offering_id, 0))
            if take <= 0:
                continue
            remaining[n.offering_id] -= take
            self.interrupted_nodes += take
            events.append(InterruptEvent(time=n.time,
                                         offering_id=n.offering_id,
                                         count=take, reason=n.reason))
        return events

    def probe_fulfillment(self, offering_id: str, count: int) -> int:
        """One-off fulfillment probe (Fig. 9): how many of ``count`` nodes
        the market would grant right now.  Recorded and replayable."""
        granted = int(self.source.fulfill(offering_id, count, self.time))
        self._record(probe_record(self.time, offering_id, count, granted))
        return granted


def run_replicas(scenario: Scenario, interrupt_seeds: Sequence[int], *,
                 catalog: Optional[Sequence[Offering]] = None,
                 keep_snapshots: bool = False) -> List[SimResult]:
    """Per-seed multi-replica runner: N scenario replicas over one shared
    market path and one shared ``CompiledMarket`` per (state, request shape).

    This is the *reference* sweep implementation: one full ``ClusterSim``
    per seed.  For Monte-Carlo sizes (tens to thousands of seeds) use
    ``repro.sim.fleet.FleetSim`` / ``run_fleet`` (DESIGN.md §11), which is
    proven per-seed identical to this path and ~20-50× faster per replica.

    The market evolution is computed once (:func:`script_market_states`);
    each replica varies only the interruption RNG stream.  Because every
    replica at a given tick sees the identical snapshot, preprocessing +
    market compilation happen once and every replica's GSS prescan rides
    the PR 1 batched solver against the same compiled arrays — a replica
    is pure policy work, not market work.  A replica's decisions are
    identical to a standalone ``ClusterSim`` run at the same seeds
    (asserted in tests/test_scenario_engine.py).

    ``apply_fulfillment`` scenarios are rejected: live fulfillment draws
    from (and advances) the market's price RNG, which a scripted shared
    path cannot reproduce — the replica≡standalone guarantee would
    silently break.  Sweep fulfillment-sensitive scenarios with
    independent ``ClusterSim`` runs instead.
    """
    if scenario.apply_fulfillment:
        raise ValueError(
            "run_replicas does not support apply_fulfillment scenarios: "
            "live fulfillment consumes the market price RNG, so replicas "
            "over a scripted market path would diverge from standalone "
            "runs; use independent ClusterSim runs for that sweep")
    catalog = (list(catalog) if catalog is not None
               else scenario.build_catalog())
    states = script_market_states(scenario, catalog)
    compile_cache: Dict = {}
    results = []
    for seed in interrupt_seeds:
        sc = dataclasses.replace(scenario, interrupt_seed=int(seed))
        source = ScriptedMarketSource(
            catalog, states, make_interrupt_model(sc.interrupt_model),
            seed=int(seed))
        sim = ClusterSim(sc, catalog=catalog, source=source,
                         compile_cache=compile_cache,
                         keep_snapshots=keep_snapshots)
        results.append(sim.run())
    return results
