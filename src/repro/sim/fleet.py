"""FleetSim: the replica-major vectorized Monte-Carlo engine (DESIGN.md §11).

``run_replicas`` (PR 2) shares the market path and the compiled market
across a multi-seed sweep but still executes one Python-loop ``ClusterSim``
per seed, so a 1000-seed risk backtest costs ~1000× one run.  ``FleetSim``
advances **all R interruption seeds simultaneously** over the shared
scripted market path:

* **array-resident pool state** — an (R, n_offerings) int64 count matrix
  drives the fleet-wide batched interrupt sampling; per-replica
  ``NodePool`` views are materialized only at decision/round boundaries,
  which is what keeps every float of the cost/perf accounting on the exact
  code path ``ClusterSim`` uses (``NodePool.hourly_cost`` / ``perf_rate``
  / ``_apply_losses`` — bit-identical accrual, not approximately-equal);
* **batched interrupt sampling** — one vectorized hazard evaluation per
  tick across the whole fleet (``pressure_interrupt_probability_batch``
  over the active columns of the count matrix), then one binomial draw
  per replica on that replica's own RNG stream.  The draws cannot be
  merged further without breaking the per-seed determinism contract —
  seed ``s`` must produce the byte-identical trace a standalone
  ``ClusterSim`` at ``interrupt_seed=s`` produces — and the vectorized
  single-replica sampler (``repro.sim.interrupts``) already guarantees
  one RNG call per replica per tick;
* **cross-replica decision memoization** — replicas whose decision inputs
  coincide at a tick (market-state index, residual demand, excluded
  offerings, policy-state digest) share one GSS×ILP solve through the
  :class:`~repro.core.provisioner.DecisionMemo` hook.  In steady state
  most replicas collapse onto a handful of unique solves per tick,
  turning O(R·solves) into O(unique·solves) + O(R) array work;
* **collect-then-solve tick phase** (DESIGN.md §12) — when replicas
  *diverge* (heterogeneous demand, differing exclusions) and the memo
  stops collapsing, each event gathers every memo-miss decision into a
  :class:`~repro.core.provisioner.SolveBatch` and solves them as one
  cross-decision ``bracketed_gss_many`` — a single stacked engine
  invocation per golden round, dispatched through the pluggable solver
  backend (``backend=``, numpy or JAX) — before launching.  Decision
  content is untouched: batched-on and batched-off runs produce
  byte-identical traces (``batch_decisions=False`` restores the PR 4
  sequential phase).

Determinism / equality contract: for every seed, the fleet replica's
``ProvisioningDecision`` sequence, ``SimRound`` list, ``total_cost``,
``total_perf_hours``, and (with ``record_traces=True``) the JSONL trace
are **identical** — floats bit-for-bit — to a standalone ``ClusterSim``
run and to ``run_replicas`` at the same seed (tests/test_fleet.py).
``apply_fulfillment`` scenarios are rejected for the same reason
``run_replicas`` rejects them: live fulfillment consumes the market price
RNG, which a shared scripted path cannot reproduce.

When to use what (DESIGN.md §11): ``ClusterSim`` for one run with live
event-stream consumers; ``run_replicas`` when per-replica trace recording
of a handful of seeds is the point; ``FleetSim`` for Monte-Carlo sweeps
(tens to thousands of seeds) where replica throughput dominates.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..chaos.faults import ChaosController
from ..core import events_log
from ..core.backend import SolverBackend, make_backend
from ..core.efficiency import NodePool, Request
from ..core.market import Offering, pressure_interrupt_probability_batch
from ..core.market import snapshot_with
from ..core.provisioner import (DecisionMemo, PendingDecision, SolveBatch,
                                merge_pools)
from .engine import (SimResult, SimRound, _EPS, _INITIAL, _apply_losses,
                     _schedule, _split_pending, accrual_increments,
                     billable_pool, failed_decision, script_market_states,
                     shared_precompile, shock_affected, solver_down,
                     useful_scale)
from .events import (InterruptNotice, catalog_digest, decision_record,
                     demand_record, fault_record, fulfillment_record,
                     header_record, interrupts_record, market_state_record,
                     shock_record, summary_record, tick_record)
from .interrupts import (InterruptModel, NullInterruptModel,
                         PressureInterruptModel, PriceCrossingInterruptModel,
                         RebalanceRecommendationModel, make_interrupt_model)
from ..region.market import (apply_hazard_scale, hazard_scale_rows,
                             pool_egress_rate)
from .policy import make_policy
from .scenario import Scenario, Shock
from .trace import TraceRecorder


@dataclasses.dataclass
class _Replica:
    """Per-seed state the fleet cannot share: pool, RNG, policy, totals.

    ``request`` is per-replica because ``Scenario.demand_jitter`` makes the
    demand itself seed-dependent (heterogeneous-demand scenarios); without
    jitter every replica carries an equal copy of the shared request."""

    row: int                              # row in the fleet count matrix
    seed: int
    policy: object
    model: InterruptModel
    observers: List
    recorder: Optional[TraceRecorder]
    pool: NodePool
    request: Optional[Request] = None
    pending: List[InterruptNotice] = dataclasses.field(default_factory=list)
    total_cost: float = 0.0
    total_perf_hours: float = 0.0
    total_egress: float = 0.0
    cost_accrued_to: float = 0.0
    interrupted_nodes: int = 0
    decisions: List[Tuple[str, object]] = dataclasses.field(
        default_factory=list)
    rounds: List[SimRound] = dataclasses.field(default_factory=list)


class FleetSim:
    """Advance R scenario replicas in lockstep over one shared market path.

    Construction mirrors ``run_replicas``: one scenario, a sequence of
    interruption seeds, an optional explicit catalog.  ``run()`` returns
    one :class:`SimResult` per seed (same order), each carrying the
    fleet-wide cache counters in ``cache_stats``.

    ``record_traces=False`` (the default) skips building trace records —
    the big constant factor of a sweep — but changes nothing else; with
    ``record_traces=True`` every replica's trace is byte-identical to the
    standalone run's.  ``observer_factory(catalog)`` (optional) builds a
    fresh observer list per replica (e.g. a calibration probe), fed the
    identical event stream a standalone run would feed it.
    """

    def __init__(self, scenario: Scenario, interrupt_seeds: Sequence[int], *,
                 catalog: Optional[Sequence[Offering]] = None,
                 record_traces: bool = False, keep_snapshots: bool = False,
                 observer_factory: Optional[Callable] = None,
                 clock: Optional[Callable[[], float]] = None,
                 memoize: bool = True, batch_decisions: bool = True,
                 backend: Optional[SolverBackend] = None):
        if isinstance(backend, str):
            # convenience: FleetSim(..., backend="jax:fused") resolves the
            # registry spec exactly like make_backend would
            backend = make_backend(backend)
        if scenario.apply_fulfillment:
            raise ValueError(
                "FleetSim does not support apply_fulfillment scenarios: "
                "live fulfillment consumes the market price RNG, so replicas "
                "over a scripted market path would diverge from standalone "
                "runs; use independent ClusterSim runs for that sweep")
        self.scenario = scenario
        self.catalog = (list(catalog) if catalog is not None
                        else scenario.build_catalog())
        self.index = {o.offering_id: i for i, o in enumerate(self.catalog)}
        self._if_band = np.array([o.interruption_freq for o in self.catalog],
                                 dtype=np.float64)
        self.states = script_market_states(scenario, self.catalog)
        self.request = scenario.request()
        self.memo: Optional[DecisionMemo] = DecisionMemo() if memoize else None
        # collect-then-solve tick phase (DESIGN.md §12): decisions whose
        # policies support batching are gathered per event and solved as
        # one cross-decision bracketed_gss_many dispatch; decision content
        # is unchanged (tests prove batched-on ≡ batched-off traces)
        self.solve_batch: Optional[SolveBatch] = (
            SolveBatch(backend=backend) if batch_decisions else None)
        self.compile_cache: Dict = {}
        self.cache_stats: Dict[str, int] = {"compile_hits": 0,
                                            "compile_misses": 0}
        self.keep_snapshots = keep_snapshots
        self.record_traces = record_traces
        self.time = 0.0
        self.ticks = 0
        self.wall_seconds = 0.0
        self._state_pos = 0
        self._state_idx = -1
        self._spot: Optional[np.ndarray] = None
        self._t3: Optional[np.ndarray] = None
        self._snapshot: Optional[List[Offering]] = None
        self._snap_index: Dict[str, Offering] = {}
        self._ran = False

        # one shared chaos controller (DESIGN.md §16): every replica sees
        # the identical market path, so the observed-feed transformation is
        # fleet-wide — exactly what each standalone run would derive
        self.chaos = (ChaosController(scenario.faults, self.catalog)
                      if scenario.faults else None)
        self._events_snap = events_log.snapshot()

        # regional hazard regime + egress config (DESIGN.md §17), both
        # None outside a regional scenario so the inert path is untouched
        self._hazard_rows = hazard_scale_rows(scenario.region, self.catalog)
        self._egress_cfg = (scenario.region
                            if scenario.region is not None
                            and scenario.region.egress_per_pod_hour > 0.0
                            else None)

        digest = catalog_digest(self.catalog)
        policy_kwargs = {} if clock is None else {"clock": clock}
        self.replicas: List[_Replica] = []
        for row, seed in enumerate(interrupt_seeds):
            policy = make_policy(scenario.policy,
                                 tolerance=scenario.tolerance,
                                 ttl_hours=scenario.ttl_hours,
                                 region=scenario.region,
                                 **policy_kwargs)
            policy.bind(self.catalog)
            policy.bind_chaos(self.chaos)
            policy.set_decision_memo(self.memo)
            if self.solve_batch is not None:
                policy.set_solve_batch(self.solve_batch)
            model = make_interrupt_model(scenario.interrupt_model)
            model.reset(self.catalog, int(seed))
            if self._hazard_rows is not None:
                model.set_hazard_scale(dict(zip(
                    (o.offering_id for o in self.catalog),
                    self._hazard_rows.tolist())))
            extra = list(observer_factory(self.catalog)) \
                if observer_factory is not None else []
            recorder = None
            if record_traces:
                recorder = TraceRecorder()
                sc = dataclasses.replace(scenario, interrupt_seed=int(seed))
                recorder.write(header_record(sc.to_dict(), len(self.catalog),
                                             digest))
            self.replicas.append(_Replica(
                row=row, seed=int(seed), policy=policy, model=model,
                observers=[policy, *extra], recorder=recorder,
                pool=NodePool(items=[], counts=[]),
                request=self.request))
        # array-resident pool state: counts per (replica, offering), the
        # substrate of the fleet-wide batched interrupt sampling
        self.counts = np.zeros((len(self.replicas), len(self.catalog)),
                               dtype=np.int64)

    # -- shared-state plumbing ---------------------------------------------
    def _record_all(self, rec: Dict) -> None:
        if self.record_traces:
            for rep in self.replicas:
                rep.recorder.write(rec)

    def _refresh(self) -> None:
        """Pop the next scripted state; update the shared snapshot; fan the
        refresh out to every replica's observers (policy first, exactly the
        standalone fan-out order)."""
        spot, t3 = self.states[self._state_pos]
        self._state_pos += 1
        self._state_idx += 1
        # TRUE state: hazards (_spot/_t3/_snap_index) and billing stay in
        # reality; the policy decides on the chaos-observed snapshot
        # (DESIGN.md §16) — mirroring ClusterSim._refresh exactly
        self._spot, self._t3 = spot, t3
        recs = ([market_state_record(self.time, spot, t3)]
                if self.record_traces else None)
        if self.chaos is not None:
            spot_obs, t3_obs, transitions = self.chaos.observe(
                self._state_idx, self.time, spot, t3)
            if recs is not None:
                recs.extend(fault_record(self.time, kind, phase, idx)
                            for kind, phase, idx in transitions)
            self._true_snapshot = snapshot_with(self.catalog, spot, t3)
            self._snapshot = (self._true_snapshot
                              if spot_obs is spot and t3_obs is t3
                              else snapshot_with(self.catalog, spot_obs,
                                                 t3_obs))
        else:
            spot_obs, t3_obs = spot, t3
            self._snapshot = snapshot_with(self.catalog, spot, t3)
            self._true_snapshot = self._snapshot
        self._snap_index = {o.offering_id: o for o in self._true_snapshot}
        for rep in self.replicas:
            if recs is not None:
                for rec in recs:
                    rep.recorder.write(rec)
            for obs in rep.observers:
                obs.observe_market(self.time, spot_obs, t3_obs)

    def _precompiled(self, request: Request):
        return shared_precompile(self.compile_cache, self.cache_stats,
                                 self._state_idx, self._snapshot, request)

    def _set_pool(self, rep: _Replica, pool: NodePool) -> None:
        rep.pool = pool
        row = self.counts[rep.row]
        row[:] = 0
        for it, c in zip(pool.items, pool.counts):
            row[self.index[it.offering.offering_id]] = c

    def _decide(self, rep: _Replica, call: Callable):
        """Run one replica's decision with the memo context bound to
        (shared market state, policy name, policy-state digest) — the
        per-replica part of the memo key contract (DESIGN.md §11).  Under
        the collect phase the result may be a :class:`PendingDecision`
        token; :meth:`_resolved` materializes it after the batch runs."""
        if self.memo is None:
            return call()
        self.memo.context = (self._state_idx, rep.policy.name,
                             rep.policy.memo_digest())
        try:
            return call()
        finally:
            self.memo.context = None

    def _execute_batch(self) -> None:
        if self.solve_batch is not None and len(self.solve_batch):
            self.solve_batch.execute()

    @staticmethod
    def _resolved(decision):
        if isinstance(decision, PendingDecision):
            return decision.resolve()
        return decision

    # -- per-replica accounting (ClusterSim's exact float sequence, via the
    # shared engine helpers) ------------------------------------------------
    def _accrue_cost(self, rep: _Replica, now: float) -> None:
        dt = now - rep.cost_accrued_to
        cost, perf = accrual_increments(rep.pool, rep.request.pods, dt)
        rep.total_cost += cost
        rep.total_perf_hours += perf
        if self._egress_cfg is not None:
            egress = pool_egress_rate(self._egress_cfg, rep.pool) * dt
            rep.total_cost += egress
            rep.total_egress += egress
        rep.cost_accrued_to = now

    def _notify_pool(self, rep: _Replica, reason: str) -> None:
        """Formal observer-protocol pool fan-out, mirroring
        ``ClusterSim._notify_pool`` (fleet ≡ standalone event streams)."""
        for obs in rep.observers:
            obs.observe_pool(self.time, rep.pool, reason)

    def _launch(self, rep: _Replica, decision, reason: str,
                base_pool: Optional[NodePool] = None) -> None:
        new_pool = billable_pool(self.chaos, self._snap_index,
                                 decision.pool)
        # ICE clip: pure function of the REQUESTED counts, identical to
        # ClusterSim._launch's chaos branch (apply_fulfillment scenarios
        # are rejected at construction, so grants start at requested)
        caps = (self.chaos.ice_caps(self.time, new_pool.as_dict())
                if self.chaos is not None and new_pool.total_nodes
                else None)
        if caps is not None:
            requested = new_pool.as_dict()
            grants = {oid: min(g, caps.get(oid, g))
                      for oid, g in requested.items()}
            if rep.recorder is not None:
                rep.recorder.write(fulfillment_record(self.time, grants))
            for obs in rep.observers:
                obs.observe_fulfillment(self.time, requested, grants)
            items, counts = [], []
            for it, c in zip(new_pool.items, new_pool.counts):
                g = min(c, grants.get(it.offering.offering_id, 0))
                if g > 0:
                    items.append(it)
                    counts.append(g)
            new_pool = NodePool(items=items, counts=counts,
                                alpha=new_pool.alpha,
                                request=new_pool.request)
        if rep.recorder is not None:
            rep.recorder.write(decision_record(
                self.time, reason, rep.policy.name,
                decision.pool.as_dict(), decision.alpha, decision.metrics))
        rep.decisions.append((reason, decision))
        if base_pool is not None and base_pool.total_nodes:
            self._set_pool(rep, merge_pools(base_pool, new_pool))
        else:
            self._set_pool(rep, new_pool)
        self._notify_pool(rep, reason)

    # -- events (each: collect decisions → execute batch → launch) ----------
    def _on_initial(self) -> None:
        self._refresh()
        staged = []
        for rep in self.replicas:
            if self.scenario.demand_jitter:
                rep.request = dataclasses.replace(
                    rep.request, pods=self.scenario.effective_pods(
                        rep.seed, 0.0, self.scenario.pods))
            if solver_down(self.chaos, rep.policy, self.time):
                staged.append((rep, failed_decision(rep.request)))
                continue
            pre = self._precompiled(rep.request)
            decision = self._decide(
                rep, lambda rep=rep, pre=pre: rep.policy.provision(
                    rep.request, self._snapshot, self.time, precompiled=pre))
            staged.append((rep, decision))
        self._execute_batch()
        for rep, decision in staged:
            self._launch(rep, self._resolved(decision), "initial")

    def _on_shock(self, shock: Shock) -> None:
        if self.record_traces:
            self._record_all(shock_record(self.time, shock.kind,
                                          shock.selector, shock.factor,
                                          shock_affected(self.catalog,
                                                         shock)))
        self._refresh()

    def _on_demand(self, pods: int) -> None:
        for rep in self.replicas:
            self._accrue_cost(rep, self.time)
        self.request = dataclasses.replace(self.request, pods=pods)
        staged = []
        for rep in self.replicas:
            rpods = self.scenario.effective_pods(rep.seed, self.time, pods)
            rep.request = dataclasses.replace(rep.request, pods=rpods)
            if rep.recorder is not None:
                rep.recorder.write(demand_record(self.time, rpods))
            shortfall = rpods - rep.pool.total_pods
            if shortfall <= 0 and rep.pool.total_nodes:
                continue
            repl_request = (dataclasses.replace(rep.request, pods=shortfall)
                            if rep.pool.total_nodes else rep.request)
            if solver_down(self.chaos, rep.policy, self.time):
                staged.append((rep, failed_decision(repl_request)))
                continue
            pre = self._precompiled(repl_request)
            decision = self._decide(
                rep, lambda rep=rep, req=repl_request, pre=pre:
                rep.policy.provision(req, self._snapshot, self.time,
                                     precompiled=pre))
            staged.append((rep, decision))
        self._execute_batch()
        for rep, decision in staged:
            self._launch(rep, self._resolved(decision), "demand",
                         base_pool=rep.pool if rep.pool.total_nodes else None)

    def _on_tick(self, t: float, dt: float) -> None:
        self.ticks += 1
        scales = []
        for rep in self.replicas:
            scales.append(useful_scale(rep.pool,     # interval's pool
                                       rep.request.pods))
            self._accrue_cost(rep, t)
        self._record_all(tick_record(t, dt))
        self._refresh()
        pool_dicts = [rep.pool.as_dict() for rep in self.replicas]
        sampled_fleet = self._sample_fleet(dt, t, pool_dicts)
        staged = []
        for rep, scale, sampled, pool_dict in zip(self.replicas, scales,
                                                  sampled_fleet, pool_dicts):
            matured = any(n.effective_time <= t + _EPS for n in rep.pending)
            if (self.scenario.inject_if_idle and not sampled and not matured
                    and any(c > 0 for c in pool_dict.values())):
                oid, c = max(pool_dict.items(), key=lambda kv: kv[1])
                sampled = [InterruptNotice(time=t, offering_id=oid, count=c,
                                           reason="fault-injection")]
            if rep.recorder is not None:
                rep.recorder.write(interrupts_record(t, sampled))
            for obs in rep.observers:
                obs.observe_interrupts(t, dt, pool_dict, sampled)
            effective, rep.pending = _split_pending(rep.pending, sampled, t)

            survivors, lost_nodes, lost_pods, lost_perf = _apply_losses(
                rep.pool, effective)
            rep.total_perf_hours -= 0.5 * dt * lost_perf * scale
            rep.interrupted_nodes += lost_nodes
            decision, shortfall = None, 0
            if effective:
                shortfall = max(0, rep.request.pods - survivors.total_pods)
                if solver_down(self.chaos, rep.policy, t):
                    decision = (failed_decision(dataclasses.replace(
                        rep.request, pods=shortfall)) if shortfall > 0
                        else None)
                else:
                    pre = self._precompiled(rep.request)
                    decision = self._decide(
                        rep, lambda rep=rep, eff=effective, surv=survivors,
                        pre=pre: rep.policy.on_interrupts(
                            eff, rep.request, self._snapshot,
                            surv.total_pods, t, precompiled=pre))
            staged.append((rep, sampled, effective, survivors, lost_nodes,
                           lost_pods, lost_perf, shortfall, decision))
        self._execute_batch()
        for (rep, sampled, effective, survivors, lost_nodes, lost_pods,
             lost_perf, shortfall, decision) in staged:
            decision = self._resolved(decision)
            if effective:
                self._set_pool(rep, survivors)
                if decision is not None:
                    self._launch(rep, decision, "interrupt",
                                 base_pool=survivors)
                else:
                    self._notify_pool(rep, "losses")
            rep.rounds.append(SimRound(
                time=t, notices=list(sampled), effective=effective,
                lost_nodes=lost_nodes, lost_pods=lost_pods,
                shortfall=shortfall, decision=decision, pool=rep.pool,
                snapshot=self._snapshot if self.keep_snapshots else None,
                lost_perf=lost_perf))

    # -- batched interrupt sampling -----------------------------------------
    def _sample_fleet(self, dt: float, now: float,
                      pool_dicts: List[Dict[str, int]],
                      ) -> List[List[InterruptNotice]]:
        """Per-replica notice lists for this tick, drawn fleet-wide.

        Known models get the batched path (one shared hazard matrix /
        crossing mask per tick; per-replica draws only where the per-seed
        RNG contract demands them), delegating every piece of model
        *semantics* — the crossing rule, the advisory-lead stamping, the
        binomial draw — back to the model's own methods so there is one
        definition of each.  An unknown custom model falls back to its
        per-replica ``sample`` — still one vectorized call per replica if
        it follows the ``PressureInterruptModel`` idiom.
        """
        if not self.replicas:
            return []
        proto = self.replicas[0].model
        wrapper = None
        if isinstance(proto, RebalanceRecommendationModel):
            wrapper = proto
            inner_of = lambda m: m.inner               # noqa: E731
            proto = proto.inner
        else:
            inner_of = lambda m: m                     # noqa: E731

        if isinstance(proto, NullInterruptModel):
            per = [[] for _ in self.replicas]
        elif isinstance(proto, PriceCrossingInterruptModel):
            # deterministic, market-wide: one crossing mask for the fleet
            # (bids are seed-independent, so replica 0's model speaks for
            # all; the rule itself lives in crossed_ids)
            crossed = proto.crossed_ids(self._snap_index)
            per = [[InterruptNotice(time=now, offering_id=oid, count=c,
                                    reason="price-crossing")
                    for oid, c in pool.items() if c > 0 and oid in crossed]
                   for pool in pool_dicts]
        elif isinstance(proto, PressureInterruptModel):
            per = self._sample_pressure(inner_of, dt, now, pool_dicts)
        else:
            return [rep.model.sample(self._snap_index, pool, dt, now)
                    for rep, pool in zip(self.replicas, pool_dicts)]

        if wrapper is not None:
            per = [wrapper.wrap(notices) for notices in per]
        return per

    def _sample_pressure(self, inner_of, dt: float, now: float,
                         pool_dicts: List[Dict[str, int]],
                         ) -> List[List[InterruptNotice]]:
        """One vectorized hazard evaluation across the whole fleet (the
        (R, active) probability matrix from the count matrix), then one
        binomial draw per replica on its own stream — bitwise the same
        probabilities and the same RNG consumption as R standalone runs."""
        active = np.flatnonzero(self.counts.any(axis=0))
        if active.size == 0:
            return [[] for _ in self.replicas]
        probs = pressure_interrupt_probability_batch(
            self.counts[:, active],
            self._t3[active].astype(np.float64),
            self._if_band[active], dt)
        if self._hazard_rows is not None:
            # regional hazard regime: same law (apply_hazard_scale), same
            # float sequence as the standalone model's per-entry path
            probs = apply_hazard_scale(probs, self._hazard_rows[active])
        col = {int(c): j for j, c in enumerate(active)}
        per: List[List[InterruptNotice]] = []
        for rep, pool in zip(self.replicas, pool_dicts):
            entries = [(oid, c) for oid, c in pool.items() if c > 0]
            if not entries:
                per.append([])
                continue
            counts = np.array([c for _, c in entries], dtype=np.int64)
            p = probs[rep.row, [col[self.index[oid]] for oid, _ in entries]]
            lost = inner_of(rep.model).draw_lost_counts(counts, p)
            per.append([InterruptNotice(time=now, offering_id=oid,
                                        count=int(k))
                        for (oid, _), k in zip(entries, lost) if k > 0])
        return per

    # -- run ----------------------------------------------------------------
    def run(self) -> List[SimResult]:
        if self._ran:
            raise RuntimeError("FleetSim.run() may only be called once; "
                               "construct a new FleetSim per sweep")
        self._ran = True
        t0 = time.perf_counter()
        for t, prio, payload in _schedule(self.scenario):
            self.time = t
            if payload is _INITIAL:
                self._on_initial()
            elif prio == 0:
                self._on_shock(payload)
            elif prio == 1:
                self._on_demand(payload)
            else:
                self._on_tick(t, payload)
        results = []
        base_stats = self.stats()
        for k, v in events_log.delta_since(self._events_snap).items():
            base_stats[f"event_{k}"] = base_stats.get(f"event_{k}", 0) + v
        for rep in self.replicas:
            if rep.recorder is not None:
                rep.recorder.write(summary_record(
                    self.time, rep.total_cost, rep.interrupted_nodes,
                    len(rep.decisions), rep.pool.as_dict()))
            stats = dict(base_stats)
            chaos_stats = getattr(rep.policy, "chaos_stats", None)
            if chaos_stats is not None:
                for k, v in chaos_stats().items():
                    stats[f"chaos_{k}"] = v
            results.append(SimResult(
                scenario=dataclasses.replace(self.scenario,
                                             interrupt_seed=rep.seed),
                decisions=rep.decisions, rounds=rep.rounds,
                total_cost=rep.total_cost,
                interrupted_nodes=rep.interrupted_nodes,
                pool=rep.pool, recorder=rep.recorder or TraceRecorder(),
                total_perf_hours=rep.total_perf_hours,
                total_egress=rep.total_egress,
                cache_stats=stats))
        self.wall_seconds = time.perf_counter() - t0
        return results

    def stats(self) -> Dict[str, int]:
        """Fleet-wide cache-effectiveness counters (also stamped onto every
        returned ``SimResult.cache_stats``)."""
        out = dict(self.cache_stats)
        out["replicas"] = len(self.replicas)
        out["ticks"] = self.ticks
        if self.memo is not None:
            out.update(self.memo.stats())
        if self.solve_batch is not None:
            be = self.solve_batch.backend
            info = getattr(be, "device_cache_info", None)
            if callable(info):
                for k, v in info().items():
                    out[f"device_cache_{k}"] = v
        return out


def run_fleet(scenario: Scenario, interrupt_seeds: Sequence[int], *,
              catalog: Optional[Sequence[Offering]] = None,
              record_traces: bool = False, keep_snapshots: bool = False,
              observer_factory: Optional[Callable] = None,
              clock: Optional[Callable[[], float]] = None,
              memoize: bool = True, batch_decisions: bool = True,
              backend: Optional[SolverBackend] = None) -> List[SimResult]:
    """Accelerated ``run_replicas``: one :class:`SimResult` per seed,
    per-seed identical to standalone ``ClusterSim`` runs — decisions,
    rounds, and float totals always; the JSONL trace too, but **only with
    ``record_traces=True``**.  By default no trace records are built (the
    big constant factor of a sweep), so ``result.records`` /
    ``decision_records()`` are empty — pass ``record_traces=True`` when a
    consumer (e.g. ``calibration_report``) reads the trace."""
    return FleetSim(scenario, interrupt_seeds, catalog=catalog,
                    record_traces=record_traces,
                    keep_snapshots=keep_snapshots,
                    observer_factory=observer_factory, clock=clock,
                    memoize=memoize, batch_decisions=batch_decisions,
                    backend=backend).run()


def run_fleet_paths(scenario: Scenario, path_seeds: Sequence[int],
                    interrupt_seeds: Sequence[int],
                    **kwargs) -> List[List[SimResult]]:
    """Sweep *correlated market paths* on top of the interrupt-seed sweep
    (DESIGN.md §17): one FleetSim per ``path_seed``, each re-deriving the
    scenario's regional shock stream from ``shock_seed=path_seed`` — the
    shared factor ``z0`` moves every region together within a path while
    paths stay independent.  Requires a regional scenario
    (``scenario.region`` set); returns one result list per path seed, in
    order, each aligned to ``interrupt_seeds``.  Every inner run keeps the
    per-seed fleet ≡ standalone contract verbatim, since a path is just a
    scenario with a different ``RegionConfig.shock_seed``."""
    if scenario.region is None:
        raise ValueError("run_fleet_paths needs a regional scenario "
                         "(scenario.region is None)")
    out: List[List[SimResult]] = []
    for ps in path_seeds:
        sc = dataclasses.replace(
            scenario, region=dataclasses.replace(scenario.region,
                                                 shock_seed=int(ps)))
        out.append(run_fleet(sc, interrupt_seeds, **kwargs))
    return out
