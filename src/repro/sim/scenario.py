"""Declarative scenario specs: a paper figure as ~20 lines of config.

A :class:`Scenario` fully determines a simulation run — catalog seed,
market evolution seeds, demand schedule, shock schedule, interruption
model, and provisioning policy are all plain JSON-serializable values —
so the trace header alone is enough to re-instantiate and replay a run
(DESIGN.md §9).  Interrupt models and policies are referenced by spec
*string* (parsed by ``make_interrupt_model`` / ``make_policy``) precisely
to keep the spec serializable.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from ..core.efficiency import Request
from ..core.market import Offering, generate_catalog


@dataclasses.dataclass(frozen=True)
class Shock:
    """A deterministic scheduled market shock (price spike, supply crunch).

    ``selector`` substring-matches offering_ids ("" = the whole market);
    ``kind`` is "price" or "capacity"; ``factor`` multiplies spot price or
    T3 respectively (clipped to the market's valid ranges).
    """

    time: float
    kind: str
    factor: float
    selector: str = ""

    def __post_init__(self):
        # normalize numerics so construction and the trace-header JSON
        # round trip serialize identically (9 vs 9.0 would break the
        # byte-identical replay contract)
        object.__setattr__(self, "time", float(self.time))
        object.__setattr__(self, "factor", float(self.factor))

    def factors(self) -> Tuple[float, float]:
        """(price_factor, t3_factor) — the single source of the kind→factor
        dispatch, shared by the live source and the scripted market path so
        the two can never desynchronize."""
        if self.kind == "price":
            return self.factor, 1.0
        if self.kind == "capacity":
            return 1.0, self.factor
        raise ValueError(f"unknown shock kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Everything needed to reproduce one trace-driven simulation run."""

    name: str
    duration_hours: float = 24.0
    step_hours: float = 6.0
    # -- demand -----------------------------------------------------------
    pods: int = 100
    cpu_per_pod: float = 2.0
    mem_per_pod: float = 2.0
    workload: Tuple[str, ...] = ()            # subset of ("network", "disk")
    demand_schedule: Tuple[Tuple[float, int], ...] = ()   # (time, new pods)
    # -- environment ------------------------------------------------------
    shocks: Tuple[Shock, ...] = ()
    interrupt_model: str = "pressure"
    catalog_seed: int = 0
    max_offerings: int = 600
    market_seed: int = 0
    interrupt_seed: int = 0
    price_vol: float = 0.06
    t3_vol: float = 1.6
    # -- control plane ----------------------------------------------------
    policy: str = "kubepacs"
    tolerance: float = 0.01
    ttl_hours: float = 2.0              # UnavailableOfferingsCache TTL
    apply_fulfillment: bool = False     # clip launches by live T3 capacity
    inject_if_idle: bool = False        # §5.4.3 fault injection: if a tick
    #                                     samples no interrupt, kill the
    #                                     largest allocation deterministically

    def __post_init__(self):
        # normalize order-insensitive and numeric fields so construction
        # and the to_dict/from_dict trace-header round trip compare equal
        # AND serialize to identical bytes (int vs float demand times
        # would break the byte-identical replay contract)
        object.__setattr__(self, "workload", tuple(sorted(self.workload)))
        object.__setattr__(self, "demand_schedule",
                           tuple((float(t), int(p))
                                 for t, p in self.demand_schedule))
        object.__setattr__(self, "duration_hours", float(self.duration_hours))
        object.__setattr__(self, "step_hours", float(self.step_hours))

    def request(self) -> Request:
        return Request(pods=self.pods, cpu_per_pod=self.cpu_per_pod,
                       mem_per_pod=self.mem_per_pod,
                       workload=frozenset(self.workload))

    def build_catalog(self) -> List[Offering]:
        return generate_catalog(seed=self.catalog_seed,
                                max_offerings=self.max_offerings)

    # -- (de)serialization — the trace-header round trip -------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["workload"] = sorted(self.workload)
        d["demand_schedule"] = [list(x) for x in self.demand_schedule]
        d["shocks"] = [dataclasses.asdict(s) for s in self.shocks]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        d = dict(d)
        d["workload"] = tuple(d.get("workload", ()))
        d["demand_schedule"] = tuple(
            tuple(x) for x in d.get("demand_schedule", ()))
        d["shocks"] = tuple(Shock(**s) for s in d.get("shocks", ()))
        return cls(**d)   # __post_init__ normalizes numerics/order
