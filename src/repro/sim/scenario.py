"""Declarative scenario specs: a paper figure as ~20 lines of config.

A :class:`Scenario` fully determines a simulation run — catalog seed,
market evolution seeds, demand schedule, shock schedule, interruption
model, and provisioning policy are all plain JSON-serializable values —
so the trace header alone is enough to re-instantiate and replay a run
(DESIGN.md §9).  Interrupt models and policies are referenced by spec
*string* (parsed by ``make_interrupt_model`` / ``make_policy``) precisely
to keep the spec serializable.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..chaos.faults import Fault
from ..core.efficiency import Request
from ..core.market import Offering, generate_catalog, restrict
from ..region.config import RegionConfig


@dataclasses.dataclass(frozen=True)
class Shock:
    """A deterministic scheduled market shock (price spike, supply crunch).

    ``selector`` substring-matches offering_ids ("" = the whole market);
    ``kind`` is "price" or "capacity"; ``factor`` multiplies spot price or
    T3 respectively (clipped to the market's valid ranges).
    """

    time: float
    kind: str
    factor: float
    selector: str = ""

    def __post_init__(self):
        # normalize numerics so construction and the trace-header JSON
        # round trip serialize identically (9 vs 9.0 would break the
        # byte-identical replay contract)
        object.__setattr__(self, "time", float(self.time))
        object.__setattr__(self, "factor", float(self.factor))

    def factors(self) -> Tuple[float, float]:
        """(price_factor, t3_factor) — the single source of the kind→factor
        dispatch, shared by the live source and the scripted market path so
        the two can never desynchronize."""
        if self.kind == "price":
            return self.factor, 1.0
        if self.kind == "capacity":
            return 1.0, self.factor
        raise ValueError(f"unknown shock kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Everything needed to reproduce one trace-driven simulation run."""

    name: str
    duration_hours: float = 24.0
    step_hours: float = 6.0
    # -- demand -----------------------------------------------------------
    pods: int = 100
    cpu_per_pod: float = 2.0
    mem_per_pod: float = 2.0
    workload: Tuple[str, ...] = ()            # subset of ("network", "disk")
    demand_schedule: Tuple[Tuple[float, int], ...] = ()   # (time, new pods)
    # -- environment ------------------------------------------------------
    shocks: Tuple[Shock, ...] = ()
    interrupt_model: str = "pressure"
    catalog_seed: int = 0
    max_offerings: int = 600
    market_seed: int = 0
    interrupt_seed: int = 0
    price_vol: float = 0.06
    t3_vol: float = 1.6
    # -- control plane ----------------------------------------------------
    policy: str = "kubepacs"
    tolerance: float = 0.01
    ttl_hours: float = 2.0              # UnavailableOfferingsCache TTL
    apply_fulfillment: bool = False     # clip launches by live T3 capacity
    inject_if_idle: bool = False        # §5.4.3 fault injection: if a tick
    #                                     samples no interrupt, kill the
    #                                     largest allocation deterministically
    demand_jitter: float = 0.0          # per-replica demand jitter amplitude
    #                                     (fraction; see effective_pods)
    # -- chaos (DESIGN.md §16) --------------------------------------------
    faults: Tuple[Fault, ...] = ()      # deterministic fault windows; part
    #                                     of the spec, so the trace header
    #                                     alone still replays the run
    # -- regions (DESIGN.md §17) ------------------------------------------
    region: Optional[RegionConfig] = None   # multi-region knobs; None (and
    #                                         every RegionConfig default)
    #                                         is bit-inert

    def __post_init__(self):
        # normalize order-insensitive and numeric fields so construction
        # and the to_dict/from_dict trace-header round trip compare equal
        # AND serialize to identical bytes (int vs float demand times
        # would break the byte-identical replay contract)
        object.__setattr__(self, "workload", tuple(sorted(self.workload)))
        object.__setattr__(self, "demand_schedule",
                           tuple((float(t), int(p))
                                 for t, p in self.demand_schedule))
        object.__setattr__(self, "duration_hours", float(self.duration_hours))
        object.__setattr__(self, "step_hours", float(self.step_hours))
        object.__setattr__(self, "demand_jitter", float(self.demand_jitter))

    def effective_pods(self, seed: int, time: float, pods: int) -> int:
        """Per-replica demand for a (initial or scheduled) demand event.

        With ``demand_jitter`` = j > 0 the base ``pods`` is scaled by a
        factor drawn uniformly from [1−j, 1+j] — *stream-free*: the draw
        seeds a fresh generator from (interruption seed, event time, base
        pods), so it is a pure function of those values, consumes no RNG
        stream anywhere, and therefore reproduces identically in
        ``ClusterSim``, ``run_replicas``, ``FleetSim``, and trace replay
        (the per-seed equality contract, DESIGN.md §12).  Replicas at
        different seeds see different demands — the heterogeneous-demand
        regime where the cross-replica DecisionMemo stops collapsing
        solves and the collect-then-solve batch must carry the load.
        With ``demand_jitter == 0`` (the default) the base demand passes
        through untouched, keeping every pre-existing scenario byte-exact.
        """
        if not self.demand_jitter:
            return int(pods)
        rng = np.random.default_rng(
            (int(seed) & 0xFFFFFFFF, int(round(time * 3600.0)), int(pods)))
        factor = 1.0 + self.demand_jitter * (2.0 * rng.random() - 1.0)
        return max(1, int(round(pods * factor)))

    def request(self) -> Request:
        return Request(pods=self.pods, cpu_per_pod=self.cpu_per_pod,
                       mem_per_pod=self.mem_per_pod,
                       workload=frozenset(self.workload))

    def build_catalog(self) -> List[Offering]:
        catalog = generate_catalog(seed=self.catalog_seed,
                                   max_offerings=self.max_offerings)
        if self.region is not None and self.region.regions:
            # restrict *after* generation: generate_catalog draws from one
            # shared rng across regions, so passing a region subset into it
            # would change every draw — filtering the full catalog keeps
            # the surviving offerings byte-identical to the K=all run
            catalog = restrict(catalog, regions=self.region.regions)
        return catalog

    # -- (de)serialization — the trace-header round trip -------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["workload"] = sorted(self.workload)
        d["demand_schedule"] = [list(x) for x in self.demand_schedule]
        d["shocks"] = [dataclasses.asdict(s) for s in self.shocks]
        d["faults"] = [dataclasses.asdict(f) for f in self.faults]
        d["region"] = None if self.region is None else self.region.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        d = dict(d)
        d["workload"] = tuple(d.get("workload", ()))
        d["demand_schedule"] = tuple(
            tuple(x) for x in d.get("demand_schedule", ()))
        d["shocks"] = tuple(Shock(**s) for s in d.get("shocks", ()))
        d["faults"] = tuple(Fault(**f) for f in d.get("faults", ()))
        region = d.get("region")
        d["region"] = (None if region is None
                       else RegionConfig.from_dict(region))
        return cls(**d)   # __post_init__ normalizes numerics/order


def high_demand_scenario(pods: int = 250_000, **overrides) -> Scenario:
    """Demand-coarsening stress family (DESIGN.md §14).

    Six-figure pod demands against a generated catalog whose per-instance
    pod counts share a large power-of-two factor: quarter-vCPU /
    quarter-GiB pods make ``Pod_i = 4·vCPU_i``, so the compiled market's
    ``pods_gcd`` is ≥ 8 and the coarsening ladder always has a gcd rung
    available.  At the default demand the residual still exceeds
    ``max_rows·gcd``, so the default policy lands on the certified approx
    tier — pass a custom :class:`~repro.core.CoarseningConfig` to the
    provisioner to pin the gcd tier instead.  The demand schedule swings
    ±20 % so re-provisioning stays in the coarse regime all run."""
    base = dict(
        name=f"high_demand_{pods}", duration_hours=24.0, step_hours=6.0,
        pods=pods, cpu_per_pod=0.25, mem_per_pod=0.25,
        demand_schedule=((6.0, int(pods * 1.2)), (12.0, int(pods * 0.8)),
                         (18.0, int(pods * 1.1))),
        interrupt_model="pressure",
        policy="kubepacs", catalog_seed=17, max_offerings=400,
        market_seed=17, interrupt_seed=17)
    base.update(overrides)
    return Scenario(**base)


def serving_scenario(workload: str = "diurnal", *, base_qps: float = 1000.0,
                     seed: int = 11, policy: str = "serving_slo",
                     duration_hours: float = 24.0, step_hours: float = 1.0,
                     profile=None, **overrides) -> Scenario:
    """SLO-driven serving scenario family (DESIGN.md §15): the pod-demand
    schedule is *derived* from a deterministic request-rate trace
    (:class:`repro.serve_sim.workload.WorkloadSpec`) via square-root
    staffing against the perf model's reference QPS/pod — so every
    compared policy faces the identical capacity demand and differs only
    in which offerings provide it.  ``workload`` picks the trace family
    (``diurnal`` | ``bursty`` | ``flash``); hourly ticks keep the
    interrupt → re-provision → recovery loop running all day.  Lazy
    imports keep ``repro.sim`` ↔ ``repro.serve_sim`` acyclic."""
    from ..serve_sim.perf_model import default_profile, reference_qps_per_pod
    from ..serve_sim.workload import WorkloadSpec, demand_schedule_from_trace
    if profile is None:
        profile = default_profile()
    spec = WorkloadSpec(kind=workload, base_qps=base_qps, seed=seed,
                        duration_hours=duration_hours,
                        step_hours=step_hours)
    initial, schedule = demand_schedule_from_trace(
        spec, reference_qps_per_pod(profile))
    base = dict(
        name=f"serving_{workload}", duration_hours=duration_hours,
        step_hours=step_hours, pods=initial, cpu_per_pod=2.0,
        mem_per_pod=4.0, demand_schedule=schedule,
        interrupt_model="pressure", policy=policy,
        catalog_seed=11, max_offerings=250, market_seed=11,
        interrupt_seed=seed)
    base.update(overrides)
    return Scenario(**base)


def heterogeneous_demand_scenario(**overrides) -> Scenario:
    """Standard low-memo-hit stress scenario (DESIGN.md §12).

    Per-replica demand jitter (±15 % at the initial provisioning and at
    every scheduled demand change) makes each replica's requested pod
    count unique, so the cross-replica DecisionMemo's keys almost never
    coincide — the regime the PR 4 fleet engine is weakest in and the
    collect-then-solve batched tick phase exists for.  Pressure-sampled
    interrupts plus a mid-run capacity crunch keep the §4.1 exclusion /
    shortfall machinery exercised while replicas diverge.
    """
    base = dict(
        name="heterogeneous_demand", duration_hours=48.0, step_hours=6.0,
        pods=160, cpu_per_pod=2.0, mem_per_pod=2.0,
        demand_schedule=((6.0, 220), (18.0, 140), (30.0, 260)),
        demand_jitter=0.15,
        interrupt_model="pressure",
        shocks=(Shock(time=24.0, kind="capacity", factor=0.7),),
        policy="kubepacs", catalog_seed=17, max_offerings=200,
        market_seed=17, interrupt_seed=17)
    base.update(overrides)
    return Scenario(**base)
