"""Model/config system: one dataclass covers all 10 assigned architectures.

A model is a heterogeneous stack of layers; each layer has a token *mixer*
("attn" | "mamba") and an *ffn* ("mlp" | "moe" | "none").  The stack is
expressed as ``prefix_layers`` unrolled layers followed by a repeating period
of ``scan_period`` layers scanned ``n_periods`` times (HLO stays O(period),
not O(depth) — required for 61-layer trillion-param dry-runs on one CPU).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str          # "attn" | "mamba"
    ffn: str            # "mlp" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | ssm | hybrid | vlm | audio | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0           # stablelm: partial rotary
    norm: str = "rmsnorm"           # "rmsnorm" | "layernorm"
    # --- embeddings / head ---
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- Mamba (mamba-1) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- stack layout ---
    layout: Tuple[LayerSpec, ...] = ()
    prefix_layers: int = 0          # leading unrolled layers
    scan_period: int = 1            # repeating period for the scanned tail
    # --- modality frontend (stubs per assignment) ---
    input_mode: str = "tokens"      # "tokens" | "vlm" | "audio_codes"
    vision_prefix: int = 256        # vlm: precomputed patch embeddings
    n_codebooks: int = 1            # musicgen: EnCodec codebooks
    # --- numerics / execution ---
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"
    remat_policy: str = "dots"      # "none" | "dots" | "full"
    attention_impl: str = "auto"    # "auto" | "naive" | "chunked" | "pallas"
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    attn_causal_skip: bool = False  # triangular block schedule (halves FLOPs)
    mamba_chunk: int = 256
    # --- sharding ---
    fsdp: bool = False              # also shard weight "other" axis over data
    expert_parallel: bool = True    # shard experts over model axis

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        body = self.n_layers - self.prefix_layers
        assert body % self.scan_period == 0, (self.name, body, self.scan_period)
        return body // self.scan_period

    @property
    def dt_rank(self) -> int:
        return max(1, -(-self.d_model // 16))     # ceil(d_model/16), mamba-1

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_spec(self, i: int) -> LayerSpec:
        return self.layout[i]

    def period_layout(self) -> Tuple[LayerSpec, ...]:
        """The LayerSpecs of one scanned period (validated homogeneous)."""
        body = self.layout[self.prefix_layers:]
        period = body[: self.scan_period]
        for p in range(self.n_periods):
            chunk = body[p * self.scan_period:(p + 1) * self.scan_period]
            assert chunk == period, f"{self.name}: layout not periodic at {p}"
        return period

    def validate(self) -> "ModelConfig":
        assert len(self.layout) == self.n_layers, self.name
        assert self.n_heads % self.n_kv_heads == 0, self.name
        if any(l.ffn == "moe" for l in self.layout):
            assert self.n_experts > 0 and self.n_experts_active > 0
            assert self.moe_d_ff > 0
        self.period_layout()
        return self


# ---------------------------------------------------------------------------
# Layout builders
# ---------------------------------------------------------------------------

def dense_layout(n: int) -> Tuple[LayerSpec, ...]:
    return tuple(LayerSpec("attn", "mlp") for _ in range(n))


def mamba_layout(n: int) -> Tuple[LayerSpec, ...]:
    return tuple(LayerSpec("mamba", "none") for _ in range(n))


def moe_layout(n: int) -> Tuple[LayerSpec, ...]:
    return tuple(LayerSpec("attn", "moe") for _ in range(n))


def jamba_layout(n: int, period: int = 8, attn_at: int = 4,
                 moe_every: int = 2) -> Tuple[LayerSpec, ...]:
    """Jamba: 1 attention per ``period`` layers (rest Mamba), MoE every
    ``moe_every``-th layer (odd positions), per arXiv:2403.19887."""
    out = []
    for i in range(n):
        mixer = "attn" if i % period == attn_at else "mamba"
        ffn = "moe" if i % moe_every == 1 else "mlp"
        out.append(LayerSpec(mixer, ffn))
    return tuple(out)


# ---------------------------------------------------------------------------
# Input shapes (the assignment's 4 shapes) + registry plumbing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

#: archs allowed to run long_500k (sub-quadratic token mixing)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True


_REGISTRY: Dict[str, "tuple"] = {}


def register(arch_id: str, full, smoke) -> None:
    _REGISTRY[arch_id] = (full, smoke)


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    """Public entry: ``get_config("qwen2.5-14b")`` or the reduced smoke twin."""
    from . import _load_all   # populate registry lazily
    _load_all()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    full, smoke_fn = _REGISTRY[arch_id]
    return (smoke_fn() if smoke else full()).validate()


def list_archs():
    from . import _load_all
    _load_all()
    return sorted(_REGISTRY)
