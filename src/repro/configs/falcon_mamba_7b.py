"""falcon-mamba-7b — attention-free Mamba-1 SSM [arXiv:2410.05355]."""
from .base import ModelConfig, mamba_layout, register


def full() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=65024, ssm_state=16, ssm_conv=4, ssm_expand=2,
        layout=mamba_layout(64), scan_period=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=256, ssm_state=8, ssm_conv=4, ssm_expand=2,
        layout=mamba_layout(2), scan_period=1,
    )


register("falcon-mamba-7b", full, smoke)
