"""qwen2.5-32b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-32B]."""
from .base import ModelConfig, dense_layout, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=27648, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
        layout=dense_layout(64), scan_period=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=192, vocab_size=256, qkv_bias=True, rope_theta=1e6,
        layout=dense_layout(2), scan_period=1,
    )


register("qwen2.5-32b", full, smoke)
