"""Architecture registry: one module per assigned arch (+ reduced smoke twin).

``get_config(arch_id, smoke=False)`` is the public entry point; arch ids are
the assignment's ids (e.g. ``--arch qwen2.5-14b``).
"""

from .base import (ModelConfig, LayerSpec, InputShape, SHAPES,
                   shape_applicable, get_config, list_archs, register)

_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (internlm2_1_8b, qwen2_5_14b, stablelm_3b, qwen2_5_32b,
                   falcon_mamba_7b, jamba_1_5_large, internvl2_1b,
                   musicgen_large, qwen3_moe_30b, kimi_k2_1t)  # noqa: F401


__all__ = ["ModelConfig", "LayerSpec", "InputShape", "SHAPES",
           "shape_applicable", "get_config", "list_archs", "register"]
