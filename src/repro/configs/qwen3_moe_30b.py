"""qwen3-moe-30b-a3b — 128-expert top-8 MoE, GQA kv=4, head_dim 128
[hf:Qwen/Qwen3-30B-A3B].  (Qwen3's q/k-norm is omitted; noted in DESIGN.md.)"""
from .base import ModelConfig, moe_layout, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab_size=151936, rope_theta=1e6,
        n_experts=128, n_experts_active=8, moe_d_ff=768,
        layout=moe_layout(48), scan_period=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256, rope_theta=1e6,
        n_experts=8, n_experts_active=2, moe_d_ff=96,
        layout=moe_layout(2), scan_period=1,
    )


register("qwen3-moe-30b-a3b", full, smoke)
