"""kimi-k2-1t-a32b — trillion-parameter MoE: 384 experts top-8 + 1 shared
expert, first layer dense (DeepSeek-V3 lineage) [arXiv:2501.kimi2].
head_dim 112 (= 7168/64); dense first-layer d_ff 18432."""
from .base import LayerSpec, ModelConfig, moe_layout, register


def full() -> ModelConfig:
    layout = (LayerSpec("attn", "mlp"),) + moe_layout(60)
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=18432, vocab_size=163840, rope_theta=50_000.0,
        n_experts=384, n_experts_active=8, moe_d_ff=2048,
        n_shared_experts=1,
        layout=layout, prefix_layers=1, scan_period=1,
    )


def smoke() -> ModelConfig:
    layout = (LayerSpec("attn", "mlp"),) + moe_layout(2)
    return ModelConfig(
        name="kimi-k2-1t-a32b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab_size=256, rope_theta=50_000.0,
        n_experts=8, n_experts_active=2, moe_d_ff=64,
        n_shared_experts=1,
        layout=layout, prefix_layers=1, scan_period=1,
    )


register("kimi-k2-1t-a32b", full, smoke)
