"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE 16e top-2
[arXiv:2403.19887].  72 layers = 9 scanned periods of 8 (attn at period
position 4, MoE on odd positions)."""
from .base import ModelConfig, jamba_layout, register


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab_size=65536,
        n_experts=16, n_experts_active=2, moe_d_ff=24576,
        ssm_state=16, ssm_conv=4, ssm_expand=2,
        layout=jamba_layout(72), scan_period=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256,
        n_experts=4, n_experts_active=2, moe_d_ff=128,
        ssm_state=8, ssm_conv=4, ssm_expand=2,
        layout=jamba_layout(8), scan_period=8,
    )


register("jamba-1.5-large-398b", full, smoke)
