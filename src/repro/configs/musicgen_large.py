"""musicgen-large — decoder-only over EnCodec tokens (4 codebooks, vocab 2048
each); the EnCodec frontend/delay-pattern is a STUB: `input_specs()` feeds
pre-interleaved code frames [arXiv:2306.05284].  Adaptation note (DESIGN.md):
MusicGen uses sinusoidal positions; we use RoPE, the substrate's native
position scheme — backbone compute/communication shape is unchanged.
"""
from .base import ModelConfig, dense_layout, register


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=2048, norm="layernorm",
        input_mode="audio_codes", n_codebooks=4,
        layout=dense_layout(48), scan_period=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=64, norm="layernorm",
        input_mode="audio_codes", n_codebooks=4,
        layout=dense_layout(2), scan_period=1,
    )


register("musicgen-large", full, smoke)
