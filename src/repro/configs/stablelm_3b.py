"""stablelm-3b — dense MHA, LayerNorm, partial rotary [hf:stabilityai/stablelm-2]."""
from .base import ModelConfig, dense_layout, register


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab_size=50304, norm="layernorm", rope_pct=0.25,
        layout=dense_layout(32), scan_period=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, norm="layernorm", rope_pct=0.25,
        layout=dense_layout(2), scan_period=1,
    )


register("stablelm-3b", full, smoke)
