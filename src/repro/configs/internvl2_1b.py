"""internvl2-1b — VLM: Qwen2-0.5B-style LM backbone; the InternViT frontend
is a STUB per the assignment (`input_specs()` feeds precomputed patch
embeddings as a 256-position prefix) [arXiv:2404.16821].

vocab 151655 padded to 151680 (multiple of 128) for clean model-axis sharding.
"""
from .base import ModelConfig, dense_layout, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab_size=151680, qkv_bias=True, rope_theta=1e6,
        tie_embeddings=True, input_mode="vlm", vision_prefix=256,
        layout=dense_layout(24), scan_period=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, qkv_bias=True, rope_theta=1e6,
        tie_embeddings=True, input_mode="vlm", vision_prefix=8,
        layout=dense_layout(2), scan_period=1,
    )


register("internvl2-1b", full, smoke)
