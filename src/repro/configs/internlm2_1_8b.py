"""internlm2-1.8b — dense GQA [arXiv:2403.17297]."""
from .base import ModelConfig, dense_layout, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", family="dense",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab_size=92544, rope_theta=1e6,
        layout=dense_layout(24), scan_period=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, rope_theta=1e6,
        layout=dense_layout(2), scan_period=1,
    )


register("internlm2-1.8b", full, smoke)
