"""Pallas TPU selective-scan kernel for Mamba-1 (chunked recurrence).

Grid: (batch, d_inner blocks, sequence chunks) — the chunk axis is innermost
so the hidden-state scratch h:(di_blk, N) persists across chunks.  Within a
chunk the recurrence is stepped sequentially in VMEM (N=16 keeps each step a
(di_blk, N) FMA, VPU-friendly); the HBM traffic is one read of x/dt/B/C and
one write of y per token — the operational-intensity win over a naive HBM
round-trip per step, which is the TPU adaptation of Mamba's CUDA kernel
(SRAM-resident state) per DESIGN.md §6.

Validated against ``ref.selective_scan_ref`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref, *,
                 chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                 # (di_blk, N)
    d = d_ref[...].astype(jnp.float32)                 # (di_blk,)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)           # (di_blk,)
        dtt = dt_ref[0, t].astype(jnp.float32)         # (di_blk,)
        bt = b_ref[0, t].astype(jnp.float32)           # (N,)
        ct = c_ref[0, t].astype(jnp.float32)           # (N,)
        decay = jnp.exp(dtt[:, None] * a)              # (di_blk, N)
        h = decay * h + (dtt * xt)[:, None] * bt[None, :]
        y = (h * ct[None, :]).sum(axis=1) + d * xt
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h


def selective_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                          Bmat: jax.Array, Cmat: jax.Array, D: jax.Array, *,
                          chunk: int = 128, di_block: int = 256,
                          interpret: bool = False) -> jax.Array:
    """x,dt:(B,S,di)  A:(di,N)  Bmat,Cmat:(B,S,N)  D:(di,) -> y:(B,S,di)."""
    b, s, di = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    di_block = min(di_block, di)
    assert s % chunk == 0 and di % di_block == 0
    nc, nd = s // chunk, di // di_block

    grid = (b, nd, nc)
    kernel = functools.partial(_scan_kernel, chunk=chunk)
    from jax.experimental.pallas import tpu as pltpu

    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di_block),
                         lambda ib, id_, ic: (ib, ic, id_)),   # x
            pl.BlockSpec((1, chunk, di_block),
                         lambda ib, id_, ic: (ib, ic, id_)),   # dt
            pl.BlockSpec((1, chunk, n),
                         lambda ib, id_, ic: (ib, ic, 0)),     # B
            pl.BlockSpec((1, chunk, n),
                         lambda ib, id_, ic: (ib, ic, 0)),     # C
            pl.BlockSpec((di_block, n),
                         lambda ib, id_, ic: (id_, 0)),        # A
            pl.BlockSpec((di_block,),
                         lambda ib, id_, ic: (id_,)),          # D
        ],
        out_specs=pl.BlockSpec((1, chunk, di_block),
                               lambda ib, id_, ic: (ib, ic, id_)),
        out_shape=jax.ShapeDtypeStruct((b, s, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((di_block, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bmat, Cmat, A, D)
    return y
