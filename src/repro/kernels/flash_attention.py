"""Pallas TPU flash attention (online softmax, causal, GQA via index_map).

TPU-native design (DESIGN.md §6): q/k/v tiles live in VMEM with MXU-aligned
block shapes (multiples of 128 on the contracting/lane dims); the kv axis is
the innermost grid dimension so the (m, l, acc) scratch accumulators persist
across kv blocks — the canonical TPU flash schedule.  GQA never materializes
repeated KV heads: the k/v BlockSpec index_map folds the query-head index h
onto its kv head h // G.

Validated in interpret mode on CPU against ``ref.flash_attention_ref`` /
``ref.attention_naive`` (tests/test_kernels.py sweeps shapes and dtypes).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, q_offset: int, kv_len: Optional[int],
                  q_chunk: int, kv_chunk: int, n_kv_blocks: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (qc, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (kc, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (kc, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (qc, kc)

    qpos = q_offset + iq * q_chunk + jax.lax.broadcasted_iota(
        jnp.int32, (q_chunk, kv_chunk), 0)
    tpos = ik * kv_chunk + jax.lax.broadcasted_iota(
        jnp.int32, (q_chunk, kv_chunk), 1)
    mask = jnp.ones((q_chunk, kv_chunk), jnp.bool_)
    if causal:
        mask &= tpos <= qpos
    if kv_len is not None:
        mask &= tpos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - safe_m[:, None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-37)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, q_offset: int = 0,
                           kv_len: Optional[int] = None,
                           q_chunk: int = 256, kv_chunk: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q:(B,Sq,H,hd)  k,v:(B,Skv,KV,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    assert h % kv == 0
    g = h // kv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0
    nq, nk = sq // q_chunk, skv // kv_chunk

    # layout: (B, H, S, hd) — head-major so each grid cell owns one head
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _flash_kernel, causal=causal, q_offset=q_offset, kv_len=kv_len,
        q_chunk=q_chunk, kv_chunk=kv_chunk, n_kv_blocks=nk,
        scale=1.0 / (hd ** 0.5))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_chunk, hd),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, kv_chunk, hd),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, kv_chunk, hd),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_chunk, hd),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            _vmem((q_chunk,), jnp.float32),        # m: running max
            _vmem((q_chunk,), jnp.float32),        # l: running denominator
            _vmem((q_chunk, hd), jnp.float32),     # acc: running numerator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
