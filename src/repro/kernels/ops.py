"""Jit'd dispatch wrappers: Pallas on TPU, jnp reference elsewhere.

The model layer calls these; ``impl`` resolution:
  * "auto"     — pallas on TPU backends, chunked jnp reference otherwise
  * "pallas"   — force pallas (compiled on TPU, interpret=True elsewhere)
  * "chunked"  — chunked jnp reference (flash-style, bounded memory)
  * "naive"    — O(S²) reference (tests/small shapes only)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .mamba_scan import selective_scan_pallas
from .. import sharding


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _constrain_qkv(q, k, v):
    """Apply the active attention parallelism mode (sharding.flash_mode)."""
    mode = sharding.flash_mode(q.shape[0], q.shape[1])
    if mode == "ulysses":
        spec = sharding.ulysses_spec(4)
        return (sharding.constrain(q, spec), sharding.constrain(k, spec),
                sharding.constrain(v, spec), mode)
    if mode == "cp":
        return (sharding.constrain(q, sharding.cp_q_spec(4)),
                sharding.constrain(k, sharding.cp_kv_spec(4)),
                sharding.constrain(v, sharding.cp_kv_spec(4)), mode)
    return q, k, v, mode


def _constrain_out(o, mode):
    if mode == "ulysses":
        return sharding.constrain(o, sharding.ulysses_spec(4))
    if mode == "cp":
        return sharding.constrain(o, sharding.cp_q_spec(4))
    return o


@functools.lru_cache(maxsize=None)
def _diff_flash(causal: bool, impl: str, q_chunk: int, kv_chunk: int,
                causal_skip: bool = False):
    """custom_vjp flash attention: forward via the chosen impl, backward via
    the block-recompute flash backward (O(block²) live memory — the inner
    scans never stash their carries for autodiff)."""

    def _chunks(q):
        # context parallelism needs q chunks no larger than one seq shard
        mode = sharding.flash_mode(q.shape[0], q.shape[1])
        qc = q_chunk
        if mode == "cp":
            ctx = sharding.active()
            msize = ctx[0].shape[ctx[1].model] if ctx else 1
            qc = min(qc, max(q.shape[1] // msize, 1))
        return qc, kv_chunk

    def fwd_impl(q, k, v):
        q, k, v, mode = _constrain_qkv(q, k, v)
        qc, kc = _chunks(q)
        skip = causal_skip and q.shape[1] // max(qc, 1) <= 64
        if impl == "pallas":
            o = flash_attention_pallas(
                q, k, v, causal=causal, q_chunk=min(qc, 256),
                kv_chunk=min(kc, 256), interpret=not _on_tpu())
            # lse recomputed cheaply in fp32 chunks for the residual
            _, lse = ref.flash_fwd_chunked(q, k, v, causal=causal,
                                           q_chunk=qc, kv_chunk=kc)
        else:
            o, lse = ref.flash_fwd_chunked(q, k, v, causal=causal,
                                           q_chunk=qc, kv_chunk=kc,
                                           causal_skip=skip)
        return _constrain_out(o, mode), lse

    @jax.custom_vjp
    def f(q, k, v):
        return fwd_impl(q, k, v)[0]

    def f_fwd(q, k, v):
        o, lse = fwd_impl(q, k, v)
        return o, (q, k, v, o, lse)

    def f_bwd(res, do):
        q, k, v, o, lse = res
        q, k, v, mode = _constrain_qkv(q, k, v)
        do = _constrain_out(do, mode)
        qc, kc = _chunks(q)
        dq, dk, dv = ref.flash_bwd_chunked(q, k, v, o, lse, do, causal=causal,
                                           q_chunk=qc, kv_chunk=kc)
        dq = _constrain_out(dq, mode)
        if mode == "cp":
            # dk/dv are partial over model shards; one reduction here
            dk = sharding.constrain(dk, sharding.cp_kv_spec(4))
            dv = sharding.constrain(dv, sharding.cp_kv_spec(4))
        return dq, dk, dv

    f.defvjp(f_fwd, f_bwd)
    return f


def flash_attention(q, k, v, *, causal=True, q_offset=0, kv_len=None,
                    impl: str = "auto", q_chunk: int = 512,
                    kv_chunk: int = 512, causal_skip: bool = False):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "chunked"
    # Self-attention with static offsets: differentiable custom-vjp path.
    if kv_len is None and impl in ("pallas", "chunked") and q_offset == 0:
        return _diff_flash(causal, impl, q_chunk, kv_chunk,
                           causal_skip)(q, k, v)
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
            q_chunk=min(q_chunk, 256), kv_chunk=min(kv_chunk, 256),
            interpret=not _on_tpu())
    if impl == "chunked":
        return ref.flash_attention_ref(
            q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
            q_chunk=q_chunk, kv_chunk=kv_chunk)
    if impl == "naive":
        return ref.attention_naive(q, k, v, causal=causal, q_offset=q_offset,
                                   kv_len=kv_len)
    raise ValueError(f"unknown attention impl {impl!r}")


def selective_scan(x, dt, A, Bmat, Cmat, D, *, h0=None, impl: str = "auto",
                   chunk: int = 256):
    """Returns (y, h_final).  The pallas path recomputes h_final cheaply."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "chunked"
    if impl == "pallas" and h0 is None:
        y = selective_scan_pallas(x, dt, A, Bmat, Cmat, D, chunk=min(chunk, 128),
                                  interpret=not _on_tpu())
        # final state for cache handoff: one chunked pass over the tail chunk
        _, h = ref.selective_scan_chunked(x[:, -chunk:], dt[:, -chunk:], A,
                                          Bmat[:, -chunk:], Cmat[:, -chunk:],
                                          D, h0=_tail_h0(x, dt, A, Bmat, Cmat, D, chunk),
                                          chunk=chunk)
        return y, h
    if impl in ("pallas", "chunked"):
        return ref.selective_scan_chunked(x, dt, A, Bmat, Cmat, D, h0=h0,
                                          chunk=chunk)
    if impl == "naive":
        return ref.selective_scan_ref(x, dt, A, Bmat, Cmat, D, h0=h0)
    raise ValueError(f"unknown scan impl {impl!r}")


def _tail_h0(x, dt, A, Bmat, Cmat, D, chunk):
    """State just before the last chunk (None when sequence is one chunk)."""
    s = x.shape[1]
    if s <= chunk:
        return None
    _, h = ref.selective_scan_chunked(x[:, :-chunk], dt[:, :-chunk], A,
                                      Bmat[:, :-chunk], Cmat[:, :-chunk], D,
                                      chunk=chunk)
    return h
