"""Pure-jnp oracles for every Pallas kernel (and the CPU execution path).

* :func:`attention_naive` — O(S²)-memory reference, small shapes only.
* :func:`flash_attention_ref` — chunked online-softmax attention; numerically
  the kernel's oracle AND the CPU/dry-run path (never materializes S×S).
* :func:`selective_scan_ref` — sequential Mamba-1 selective scan oracle.
* :func:`selective_scan_chunked` — chunked associative-scan formulation used
  by the model on CPU (bounded memory, same math).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _gqa_fold(q, k):
    """(B,Sq,H,hd),(B,Skv,KV,hd) -> group count G with H = KV*G."""
    h, kv = q.shape[2], k.shape[2]
    assert h % kv == 0, (h, kv)
    return h // kv


def attention_naive(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset: int = 0,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference attention.  q:(B,Sq,H,hd) k,v:(B,Skv,KV,hd) -> (B,Sq,H,hd).

    ``q_offset``: absolute position of q[0] (decode: cache length so far).
    ``kv_len``: number of valid cache positions (rest masked), scalar.
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = _gqa_fold(q, k)
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgh,btkh->bkgqt", qg, k) / jnp.sqrt(
        jnp.asarray(hd, q.dtype))
    scores = scores.astype(jnp.float32)
    qpos = q_offset + jnp.arange(sq)[:, None]
    tpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= tpos <= qpos
    if kv_len is not None:
        mask &= tpos < kv_len
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0, kv_len=None,
                        q_chunk: int = 512, kv_chunk: int = 512) -> jax.Array:
    o, _ = flash_fwd_chunked(q, k, v, causal=causal, q_offset=q_offset,
                             kv_len=kv_len, q_chunk=q_chunk,
                             kv_chunk=kv_chunk)
    return o


def flash_fwd_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, q_offset: int = 0,
                      kv_len: Optional[jax.Array] = None,
                      q_chunk: int = 512, kv_chunk: int = 512,
                      causal_skip: bool = False):
    """Online-softmax chunked attention; O(q_chunk·kv_chunk) live memory.
    Returns (o, lse) where lse:(B,Sq,KV,G) is the row logsumexp (needed by
    the recompute backward).

    ``causal_skip``: unroll the q-block loop so each q block only scans kv
    blocks at or below its diagonal — halves attention FLOPs for causal
    self-attention (q_offset==0, aligned chunks) at O(nq) HLO growth."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = _gqa_fold(q, k)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, q_chunk, skv, kv_chunk)
    nq, nk = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    qg = q.reshape(b, nq, q_chunk, kv, g, hd)
    kb = k.reshape(b, nk, kv_chunk, kv, hd)
    vb = v.reshape(b, nk, kv_chunk, kv, hd)

    def q_block(iq, q_blk):
        # q_blk: (b, q_chunk, kv, g, hd)
        m0 = jnp.full((b, q_chunk, kv, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kv, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, kv, g, hd), jnp.float32)

        def kv_step(carry, ik_kv):
            m, l, acc = carry
            ik, k_blk, v_blk = ik_kv
            s = jnp.einsum("bqkgh,btkh->bqkgt", q_blk, k_blk).astype(
                jnp.float32) * scale
            qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)[:, None]
            tpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= tpos <= qpos
            if kv_len is not None:
                mask &= tpos < kv_len
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # rows with no valid key yet keep m=-inf; guard the exp
            safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - safe_m[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bqkgt,btkh->bqkgh", p.astype(v_blk.dtype), v_blk)
            acc = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        lse = jnp.where(jnp.isfinite(m), m + jnp.log(jnp.maximum(l, 1e-37)),
                        -jnp.inf)
        return out.astype(q.dtype), lse

    if causal_skip and causal and kv_len is None and q_offset == 0 \
            and q_chunk == kv_chunk:
        # unrolled triangular schedule: q block i scans kv blocks 0..i
        outs, lses = [], []
        for iq in range(nq):
            m0 = jnp.full((b, q_chunk, kv, g), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((b, q_chunk, kv, g), jnp.float32)
            a0 = jnp.zeros((b, q_chunk, kv, g, hd), jnp.float32)
            q_blk = qg[:, iq]

            def kv_step(carry, ik_kv, iq=iq, q_blk=q_blk):
                m, l, acc = carry
                ik, k_blk, v_blk = ik_kv
                s = jnp.einsum("bqkgh,btkh->bqkgt", q_blk, k_blk).astype(
                    jnp.float32) * scale
                qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None]
                tpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
                s = jnp.where((tpos <= qpos)[None, :, None, None, :], s,
                              -jnp.inf)
                m_new = jnp.maximum(m, s.max(axis=-1))
                safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
                p = jnp.exp(s - safe_m[..., None])
                p = jnp.where(jnp.isfinite(s), p, 0.0)
                corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
                l = l * corr + p.sum(axis=-1)
                pv = jnp.einsum("bqkgt,btkh->bqkgh", p.astype(v_blk.dtype),
                                v_blk)
                acc = acc * corr[..., None] + pv.astype(jnp.float32)
                return (m_new, l, acc), None

            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (jnp.arange(iq + 1), jnp.moveaxis(kb[:, :iq + 1], 1, 0),
                 jnp.moveaxis(vb[:, :iq + 1], 1, 0)))
            outs.append((acc / jnp.maximum(l[..., None], 1e-37)
                         ).astype(q.dtype))
            lses.append(jnp.where(jnp.isfinite(m),
                                  m + jnp.log(jnp.maximum(l, 1e-37)),
                                  -jnp.inf))
        out = jnp.stack(outs, axis=1).reshape(b, sq, h, hd)
        lse = jnp.stack(lses, axis=1).reshape(b, sq, kv, g)
        return out, lse

    out, lse = jax.lax.map(lambda args: q_block(*args),
                           (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, hd)
    lse = jnp.moveaxis(lse, 0, 1).reshape(b, sq, kv, g)
    return out, lse


def flash_bwd_chunked(q, k, v, o, lse, do, *, causal=True, q_offset=0,
                      kv_len=None, q_chunk: int = 512, kv_chunk: int = 512):
    """Flash backward: recompute probabilities per (q, kv) block pair.

    dv = pᵀ·do ;  dp = do·vᵀ ;  ds = p⊙(dp − Δ)·scale with Δ = Σ(do⊙o) ;
    dq += ds·k ;  dk += dsᵀ·q.   Live memory is one block pair.
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nk = sq // q_chunk, skv // kv_chunk
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(b, nq, q_chunk, kv, g, hd)
    og = o.reshape(b, nq, q_chunk, kv, g, hd)
    dog = do.reshape(b, nq, q_chunk, kv, g, hd)
    lseg = lse.reshape(b, nq, q_chunk, kv, g)
    kb = k.reshape(b, nk, kv_chunk, kv, hd)
    vb = v.reshape(b, nk, kv_chunk, kv, hd)
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32),
                    axis=-1)                                   # (b,nq,qc,kv,g)

    def q_step(carry, inp):
        dk_acc, dv_acc = carry
        iq, q_blk, do_blk, lse_blk, delta_blk = inp

        def kv_step(c2, inp2):
            dq_blk, dk_acc, dv_acc = c2
            ik, k_blk, v_blk = inp2
            s = jnp.einsum("bqkgh,btkh->bqkgt", q_blk, k_blk).astype(
                jnp.float32) * scale
            qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)[:, None]
            tpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= tpos <= qpos
            if kv_len is not None:
                mask &= tpos < kv_len
            p = jnp.where(mask[None, :, None, None, :],
                          jnp.exp(s - lse_blk[..., None]), 0.0)
            dof = do_blk.astype(jnp.float32)
            dv_blk = jnp.einsum("bqkgt,bqkgh->btkh", p, dof)
            dp = jnp.einsum("bqkgh,btkh->bqkgt", dof,
                            v_blk.astype(jnp.float32))
            ds = p * (dp - delta_blk[..., None]) * scale
            dq_blk = dq_blk + jnp.einsum("bqkgt,btkh->bqkgh", ds,
                                         k_blk.astype(jnp.float32))
            dk_blk = jnp.einsum("bqkgt,bqkgh->btkh", ds,
                                q_blk.astype(jnp.float32))
            dk_acc = jax.lax.dynamic_update_slice(
                dk_acc, jax.lax.dynamic_slice(
                    dk_acc, (0, ik * kv_chunk, 0, 0),
                    (b, kv_chunk, kv, hd)) + dk_blk, (0, ik * kv_chunk, 0, 0))
            dv_acc = jax.lax.dynamic_update_slice(
                dv_acc, jax.lax.dynamic_slice(
                    dv_acc, (0, ik * kv_chunk, 0, 0),
                    (b, kv_chunk, kv, hd)) + dv_blk, (0, ik * kv_chunk, 0, 0))
            return (dq_blk, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, q_chunk, kv, g, hd), jnp.float32)
        (dq_blk, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        return (dk_acc, dv_acc), dq_blk

    dk0 = jnp.zeros((b, skv, kv, hd), jnp.float32)
    dv0 = jnp.zeros((b, skv, kv, hd), jnp.float32)
    (dk, dv), dq = jax.lax.scan(
        q_step, (dk0, dv0),
        (jnp.arange(nq), jnp.moveaxis(qg, 1, 0), jnp.moveaxis(dog, 1, 0),
         jnp.moveaxis(lseg, 1, 0), jnp.moveaxis(delta, 1, 0)))
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, sq, h, hd).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


# ---------------------------------------------------------------------------
# Mamba-1 selective scan
# ---------------------------------------------------------------------------

def selective_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bmat: jax.Array, Cmat: jax.Array, D: jax.Array,
                       h0: Optional[jax.Array] = None,
                       ) -> Tuple[jax.Array, jax.Array]:
    """Sequential oracle.  x,dt:(B,S,di)  A:(di,N)  Bmat,Cmat:(B,S,N)  D:(di,)

    h_t = exp(dt_t·A)·h_{t-1} + (dt_t·x_t)·B_t ;  y_t = (h_t·C_t).sum + D·x_t
    Returns (y:(B,S,di), h_final:(B,di,N)).
    """
    b, s, di = x.shape
    n = A.shape[1]
    h_init = jnp.zeros((b, di, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs      # (B,di), (B,di), (B,N), (B,N)
        decay = jnp.exp(dtt.astype(jnp.float32)[..., None] * A[None].astype(jnp.float32))
        h = decay * h + (dtt * xt).astype(jnp.float32)[..., None] * bt.astype(jnp.float32)[:, None, :]
        y = (h * ct.astype(jnp.float32)[:, None, :]).sum(-1) + D.astype(jnp.float32) * xt.astype(jnp.float32)
        return h, y

    h, ys = jax.lax.scan(step, h_init,
                         (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
                          jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def selective_scan_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                           Bmat: jax.Array, Cmat: jax.Array, D: jax.Array,
                           h0: Optional[jax.Array] = None, chunk: int = 256,
                           ) -> Tuple[jax.Array, jax.Array]:
    """Chunked associative-scan formulation (bounded memory, parallel in-chunk).

    Composition law for h' = a·h + b:  (a2,b2)∘(a1,b1) = (a1·a2, a2·b1 + b2).
    """
    b, s, di = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    h_init = jnp.zeros((b, di, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    xs = (jnp.moveaxis(x.reshape(b, nc, chunk, di), 1, 0),
          jnp.moveaxis(dt.reshape(b, nc, chunk, di), 1, 0),
          jnp.moveaxis(Bmat.reshape(b, nc, chunk, n), 1, 0),
          jnp.moveaxis(Cmat.reshape(b, nc, chunk, n), 1, 0))

    def chunk_step(h, inputs):
        xc, dtc, bc, cc = inputs      # (B,chunk,di), (B,chunk,di), (B,chunk,N) ×2
        dtf = dtc.astype(jnp.float32)
        decay = jnp.exp(dtf[..., None] * Af[None, None])             # (B,c,di,N)
        inc = (dtf * xc.astype(jnp.float32))[..., None] * \
            bc.astype(jnp.float32)[:, :, None, :]                    # (B,c,di,N)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (decay, inc), axis=1)
        hs = a_cum * h[:, None] + b_cum                              # (B,c,di,N)
        y = (hs * cc.astype(jnp.float32)[:, :, None, :]).sum(-1) \
            + D.astype(jnp.float32) * xc.astype(jnp.float32)
        return hs[:, -1], y

    h, ys = jax.lax.scan(chunk_step, h_init, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di).astype(x.dtype)
    return y, h
