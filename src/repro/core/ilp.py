"""The ILP node-selection solver (paper §3.1, Eq. 4–5) — batched engine.

    minimize   Σ_i ( -α·Perf_i/Perf_min + (1-α)·SP_i/SP_min ) · x_i
    subject to Σ_i Pod_i·x_i ≥ Req_pod,   0 ≤ x_i ≤ T3_i,   x_i ∈ ℤ

Three interchangeable solvers (all exact):

* :func:`solve_ilp` — the production path.  Items with negative objective
  coefficient are saturated at their T3 bound (any ILP optimum does this; it
  is exactly the high-α over-provisioning collapse of Table 2), and the
  residual min-cost covering problem over non-negative items is a bounded
  knapsack solved exactly by a memory-flat DP: LP-bound bundle pruning, a
  forward value pass, and min-plus divide-and-conquer backtracking that
  reconstructs the optimal counts in O(bundles + residual) peak memory
  (the seed implementation materialised an O(bundles × residual) float64
  history matrix — ≈80 MB at 500 bundles × 20k pods).  See DESIGN.md §8.
* :func:`solve_ilp_batch` — one vectorized (n_α × R+1) numpy DP evaluating
  *all* α of a GSS prescan at once.  Bundle structure (pods, bounds, binary
  splits) is α-independent; only the objective coefficients vary, so the DP
  shift pattern is shared across the α axis and per-α saturation masks are
  computed by broadcasting :func:`objective_coefficients` over the α grid.
* :func:`solve_ilp_pulp` — the paper's actual tool (PuLP/CBC), used to
  cross-validate the DP in tests and available as a drop-in backend.

:func:`solve_ilp_reference` preserves the seed history-matrix solver
verbatim for cross-validation tests and as the benchmark baseline.

All count-returning entry points return per-item integers, or ``None`` when
demand exceeds the total bounded capacity (the paper assumes the cloud
always has capacity; the provisioner surfaces this explicitly instead).

Preprocessing (bundle splitting, pod/bound arrays, normalised objective
terms) is hoisted into :class:`CompiledMarket`, built once per candidate
set and reused across every α evaluated by a provisioning cycle — and
across the re-optimisation cycles of §4.1 interrupt handling via the
provisioner-level cache.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .efficiency import CandidateItem

_INF = float("inf")

#: below this many bundles (or this small a target) the D&C backtracker
#: switches to a dense history DP — the matrix is tiny there and the switch
#: caps recursion overhead.
_DENSE_BUNDLES = 16
_DENSE_TARGET = 512


@dataclasses.dataclass(frozen=True)
class IlpStats:
    """Solver introspection for the overhead study (paper Fig. 7 / §5.3)."""

    n_items: int
    n_bundles: int
    residual_demand: int
    objective: float


def objective_coefficients(items: Sequence[CandidateItem],
                           alpha: float) -> np.ndarray:
    """Eq. 4–5 coefficients: -α·Perf_i/Perf_min + (1-α)·SP_i/SP_min."""
    if not items:
        return np.zeros((0,))
    perf = np.array([it.perf for it in items], dtype=np.float64)
    sp = np.array([it.spot_price for it in items], dtype=np.float64)
    positive_perf = perf[perf > 0]
    perf_min = positive_perf.min() if positive_perf.size else 1.0
    sp_min = sp.min()
    if sp_min <= 0:
        raise ValueError("spot prices must be positive")
    return -alpha * perf / perf_min + (1.0 - alpha) * sp / sp_min


def _binary_bundles(count: int) -> List[int]:
    """Split a bound into power-of-two bundles (exact bounded knapsack)."""
    out, k = [], 1
    while count > 0:
        take = min(k, count)
        out.append(take)
        count -= take
        k <<= 1
    return out


# ---------------------------------------------------------------------------
# CompiledMarket: α-independent preprocessing, built once per candidate set
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompiledMarket:
    """Everything about a candidate set that does not depend on α or demand.

    The ILP objective at any α is a linear reweighting of two fixed vectors
    (``perf_norm`` and ``price_norm``); the bounded-knapsack structure
    (per-item pods, T3 bounds, binary bundle splits) never changes.  Building
    this once per provisioning cycle and once per §4.1 re-optimisation is
    what lets GSS evaluate ~20 α values without re-running preprocessing.
    """

    items: Tuple[CandidateItem, ...]
    pods: np.ndarray          # (n,) int64   Pod_i
    bound: np.ndarray         # (n,) int64   T3_i
    perf: np.ndarray          # (n,) float64 Perf_i = BS_i·Pod_i
    price: np.ndarray         # (n,) float64 SP_i
    perf_min: float
    sp_min: float
    perf_norm: np.ndarray     # (n,) Perf_i / Perf_min
    price_norm: np.ndarray    # (n,) SP_i / SP_min
    structural: np.ndarray    # (n,) bool — pods > 0 and bound > 0
    b_item: np.ndarray        # (B,) int64  bundle -> item index
    b_pods: np.ndarray        # (B,) int64  bundle pod size
    b_copies: np.ndarray      # (B,) int64  bundle node count

    @property
    def n(self) -> int:
        return len(self.items)

    @property
    def n_bundles(self) -> int:
        return len(self.b_item)

    @property
    def metric_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(Perf_i, SP_i, Pod_i) float64 triple for ``score_counts_batch``."""
        return self.perf, self.price, self.pods.astype(np.float64)

    def coefficients(self, alphas: np.ndarray,
                     exclude: Optional[np.ndarray] = None) -> np.ndarray:
        """Broadcast Eq. 4–5 over an α grid: (n_alpha, n_items).

        With an ``exclude`` mask the Perf_min/SP_min normalisation is taken
        over the surviving candidates only — identical to rebuilding the
        candidate set without the excluded offerings (§4.1 cache semantics).
        """
        a = np.asarray(alphas, dtype=np.float64).reshape(-1, 1)
        if exclude is None or not np.any(exclude):
            return -a * self.perf_norm + (1.0 - a) * self.price_norm
        m = ~exclude
        perf_pos = self.perf[m & (self.perf > 0)]
        perf_min = float(perf_pos.min()) if perf_pos.size else 1.0
        prices = self.price[m]
        sp_min = float(prices.min()) if prices.size else 1.0
        if sp_min <= 0:
            raise ValueError("spot prices must be positive")
        return -a * (self.perf / perf_min) + (1.0 - a) * (self.price / sp_min)


def compile_market(items: Sequence[CandidateItem]) -> CompiledMarket:
    """Hoist all α-independent solver preprocessing out of the hot path."""
    items = tuple(items)
    n = len(items)
    pods = np.array([it.pods for it in items], dtype=np.int64)
    bound = np.array([it.t3 for it in items], dtype=np.int64)
    perf = np.array([it.perf for it in items], dtype=np.float64)
    price = np.array([it.spot_price for it in items], dtype=np.float64)
    if n:
        positive_perf = perf[perf > 0]
        perf_min = float(positive_perf.min()) if positive_perf.size else 1.0
        sp_min = float(price.min())
        if sp_min <= 0:
            raise ValueError("spot prices must be positive")
    else:
        perf_min, sp_min = 1.0, 1.0
    structural = (pods > 0) & (bound > 0)

    b_item: List[int] = []
    b_copies: List[int] = []
    for i in np.nonzero(structural)[0]:
        for copies in _binary_bundles(int(bound[i])):
            b_item.append(int(i))
            b_copies.append(copies)
    b_item_arr = np.array(b_item, dtype=np.int64)
    b_copies_arr = np.array(b_copies, dtype=np.int64)
    b_pods_arr = (pods[b_item_arr] * b_copies_arr if len(b_item)
                  else np.zeros(0, dtype=np.int64))
    return CompiledMarket(
        items=items, pods=pods, bound=bound, perf=perf, price=price,
        perf_min=perf_min, sp_min=sp_min,
        perf_norm=perf / perf_min, price_norm=price / sp_min,
        structural=structural,
        b_item=b_item_arr, b_pods=b_pods_arr, b_copies=b_copies_arr)


def reweight_market(market: CompiledMarket, perf: np.ndarray,
                    price: np.ndarray,
                    items: Optional[Sequence[CandidateItem]] = None,
                    ) -> CompiledMarket:
    """Array-adjustment entry point: a compiled market with substituted
    (Perf_i, SP_i) objective vectors.

    The bounded-knapsack *structure* (Pod_i, T3_i, binary bundle splits) is
    independent of the objective, so swapping in adjusted performance/price
    vectors — the risk subsystem's uptime-discounted Perf and
    re-provision-charged SP (``repro.risk.objective``) — costs O(n) instead
    of a full :func:`compile_market`.  Pass ``items`` (e.g. from
    :func:`repro.core.efficiency.reweight_items`) to keep ``market.items``
    consistent with the new vectors; otherwise the original items are kept
    and only the solver-facing arrays change.
    """
    perf = np.asarray(perf, dtype=np.float64)
    price = np.asarray(price, dtype=np.float64)
    if len(perf) != market.n or len(price) != market.n:
        raise ValueError(f"adjusted vectors must have {market.n} entries")
    if market.n == 0:
        return market
    if np.any(price <= 0):
        raise ValueError("adjusted prices must be positive")
    positive_perf = perf[perf > 0]
    perf_min = float(positive_perf.min()) if positive_perf.size else 1.0
    sp_min = float(price.min())
    return dataclasses.replace(
        market,
        items=market.items if items is None else tuple(items),
        perf=perf, price=price, perf_min=perf_min, sp_min=sp_min,
        perf_norm=perf / perf_min, price_norm=price / sp_min)


# ---------------------------------------------------------------------------
# Memory-flat covering knapsack: value pass, LP pruning, D&C backtracking
# ---------------------------------------------------------------------------

def _cover_dp(bpods: np.ndarray, bcosts: np.ndarray, target: int,
              ) -> np.ndarray:
    """Forward value pass: dp[j] = min cost of a bundle subset with ≥ j pods.

    O(target) memory; the 0/1 semantics hold because ``dp[:-pb] + cb`` is
    materialised before the in-place minimum writes back.
    """
    dp = np.full(target + 1, _INF)
    dp[0] = 0.0
    for b in range(len(bpods)):
        pb = int(bpods[b])
        cb = bcosts[b]
        if pb > target:
            np.minimum(dp, cb, out=dp)
            continue
        np.minimum(dp[pb:], dp[:-pb] + cb, out=dp[pb:])
        if pb > 1:
            np.minimum(dp[1:pb], dp[0] + cb, out=dp[1:pb])
    return dp


def _cover_dp_batch(bpods: np.ndarray, costs: np.ndarray, target: int,
                    ) -> np.ndarray:
    """Vectorized (n_alpha × target+1) value pass over a shared bundle set.

    The shift pattern (``bpods``) is α-independent, so a single pass over
    the bundle axis updates every α row at once; rows where a bundle is
    masked out carry +inf cost and never win the minimum.
    """
    n_rows = costs.shape[0]
    dp = np.full((n_rows, target + 1), _INF)
    dp[:, 0] = 0.0
    col = np.empty((n_rows, 1))
    for b in range(len(bpods)):
        pb = int(bpods[b])
        col[:, 0] = costs[:, b]
        if pb > target:
            np.minimum(dp, col, out=dp)
            continue
        np.minimum(dp[:, pb:], dp[:, :-pb] + col, out=dp[:, pb:])
        if pb > 1:
            np.minimum(dp[:, 1:pb], dp[:, :1] + col, out=dp[:, 1:pb])
    return dp


def _lp_prune(bpods: np.ndarray, bcosts: np.ndarray, target: int,
              ) -> np.ndarray:
    """Exact LP-bound pruning: drop bundles no optimal solution can use.

    Sort by unit cost; the fractional greedy gives a lower bound LP(j) for
    covering j pods and the integral greedy a feasible upper bound UB.  Any
    solution containing bundle b costs ≥ c_b + LP(target − p_b), so bundles
    with c_b + LP(target − p_b) > UB are provably absent from *every*
    optimum and can be removed before the DP.  All optimal solutions
    survive, hence the pruned instance stays feasible and exact.
    """
    B = len(bpods)
    if B == 0 or target <= 0:
        return np.ones(B, dtype=bool)
    rate = bcosts / bpods
    order = np.argsort(rate, kind="stable")
    p_sorted = bpods[order].astype(np.float64)
    c_sorted = bcosts[order]
    cum_p = np.cumsum(p_sorted)
    cum_c = np.cumsum(c_sorted)
    if cum_p[-1] < target:                      # infeasible: caller handles
        return np.ones(B, dtype=bool)

    # integral greedy upper bound: first prefix that covers the target
    k_ub = int(np.searchsorted(cum_p, target))
    ub = float(cum_c[k_ub])

    # fractional lower bound LP(j), evaluated at j = target − p_b for all b
    resid = np.maximum(target - bpods, 0).astype(np.float64)
    k = np.searchsorted(cum_p, resid)
    prev_p = np.where(k > 0, cum_p[np.maximum(k - 1, 0)], 0.0)
    prev_c = np.where(k > 0, cum_c[np.maximum(k - 1, 0)], 0.0)
    lp = prev_c + (resid - prev_p) * (c_sorted[k] / p_sorted[k])
    lp[resid <= 0] = 0.0
    keep = bcosts + lp <= ub * (1.0 + 1e-12) + 1e-9
    return keep


def _dense_backtrack(bpods: np.ndarray, bcosts: np.ndarray, target: int,
                     ) -> np.ndarray:
    """Seed-style history DP for small sub-problems (bounded matrix size)."""
    B = len(bpods)
    take = np.zeros(B, dtype=bool)
    if target <= 0:
        return take
    dp = np.full(target + 1, _INF)
    dp[0] = 0.0
    history = np.empty((B + 1, target + 1))
    history[0] = dp
    for b in range(B):
        pb = int(bpods[b])
        cut = min(pb, target + 1)
        shifted = np.empty(target + 1)
        shifted[:cut] = dp[0]
        if cut <= target:
            shifted[cut:] = dp[: target + 1 - pb]
        dp = np.minimum(dp, shifted + bcosts[b])
        history[b + 1] = dp
    j = target
    for b in range(B - 1, -1, -1):
        if j == 0:
            break
        if history[b + 1][j] < history[b][j] - 1e-12:
            take[b] = True
            j = max(0, j - int(bpods[b]))
    return take


def _dc_backtrack(bpods: np.ndarray, bcosts: np.ndarray, target: int,
                  ) -> np.ndarray:
    """Min-plus divide-and-conquer backtracking in O(B + target) memory.

    dp over a disjoint union L ⊎ R satisfies
        dp[t] = min_j dp_L[j] + dp_R[t − j],
    so the split of the target between the two halves is recoverable from
    two value passes and an O(t) min-convolution — no history matrix.  Work
    telescopes to ≈2 full value passes (targets shrink geometrically).
    """
    B = len(bpods)
    if target <= 0:
        return np.zeros(B, dtype=bool)
    if B <= _DENSE_BUNDLES or target <= _DENSE_TARGET:
        return _dense_backtrack(bpods, bcosts, target)
    mid = B // 2
    dp_l = _cover_dp(bpods[:mid], bcosts[:mid], target)
    dp_r = _cover_dp(bpods[mid:], bcosts[mid:], target)
    tot = dp_l + dp_r[::-1]
    j1 = int(np.argmin(tot))
    if not np.isfinite(tot[j1]):
        raise RuntimeError("D&C backtracking hit an infeasible split")
    take = np.empty(B, dtype=bool)
    take[:mid] = _dc_backtrack(bpods[:mid], bcosts[:mid], j1)
    take[mid:] = _dc_backtrack(bpods[mid:], bcosts[mid:], target - j1)
    return take


def _solve_residual(bpods: np.ndarray, bcosts: np.ndarray, target: int,
                    ) -> Tuple[np.ndarray, int]:
    """Exact counts (bundle take-mask) for the residual covering knapsack.

    Returns (take mask over the given bundles, number of bundles that
    survived LP pruning).  Assumes feasibility was checked by the caller.
    """
    keep = _lp_prune(bpods, bcosts, target)
    kept_idx = np.flatnonzero(keep)
    take = np.zeros(len(bpods), dtype=bool)
    take[kept_idx] = _dc_backtrack(bpods[kept_idx], bcosts[kept_idx], target)
    return take, len(kept_idx)


# ---------------------------------------------------------------------------
# Public solvers
# ---------------------------------------------------------------------------

def _empty_market_result(req_pods: int, return_stats: bool):
    result = None if req_pods > 0 else []
    stats = IlpStats(0, 0, req_pods, _INF if req_pods > 0 else 0.0)
    return (result, stats) if return_stats else result


def solve_ilp(items: Sequence[CandidateItem], req_pods: int, alpha: float,
              return_stats: bool = False,
              market: Optional[CompiledMarket] = None,
              exclude: Optional[np.ndarray] = None,
              ) -> Optional[List[int]] | Tuple[Optional[List[int]], IlpStats]:
    """Exact solver for Eq. 5.  Returns x_i per item (None if infeasible).

    ``market`` reuses a :class:`CompiledMarket` (skips preprocessing);
    ``exclude`` is a per-item boolean mask of offerings barred from the
    solution (the §4.1 interrupted-offerings cache), applied at solve time
    so the compiled market survives interrupt churn.
    """
    if market is None:
        market = compile_market(items)
    elif market.n != len(items):
        raise ValueError(f"market was compiled from {market.n} items but "
                         f"{len(items)} were passed — stale CompiledMarket?")
    if market.n == 0:
        return _empty_market_result(req_pods, return_stats)

    coef = market.coefficients(np.array([alpha]), exclude)[0]
    counts, stats = _solve_compiled(market, req_pods, coef, exclude)
    return (counts, stats) if return_stats else counts


def _solve_compiled(market: CompiledMarket, req_pods: int, coef: np.ndarray,
                    exclude: Optional[np.ndarray],
                    ) -> Tuple[Optional[List[int]], IlpStats]:
    """Single-α solve against a compiled market (saturate → prune → DP)."""
    n = market.n
    active = market.structural if exclude is None else (
        market.structural & ~exclude)

    counts = np.zeros(n, dtype=np.int64)
    neg = (coef < 0) & active
    counts[neg] = market.bound[neg]
    covered = int(np.sum(market.pods[neg] * market.bound[neg]))
    objective = float(np.sum(coef[neg] * market.bound[neg]))

    residual = max(0, req_pods - covered)
    if residual == 0:
        return list(map(int, counts)), IlpStats(n, 0, 0, objective)

    in_dp = active & ~neg
    if int(np.sum(market.pods[in_dp] * market.bound[in_dp])) < residual:
        return None, IlpStats(n, 0, residual, _INF)

    b_mask = in_dp[market.b_item]
    bidx = np.flatnonzero(b_mask)
    bpods = market.b_pods[bidx]
    bcosts = coef[market.b_item[bidx]] * market.b_copies[bidx]
    take, n_bundles = _solve_residual(bpods, bcosts, residual)
    taken = bidx[take]
    np.add.at(counts, market.b_item[taken], market.b_copies[taken])
    objective += float(np.sum(coef[market.b_item[taken]]
                              * market.b_copies[taken]))
    return list(map(int, counts)), IlpStats(n, n_bundles, residual, objective)


def solve_ilp_batch(items: Sequence[CandidateItem], req_pods: int,
                    alphas: Sequence[float],
                    market: Optional[CompiledMarket] = None,
                    exclude: Optional[np.ndarray] = None,
                    return_stats: bool = False,
                    ) -> List[Optional[List[int]]] | Tuple[
                        List[Optional[List[int]]], List[IlpStats]]:
    """Solve Eq. 5 for every α of a prescan grid in one vectorized pass.

    The bundle structure is α-independent; only objective coefficients vary.
    Per-α saturation masks come from broadcasting the coefficient formula
    over the α grid; feasibility is a shared capacity comparison; counts
    are decoded per α with the memory-flat D&C backtracker on the LP-pruned
    union bundle set.  With ``return_stats`` the per-α objectives come from
    a single vectorized (n_alpha × R_max+1) numpy DP whose shift pattern is
    the common bundle pod-size vector — the test suite cross-checks those
    objectives against the decoded counts.
    """
    alphas = np.asarray(list(alphas), dtype=np.float64)
    if market is None:
        market = compile_market(items)
    elif market.n != len(items):
        raise ValueError(f"market was compiled from {market.n} items but "
                         f"{len(items)} were passed — stale CompiledMarket?")
    n_alpha = len(alphas)
    if market.n == 0:
        single = _empty_market_result(req_pods, True)
        results = [single[0] for _ in range(n_alpha)]
        stats = [single[1] for _ in range(n_alpha)]
        return (results, stats) if return_stats else results

    active = market.structural if exclude is None else (
        market.structural & ~exclude)
    coef2d = market.coefficients(alphas, exclude)            # (A, n)
    neg2d = (coef2d < 0) & active                            # saturation masks
    pods_x_bound = (market.pods * market.bound).astype(np.float64)
    covered = neg2d @ pods_x_bound                           # (A,)
    sat_obj = np.sum(np.where(neg2d, coef2d * market.bound, 0.0), axis=1)
    residual = np.maximum(0, req_pods - covered).astype(np.int64)
    in_dp = active & ~neg2d
    capacity = in_dp @ pods_x_bound
    feasible = capacity >= residual

    need_dp = feasible & (residual > 0)
    results: List[Optional[List[int]]] = [None] * n_alpha
    stats: List[IlpStats] = [IlpStats(market.n, 0, int(residual[a]), _INF)
                             for a in range(n_alpha)]

    # rows solved by saturation alone
    for a in np.flatnonzero(feasible & (residual == 0)):
        counts = np.zeros(market.n, dtype=np.int64)
        counts[neg2d[a]] = market.bound[neg2d[a]]
        results[a] = list(map(int, counts))
        stats[a] = IlpStats(market.n, 0, 0, float(sat_obj[a]))

    rows = np.flatnonzero(need_dp)
    if rows.size:
        r_max = int(residual[rows].max())
        # per-row bundle costs over the shared bundle set; masked rows -> inf
        b_coef = coef2d[np.ix_(rows, market.b_item)]         # (rows, B)
        b_costs = b_coef * market.b_copies
        b_costs[~in_dp[np.ix_(rows, market.b_item)]] = _INF
        # union LP prune across rows: keep a bundle if any row keeps it
        keep_union = np.zeros(market.n_bundles, dtype=bool)
        keeps = []
        for ri, a in enumerate(rows):
            keep = np.zeros(market.n_bundles, dtype=bool)
            row_ok = np.isfinite(b_costs[ri])
            ok_idx = np.flatnonzero(row_ok)
            keep[ok_idx] = _lp_prune(market.b_pods[ok_idx],
                                     b_costs[ri, ok_idx], int(residual[a]))
            keeps.append(keep)
            keep_union |= keep
        dp = None
        if return_stats:    # objectives ride one vectorized (A × R+1) DP
            union_idx = np.flatnonzero(keep_union)
            dp = _cover_dp_batch(market.b_pods[union_idx],
                                 b_costs[:, union_idx], r_max)

        for ri, a in enumerate(rows):
            r = int(residual[a])
            counts = np.zeros(market.n, dtype=np.int64)
            counts[neg2d[a]] = market.bound[neg2d[a]]
            row_idx = np.flatnonzero(keeps[ri])
            take = _dc_backtrack(market.b_pods[row_idx],
                                 b_costs[ri, row_idx], r)
            taken = row_idx[take]
            np.add.at(counts, market.b_item[taken], market.b_copies[taken])
            results[a] = list(map(int, counts))
            if dp is not None:
                obj = float(sat_obj[a]) + float(dp[ri, r])
                stats[a] = IlpStats(market.n, len(row_idx), r, obj)

    return (results, stats) if return_stats else results


# ---------------------------------------------------------------------------
# Reference backends
# ---------------------------------------------------------------------------

def solve_ilp_reference(items: Sequence[CandidateItem], req_pods: int,
                        alpha: float, return_stats: bool = False,
                        ) -> Optional[List[int]] | Tuple[Optional[List[int]],
                                                         IlpStats]:
    """The seed history-matrix solver, retained verbatim as the baseline for
    cross-validation tests and ``benchmarks/bench_solver.py``.  Peak memory
    is O(bundles × residual): the ``history`` matrix below is exactly what
    the production engine eliminates."""
    n = len(items)
    counts = [0] * n
    if n == 0:
        result = None if req_pods > 0 else counts
        return (result, IlpStats(0, 0, req_pods, _INF)) if return_stats else result

    coef = objective_coefficients(items, alpha)
    pods = np.array([it.pods for it in items], dtype=np.int64)
    bound = np.array([it.t3 for it in items], dtype=np.int64)

    neg = (coef < 0) & (bound > 0)
    covered = 0
    for i in np.nonzero(neg)[0]:
        counts[i] = int(bound[i])
        covered += int(pods[i] * bound[i])

    residual = max(0, req_pods - covered)
    objective = float(np.sum(coef[neg] * bound[neg]))

    if residual == 0:
        stats = IlpStats(n, 0, 0, objective)
        return (counts, stats) if return_stats else counts

    idx = [i for i in range(n)
           if not neg[i] and bound[i] > 0 and pods[i] > 0]
    if int(np.sum(pods[idx] * bound[idx])) < residual:
        return (None, IlpStats(n, 0, residual, _INF)) if return_stats else None

    bundles: List[Tuple[int, int, float, int]] = []   # (item, pods, cost, copies)
    for i in idx:
        for copies in _binary_bundles(int(bound[i])):
            bundles.append((i, int(pods[i] * copies),
                            float(coef[i] * copies), copies))

    R = residual
    dp = np.full(R + 1, _INF)
    dp[0] = 0.0
    history = np.empty((len(bundles) + 1, R + 1))
    history[0] = dp
    for b, (_, pb, cb, _) in enumerate(bundles):
        shifted = np.empty(R + 1)
        cut = min(pb, R + 1)
        shifted[:cut] = dp[0]
        if cut <= R:
            shifted[cut:] = dp[: R + 1 - pb]
        dp = np.minimum(dp, shifted + cb)
        history[b + 1] = dp

    if not np.isfinite(dp[R]):
        return (None, IlpStats(n, len(bundles), residual, _INF)) if return_stats else None

    j = R
    for b in range(len(bundles) - 1, -1, -1):
        if j == 0:
            break
        if history[b + 1][j] < history[b][j] - 1e-12:
            i, pb, _, copies = bundles[b]
            counts[i] += copies
            j = max(0, j - pb)
    objective += float(dp[R])

    stats = IlpStats(n, len(bundles), residual, objective)
    return (counts, stats) if return_stats else counts


def solve_ilp_pulp(items: Sequence[CandidateItem], req_pods: int,
                   alpha: float) -> Optional[List[int]]:
    """Reference backend using PuLP/CBC (the paper's implementation, §4)."""
    import pulp

    coef = objective_coefficients(items, alpha)
    prob = pulp.LpProblem("kubepacs_node_selection", pulp.LpMinimize)
    xs = [pulp.LpVariable(f"x_{i}", lowBound=0, upBound=int(it.t3),
                          cat="Integer") for i, it in enumerate(items)]
    prob += pulp.lpSum(float(coef[i]) * xs[i] for i in range(len(items)))
    prob += pulp.lpSum(int(it.pods) * xs[i]
                       for i, it in enumerate(items)) >= int(req_pods)
    status = prob.solve(pulp.PULP_CBC_CMD(msg=False))
    if pulp.LpStatus[status] != "Optimal":
        return None
    return [int(round(x.value() or 0)) for x in xs]
