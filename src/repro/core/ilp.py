"""The ILP node-selection solver (paper §3.1, Eq. 4–5).

    minimize   Σ_i ( -α·Perf_i/Perf_min + (1-α)·SP_i/SP_min ) · x_i
    subject to Σ_i Pod_i·x_i ≥ Req_pod,   0 ≤ x_i ≤ T3_i,   x_i ∈ ℤ

Two interchangeable solvers:

* :func:`solve_ilp` — exact, dependency-free.  Items with negative objective
  coefficient are saturated at their T3 bound (any ILP optimum does this; it
  is exactly the high-α over-provisioning collapse of Table 2), and the
  residual min-cost covering problem over non-negative items is a bounded
  knapsack solved exactly by DP with binary bundle splitting.  Runs in
  O(Σ_i log T3_i · Req_pod) with vectorized numpy updates.
* :func:`solve_ilp_pulp` — the paper's actual tool (PuLP/CBC), used to
  cross-validate the DP in tests and available as a drop-in backend.

Both return per-item integer counts, or ``None`` when demand exceeds the
total bounded capacity (the paper assumes the cloud always has capacity;
the provisioner surfaces this explicitly instead).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .efficiency import CandidateItem

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class IlpStats:
    """Solver introspection for the overhead study (paper Fig. 7 / §5.3)."""

    n_items: int
    n_bundles: int
    residual_demand: int
    objective: float


def objective_coefficients(items: Sequence[CandidateItem],
                           alpha: float) -> np.ndarray:
    """Eq. 4–5 coefficients: -α·Perf_i/Perf_min + (1-α)·SP_i/SP_min."""
    if not items:
        return np.zeros((0,))
    perf = np.array([it.perf for it in items], dtype=np.float64)
    sp = np.array([it.spot_price for it in items], dtype=np.float64)
    positive_perf = perf[perf > 0]
    perf_min = positive_perf.min() if positive_perf.size else 1.0
    sp_min = sp.min()
    if sp_min <= 0:
        raise ValueError("spot prices must be positive")
    return -alpha * perf / perf_min + (1.0 - alpha) * sp / sp_min


def _binary_bundles(count: int) -> List[int]:
    """Split a bound into power-of-two bundles (exact bounded knapsack)."""
    out, k = [], 1
    while count > 0:
        take = min(k, count)
        out.append(take)
        count -= take
        k <<= 1
    return out


def solve_ilp(items: Sequence[CandidateItem], req_pods: int, alpha: float,
              return_stats: bool = False,
              ) -> Optional[List[int]] | Tuple[Optional[List[int]], IlpStats]:
    """Exact solver for Eq. 5.  Returns x_i per item (None if infeasible)."""
    n = len(items)
    counts = [0] * n
    if n == 0:
        result = None if req_pods > 0 else counts
        return (result, IlpStats(0, 0, req_pods, _INF)) if return_stats else result

    coef = objective_coefficients(items, alpha)
    pods = np.array([it.pods for it in items], dtype=np.int64)
    bound = np.array([it.t3 for it in items], dtype=np.int64)

    # Saturate strictly-negative-coefficient items (always optimal for an
    # unpenalized minimization; this is what makes α→1 over-provision).
    neg = (coef < 0) & (bound > 0)
    covered = 0
    for i in np.nonzero(neg)[0]:
        counts[i] = int(bound[i])
        covered += int(pods[i] * bound[i])

    residual = max(0, req_pods - covered)
    objective = float(np.sum(coef[neg] * bound[neg]))

    if residual == 0:
        stats = IlpStats(n, 0, 0, objective)
        return (counts, stats) if return_stats else counts

    # Residual min-cost covering knapsack over non-negative items.
    idx = [i for i in range(n)
           if not neg[i] and bound[i] > 0 and pods[i] > 0]
    if int(np.sum(pods[idx] * bound[idx])) < residual:
        return (None, IlpStats(n, 0, residual, _INF)) if return_stats else None

    bundles: List[Tuple[int, int, float, int]] = []   # (item, pods, cost, copies)
    for i in idx:
        for copies in _binary_bundles(int(bound[i])):
            bundles.append((i, int(pods[i] * copies),
                            float(coef[i] * copies), copies))

    R = residual
    dp = np.full(R + 1, _INF)
    dp[0] = 0.0
    history = np.empty((len(bundles) + 1, R + 1))
    history[0] = dp
    for b, (_, pb, cb, _) in enumerate(bundles):
        shifted = np.empty(R + 1)
        cut = min(pb, R + 1)
        shifted[:cut] = dp[0]
        if cut <= R:
            shifted[cut:] = dp[: R + 1 - pb]
        dp = np.minimum(dp, shifted + cb)
        history[b + 1] = dp

    if not np.isfinite(dp[R]):
        return (None, IlpStats(n, len(bundles), residual, _INF)) if return_stats else None

    # Backtrack through DP history (exact; ties resolve to "skip").
    j = R
    for b in range(len(bundles) - 1, -1, -1):
        if j == 0:
            break
        if history[b + 1][j] < history[b][j] - 1e-12:
            i, pb, _, copies = bundles[b]
            counts[i] += copies
            j = max(0, j - pb)
    objective += float(dp[R])

    stats = IlpStats(n, len(bundles), residual, objective)
    return (counts, stats) if return_stats else counts


def solve_ilp_pulp(items: Sequence[CandidateItem], req_pods: int,
                   alpha: float) -> Optional[List[int]]:
    """Reference backend using PuLP/CBC (the paper's implementation, §4)."""
    import pulp

    coef = objective_coefficients(items, alpha)
    prob = pulp.LpProblem("kubepacs_node_selection", pulp.LpMinimize)
    xs = [pulp.LpVariable(f"x_{i}", lowBound=0, upBound=int(it.t3),
                          cat="Integer") for i, it in enumerate(items)]
    prob += pulp.lpSum(float(coef[i]) * xs[i] for i in range(len(items)))
    prob += pulp.lpSum(int(it.pods) * xs[i]
                       for i, it in enumerate(items)) >= int(req_pods)
    status = prob.solve(pulp.PULP_CBC_CMD(msg=False))
    if pulp.LpStatus[status] != "Optimal":
        return None
    return [int(round(x.value() or 0)) for x in xs]
