"""The ILP node-selection solver (paper §3.1, Eq. 4–5) — batched engine.

    minimize   Σ_i ( -α·Perf_i/Perf_min + (1-α)·SP_i/SP_min ) · x_i
    subject to Σ_i Pod_i·x_i ≥ Req_pod,   0 ≤ x_i ≤ T3_i,   x_i ∈ ℤ

One exact engine behind three entry points (DESIGN.md §8 + §12):

* :func:`solve_ilp` — single (α, demand) solve.  Items with negative
  objective coefficient are saturated at their T3 bound (any ILP optimum
  does this; it is exactly the high-α over-provisioning collapse of
  Table 2); the residual min-cost covering problem over non-negative items
  is a bounded knapsack solved exactly by LP-bound bundle pruning plus one
  forward min-plus value pass that emits *improvement bits*, from which
  the optimal counts are reconstructed in O(bundles) — the value pass runs
  on a pluggable :mod:`repro.core.backend` (numpy or JAX-jitted).
* :func:`solve_ilp_batch` — all α of a GSS prescan grid for one demand.
* :func:`solve_ilp_many` — the cross-decision batch: every pending
  decision of a FleetSim tick (each with its own demand, α grid, and §4.1
  exclusion mask) stacked into one engine invocation.  Rows that share
  (exclusion mask, α) share one objective row with its saturation
  analysis and rate ordering; rows that additionally share the residual
  share the whole plan — one LP prune, one DP, one decode per unique
  (objective, residual) pair, dispatched to the backend in stacked
  slices (accelerator backends take the stack whole, the host backend
  keeps each slice's working set cache-sized).

All three produce *bit-identical selections* for a given row regardless of
batching and backend: the value pass is a fixed sequence of elementwise
float64 ops (see :mod:`repro.core.backend`) and tie-breaking lives entirely
in the shared improvement-bit backtracker.

:func:`solve_ilp_reference` preserves the seed history-matrix solver
verbatim for cross-validation tests and as the benchmark baseline;
:func:`solve_ilp_pulp` wraps the paper's actual tool (PuLP/CBC).

All count-returning entry points return per-item integers, or ``None`` when
demand exceeds the total bounded capacity (the paper assumes the cloud
always has capacity; the provisioner surfaces this explicitly instead).

Preprocessing (bundle splitting, pod/bound arrays, normalised objective
terms) is hoisted into :class:`CompiledMarket`, built once per candidate
set and reused across every α evaluated by a provisioning cycle — and
across the re-optimisation cycles of §4.1 interrupt handling via the
provisioner-level cache.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Hashable, List, Optional, Sequence, Tuple

import numpy as np

from .backend import (_CORE_MIN, _CORE_PAD, _CORE_TRIGGER,
                      DEFAULT_COARSENING, CoarseningConfig, SolverBackend,
                      get_backend)
from .efficiency import CandidateItem

__all__ = [
    "CoarseningConfig", "DEFAULT_COARSENING", "CompiledMarket", "IlpStats",
    "compile_market", "reweight_market", "objective_coefficients",
    "solve_ilp", "solve_ilp_batch", "solve_ilp_many", "solve_ilp_reference",
    "solve_ilp_pulp",
]

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class IlpStats:
    """Solver introspection for the overhead study (paper Fig. 7 / §5.3).

    ``coarse`` records which demand-coarsening tier solved the row
    (DESIGN.md §14): ``"exact"`` (granularity 1), ``"gcd"`` (provably
    exact at granularity = the market pod GCD), ``"approx"`` (greedy
    rate-order prefix + exact DP over the boundary residual window,
    ``granularity`` = the window width and ``gap_bound`` the a-posteriori
    LP-certified objective gap), or ``"approx_fallback"`` (the
    certificate failed; the row was re-solved exactly)."""

    n_items: int
    n_bundles: int
    residual_demand: int
    objective: float
    coarse: str = "exact"
    granularity: int = 1
    gap_bound: float = 0.0


def objective_coefficients(items: Sequence[CandidateItem],
                           alpha: float) -> np.ndarray:
    """Eq. 4–5 coefficients: -α·Perf_i/Perf_min + (1-α)·SP_i/SP_min."""
    if not items:
        return np.zeros((0,))
    perf = np.array([it.perf for it in items], dtype=np.float64)
    sp = np.array([it.spot_price for it in items], dtype=np.float64)
    positive_perf = perf[perf > 0]
    perf_min = positive_perf.min() if positive_perf.size else 1.0
    sp_min = sp.min()
    if sp_min <= 0:
        raise ValueError("spot prices must be positive")
    return -alpha * perf / perf_min + (1.0 - alpha) * sp / sp_min


def _binary_bundles(count: int) -> List[int]:
    """Split a bound into power-of-two bundles (exact bounded knapsack)."""
    out, k = [], 1
    while count > 0:
        take = min(k, count)
        out.append(take)
        count -= take
        k <<= 1
    return out


# ---------------------------------------------------------------------------
# CompiledMarket: α-independent preprocessing, built once per candidate set
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompiledMarket:
    """Everything about a candidate set that does not depend on α or demand.

    The ILP objective at any α is a linear reweighting of two fixed vectors
    (``perf_norm`` and ``price_norm``); the bounded-knapsack structure
    (per-item pods, T3 bounds, binary bundle splits) never changes.  Building
    this once per provisioning cycle and once per §4.1 re-optimisation is
    what lets GSS evaluate ~20 α values without re-running preprocessing.
    """

    items: Tuple[CandidateItem, ...]
    pods: np.ndarray          # (n,) int64   Pod_i
    bound: np.ndarray         # (n,) int64   T3_i
    perf: np.ndarray          # (n,) float64 Perf_i = BS_i·Pod_i
    price: np.ndarray         # (n,) float64 SP_i
    perf_min: float
    sp_min: float
    perf_norm: np.ndarray     # (n,) Perf_i / Perf_min
    price_norm: np.ndarray    # (n,) SP_i / SP_min
    structural: np.ndarray    # (n,) bool — pods > 0 and bound > 0
    b_item: np.ndarray        # (B,) int64  bundle -> item index
    b_pods: np.ndarray        # (B,) int64  bundle pod size
    b_copies: np.ndarray      # (B,) int64  bundle node count

    @property
    def n(self) -> int:
        return len(self.items)

    @property
    def n_bundles(self) -> int:
        return len(self.b_item)

    @property
    def metric_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(Perf_i, SP_i, Pod_i) float64 triple for ``score_counts_batch``."""
        return self.perf, self.price, self.pods.astype(np.float64)

    @functools.cached_property
    def pods_gcd(self) -> int:
        """GCD of every structural item's pod count (1 when there are
        none).  Any row's DP-active bundle set is a subset of the
        structural bundles, and every bundle's pod size is an item pod
        count times its copy count — so this market-wide GCD divides every
        active bundle of every row, which is exactly the divisibility
        condition under which gcd-coarsening is bit-exact (DESIGN.md §14).
        """
        p = self.pods[self.structural]
        return int(np.gcd.reduce(p)) if p.size else 1

    @functools.cached_property
    def digest(self) -> str:
        """Content digest of every solver-relevant array — the device-cache
        key of the fused backend (DESIGN.md §13): two markets with equal
        digests produce identical device uploads, so a recompiled but
        unchanged market re-uses its resident arrays, while any offering
        change invalidates the entry.  (``cached_property`` writes straight
        to ``__dict__``, which a frozen dataclass permits.)"""
        h = hashlib.blake2b(digest_size=16)
        for a in (self.pods, self.bound, self.perf, self.price,
                  self.structural, self.b_item, self.b_pods,
                  self.b_copies):
            h.update(np.ascontiguousarray(a).tobytes())
        return h.hexdigest()

    def norms(self, exclude: Optional[np.ndarray] = None,
              ) -> Tuple[np.ndarray, np.ndarray]:
        """(Perf_i/Perf_min, SP_i/SP_min) normalised objective vectors.

        With an ``exclude`` mask the Perf_min/SP_min normalisation is taken
        over the surviving candidates only — identical to rebuilding the
        candidate set without the excluded offerings (§4.1 cache semantics).
        GSS evaluators cache this pair once per (market, mask) and rebuild
        per-α coefficient rows as ``-α·pn + (1-α)·qn`` — the same
        elementwise float64 ops :meth:`coefficients` performs, so the
        cached path is bit-identical to the uncached one.
        """
        if exclude is None or not np.any(exclude):
            return self.perf_norm, self.price_norm
        m = ~exclude
        perf_pos = self.perf[m & (self.perf > 0)]
        perf_min = float(perf_pos.min()) if perf_pos.size else 1.0
        prices = self.price[m]
        sp_min = float(prices.min()) if prices.size else 1.0
        if sp_min <= 0:
            raise ValueError("spot prices must be positive")
        return self.perf / perf_min, self.price / sp_min

    def coefficients(self, alphas: np.ndarray,
                     exclude: Optional[np.ndarray] = None) -> np.ndarray:
        """Broadcast Eq. 4–5 over an α grid: (n_alpha, n_items)."""
        a = np.asarray(alphas, dtype=np.float64).reshape(-1, 1)
        perf_norm, price_norm = self.norms(exclude)
        return -a * perf_norm + (1.0 - a) * price_norm


def compile_market(items: Sequence[CandidateItem]) -> CompiledMarket:
    """Hoist all α-independent solver preprocessing out of the hot path."""
    items = tuple(items)
    n = len(items)
    pods = np.array([it.pods for it in items], dtype=np.int64)
    bound = np.array([it.t3 for it in items], dtype=np.int64)
    perf = np.array([it.perf for it in items], dtype=np.float64)
    price = np.array([it.spot_price for it in items], dtype=np.float64)
    if n:
        positive_perf = perf[perf > 0]
        perf_min = float(positive_perf.min()) if positive_perf.size else 1.0
        sp_min = float(price.min())
        if sp_min <= 0:
            raise ValueError("spot prices must be positive")
    else:
        perf_min, sp_min = 1.0, 1.0
    structural = (pods > 0) & (bound > 0)

    b_item: List[int] = []
    b_copies: List[int] = []
    for i in np.nonzero(structural)[0]:
        for copies in _binary_bundles(int(bound[i])):
            b_item.append(int(i))
            b_copies.append(copies)
    b_item_arr = np.array(b_item, dtype=np.int64)
    b_copies_arr = np.array(b_copies, dtype=np.int64)
    b_pods_arr = (pods[b_item_arr] * b_copies_arr if len(b_item)
                  else np.zeros(0, dtype=np.int64))
    return CompiledMarket(
        items=items, pods=pods, bound=bound, perf=perf, price=price,
        perf_min=perf_min, sp_min=sp_min,
        perf_norm=perf / perf_min, price_norm=price / sp_min,
        structural=structural,
        b_item=b_item_arr, b_pods=b_pods_arr, b_copies=b_copies_arr)


def reweight_market(market: CompiledMarket, perf: np.ndarray,
                    price: np.ndarray,
                    items: Optional[Sequence[CandidateItem]] = None,
                    ) -> CompiledMarket:
    """Array-adjustment entry point: a compiled market with substituted
    (Perf_i, SP_i) objective vectors.

    The bounded-knapsack *structure* (Pod_i, T3_i, binary bundle splits) is
    independent of the objective, so swapping in adjusted performance/price
    vectors — the risk subsystem's uptime-discounted Perf and
    re-provision-charged SP (``repro.risk.objective``) — costs O(n) instead
    of a full :func:`compile_market`.  Pass ``items`` (e.g. from
    :func:`repro.core.efficiency.reweight_items`) to keep ``market.items``
    consistent with the new vectors; otherwise the original items are kept
    and only the solver-facing arrays change.
    """
    perf = np.asarray(perf, dtype=np.float64)
    price = np.asarray(price, dtype=np.float64)
    if len(perf) != market.n or len(price) != market.n:
        raise ValueError(f"adjusted vectors must have {market.n} entries")
    if market.n == 0:
        return market
    if np.any(price <= 0):
        raise ValueError("adjusted prices must be positive")
    positive_perf = perf[perf > 0]
    perf_min = float(positive_perf.min()) if positive_perf.size else 1.0
    sp_min = float(price.min())
    return dataclasses.replace(
        market,
        items=market.items if items is None else tuple(items),
        perf=perf, price=price, perf_min=perf_min, sp_min=sp_min,
        perf_norm=perf / perf_min, price_norm=price / sp_min)


# ---------------------------------------------------------------------------
# Covering knapsack: LP pruning + backend value pass + improvement-bit decode
# ---------------------------------------------------------------------------

def _cover_dp(bpods: np.ndarray, bcosts: np.ndarray, target: int,
              ) -> np.ndarray:
    """Reference forward value pass: dp[j] = min cost of a bundle subset
    with ≥ j pods.  Kept as the plain-numpy spec of the backend kernel
    (``repro.core.backend``) for tests; the production path uses the
    backend's fused value-pass-with-bits instead.
    """
    dp = np.full(target + 1, _INF)
    dp[0] = 0.0
    scratch = np.empty(target + 1)
    for b in range(len(bpods)):
        pb = int(bpods[b])
        cb = bcosts[b]
        if not np.isfinite(cb):
            continue
        if pb > target:
            np.minimum(dp[1:], cb, out=dp[1:])
            continue
        k = target + 1 - pb
        cand = np.add(dp[:k], cb, out=scratch[:k])
        np.minimum(dp[pb:], cand, out=dp[pb:])
        if pb > 1:
            np.minimum(dp[1:pb], dp[0] + cb, out=dp[1:pb])
    return dp


#: core-DP upper-bound tuning for :func:`_lp_prune` (``_CORE_PAD``,
#: ``_CORE_MIN``, ``_CORE_TRIGGER``) now lives in :mod:`repro.core.backend`
#: — the fused device solver replicates the same pruning decisions and
#: importing them from here would create a cycle.  Re-exported above.


def _lp_prune(bpods: np.ndarray, bcosts: np.ndarray, target: int,
              ub_cache: Optional[dict] = None) -> np.ndarray:
    """Exact LP-bound pruning: drop bundles no optimal solution can use.

    Sort by unit cost; the fractional greedy gives a lower bound LP(j) for
    covering j pods and the integral greedy a feasible upper bound UB.  Any
    solution containing bundle b costs ≥ c_b + LP(target − p_b), so bundles
    with c_b + LP(target − p_b) > UB are provably absent from *every*
    optimum and can be removed before the decode DP.  All optimal solutions
    survive for any valid UB, hence the pruned instance stays feasible and
    exact.

    The greedy prefix can overshoot badly at awkward targets (a loose UB
    lets almost every bundle survive), so when it leaves more than
    ``_CORE_TRIGGER`` bundles alive the bound is tightened by a *core DP*:
    the exact cover DP over the best-rate core bundles (which contain the
    greedy prefix, so the core optimum covers the target and its cost is a
    valid — near-optimal in practice — UB).  ``ub_cache`` memoises the
    core bound per target across repeated calls on one objective.

    This standalone function is the reference statement of the prune rule
    (and the form the test suite exercises); the production engine inlines
    the same ingredients in :func:`_solve_rows`, where the argsort and
    cumulative arrays are shared across every residual of an objective.
    Every ingredient is a deterministic function of (costs, target), so
    pruning — like everything else in the engine — is
    batch-composition-invariant.
    """
    B = len(bpods)
    if B == 0 or target <= 0:
        return np.ones(B, dtype=bool)
    rate = bcosts / bpods
    order = np.argsort(rate, kind="stable")
    p_sorted = bpods[order].astype(np.float64)
    c_sorted = bcosts[order]
    cum_p = np.cumsum(p_sorted)
    cum_c = np.cumsum(c_sorted)
    if cum_p[-1] < target:                      # infeasible: caller handles
        return np.ones(B, dtype=bool)

    # integral greedy upper bound: first prefix that covers the target
    k_ub = int(np.searchsorted(cum_p, target))
    ub = float(cum_c[k_ub])

    # fractional lower bound LP(j), evaluated at j = target − p_b for all b
    resid = np.maximum(target - bpods, 0).astype(np.float64)
    k = np.searchsorted(cum_p, resid)
    prev_p = np.where(k > 0, cum_p[np.maximum(k - 1, 0)], 0.0)
    prev_c = np.where(k > 0, cum_c[np.maximum(k - 1, 0)], 0.0)
    lp = prev_c + (resid - prev_p) * (c_sorted[k] / p_sorted[k])
    lp[resid <= 0] = 0.0
    keep = bcosts + lp <= ub * (1.0 + 1e-12) + 1e-9
    if int(np.sum(keep)) <= _CORE_TRIGGER:
        return keep

    core_ub = ub_cache.get(target) if ub_cache is not None else None
    if core_ub is None:
        K = min(B, max(k_ub + _CORE_PAD, _CORE_MIN))
        core_ub = float(_cover_dp(bpods[order[:K]], c_sorted[:K],
                                  target)[target])
        if ub_cache is not None:
            ub_cache[target] = core_ub
    if core_ub < ub:
        keep = bcosts + lp <= core_ub * (1.0 + 1e-12) + 1e-9
    return keep


def _backtrack_bits(bits: np.ndarray, bpods: np.ndarray, target: int,
                    ) -> np.ndarray:
    """Greedy improvement-bit backtrack (the seed backtracker's rule).

    Walking bundles last-to-first with remaining target ``j``: bundle ``b``
    is taken iff it *strictly improved* (plain ``<``, no epsilon — dp
    values are exact) the value at coverage ``j`` when the forward pass
    processed it — equivalently, every optimal solution over bundles
    ``0..b`` uses it.
    This single rule is the engine's entire tie-breaking: backends produce
    bit-identical ``bits``, so selections are backend-invariant
    (DESIGN.md §12).
    """
    take = np.zeros(len(bpods), dtype=bool)
    j = target
    for b in range(len(bpods) - 1, -1, -1):
        if j == 0:
            break
        if bits[b, j]:
            take[b] = True
            j = max(0, j - int(bpods[b]))
    return take


def _plan_scale(cfg: Optional[CoarseningConfig], g: int,
                residual: int) -> Tuple[str, int]:
    """The demand-coarsening mode ladder (DESIGN.md §14), a deterministic
    function of (config, market gcd, residual) — so, like everything else
    in the engine, batch-composition-invariant.

    * residual ≤ threshold → ``("exact", 1)``: the coarsening layer is
      inert at the paper's scales.
    * gcd mode when the market GCD ``g`` shrinks the DP to at most
      ``max_rows`` rows → ``("gcd", g)``, provably bit-exact.
    * otherwise the approx tier → ``("approx", approx_rows)``: the bulk of
      the demand is covered by the rate-order greedy prefix (the integral
      form of the LP optimum, whose structure the engine's own pruning
      bound already trusts) down to a boundary window of ``approx_rows``
      pods, and only that window is solved by an exact cover DP — bounded
      suboptimality via an a-posteriori LP certificate, with an automatic
      exact fallback when the certificate fails.
    * approx disabled (or residual inside the window): degrade to gcd if
      available, else exact.
    """
    if cfg is None or not cfg.enabled or residual <= cfg.threshold:
        return "exact", 1
    if g > 1 and -(-residual // g) <= cfg.max_rows:
        return "gcd", g
    if cfg.allow_approx and residual > cfg.approx_rows:
        return "approx", cfg.approx_rows
    return ("gcd", g) if g > 1 else ("exact", 1)


# ---------------------------------------------------------------------------
# The row engine: every public solver is a view over _solve_rows
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SolveRow:
    """One (demand, objective) instance of the stacked engine invocation.

    ``key`` identifies the objective: rows with equal ``key`` MUST carry
    identical ``coef``/``active`` arrays (the caller's contract) and then
    share saturation analysis, bundle compaction, and — when their
    LP-pruned bundle sets coincide — one padded backend DP row.
    """

    req_pods: int
    alpha: float
    coef: np.ndarray                       # (n,) Eq. 4–5 objective row
    active: np.ndarray                     # (n,) structural & ~exclude
    key: Hashable                          # objective identity for grouping


def _solve_rows(market: CompiledMarket, rows: Sequence[SolveRow],
                backend: Optional[SolverBackend] = None,
                coarsening: Optional[CoarseningConfig] = None,
                ) -> Tuple[List[Optional[List[int]]], List[IlpStats]]:
    """Solve every row, deduplicating shared structure.

    Rows whose residual exceeds ``coarsening.threshold`` run the cover DP
    through the demand-coarsening ladder (:func:`_plan_scale`): the gcd
    tier is bit-exact; the approx tier carries a certified gap bound with
    an automatic exact fallback.  Everything below the threshold — all of
    the paper's scenarios under the default config — is byte-for-byte the
    uncoarsened engine.

    Pipeline (DESIGN.md §12).  Per objective key: saturation mask, covered
    capacity, residual-DP bundle compaction, and one rate-order argsort.
    Per unique (key, residual): LP pruning — any bundle b with
    ``c_b + LP(residual − p_b)`` above a feasible upper bound is provably
    in no optimal solution.  The bound starts as the integral greedy
    prefix; when that alone leaves more than ``_CORE_TRIGGER`` bundles
    alive, a *core DP* (value-only, over the ``max(k_greedy + _CORE_PAD,
    _CORE_MIN)`` best-rate bundles, where optimal solutions live in
    practice) tightens it to near-optimal, and the surviving set of the
    tighter test is re-derived (always a subset of the greedy keep).  The
    final improvement-bit DP then runs over each plan's kept bundles in
    market order and its bits decode the selection.  Both backend phases
    stack all plans into one dispatch each.  Every choice is a
    deterministic function of (objective, residual), so a row's selection
    is independent of what else shares the batch — the scalar path IS the
    one-row batch.
    """
    backend = backend or get_backend()
    cfg = DEFAULT_COARSENING if coarsening is None else coarsening
    gcd = market.pods_gcd
    n = market.n
    results: List[Optional[List[int]]] = [None] * len(rows)
    stats: List[Optional[IlpStats]] = [None] * len(rows)

    # -- per-objective saturation analysis ---------------------------------
    obj_cache: dict = {}                   # key -> per-objective dict
    for r in rows:
        o = obj_cache.get(r.key)
        if o is None:
            neg = (r.coef < 0) & r.active
            covered = int(np.sum(market.pods[neg] * market.bound[neg]))
            in_dp = r.active & ~neg
            capacity = int(np.sum(market.pods[in_dp] * market.bound[in_dp]))
            obj_cache[r.key] = o = {
                "neg": neg, "covered": covered, "in_dp": in_dp,
                "capacity": capacity, "coef": r.coef, "sat_counts": None,
                "sat_obj": None, "bundles": None, "rate": None,
            }

    def _saturated(o) -> Tuple[np.ndarray, float]:
        if o["sat_counts"] is None:
            counts = np.zeros(n, dtype=np.int64)
            counts[o["neg"]] = market.bound[o["neg"]]
            o["sat_counts"] = counts
            o["sat_obj"] = float(np.sum(o["coef"][o["neg"]]
                                        * market.bound[o["neg"]]))
        return o["sat_counts"], o["sat_obj"]

    def _bundles(o) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if o["bundles"] is None:
            bidx = np.flatnonzero(o["in_dp"][market.b_item])
            o["bundles"] = (bidx, market.b_pods[bidx],
                            o["coef"][market.b_item[bidx]]
                            * market.b_copies[bidx])
        return o["bundles"]

    def _rate(o):
        """Rate-order view of the objective's DP bundles (argsort shared
        across every residual of the objective)."""
        if o["rate"] is None:
            _, bpods, bcosts = _bundles(o)
            order = np.argsort(bcosts / bpods, kind="stable")
            p_sorted = bpods[order].astype(np.float64)
            c_sorted = bcosts[order]
            o["rate"] = (order, p_sorted, c_sorted,
                         np.cumsum(p_sorted), np.cumsum(c_sorted))
        return o["rate"]

    def _lp_bound(o, residual: int) -> np.ndarray:
        """Fractional greedy lower bound LP(residual − p_b) per bundle."""
        _, bpods, _bc = _bundles(o)
        order, p_sorted, c_sorted, cum_p, cum_c = _rate(o)
        rb = np.maximum(residual - bpods, 0).astype(np.float64)
        kk = np.searchsorted(cum_p, rb)
        prev_p = np.where(kk > 0, cum_p[np.maximum(kk - 1, 0)], 0.0)
        prev_c = np.where(kk > 0, cum_c[np.maximum(kk - 1, 0)], 0.0)
        lp = prev_c + (rb - prev_p) * (c_sorted[kk] / p_sorted[kk])
        lp[rb <= 0] = 0.0
        return lp

    def _lp_at(o, residual: int) -> float:
        """Scalar LP(residual): the fractional greedy lower bound on the
        exact optimum — the approx tier's suboptimality certificate."""
        if residual <= 0:
            return 0.0
        _order, p_sorted, c_sorted, cum_p, cum_c = _rate(o)
        k = int(np.searchsorted(cum_p, float(residual)))
        prev_p = float(cum_p[k - 1]) if k > 0 else 0.0
        prev_c = float(cum_c[k - 1]) if k > 0 else 0.0
        return prev_c + (residual - prev_p) * float(c_sorted[k]
                                                    / p_sorted[k])

    # -- classify rows; one plan per unique (objective, residual) ----------
    plans: dict = {}
    row_plan: List = []       # per row: (kind, obj-or-plan, residual)
    for r in rows:
        o = obj_cache[r.key]
        residual = max(0, r.req_pods - o["covered"])
        if residual == 0:
            row_plan.append(("sat", o, 0))
            continue
        if o["capacity"] < residual:
            row_plan.append(("none", o, residual))
            continue
        mode, param = _plan_scale(cfg, gcd, residual)
        pkey = (r.key, residual)
        plan = plans.get(pkey)
        if plan is None:
            order, _p, _c, cum_p, cum_c = _rate(o)
            if mode == "approx":
                # greedy rate-order prefix down to the boundary window:
                # the minimal prefix covering residual − window pods (its
                # cumulative arrays are shared by every residual of the
                # objective — the coarse work α-grid rows reuse).  Only
                # the ≤ window-pod remainder meets an exact cover DP.
                need = residual - param
                k_cut = (min(int(np.searchsorted(cum_p, need)) + 1,
                             len(order)) if need > 0 else 0)
                cov = int(cum_p[k_cut - 1]) if k_cut else 0
                tres = max(0, residual - cov)
                tail = order[k_cut:]
                _, _bp, bcosts = _bundles(o)
                # the window DP is the exact engine restated on the tail
                # subproblem (tail capacity ≥ tres by construction), so it
                # reuses the same greedy-UB / per-bundle-LP prune and the
                # phase-1 core tightening; lp = +inf off-tail keeps the
                # committed prefix out of the DP (binary bundles are
                # use-once).
                lp = np.full(len(bcosts), _INF)
                ub, core, keep = 0.0, None, np.zeros(len(bcosts), bool)
                if tres > 0 and len(tail):
                    tp, tc = _p[k_cut:], _c[k_cut:]
                    base_p = float(cum_p[k_cut - 1]) if k_cut else 0.0
                    base_c = float(cum_c[k_cut - 1]) if k_cut else 0.0
                    cum_tp = cum_p[k_cut:] - base_p
                    cum_tc = cum_c[k_cut:] - base_c
                    k_ub = int(np.searchsorted(cum_tp, float(tres)))
                    ub = float(cum_tc[k_ub])
                    rb = np.maximum(tres - tp, 0).astype(np.float64)
                    kk = np.searchsorted(cum_tp, rb)
                    prev_p = np.where(kk > 0, cum_tp[np.maximum(kk - 1, 0)],
                                      0.0)
                    prev_c = np.where(kk > 0, cum_tc[np.maximum(kk - 1, 0)],
                                      0.0)
                    lp_t = prev_c + (rb - prev_p) * (tc[kk] / tp[kk])
                    lp_t[rb <= 0] = 0.0
                    lp[tail] = lp_t
                    keep = bcosts + lp <= ub * (1.0 + 1e-12) + 1e-9
                    if int(np.sum(keep)) > _CORE_TRIGGER:
                        K = min(len(tail), max(k_ub + _CORE_PAD, _CORE_MIN))
                        core = tail[:K]
                plans[pkey] = plan = {
                    "o": o, "resid": residual, "mode": "approx",
                    "window": param, "prefix": order[:k_cut],
                    "pcost": float(cum_c[k_cut - 1]) if k_cut else 0.0,
                    "tres": tres, "scale": 1, "sres": tres,
                    "lp": lp, "ub": ub, "core": core, "keep": keep,
                    "counts": None, "objective": _INF, "n_bundles": 0,
                    "coarse": "approx", "gap": 0.0}
                row_plan.append(("dp", plan, residual))
                continue
            # exact / gcd tiers share one code path: the DP runs at
            # granularity ``scale`` (1 = exact; the market gcd = bitwise
            # identical to the unscaled DP, DESIGN.md §14).  Prune math
            # deliberately stays at unscaled pods/residual, so the keep
            # set is the exact engine's in both tiers.
            scale = param if mode == "gcd" else 1
            sres = -(-residual // scale)
            k_ub = int(np.searchsorted(cum_p, residual))
            lp = _lp_bound(o, residual)
            _, _bp, bcosts = _bundles(o)
            ub = float(cum_c[k_ub])            # integral greedy prefix
            keep = bcosts + lp <= ub * (1.0 + 1e-12) + 1e-9
            core = None
            if int(np.sum(keep)) > _CORE_TRIGGER:
                # loose greedy bound: plan a core DP to tighten it first
                K = min(len(order), max(k_ub + _CORE_PAD, _CORE_MIN))
                core = order[:K]
            plans[pkey] = plan = {
                "o": o, "resid": residual, "mode": mode, "scale": scale,
                "sres": sres, "lp": lp, "ub": ub,
                "core": core, "keep": keep, "counts": None,
                "objective": _INF, "n_bundles": 0,
                "coarse": "gcd" if scale > 1 else "exact", "gap": 0.0}
        row_plan.append(("dp", plan, residual))

    plan_list = list(plans.values())

    def _scaled(bpods: np.ndarray, scale: int) -> np.ndarray:
        return bpods if scale == 1 else bpods // scale

    # -- phase 1: core upper bounds (value-only, one dispatch) -------------
    # gcd-mode plans run the core DP at scaled pods/target: bitwise the
    # unscaled DP (DESIGN.md §14), so the tightened keep set is identical
    cored = [p for p in plan_list if p["core"] is not None]
    if cored:
        reqs = []
        for p in cored:
            _, bpods, bcosts = _bundles(p["o"])
            reqs.append((_scaled(bpods, p["scale"])[p["core"]],
                         bcosts[p["core"]], p["sres"]))
        for p, dp in zip(cored, backend.cover_values(reqs)):
            # the core contains the greedy cover prefix, so its optimum is
            # finite and ≤ the greedy bound; survivors of the tighter test
            # are a subset of the greedy keep
            core_ub = float(dp[p["sres"]])
            if core_ub < p["ub"]:
                p["ub"] = core_ub
                _, _bp, bcosts = _bundles(p["o"])
                p["keep"] = bcosts + p["lp"] <= core_ub * (1.0 + 1e-12) + 1e-9

    def _exact_plan(o, residual: int):
        """One-row exact prune + DP + decode — the approx tier's fallback.
        A deterministic function of (objective, residual), identical to
        what the batched exact path produces for the same pair."""
        order, _p, _c, cum_p, cum_c = _rate(o)
        bidx, bpods, bcosts = _bundles(o)
        k_ub = int(np.searchsorted(cum_p, residual))
        lp = _lp_bound(o, residual)
        ub = float(cum_c[k_ub])
        keep = bcosts + lp <= ub * (1.0 + 1e-12) + 1e-9
        if int(np.sum(keep)) > _CORE_TRIGGER:
            K = min(len(order), max(k_ub + _CORE_PAD, _CORE_MIN))
            core = order[:K]
            dp = backend.cover_values(
                [(bpods[core], bcosts[core], residual)])[0]
            core_ub = float(dp[residual])
            if core_ub < ub:
                keep = bcosts + lp <= core_ub * (1.0 + 1e-12) + 1e-9
        kept = np.flatnonzero(keep)
        dp, bits = backend.cover_bits(
            [(bpods[kept], bcosts[kept], residual)])[0]
        take = _backtrack_bits(bits, bpods[kept], residual)
        return bidx[kept[take]], float(dp[residual]), len(kept)

    def _approx_finish(p, tail_taken: Optional[np.ndarray],
                       tail_obj: float) -> None:
        """Assemble an approx plan from its greedy prefix + boundary-DP
        take (``tail_taken`` in market bundle order), then check the LP
        certificate: the prefix + exact-window total is a feasible
        solution (cost ≥ optimum) and LP(residual) a lower bound (≤
        optimum), so ``total − LP`` bounds the true gap from above.
        Certificate violated → exact fallback."""
        o = p["o"]
        bidx, _bp, _bc = _bundles(o)
        total = p["pcost"] + tail_obj
        lp = _lp_at(o, p["resid"])
        gap = total - lp
        if gap <= cfg.rel_gap * max(abs(lp), 1e-9):
            taken = (p["prefix"] if tail_taken is None else
                     np.concatenate([p["prefix"], tail_taken]))
            p["counts"] = bidx[taken]
            p["objective"] = total
            p["n_bundles"] += len(p["prefix"])
            p["gap"] = max(gap, 0.0)
        else:
            p["counts"], p["objective"], p["n_bundles"] = _exact_plan(
                o, p["resid"])
            p["coarse"] = "approx_fallback"
            p["gap"] = 0.0

    # -- phase 2: the decode DP over each plan's kept set ------------------
    # dispatched in backend-preferred slices: the host backend keeps the
    # live bits working set small, accelerator backends take it all at
    # once.  Approx plans ride the same dispatch: their req is the exact
    # boundary-window DP over the pruned non-prefix bundles.
    chunk = max(1, getattr(backend, "max_group_batch", len(plan_list) or 1))
    for lo in range(0, len(plan_list), chunk):
        part = plan_list[lo:lo + chunk]
        reqs, ready = [], []
        for p in part:
            if p["mode"] == "approx" and p["tres"] == 0:
                _approx_finish(p, None, 0.0)  # prefix covers the demand
                continue
            _, bpods, bcosts = _bundles(p["o"])
            p["kept"] = np.flatnonzero(p["keep"])    # market bundle order
            p["n_bundles"] = len(p["kept"])
            reqs.append((_scaled(bpods, p["scale"])[p["kept"]],
                         bcosts[p["kept"]], p["sres"]))
            ready.append(p)
        for p, (dp, bits) in zip(ready, backend.cover_bits(reqs)):
            bidx, bpods, _bc = _bundles(p["o"])
            take = _backtrack_bits(
                bits, _scaled(bpods, p["scale"])[p["kept"]], p["sres"])
            if p["mode"] == "approx":
                _approx_finish(p, p["kept"][take], float(dp[p["sres"]]))
                continue
            p["counts"] = bidx[p["kept"][take]]
            p["objective"] = float(dp[p["sres"]])

    # -- assemble rows (duplicates share decoded plans) --------------------
    for i, (r, (kind, ctx, residual)) in enumerate(zip(rows, row_plan)):
        o = ctx if kind in ("sat", "none") else ctx["o"]
        if kind == "none":
            stats[i] = IlpStats(n, 0, residual, _INF)
            continue
        sat_counts, sat_obj = _saturated(o)
        if kind == "sat":
            results[i] = list(map(int, sat_counts))
            stats[i] = IlpStats(n, 0, 0, sat_obj)
            continue
        plan = ctx
        counts = sat_counts.copy()
        taken = plan["counts"]
        np.add.at(counts, market.b_item[taken], market.b_copies[taken])
        results[i] = list(map(int, counts))
        stats[i] = IlpStats(
            n, plan["n_bundles"], residual, sat_obj + plan["objective"],
            coarse=plan["coarse"],
            granularity=(plan["window"] if plan["mode"] == "approx"
                         else plan["scale"]),
            gap_bound=plan["gap"])
    return results, stats


# ---------------------------------------------------------------------------
# Public solvers
# ---------------------------------------------------------------------------

def _empty_market_result(req_pods: int, return_stats: bool):
    result = None if req_pods > 0 else []
    stats = IlpStats(0, 0, req_pods, _INF if req_pods > 0 else 0.0)
    return (result, stats) if return_stats else result


def _checked_market(items: Sequence[CandidateItem],
                    market: Optional[CompiledMarket]) -> CompiledMarket:
    if market is None:
        return compile_market(items)
    if market.n != len(items):
        raise ValueError(f"market was compiled from {market.n} items but "
                         f"{len(items)} were passed — stale CompiledMarket?")
    return market


def solve_ilp(items: Sequence[CandidateItem], req_pods: int, alpha: float,
              return_stats: bool = False,
              market: Optional[CompiledMarket] = None,
              exclude: Optional[np.ndarray] = None,
              backend: Optional[SolverBackend] = None,
              coef: Optional[np.ndarray] = None,
              coarsening: Optional[CoarseningConfig] = None,
              ) -> Optional[List[int]] | Tuple[Optional[List[int]], IlpStats]:
    """Exact solver for Eq. 5.  Returns x_i per item (None if infeasible).

    ``market`` reuses a :class:`CompiledMarket` (skips preprocessing);
    ``exclude`` is a per-item boolean mask of offerings barred from the
    solution (the §4.1 interrupted-offerings cache), applied at solve time
    so the compiled market survives interrupt churn.  ``coef`` optionally
    supplies the precomputed objective row (GSS evaluators cache
    ``market.norms(exclude)`` and rebuild rows per probe — bit-identical
    to the uncached path); it must equal
    ``market.coefficients([alpha], exclude)[0]``.  ``coarsening``
    overrides the demand-coarsening policy (default
    :data:`DEFAULT_COARSENING`, inert below 8192 residual pods).
    """
    market = _checked_market(items, market)
    if market.n == 0:
        return _empty_market_result(req_pods, return_stats)
    if coef is None:
        coef = market.coefficients(np.array([alpha]), exclude)[0]
    active = market.structural if exclude is None else (
        market.structural & ~exclude)
    results, stats = _solve_rows(
        market, [SolveRow(req_pods, alpha, coef, active, key=0)], backend,
        coarsening=coarsening)
    return (results[0], stats[0]) if return_stats else results[0]


def solve_ilp_batch(items: Sequence[CandidateItem], req_pods: int,
                    alphas: Sequence[float],
                    market: Optional[CompiledMarket] = None,
                    exclude: Optional[np.ndarray] = None,
                    return_stats: bool = False,
                    backend: Optional[SolverBackend] = None,
                    coarsening: Optional[CoarseningConfig] = None,
                    ) -> List[Optional[List[int]]] | Tuple[
                        List[Optional[List[int]]], List[IlpStats]]:
    """Solve Eq. 5 for every α of a prescan grid in one engine invocation.

    The bundle structure is α-independent; only objective coefficients vary
    (one broadcast over the grid).  Rows that saturate the demand skip the
    DP entirely; the rest share LP-pruned backend DP rows wherever their
    pruned bundle sets coincide (:func:`_solve_rows`).
    """
    grid = [float(a) for a in alphas]
    market = _checked_market(items, market)
    if market.n == 0:
        single = _empty_market_result(req_pods, True)
        results = [single[0] for _ in grid]
        stats = [single[1] for _ in grid]
        return (results, stats) if return_stats else results
    coef2d = market.coefficients(np.asarray(grid, dtype=np.float64), exclude)
    active = market.structural if exclude is None else (
        market.structural & ~exclude)
    rows = [SolveRow(req_pods, a, coef2d[k], active, key=a)
            for k, a in enumerate(grid)]
    results, stats = _solve_rows(market, rows, backend,
                                 coarsening=coarsening)
    return (results, stats) if return_stats else results


def solve_ilp_many(items: Sequence[CandidateItem],
                   requests: Sequence[int],
                   alphas: Sequence[float] | Sequence[Sequence[float]],
                   market: Optional[CompiledMarket] = None,
                   excludes: Optional[Sequence[Optional[np.ndarray]]] = None,
                   backend: Optional[SolverBackend] = None,
                   return_stats: bool = False,
                   coarsening: Optional[CoarseningConfig] = None,
                   ) -> List[List[Optional[List[int]]]] | Tuple[
                       List[List[Optional[List[int]]]], List[List[IlpStats]]]:
    """The cross-decision batch (DESIGN.md §12): solve every (decision, α)
    pair of a FleetSim tick in one engine invocation.

    ``requests[d]`` is decision ``d``'s demand, ``alphas`` either one grid
    shared by all decisions or a per-decision list of grids, and
    ``excludes[d]`` its §4.1 exclusion mask (or None).  Decisions that
    share (mask, α) share one objective row and saturation analysis;
    those additionally sharing the residual share the entire prune + DP +
    decode plan — the (n_decisions × n_α) stack collapses to its unique
    (objective, residual) pairs before the backend dispatches.  Per-row
    selections are bit-identical to per-decision :func:`solve_ilp_batch`
    calls.

    Returns one list of per-α count vectors (``None`` = infeasible) per
    decision, ``alphas``-shaped.
    """
    n_dec = len(requests)
    shared_grid = not n_dec or np.isscalar(alphas[0]) or isinstance(
        alphas[0], (int, float))
    grids: List[List[float]] = (
        [[float(a) for a in alphas]] * n_dec if shared_grid
        else [[float(a) for a in g] for g in alphas])
    if len(grids) != n_dec:
        raise ValueError("per-decision alphas must match len(requests)")
    if excludes is None:
        excludes = [None] * n_dec
    if len(excludes) != n_dec:
        raise ValueError("excludes must match len(requests)")
    market = _checked_market(items, market)

    if market.n == 0:
        out, st = [], []
        for d in range(n_dec):
            single = _empty_market_result(requests[d], True)
            out.append([single[0] for _ in grids[d]])
            st.append([single[1] for _ in grids[d]])
        return (out, st) if return_stats else out

    # dedupe masks -> tokens; per (token, α) one coefficient row
    mask_tokens: dict = {}
    masks: List[Optional[np.ndarray]] = []
    token_of: List[int] = []
    for ex in excludes:
        mkey = None if ex is None else ex.tobytes()
        tok = mask_tokens.get(mkey)
        if tok is None:
            tok = len(masks)
            mask_tokens[mkey] = tok
            masks.append(ex)
        token_of.append(tok)
    per_tok_alphas: List[List[float]] = [[] for _ in masks]
    per_tok_seen: List[dict] = [{} for _ in masks]
    for d in range(n_dec):
        tok = token_of[d]
        for a in grids[d]:
            if a not in per_tok_seen[tok]:
                per_tok_seen[tok][a] = len(per_tok_alphas[tok])
                per_tok_alphas[tok].append(a)
    coef_rows: List[np.ndarray] = []
    actives: List[np.ndarray] = []
    for tok, mask in enumerate(masks):
        coef_rows.append(market.coefficients(
            np.asarray(per_tok_alphas[tok], dtype=np.float64), mask))
        actives.append(market.structural if mask is None
                       else market.structural & ~mask)

    rows: List[SolveRow] = []
    for d in range(n_dec):
        tok = token_of[d]
        for a in grids[d]:
            rows.append(SolveRow(
                requests[d], a, coef_rows[tok][per_tok_seen[tok][a]],
                actives[tok], key=(tok, a)))
    flat, flat_stats = _solve_rows(market, rows, backend,
                                   coarsening=coarsening)

    out, st, pos = [], [], 0
    for d in range(n_dec):
        k = len(grids[d])
        out.append(flat[pos:pos + k])
        st.append(flat_stats[pos:pos + k])
        pos += k
    return (out, st) if return_stats else out


# ---------------------------------------------------------------------------
# Reference backends
# ---------------------------------------------------------------------------

def solve_ilp_reference(items: Sequence[CandidateItem], req_pods: int,
                        alpha: float, return_stats: bool = False,
                        ) -> Optional[List[int]] | Tuple[Optional[List[int]],
                                                         IlpStats]:
    """The seed history-matrix solver, retained verbatim as the baseline for
    cross-validation tests and ``benchmarks/bench_solver.py``.  Peak memory
    is O(bundles × residual): the ``history`` matrix below is exactly what
    the production engine eliminates."""
    n = len(items)
    counts = [0] * n
    if n == 0:
        result = None if req_pods > 0 else counts
        return (result, IlpStats(0, 0, req_pods, _INF)) if return_stats else result

    coef = objective_coefficients(items, alpha)
    pods = np.array([it.pods for it in items], dtype=np.int64)
    bound = np.array([it.t3 for it in items], dtype=np.int64)

    neg = (coef < 0) & (bound > 0)
    covered = 0
    for i in np.nonzero(neg)[0]:
        counts[i] = int(bound[i])
        covered += int(pods[i] * bound[i])

    residual = max(0, req_pods - covered)
    objective = float(np.sum(coef[neg] * bound[neg]))

    if residual == 0:
        stats = IlpStats(n, 0, 0, objective)
        return (counts, stats) if return_stats else counts

    idx = [i for i in range(n)
           if not neg[i] and bound[i] > 0 and pods[i] > 0]
    if int(np.sum(pods[idx] * bound[idx])) < residual:
        return (None, IlpStats(n, 0, residual, _INF)) if return_stats else None

    bundles: List[Tuple[int, int, float, int]] = []   # (item, pods, cost, copies)
    for i in idx:
        for copies in _binary_bundles(int(bound[i])):
            bundles.append((i, int(pods[i] * copies),
                            float(coef[i] * copies), copies))

    R = residual
    dp = np.full(R + 1, _INF)
    dp[0] = 0.0
    history = np.empty((len(bundles) + 1, R + 1))
    history[0] = dp
    for b, (_, pb, cb, _) in enumerate(bundles):
        shifted = np.empty(R + 1)
        cut = min(pb, R + 1)
        shifted[:cut] = dp[0]
        if cut <= R:
            shifted[cut:] = dp[: R + 1 - pb]
        dp = np.minimum(dp, shifted + cb)
        history[b + 1] = dp

    if not np.isfinite(dp[R]):
        return (None, IlpStats(n, len(bundles), residual, _INF)) if return_stats else None

    j = R
    for b in range(len(bundles) - 1, -1, -1):
        if j == 0:
            break
        if history[b + 1][j] < history[b][j] - 1e-12:
            i, pb, _, copies = bundles[b]
            counts[i] += copies
            j = max(0, j - pb)
    objective += float(dp[R])

    stats = IlpStats(n, len(bundles), residual, objective)
    return (counts, stats) if return_stats else counts


def solve_ilp_pulp(items: Sequence[CandidateItem], req_pods: int,
                   alpha: float) -> Optional[List[int]]:
    """Reference backend using PuLP/CBC (the paper's implementation, §4)."""
    import pulp

    coef = objective_coefficients(items, alpha)
    prob = pulp.LpProblem("kubepacs_node_selection", pulp.LpMinimize)
    xs = [pulp.LpVariable(f"x_{i}", lowBound=0, upBound=int(it.t3),
                          cat="Integer") for i, it in enumerate(items)]
    prob += pulp.lpSum(float(coef[i]) * xs[i] for i in range(len(items)))
    prob += pulp.lpSum(int(it.pods) * xs[i]
                       for i, it in enumerate(items)) >= int(req_pods)
    status = prob.solve(pulp.PULP_CBC_CMD(msg=False))
    if pulp.LpStatus[status] != "Optimal":
        return None
    return [int(round(x.value() or 0)) for x in xs]
