"""Spot-market data model, synthetic SpotLake-like catalog, and market simulator.

The paper consumes the SpotLake archive (spot price, on-demand price, CoreMark
benchmark score, single-node SPS, multi-node SPS/T3, interruption frequency) for
731 instance types across 4 AWS regions.  Offline we reproduce the *structure*
and the paper's qualitative marginals (Fig. 1, Fig. 2, Fig. 9):

  * on-demand price correlates with hardware spec; spot price is decoupled,
  * newer generations deliver higher benchmark scores at similar spot prices,
  * network-/disk-specialized variants raise on-demand price, not CoreMark,
  * T3 (multi-node SPS capacity) shrinks with instance size and fluctuates,
  * single-node SPS is a poor predictor of multi-node fulfillment.

Everything here is plain Python/numpy: the control plane deliberately stays off
the JAX device path (the paper runs inside the Karpenter controller at <194 MB /
1.55% CPU).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Offerings
# ---------------------------------------------------------------------------

REGIONS = ("us-east-1", "us-west-2", "eu-west-1", "ap-northeast-1")
AZS_PER_REGION = 3

#: family letter -> (GiB memory per vCPU, on-demand $ per vCPU-hour at gen 6)
FAMILY_SPECS = {
    "m": (4.0, 0.0480),   # general purpose
    "c": (2.0, 0.0425),   # compute optimized
    "r": (8.0, 0.0630),   # memory optimized
}

#: specialization suffix -> (on-demand price multiplier, kind)
SPECIALIZATIONS = {
    "": (1.00, "general"),
    "n": (1.35, "network"),
    "d": (1.25, "disk"),
    "dn": (1.55, "network+disk"),
}

#: vendor suffix -> (per-core CoreMark multiplier, price multiplier)
VENDORS = {"i": (1.00, 1.00), "a": (0.97, 0.90), "g": (0.90, 0.80)}

GENERATIONS = (5, 6, 7, 8)
#: instance size name -> vCPU count
SIZES = {
    "large": 2, "xlarge": 4, "2xlarge": 8, "4xlarge": 16,
    "8xlarge": 32, "12xlarge": 48, "16xlarge": 64, "24xlarge": 96,
}

GEN6_CORE_SCORE = 23_000.0       # per-core CoreMark anchor (gen 6 intel)
GEN_SCORE_STEP = 0.09            # +9% per generation
GEN_PRICE_STEP = 0.045           # +4.5% od price per generation


@dataclasses.dataclass(frozen=True)
class Offering:
    """One instance type in one availability zone (the ILP's ``I_i``)."""

    offering_id: str             # e.g. "c7in.4xlarge@us-east-1a"
    instance_type: str           # e.g. "c7in.4xlarge"
    family: str                  # "c"
    generation: int              # 7
    vendor: str                  # "i" | "a" | "g"
    specialization: str          # "general" | "network" | "disk" | "network+disk"
    size: str                    # "4xlarge"
    region: str
    az: str
    vcpus: int                   # CPU_i
    mem_gib: float               # Mem_i
    od_price: float              # OP_i   ($/hour)
    spot_price: float            # SP_i   ($/hour)
    bs_core: float               # BS_i   (single-core CoreMark, Table 1)
    sps_single: int              # single-node SPS in {1,2,3}
    t3: int                      # T3_i: max simultaneous nodes at SPS 3
    interruption_freq: int       # IF band in {0..4} (SpotVerse input)

    @property
    def base_instance_type(self) -> str:
        """The general-purpose sibling used as OP_base in Eq. 8."""
        return f"{self.family}{self.generation}{self.vendor}.{self.size}"


def _mk_offering(rng: np.random.Generator, family: str, gen: int, vendor: str,
                 spec_suffix: str, size: str, region: str, az: str,
                 od_base_per_vcpu: float) -> Offering:
    vcpus = SIZES[size]
    mem_per_vcpu, _ = FAMILY_SPECS[family]
    spec_mult, spec_kind = SPECIALIZATIONS[spec_suffix]
    vendor_score, vendor_price = VENDORS[vendor]

    od = (od_base_per_vcpu * vcpus * spec_mult * vendor_price
          * (1.0 + GEN_PRICE_STEP * (gen - 6)))
    # Spot discount decoupled from performance (Fig. 1), with the real
    # market's structure: small sizes are contested (shallow discounts),
    # large unpopular sizes carry deep discounts, and specialized variants'
    # spot prices do NOT carry the full on-demand premium (Fig. 1b/1c —
    # lower spot demand for n/d/dn hardware) — which is what makes the
    # Eq. 8 boost decisive under a matching workload intent.
    size_frac = math.log2(vcpus / 2.0) / math.log2(48.0)     # 0 (large) .. 1 (24xl)
    discount = float(np.clip(rng.beta(5.0, 2.5) * (0.68 + 0.42 * size_frac),
                             0.25, 0.93))
    # specialized variants' spot carries only part of the od premium
    # (lower spot demand for n/d/dn hardware): divide by a slack factor so
    # the spot premium (e.g. 1.29x for "n") sits below the od premium
    # (1.35x) that Eq. 8 credits back under a matching intent.
    spec_slack = 1.0 + 0.40 * (spec_mult - 1.0)
    spot = od * (1.0 - discount) / spec_slack

    # CoreMark per core: generation/vendor driven, *not* specialization driven
    # (Fig. 1b/1c: specialized hardware raises price, not compute score).
    bs_core = (GEN6_CORE_SCORE * vendor_score
               * (1.0 + GEN_SCORE_STEP * (gen - 6))
               * float(rng.normal(1.0, 0.015)))

    # Multi-node capacity: larger instances have lower availability [39];
    # newer generations are scarcer on the spot market.
    t3_mean = 42.0 / math.sqrt(vcpus / 2.0) * (1.0 - 0.08 * (gen - 5))
    t3 = int(np.clip(rng.poisson(max(t3_mean, 0.5)), 0, 50))
    # Single-node SPS is often high even when multi-node capacity is thin
    # (Fig. 2's trap): draw it nearly independently.
    sps_single = int(rng.choice([1, 2, 3], p=[0.15, 0.25, 0.60]))
    if t3 >= 25:
        sps_single = 3
    interruption_freq = int(np.clip(4 - t3 // 10 + rng.integers(-1, 2), 0, 4))

    itype = f"{family}{gen}{vendor}{spec_suffix}.{size}"
    return Offering(
        offering_id=f"{itype}@{az}",
        instance_type=itype,
        family=family,
        generation=gen,
        vendor=vendor,
        specialization=spec_kind,
        size=size,
        region=region,
        az=az,
        vcpus=vcpus,
        mem_gib=mem_per_vcpu * vcpus,
        od_price=round(od, 4),
        spot_price=round(max(spot, 0.001), 4),
        bs_core=round(bs_core, 1),
        sps_single=sps_single,
        t3=t3,
        interruption_freq=interruption_freq,
    )


def generate_catalog(seed: int = 0,
                     regions: Sequence[str] = REGIONS,
                     families: Sequence[str] = ("m", "c", "r"),
                     generations: Sequence[int] = GENERATIONS,
                     sizes: Optional[Sequence[str]] = None,
                     max_offerings: Optional[int] = None) -> List[Offering]:
    """Build a seeded synthetic catalog mirroring the SpotLake archive shape.

    Default scope: 3 families x 4 gens x {i,a,g} vendors x 4 specializations
    x 8 sizes x 4 regions x 3 AZs; graviton has no specialized variants and
    gen-5 has no "dn", matching AWS's real sparsity -> ~700+ instance types.
    """
    rng = np.random.default_rng(seed)
    sizes = tuple(sizes or SIZES.keys())
    out: List[Offering] = []
    for region in regions:
        for family in families:
            _, od_vcpu = FAMILY_SPECS[family]
            for gen in generations:
                for vendor in VENDORS:
                    specs = [""] if vendor == "g" else (
                        ["", "n", "d"] if gen == 5 else ["", "n", "d", "dn"])
                    for spec_suffix in specs:
                        for size in sizes:
                            for az_i in range(AZS_PER_REGION):
                                az = f"{region}{chr(ord('a') + az_i)}"
                                out.append(_mk_offering(
                                    rng, family, gen, vendor, spec_suffix,
                                    size, region, az, od_vcpu))
    if max_offerings is not None and len(out) > max_offerings:
        idx = rng.choice(len(out), size=max_offerings, replace=False)
        out = [out[i] for i in sorted(idx)]
    return out


def restrict(catalog: Iterable[Offering], *,
             instance_types: Optional[Sequence[str]] = None,
             regions: Optional[Sequence[str]] = None,
             families: Optional[Sequence[str]] = None) -> List[Offering]:
    """User-preference candidate filtering (Section 3: category / region)."""
    out = []
    for o in catalog:
        if instance_types is not None and o.instance_type not in instance_types:
            continue
        if regions is not None and o.region not in regions:
            continue
        if families is not None and o.family not in families:
            continue
        out.append(o)
    return out


# ---------------------------------------------------------------------------
# Interrupt events + market simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InterruptEvent:
    """A spot interruption notice (the 2-minute warning) for ``count`` nodes."""

    time: float                  # simulator hours
    offering_id: str
    count: int
    reason: str = "capacity-reclaim"


def snapshot_with(catalog: Sequence[Offering], spot: np.ndarray,
                  t3: np.ndarray) -> List[Offering]:
    """Materialize a market snapshot: the static catalog with live SP_i/T3_i.

    Shared by :meth:`SpotMarketSimulator.snapshot` and the scenario engine's
    replay path (``repro.sim``), which reconstructs snapshots from recorded
    ``market_state`` trace records instead of a live simulator.
    """
    return [dataclasses.replace(o, spot_price=float(spot[i]), t3=int(t3[i]))
            for i, o in enumerate(catalog)]


def pressure_interrupt_probability(count: int, t3: float,
                                   interruption_freq: int,
                                   hours: float) -> float:
    """Per-request interrupt probability of the pressure/IF model.

    Rises as the allocation approaches/exceeds the pool's live T3 capacity
    and with the SpotLake IF band.  Shared by the simulator's built-in
    sampler and ``repro.sim.interrupts.PressureInterruptModel`` (which runs
    the same law on its own RNG stream so scenario traces replay without
    touching the market's price RNG).
    """
    pressure = count / max(t3, 0.5)
    p = float(np.clip(0.01 + 0.10 * max(0.0, pressure - 0.8)
                      + 0.015 * interruption_freq, 0.0, 0.9))
    return 1.0 - (1.0 - p) ** hours


def pressure_interrupt_probability_batch(counts: np.ndarray, t3: np.ndarray,
                                         interruption_freq: np.ndarray,
                                         hours: float) -> np.ndarray:
    """Vectorized :func:`pressure_interrupt_probability` over any shape.

    Elementwise bitwise-identical to the scalar law (same IEEE-754 ops in
    the same order), so the batched samplers in ``repro.sim.interrupts``
    and the fleet engine (``repro.sim.fleet``) draw from probabilities that
    exactly match the per-node scalar path — the byte-identical-trace
    contract survives the vectorization (DESIGN.md §11).
    """
    counts = np.asarray(counts, dtype=np.float64)
    pressure = counts / np.maximum(np.asarray(t3, dtype=np.float64), 0.5)
    p = np.clip(0.01 + 0.10 * np.maximum(0.0, pressure - 0.8)
                + 0.015 * np.asarray(interruption_freq, dtype=np.float64),
                0.0, 0.9)
    return 1.0 - (1.0 - p) ** hours


class SpotMarketSimulator:
    """Time-stepped market: OU spot prices, drifting T3, interruptions.

    The simulator is the offline stand-in for AWS: the provisioner only ever
    sees `snapshot()` (a list of Offerings) and the event stream, exactly the
    interface the paper's Karpenter fork has against EC2.
    """

    def __init__(self, catalog: Sequence[Offering], seed: int = 0,
                 price_vol: float = 0.06, t3_vol: float = 1.6):
        self._rng = np.random.default_rng(seed)
        self._base = list(catalog)
        self._spot = np.array([o.spot_price for o in catalog])
        self._anchor = self._spot.copy()
        self._t3 = np.array([o.t3 for o in catalog], dtype=np.int64)
        self._od = np.array([o.od_price for o in catalog])
        self._price_vol = price_vol
        self._t3_vol = t3_vol
        self.time = 0.0
        self._index = {o.offering_id: i for i, o in enumerate(catalog)}

    # -- market state ------------------------------------------------------
    @property
    def catalog(self) -> List[Offering]:
        """The static offering universe this market evolves (t=0 prices)."""
        return list(self._base)

    def snapshot(self) -> List[Offering]:
        return snapshot_with(self._base, self._spot, self._t3)

    def state_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """Copies of the live (spot, t3) vectors — the scenario engine's
        trace hook: these two arrays fully determine ``snapshot()``."""
        return self._spot.copy(), self._t3.copy()

    def step(self, hours: float = 1.0) -> None:
        """Advance market state (mean-reverting prices, random-walk T3)."""
        n = len(self._base)
        z = self._rng.normal(0.0, 1.0, size=n)
        self._spot += (0.15 * (self._anchor - self._spot) * hours
                       + self._price_vol * self._anchor * z * math.sqrt(hours))
        self._spot = np.clip(self._spot, 0.03 * self._od, 1.0 * self._od)
        dt3 = self._rng.normal(0.0, self._t3_vol * math.sqrt(hours), size=n)
        self._t3 = np.clip(self._t3 + np.round(dt3).astype(np.int64), 0, 50)
        self.time += hours

    def apply_shock(self, selector: str = "", price_factor: float = 1.0,
                    t3_factor: float = 1.0) -> int:
        """Scale spot prices / T3 capacity of matching offerings (RNG-free).

        ``selector`` is a substring match on ``offering_id`` ("" = whole
        market).  This is the scenario engine's deterministic shock hook
        (supply crunches, price spikes, an AZ losing capacity); the OU
        mean-reversion of :meth:`step` then pulls prices back toward anchor.
        Returns the number of offerings affected.
        """
        mask = np.array([selector in o.offering_id for o in self._base],
                        dtype=bool)
        if price_factor != 1.0:
            self._spot[mask] = np.clip(self._spot[mask] * price_factor,
                                       0.03 * self._od[mask],
                                       1.0 * self._od[mask])
        if t3_factor != 1.0:
            self._t3[mask] = np.clip(
                np.round(self._t3[mask] * t3_factor).astype(np.int64), 0, 50)
        return int(mask.sum())

    # -- provisioning-side interactions -------------------------------------
    def fulfill(self, offering_id: str, count: int,
                multi_node_aware: bool = True) -> int:
        """How many of ``count`` requested nodes actually launch (Fig. 2/9).

        Fulfillment tracks the *multi-node* capacity (T3).  A request sized
        from single-node SPS alone routinely lands on thin pools and gets
        only a few nodes -- the paper's Fig. 2 failure mode.
        """
        i = self._index[offering_id]
        capacity = int(self._t3[i] + max(0.0, self._rng.normal(2.0, 2.0)))
        del multi_node_aware  # the market doesn't care how you chose
        return int(min(count, capacity))

    def interrupts_for_pool(self, pool: Dict[str, int],
                            hours: float = 1.0) -> List[InterruptEvent]:
        """Sample interruption notices for an allocated pool over ``hours``.

        Per-node hourly interrupt probability rises as the allocation
        approaches/exceeds the pool's live T3 capacity and with the IF band.
        """
        events: List[InterruptEvent] = []
        for offering_id, count in pool.items():
            if count <= 0 or offering_id not in self._index:
                continue
            i = self._index[offering_id]
            o = self._base[i]
            p = pressure_interrupt_probability(count, float(self._t3[i]),
                                               o.interruption_freq, hours)
            lost = int(self._rng.binomial(count, p))
            if lost > 0:
                events.append(InterruptEvent(
                    time=self.time, offering_id=offering_id, count=lost))
        return events
