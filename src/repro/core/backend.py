"""Pluggable solver backends for the min-plus cover DP (DESIGN.md §12).

The ILP engine reduces every solve — single-α, a GSS prescan grid, or the
cross-decision batches of ``solve_ilp_many`` — to one primitive: a forward
min-plus value pass over a bundle sequence that also emits *improvement
bits*, the per-(bundle, coverage) booleans the exact backtracker consumes.
This module defines that primitive once, with two interchangeable
implementations:

* :class:`NumpyBackend` — the host path: a Python loop over bundles with
  in-place vectorized row updates.  Always available; the reference for
  the bit-identical-selection contract.
* :class:`JaxBackend` — the accelerator path: the same recurrence as a
  ``jax.lax.scan`` under ``jit``, batched over stacked solve groups with
  bucketed padding so recompilation is bounded.  Optionally (``pallas``
  flag) the inner relaxation step runs as a Pallas kernel — interpreted
  on CPU, lowerable on TPU/GPU — for the jax_pallas north star.
* :class:`FusedJaxBackend` (``jax:fused`` / ``jax:fused:pallas``) — the
  device-resident decision plane (DESIGN.md §13): whole GSS batches run
  as two jitted programs (prescan grid + golden ``lax.while_loop``) with
  the cover DP, backtrack, and pool scoring fused on device, market
  arrays uploaded once per content digest, and a host replay that keeps
  selections bit-identical to NumPy by construction.

Canonical kernel semantics (both backends, float64):

    dp[0] = 0, dp[j>0] = +inf
    for b in 0..B-1:                       # bundle order is significant
        cand[j] = dp[max(j - pods[b], 0)] + cost[b]      (j >= 1)
        bits[b, j] = cand[j] < dp[j]                     (bits[b, 0] = False)
        dp[j]    = min(dp[j], cand[j])                   (dp[0] pinned at 0)

(The strict ``<`` needs no epsilon: dp values are exact subset-cost sums,
so a strict improvement at (b, j) means every optimal solution of the
bundle prefix uses b — the backtracker's take-rule — and equality means
skipping b is optimal.  The seed solver's 1e-12 guard band protected a
history matrix recomputed along a different float path; here bits and dp
come from the same pass.)

Every arithmetic step is an elementwise float64 op executed in the same
order by both implementations, so the resulting ``dp``/``bits`` are
bit-identical — which is what makes backend choice invisible to selections
(the backtracker's tie-breaking reads only ``bits``).  The ``j``-prefix of
``dp``/``bits`` does not depend on the padded target length, so solve
groups that share (costs, kept bundles) can share one padded row.

JAX is an *optional* dependency of this path: importing this module never
imports ``jax``.  Requesting the jax backend without jax installed warns
once and falls back to :class:`NumpyBackend`
(``KUBEPACS_SOLVER_BACKEND=numpy|jax|jax:pallas|jax:fused|jax:fused:pallas``
overrides the default).
"""

from __future__ import annotations

import collections
import dataclasses
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import events_log

#: one (bpods, costs, target) residual covering problem; ``bpods`` int64
#: (all >= 1), ``costs`` float64 (may contain +inf), ``target`` >= 1
CoverGroup = Tuple[np.ndarray, np.ndarray, int]


@dataclasses.dataclass(frozen=True)
class CoarseningConfig:
    """Demand-coarsening policy for the residual cover DP (DESIGN.md §14).

    The engine solves residuals at or below ``threshold`` exactly — the
    default keeps every paper-scale scenario (≤ 5 k pods) byte-identical to
    the uncoarsened engine.  Above it:

    * **gcd mode** (provably exact, bit-identical selections): when the
      market's structural pod counts share a gcd ``g > 1`` and
      ``ceil(residual / g) <= max_rows``, the DP runs at granularity ``g``
      — same keep set (pruning stays unscaled), same improvement bits,
      same backtrack, 1/g of the rows.
    * **approx mode** (bounded suboptimality): otherwise, when
      ``allow_approx``, a greedy rate-order prefix of whole bundles is
      committed until at most ``approx_rows`` pods of demand remain, and
      an *exact* cover DP over the remaining bundles closes that boundary
      window — so the DP cost is that of an ``approx_rows``-pod residual
      regardless of demand.  The only loss is committing whole prefix
      bundles where the fractional optimum would split one, and the
      returned objective carries an a-posteriori certificate
      ``gap_bound = objective - LP(residual)`` (LP = the fractional-greedy
      lower bound, so the true optimality gap is ≤ ``gap_bound``); if the
      certificate exceeds ``rel_gap·|LP|`` the row is silently re-solved
      exactly (``coarse == "approx_fallback"`` in
      :class:`~repro.core.ilp.IlpStats`).

    Lives in :mod:`repro.core.backend` (not ``ilp``) because the fused
    device programs replicate the same per-row mode decision from traced
    ``(threshold, max_rows, gcd)`` scalars; importing from ``ilp`` would
    create a cycle.  Frozen + hashable so configs can key solve-batch
    groups.
    """

    enabled: bool = True
    threshold: int = 8192
    max_rows: int = 4096
    approx_rows: int = 4096
    allow_approx: bool = True
    rel_gap: float = 0.05


#: process-wide default: coarsening on, but inert below 8192 residual pods,
#: so every existing scale solves byte-identically to the exact engine
DEFAULT_COARSENING = CoarseningConfig()

#: core-DP upper-bound tuning shared by the host engine (`repro.core.ilp`)
#: and the fused device program, which must replicate the host's prune
#: decisions exactly: the core DP runs over the best-rate
#: ``max(k_greedy + _CORE_PAD, _CORE_MIN)`` bundles and only triggers when
#: the greedy bound leaves more than ``_CORE_TRIGGER`` bundles alive.
_CORE_PAD = 33
_CORE_MIN = 96
_CORE_TRIGGER = 160


class SolverBackend:
    """Interface: batched cover-DP value passes with improvement bits."""

    name = "abstract"

    #: engine hint: decode in slices of at most this many DP groups so the
    #: bits arrays of one slice die before the next is computed (the host
    #: path is cache/allocator-sensitive; accelerator backends want the
    #: whole stack in one dispatch and override with a large value)
    max_group_batch = 1 << 30

    def cover_bits(self, groups: Sequence[CoverGroup],
                   ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """For each group return ``(dp, bits)`` — ``dp`` float64 of shape
        ``(target+1,)`` and ``bits`` bool of shape ``(B, target+1)`` — per
        the canonical kernel above.  Implementations may stack groups into
        one padded dispatch; returned arrays are trimmed numpy arrays."""
        raise NotImplementedError

    def cover_values(self, groups: Sequence[CoverGroup]) -> List[np.ndarray]:
        """Value-only variant: just each group's final ``dp`` vector (used
        for the engine's core upper bounds, where bits are never read)."""
        return [dp for dp, _bits in self.cover_bits(groups)]


class NumpyBackend(SolverBackend):
    """Host reference implementation (ragged — no padding waste).

    Runs each group's forward pass with preallocated scratch rows (the
    pass is memory-bandwidth-bound; allocator churn is the only other
    cost worth removing) and skips +inf bundles outright — an inert
    bundle's candidates never beat the running ``dp``, so skipping is
    exact.
    """

    name = "numpy"
    max_group_batch = 8      # keep the live bits working set cache-sized

    def cover_bits(self, groups):
        scratch = np.empty(max((g[2] for g in groups), default=0) + 1)
        return [self._one(bpods, costs, target, scratch)
                for bpods, costs, target in groups]

    def cover_values(self, groups):
        scratch = np.empty(max((g[2] for g in groups), default=0) + 1)
        return [self._values(bpods, costs, target, scratch)
                for bpods, costs, target in groups]

    @staticmethod
    def _values(bpods: np.ndarray, costs: np.ndarray, target: int,
                scratch: Optional[np.ndarray] = None) -> np.ndarray:
        if scratch is None:
            scratch = np.empty(target + 1)
        dp = np.full(target + 1, np.inf)
        dp[0] = 0.0
        for b in range(len(bpods)):
            cb = costs[b]
            if not np.isfinite(cb):
                continue
            pb = int(bpods[b])
            if pb <= target:
                k = target + 1 - pb
                cand = np.add(dp[:k], cb, out=scratch[:k])
                np.minimum(dp[pb:], cand, out=dp[pb:])
                if pb > 1:
                    np.minimum(dp[1:pb], cb, out=dp[1:pb])
            else:
                np.minimum(dp[1:], cb, out=dp[1:])
        return dp

    @staticmethod
    def _one(bpods: np.ndarray, costs: np.ndarray, target: int,
             scratch: Optional[np.ndarray] = None,
             ) -> Tuple[np.ndarray, np.ndarray]:
        B = len(bpods)
        if scratch is None:
            scratch = np.empty(target + 1)
        dp = np.full(target + 1, np.inf)
        dp[0] = 0.0
        # every finite bundle's row is fully written below (j >= 1) and the
        # j = 0 column is blanked at the end, so empty beats zeros here
        bits = np.empty((B, target + 1), dtype=bool)
        for b in range(B):
            cb = costs[b]
            if not np.isfinite(cb):
                bits[b] = False   # cand = x + inf never beats dp
                continue
            pb = int(bpods[b])
            if pb <= target:
                # j in [pb, target]: cand = dp[j - pb] + cb (pre-update dp;
                # the scratch row materializes before the in-place writes)
                k = target + 1 - pb
                cand = np.add(dp[:k], cb, out=scratch[:k])
                np.less(cand, dp[pb:], out=bits[b, pb:])
                np.minimum(dp[pb:], cand, out=dp[pb:])
                if pb > 1:        # j in [1, pb-1]: cand = dp[0] + cb = cb
                    np.less(cb, dp[1:pb], out=bits[b, 1:pb])
                    np.minimum(dp[1:pb], cb, out=dp[1:pb])
            else:                 # pb > target: cand = cb for every j >= 1
                np.less(cb, dp[1:], out=bits[b, 1:])
                np.minimum(dp[1:], cb, out=dp[1:])
        bits[:, 0] = False
        return dp, bits


def _bucket(n: int, steps: Sequence[int]) -> int:
    """Round ``n`` up to the smallest bucket (bounds jit recompilation)."""
    for s in steps:
        if n <= s:
            return s
    step = steps[-1]
    return ((n + step - 1) // step) * step


def _ensure_x64(jax) -> None:
    """Backend-init x64 check: the float64 kernel contract (module
    docstring) requires ``jax_enable_x64``.  Enabling it is *process-wide*
    — a global-config mutation co-resident JAX code in the embedding
    application may not expect (float32 default semantics change, programs
    compiled before the flip retrace) — so the flip is announced with a
    one-time ``RuntimeWarning`` (counted in ``repro.core.events_log``),
    and ``KUBEPACS_JAX_X64=0`` forbids it outright: the embedder must then
    enable x64 itself before constructing a jax backend, and construction
    fails loudly rather than silently running the solver outside its
    float64 contract."""
    if jax.config.jax_enable_x64:
        return
    if os.environ.get("KUBEPACS_JAX_X64", "1").lower() in ("0", "false",
                                                           "no"):
        raise RuntimeError(
            "KubePACS jax backends require jax_enable_x64, and "
            "KUBEPACS_JAX_X64=0 forbids enabling it process-wide; run "
            "jax.config.update('jax_enable_x64', True) in the embedding "
            "application before constructing a jax backend")
    events_log.warn_once(
        "backend_x64_flip",
        "KubePACS jax backend is enabling jax_enable_x64 process-wide "
        "(the solver's float64 bit-identity contract); set "
        "KUBEPACS_JAX_X64=0 to forbid this and manage the flag in the "
        "embedding application instead", RuntimeWarning, stacklevel=3)
    jax.config.update("jax_enable_x64", True)


class JaxBackend(SolverBackend):
    """``jax.lax.scan`` cover-DP, jitted, batched over padded groups.

    Groups are stacked into one ``(G, B_pad, R_pad)`` dispatch per call;
    pad bundles carry ``pods=1, cost=+inf`` (inert), pad target columns are
    never read back (the kernel's ``j``-prefix is padding-independent).
    ``G``/``B``/``R`` are bucketed so the jit cache stays small across the
    varying shapes of a simulation run.  All arithmetic runs in float64:
    constructing any jax backend enables x64 *process-wide* once (an
    idempotent ``jax.config.update`` at init, announced by a one-time
    ``RuntimeWarning``; ``KUBEPACS_JAX_X64=0`` forbids the mutation and
    makes the embedding application responsible for the flag — see
    :func:`_ensure_x64`).  The earlier per-dispatch ``enable_x64`` scoping
    flipped global trace state between callers, which forced jit re-traces
    of long-lived programs (the fused ``while_loop`` below most of all)
    whenever a non-x64 caller ran in between; a process-level init check
    costs nothing and keeps every compiled program valid for the life of
    the process.

    ``pallas=True`` swaps the inner relaxation step for a Pallas kernel
    (`repro.kernels` idiom); on CPU it runs in interpreter mode — a
    correctness/bring-up path, not a fast one — while TPU/GPU lower it.
    """

    name = "jax"

    #: bucket ladders: fine at small sizes, coarse (multiples of the last
    #: step) beyond, keeping padding waste and recompiles both bounded
    _G_STEPS = (1, 2, 4, 8, 16, 32, 64)
    _B_STEPS = (16, 32, 64, 128, 256, 512)
    _R_STEPS = (256, 512, 1024, 2048)

    def __init__(self, pallas: bool = False):
        import jax  # deferred: jax is optional for the solver path

        _ensure_x64(jax)
        self._jax = jax
        self._jnp = jax.numpy
        self.pallas = bool(pallas)
        if pallas:
            self.name = "jax:pallas"
        self._jit_cache: dict = {}

    # -- kernel construction -------------------------------------------------
    def _step_fn(self, interpret: bool):
        jnp = self._jnp
        if not self.pallas:
            def step(dp, xs):
                pb, cb = xs                                  # (G,), (G,)
                jidx = jnp.arange(dp.shape[1])
                idx = jnp.maximum(jidx[None, :] - pb[:, None], 0)
                cand = jnp.take_along_axis(dp, idx, axis=1) + cb[:, None]
                cand = cand.at[:, 0].set(jnp.inf)            # dp[0] pinned
                bit = cand < dp
                return jnp.minimum(dp, cand), bit
            return step

        from jax.experimental import pallas as pl

        def relax_kernel(dp_ref, pb_ref, cb_ref, out_ref, bit_ref):
            dp = dp_ref[...]                                 # (G, R+1)
            pb = pb_ref[...]                                 # (G, 1)
            cb = cb_ref[...]                                 # (G, 1)
            jidx = self._jax.lax.broadcasted_iota(
                jnp.int64, dp.shape, dimension=1)
            idx = jnp.maximum(jidx - pb, 0)
            cand = jnp.take_along_axis(dp, idx, axis=1) + cb
            cand = jnp.where(jidx == 0, jnp.inf, cand)
            bit_ref[...] = cand < dp
            out_ref[...] = jnp.minimum(dp, cand)

        def step(dp, xs):
            pb, cb = xs
            new_dp, bit = pl.pallas_call(
                relax_kernel,
                out_shape=(
                    self._jax.ShapeDtypeStruct(dp.shape, dp.dtype),
                    self._jax.ShapeDtypeStruct(dp.shape, jnp.bool_),
                ),
                interpret=interpret,
            )(dp, pb[:, None], cb[:, None].astype(dp.dtype))
            return new_dp, bit
        return step

    def _compiled(self, G: int, B: int, R: int, with_bits: bool = True):
        key = (G, B, R, with_bits)
        fn = self._jit_cache.get(key)
        if fn is None:
            jax, jnp = self._jax, self._jnp
            interpret = jax.default_backend() == "cpu"
            step = self._step_fn(interpret)

            def run(bpods, costs):                  # (G, B) int64 / float64
                dp0 = jnp.full((G, R + 1), jnp.inf,
                               dtype=jnp.float64).at[:, 0].set(0.0)
                if with_bits:
                    dp, bits = jax.lax.scan(step, dp0, (bpods.T, costs.T))
                    return dp, jnp.moveaxis(bits, 0, 1)      # (G, B, R+1)
                dp, _ = jax.lax.scan(
                    lambda d, xs: (step(d, xs)[0], None), dp0,
                    (bpods.T, costs.T))
                return dp

            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn

    # -- public API ----------------------------------------------------------
    def cover_bits(self, groups):
        return self._dispatch(groups, with_bits=True)

    def cover_values(self, groups):
        return self._dispatch(groups, with_bits=False)

    def _dispatch(self, groups, with_bits: bool):
        if not groups:
            return []
        # partition groups into (B, R) shape buckets so one outlier group
        # does not pad every other dispatch up to its size
        buckets: dict = {}
        for i, (bp, _bc, t) in enumerate(groups):
            key = (_bucket(len(bp), self._B_STEPS),
                   _bucket(t, self._R_STEPS))
            buckets.setdefault(key, []).append(i)
        out: List = [None] * len(groups)
        for (B, R), idxs in buckets.items():
            G = _bucket(len(idxs), self._G_STEPS)
            bpods = np.ones((G, B), dtype=np.int64)
            costs = np.full((G, B), np.inf)
            for g, i in enumerate(idxs):
                bp, bc, _t = groups[i]
                bpods[g, :len(bp)] = bp
                costs[g, :len(bc)] = bc
            res = self._compiled(G, B, R, with_bits)(bpods, costs)
            if with_bits:
                dp = np.asarray(res[0])
                bits = np.asarray(res[1])
                for g, i in enumerate(idxs):
                    bp, _bc, t = groups[i]
                    out[i] = (dp[g, :t + 1], bits[g, :len(bp), :t + 1])
            else:
                dp = np.asarray(res)
                for g, i in enumerate(idxs):
                    out[i] = dp[g, :groups[i][2] + 1]
        return out


# ---------------------------------------------------------------------------
# Fused device-resident decision plane (DESIGN.md §13)
# ---------------------------------------------------------------------------

#: golden ratio shrink factor — the same expression as ``repro.core.gss.PHI``
#: (both evaluate ``(sqrt(5)-1)/2`` in float64, so the constants are
#: bit-identical; gss cannot import it from here without a cycle)
_PHI = (math.sqrt(5.0) - 1.0) / 2.0

_MISS = object()      # lookup sentinel (stored values include None)


def _rc_tiers(RC: int) -> List[int]:
    """Geometric DP-width ladder ``129, 257, 513, …, RC``.

    The cover DP is prefix-closed in the pod index ``j``: every value the
    solver reads for a row with residual ``r`` lives in ``dp[: r + 1]``,
    so running the recurrence at any width ``W > r`` yields bitwise the
    same prefix.  Routing each row to the narrowest tier wider than its
    residual mirrors the host solver's residual-sized dp rows instead of
    paying the full ``RC``-wide vector ops for every probe.  x4 rungs:
    golden probes cluster near the winning alpha, whose residual sits in
    the top tier anyway, so finer rungs were measured compile-time-only.
    """
    tiers: List[int] = []
    w = 129
    while w < RC:
        tiers.append(w)
        w = (w - 1) * 4 + 1
    tiers.append(RC)
    return tiers

#: device-market array order (one tuple per cache entry, jit-stable)
_MD_FIELDS = ("pods", "bound", "perf", "price", "structural", "real",
              "b_item", "b_pods", "b_podsf", "b_copies", "b_copiesf",
              "b_struct")


class FusedJaxBackend(JaxBackend):
    """Fully device-resident decision plane (``make_backend("jax:fused")``).

    Instead of dispatching one cover-DP per golden-section probe (the
    per-round host↔device round-trips that made PR 5's jax path lose to
    NumPy), this backend runs the *entire* bracketed GSS on device as two
    jitted programs:

    * **prescan** — every (decision, grid-α) objective row solved in one
      program: saturation analysis, LP-bound bundle pruning, core-DP bound
      tightening, the improvement-bit cover DP, and the bit backtrack are
      all on-device stages under one ``jit``.
    * **golden** — a single ``lax.while_loop`` over golden rounds advancing
      all decisions in lockstep: per round one fused solve of each active
      decision's probe α plus on-device pool scoring (the ``e_total``
      formula) to steer the bracket update — no host round-trips between
      probes.

    **Bit-identical-by-construction contract.**  The device never *decides*
    anything the host cannot check: every probe's (α, counts) pair is
    recorded on device and read back once, and the host replay
    (:class:`_FusedGssRecord` driven by ``bracketed_gss_many``) re-runs the
    sequential control flow with exact host floats, consuming recorded
    counts via exact-bitwise α lookup.  Recorded counts are bitwise equal to
    the host engine's because every arithmetic step of the device row
    solver mirrors ``repro.core.ilp._solve_rows`` op-for-op (same float64
    elementwise ops in the same order — sequential-scan cumsums, stable
    argsorts, identical prune thresholds), with one hazard actively
    defused: XLA:CPU's LLVM backend contracts ``a*b`` feeding ``c+...``
    into an FMA inside fused loops, which rounds once where NumPy rounds
    twice.  Every value-critical product therefore goes through ``rmul`` —
    round, then bitcast to int64 and XOR with a runtime-zero argument —
    which is opaque to constant folding and instruction combining, so the
    product reaches the add pre-rounded exactly like the host's.  A startup
    self-check verifies this on the live XLA build and disables the fused
    path (falling back to per-round dispatch) if it fails.  If device
    control ever diverges from host control (speculation scores disagree
    with exact scores on a bracket comparison), the host replay simply
    misses a lookup and solves that α on the NumPy backend — a counted
    performance event (``fallback_solves``), never a correctness one.

    **Device residency.**  ``CompiledMarket`` arrays are uploaded once and
    cached on device keyed by ``(market.digest, N_pad, B_pad)`` (LRU,
    ``device_cache_info()`` exposes hit/miss counters), so FleetSim ticks
    re-dispatch onto resident arrays; per-item state (masks, demands,
    brackets) is the only per-tick upload.

    ``pallas=True`` (spec ``"jax:fused:pallas"``) swaps the scan cover-DP
    stage for a Pallas kernel — grid over bundle blocks, BlockSpec-tiled
    value rows, improvement bits emitted in-kernel — plus a Pallas scoring
    kernel; on CPU both run in interpreter mode (a bring-up path), off-CPU
    they lower (f64 Pallas does not lower on TPU).  With the default
    ``"jax:fused"`` spec, Pallas is *requested* automatically off-CPU and
    the ``lax.scan``/``while_loop`` path is the CPU fallback inside the
    same fused program — but every Pallas request (auto or forced) is
    gated on :meth:`_pallas_ok`, a one-time bitwise probe of the cover
    kernel against the NumPy reference on the live lowering.  The cover
    kernel's revisited-accumulator idiom requires *sequential* grid
    execution, which interpret mode and TPU guarantee but the GPU (Triton)
    lowering does not — there grid programs run concurrently and the
    loop-carried dp row races — so a lowering that cannot reproduce the
    host bitwise keeps the scan path instead of silently corrupting
    selections.
    """

    name = "jax:fused"
    supports_fused_gss = True

    #: fused-program bucket ladders.  R is deliberately finer than the base
    #: backend's (512-multiples beyond 512): every vector op in the fused
    #: row solver is O(R_pad), so 2048-jump padding would tax each row far
    #: more than the extra recompiles cost.
    _N_STEPS = (16, 32, 64, 128, 256, 512, 1024)
    _BF_STEPS = (32, 64, 128, 192, 256, 384, 512, 640, 768, 896, 1024,
                 1152, 1280, 1536, 2048)
    _RF_STEPS = (128, 256, 512)
    _D_STEPS = (1, 2, 4, 8, 16, 32, 64)
    _MAX_MARKETS = 8

    def __init__(self, pallas: bool = False):
        super().__init__(pallas=False)   # base scan path stays the fallback
        self.fused_pallas = bool(pallas)
        if pallas:
            self.name = "jax:fused:pallas"
        self._market_cache: "collections.OrderedDict" = \
            collections.OrderedDict()
        self._fused_cache: dict = {}
        self._host_fallback = NumpyBackend()
        self.device_cache_hits = 0
        self.device_cache_misses = 0
        self.fallback_solves = 0
        self.fused_records = 0
        self.program_builds = 0
        self.verify_solves = 0
        self._selfcheck_ok: Optional[bool] = None
        self._pallas_checked: Optional[bool] = None

    # -- device market cache -------------------------------------------------
    def _device_market(self, market, N: int, B: int):
        """Upload-once market arrays, keyed on (content digest, pad shape)."""
        key = (market.digest, N, B)
        ent = self._market_cache.get(key)
        if ent is not None:
            self.device_cache_hits += 1
            self._market_cache.move_to_end(key)
            return ent
        self.device_cache_misses += 1
        jnp = self._jnp
        n, nb = market.n, market.n_bundles
        pods = np.zeros(N, np.int64)
        pods[:n] = market.pods
        bound = np.zeros(N, np.int64)
        bound[:n] = market.bound
        perf = np.zeros(N)
        perf[:n] = market.perf
        price = np.ones(N)
        price[:n] = market.price
        structural = np.zeros(N, bool)
        structural[:n] = market.structural
        real = np.zeros(N, bool)
        real[:n] = True
        b_item = np.zeros(B, np.int64)
        b_item[:nb] = market.b_item
        b_pods = np.ones(B, np.int64)
        b_pods[:nb] = market.b_pods
        b_copies = np.zeros(B, np.int64)
        b_copies[:nb] = market.b_copies
        b_struct = np.zeros(B, bool)
        b_struct[:nb] = True
        ent = tuple(jnp.asarray(a) for a in (
            pods, bound, perf, price, structural, real, b_item, b_pods,
            b_pods.astype(np.float64), b_copies,
            b_copies.astype(np.float64), b_struct))
        self._market_cache[key] = ent
        while len(self._market_cache) > self._MAX_MARKETS:
            self._market_cache.popitem(last=False)
        return ent

    def device_cache_info(self) -> Dict[str, int]:
        return {"hits": self.device_cache_hits,
                "misses": self.device_cache_misses,
                "entries": len(self._market_cache),
                "fallback_solves": self.fallback_solves,
                "verify_solves": self.verify_solves,
                "program_builds": self.program_builds}

    def _fused_flags(self) -> Tuple[bool, bool]:
        on_cpu = self._jax.default_backend() == "cpu"
        want_pallas = self.fused_pallas or not on_cpu
        return (want_pallas and self._pallas_ok(on_cpu)), on_cpu

    # -- Pallas cover-DP kernel (shared by the fused programs and the
    # kernel self-check) ------------------------------------------------------
    def _pallas_cover_fn(self, W: int, B: int, interpret: bool):
        """Build ``pallas_cover(pseq, cseq) -> (dp, bits)`` at tier width
        ``W`` over ``B`` padded bundles: grid over bundle blocks, the
        (1, W) dp value row revisited as the same output block every grid
        step (accumulator idiom), improvement bits emitted in-kernel into
        each block's (block_b, W) tile.  Masked bundles (cost +inf) are
        inert: cand = sh + inf never beats dp.

        The accumulator idiom makes grid steps *sequentially dependent* —
        correct wherever the grid executes in order (interpret mode, TPU)
        and racy under parallel-grid lowerings (GPU/Triton) — which is why
        every production use is gated on :meth:`_pallas_ok`'s bitwise
        probe of this very builder."""
        jax, jnp = self._jax, self._jnp
        lax = jax.lax
        f64 = jnp.float64
        from jax.experimental import pallas as pl

        block_b = min(B, 32)
        if B % block_b:
            raise ValueError(
                f"pallas cover kernel: bundle pad B={B} is not a multiple "
                f"of block_b={block_b} — grid=(B // block_b,) would "
                "silently drop the remainder bundles; every _BF_STEPS "
                "rung (and the beyond-ladder rounding step) must stay a "
                "multiple of 32")

        def _cover_kernel(pb_ref, cb_ref, dp_ref, bits_ref):
            @pl.when(pl.program_id(0) == 0)
            def _init():
                dp_ref[...] = jnp.full((1, W), jnp.inf,
                                       dtype=f64).at[0, 0].set(0.0)

            jcol = lax.broadcasted_iota(jnp.int32, (1, W), 1)

            def body(i, dp):
                pb = pb_ref[i]
                cb = cb_ref[i]
                pbc = jnp.clip(pb, 0, W).astype(jnp.int32)
                ext = jnp.concatenate(
                    [jnp.zeros((1, W), f64), dp], axis=1)
                sh = lax.dynamic_slice(
                    ext, (jnp.int32(0), W - pbc), (1, W))
                cand = jnp.where(jcol == 0, jnp.inf, sh + cb)
                bits_ref[i, :] = (cand < dp)[0]
                return jnp.minimum(dp, cand)

            dp_ref[...] = lax.fori_loop(0, block_b, body, dp_ref[...])

        def pallas_cover(pseq, cseq):
            dp, bits = pl.pallas_call(
                _cover_kernel,
                grid=(B // block_b,),
                in_specs=[
                    pl.BlockSpec((block_b,), lambda k: (k,)),
                    pl.BlockSpec((block_b,), lambda k: (k,)),
                ],
                out_specs=(
                    pl.BlockSpec((1, W), lambda k: (0, 0)),
                    pl.BlockSpec((block_b, W), lambda k: (k, 0)),
                ),
                out_shape=(
                    jax.ShapeDtypeStruct((1, W), f64),
                    jax.ShapeDtypeStruct((B, W), jnp.bool_),
                ),
                interpret=interpret,
            )(pseq, cseq)
            return dp[0], bits

        return pallas_cover

    def _pallas_ok(self, interpret: bool) -> bool:
        """One-time bitwise probe of the Pallas cover kernel on the live
        lowering.  The kernel assumes sequential grid execution (see
        :meth:`_pallas_cover_fn`); rather than hard-coding platform
        assumptions, solve a reference bundle sequence through the real
        kernel — same interpret flag as production — and require dp *and*
        bits bitwise equal to the NumPy reference.  Any mismatch (e.g. a
        parallel-grid GPU lowering racing the dp accumulator) or lowering
        failure keeps the fused programs on the ``lax.scan`` path: same
        selections, no Pallas."""
        if self._pallas_checked is None:
            try:
                self._pallas_checked = self._run_pallas_check(interpret)
            except Exception as exc:  # pragma: no cover - lowering-specific
                events_log.warn_once(
                    "backend_pallas_disabled",
                    "pallas cover-DP kernel disabled (self-check raised "
                    f"{exc!r}); fused programs use the lax.scan path",
                    RuntimeWarning)
                self._pallas_checked = False
        return self._pallas_checked

    def _run_pallas_check(self, interpret: bool) -> bool:
        W, B = 129, 256     # 8 grid blocks: a parallel lowering must race
        cover = self._jax.jit(self._pallas_cover_fn(W, B, interpret))
        rng = np.random.default_rng(17)
        pods = rng.integers(1, 200, size=B)     # > W rows hit the clip path
        costs = rng.uniform(0.01, 3.0, size=B)
        costs[rng.random(B) < 0.25] = np.inf
        dp_d, bits_d = cover(pods.astype(np.int64), costs)
        dp_h, bits_h = NumpyBackend._one(pods.astype(np.int64), costs, W - 1)
        ok = (np.asarray(dp_d).tobytes() == dp_h.tobytes()
              and np.array_equal(np.asarray(bits_d), bits_h))
        if not ok:   # pragma: no cover - depends on lowering
            events_log.warn_once(
                "backend_pallas_disabled",
                "pallas cover-DP kernel disabled: device dp/bits do not "
                "match the host reference on this backend (parallel grid "
                "execution?); fused programs use the lax.scan path",
                RuntimeWarning)
        return ok

    # -- the device row solver (traced context) ------------------------------
    def _solver_core(self, md, z, N: int, B: int, RC: int,
                     use_pallas: bool, interpret: bool, coarse=None):
        """Build the traced-closure toolkit shared by both fused programs.

        Returns ``(rmul, prep, solve_row, solve_rows, score)``.
        ``solve_row(coef, active, req) -> (counts, feasible)`` replicates
        one ``repro.core.ilp._solve_rows`` row end to end on device; every
        float op mirrors the host op-for-op (see class docstring).
        ``solve_rows`` is its batched form.

        ``coarse`` is the traced ``(threshold, max_rows, gcd)`` int64
        triple of the active :class:`CoarseningConfig` (``None`` =
        coarsening off).  Rows whose residual exceeds the threshold and
        whose pods all share the market gcd run the DP stages at
        granularity ``g`` — exactly the host engine's gcd mode, so
        recorded counts stay bit-identical (prune math is deliberately
        left unscaled, matching the host's identical keep sets; only the
        core-bound DP, decode DP, and backtrack use scaled pods/targets,
        which the gcd-exactness theorem makes bitwise equal to the
        unscaled pass).  Traced scalars, not static: changing the config
        or the market gcd never recompiles the programs.
        """
        jax, jnp = self._jax, self._jnp
        lax = jax.lax
        (pods, bound, perf, price, structural, real, b_item, b_pods,
         b_podsf, b_copies, b_copiesf, b_struct) = md
        f64, i64, inf = jnp.float64, jnp.int64, jnp.inf
        if coarse is None:
            c_thr, c_maxr, c_gcd = i64(2 ** 62), i64(1), i64(1)
        else:
            c_thr, c_maxr, c_gcd = (jnp.asarray(x, i64) for x in coarse)

        def rmul(x, y):
            # correctly-rounded product exactly as the host computes it:
            # the bitcast^z detour (z is a runtime int64 zero argument) is
            # opaque to XLA/LLVM simplification, so the value reaching any
            # downstream add is the *rounded* product — XLA:CPU's LLVM
            # backend cannot contract the multiply into an FMA
            t = x * y
            return lax.bitcast_convert_type(
                lax.bitcast_convert_type(t, i64) ^ z, f64)

        def seqsum(v):
            # np.cumsum semantics: strictly sequential left-to-right adds
            # (jnp.cumsum reassociates above ~100 elements); unrolled so
            # the scalar chain is not one XLA loop iteration per element
            def step(c, x):
                c = c + x
                return c, c
            return lax.scan(step, f64(0.0), v, unroll=64)[1]

        def prep(excl):
            # per-decision masked normalisation == CompiledMarket.norms:
            # mins over ~exclude (perf restricted to positive entries),
            # empty masks degrading to 1.0 exactly like the host
            mreal = (~excl) & real[None, :]
            pmask = mreal & (perf > 0.0)[None, :]
            pmin = jnp.min(jnp.where(pmask, perf[None, :], inf), axis=1)
            perf_min = jnp.where(jnp.any(pmask, axis=1), pmin, 1.0)
            smin = jnp.min(jnp.where(mreal, price[None, :], inf), axis=1)
            sp_min = jnp.where(jnp.isfinite(smin), smin, 1.0)
            pn = perf[None, :] / perf_min[:, None]
            qn = price[None, :] / sp_min[:, None]
            active = structural[None, :] & ~excl
            return pn, qn, active

        # -- cover DP toolkit, one instance per residual-tier width ----------
        # dp lives as the back half of a (2*W,) extended vector whose front
        # half is zeros: the shifted read dp[j - pb] (with dp[0] = 0 for
        # j < pb) becomes one dynamic_slice at start W - clip(pb) — no
        # gather — and 0.0 + cb is bitwise the host's dp[0] + cb.  W is a
        # static tier width > the row's residual (``_rc_tiers``): the DP
        # recurrence is prefix-closed in j, so dp[j <= residual] — all a
        # row ever reads — is identical at any W > residual, while the
        # vector work per relax shrinks from O(RC) to O(W), matching the
        # host engine's residual-sized dp rows.
        def dp_tools(W):
            ext0 = jnp.concatenate(
                [jnp.zeros(W), jnp.full(W, inf).at[0].set(0.0)])
            first = jnp.arange(W) == 0

            def _relax(ext, pb, cb):
                pbc = jnp.clip(pb, 0, W)
                dp = lax.dynamic_slice(ext, (W,), (W,))
                sh = lax.dynamic_slice(ext, (W - pbc,), (W,))
                # dp[0] pinned at 0: where() fuses into the add pass
                # (an .at[0].set copies the whole W vector per relax)
                cand = jnp.where(first, inf, sh + cb)
                bit = cand < dp
                return lax.dynamic_update_slice(
                    ext, jnp.minimum(dp, cand), (W,)), bit

            def cover_values(pseq, cseq, trip, residual):
                def body(st):
                    i, ext = st
                    ext, _bit = _relax(ext, pseq[i], cseq[i])
                    return i + 1, ext
                _i, ext = lax.while_loop(lambda st: st[0] < trip, body,
                                         (i64(0), ext0))
                return ext[W + residual]

            def cover_bits_scan(kp, kc, trip, KB):
                def body(st):
                    i, ext, bits = st
                    ext, bit = _relax(ext, kp[i], kc[i])
                    bits = lax.dynamic_update_slice(bits, bit[None, :],
                                                    (i, i64(0)))
                    return i + 1, ext, bits
                _i, _e, bits = lax.while_loop(
                    lambda st: st[0] < trip, body,
                    (i64(0), ext0, jnp.zeros((KB, W), dtype=bool)))
                return bits

            if not use_pallas:
                return cover_values, cover_bits_scan, None
            return (cover_values, cover_bits_scan,
                    self._pallas_cover_fn(W, B, interpret))

        tiers = _rc_tiers(RC)
        tier_tools = [dp_tools(W) for W in tiers]

        # -- pool scoring ----------------------------------------------------
        if use_pallas:
            from jax.experimental import pallas as pl

            def _score_kernel(cnt_ref, perf_ref, price_ref, pods_ref,
                              req_ref, out_ref):
                c = cnt_ref[0, :]
                sp = jnp.sum(c * perf_ref[0, :])
                sc = jnp.sum(c * price_ref[0, :])
                sq = jnp.sum(c * pods_ref[0, :])
                rq = req_ref[0]
                ok = (sq >= rq) & (sc > 0.0) & (sq > 0.0)
                out_ref[0] = jnp.where(ok, (sp / sc) * (rq / sq), 0.0)

            def score(cnts, reqf):
                D = cnts.shape[0]
                return pl.pallas_call(
                    _score_kernel,
                    grid=(D,),
                    in_specs=[
                        pl.BlockSpec((1, N), lambda k: (k, 0)),
                        pl.BlockSpec((1, N), lambda k: (0, 0)),
                        pl.BlockSpec((1, N), lambda k: (0, 0)),
                        pl.BlockSpec((1, N), lambda k: (0, 0)),
                        pl.BlockSpec((1,), lambda k: (k,)),
                    ],
                    out_specs=pl.BlockSpec((1,), lambda k: (k,)),
                    out_shape=jax.ShapeDtypeStruct((D,), f64),
                    interpret=interpret,
                )(cnts, perf[None, :], price[None, :],
                  pods.astype(f64)[None, :], reqf)
        else:
            def score(cnts, reqf):
                # speculation-only e_total: steers device bracket control,
                # never replayed to the host (which rescores exactly)
                sp = cnts @ perf
                sc = cnts @ price
                sq = cnts @ pods.astype(f64)
                ok = (sq >= reqf) & (sc > 0.0) & (sq > 0.0)
                return jnp.where(ok, (sp / sc) * (reqf / sq), 0.0)

        # -- one engine row on device ----------------------------------------
        def solve_row(coef, active, req):
            neg = (coef < 0.0) & active
            sat = jnp.where(neg, bound, i64(0))
            covered = jnp.sum(jnp.where(neg, pods * bound, i64(0)))
            residual = jnp.maximum(req - covered, 0)
            in_dp = active & ~neg
            capacity = jnp.sum(jnp.where(in_dp, pods * bound, i64(0)))

            # gcd-mode coarsening decision, mirroring the host engine's
            # _plan_scale: the gcd divides every structural pod count, so
            # scaled DP/backtrack columns are bitwise the unscaled ones
            # (DESIGN.md §14) — eff_g stays 1 (an exact identity: x // 1)
            # below the threshold, keeping pre-coarsening numerics intact
            rs_g = (residual + c_gcd - 1) // c_gcd
            use_g = (residual > c_thr) & (c_gcd > 1) & (rs_g <= c_maxr)
            eff_g = jnp.where(use_g, c_gcd, i64(1))
            eff_res = (residual + eff_g - 1) // eff_g

            def make_dp_case(tools):
                cover_values, cover_bits_scan, pallas_cover = tools

                def dp_case(_):
                    # masked-not-compacted: excluded/saturated bundles get
                    # cost +inf, so their rate sorts to the end and the
                    # finite sorted prefix (and its sequential cumsums) is
                    # bitwise the host's compacted arrays while shapes
                    # stay static
                    bmask = in_dp[b_item] & b_struct
                    bcosts = jnp.where(bmask,
                                       rmul(coef[b_item], b_copiesf), inf)
                    rate = bcosts / b_podsf
                    order = jnp.argsort(rate, stable=True)
                    p_sorted = b_podsf[order]
                    c_sorted = bcosts[order]
                    cum_p = seqsum(p_sorted)
                    cum_c = seqsum(c_sorted)
                    k_ub = jnp.searchsorted(cum_p, residual.astype(f64))
                    ub = cum_c[k_ub]
                    rb = jnp.maximum(residual - b_pods, 0).astype(f64)
                    kk = jnp.searchsorted(cum_p, rb)
                    km = jnp.maximum(kk - 1, 0)
                    prev_p = jnp.where(kk > 0, cum_p[km], 0.0)
                    prev_c = jnp.where(kk > 0, cum_c[km], 0.0)
                    lp = prev_c + rmul(rb - prev_p,
                                       c_sorted[kk] / p_sorted[kk])
                    lp = jnp.where(rb <= 0.0, 0.0, lp)
                    keep = (bcosts + lp) <= rmul(ub, 1.0 + 1e-12) + 1e-9
                    n_active = jnp.sum(bmask)
                    # DP stages run at granularity eff_g (1 = exact); the
                    # prune math above deliberately stays unscaled so the
                    # keep set is the exact engine's
                    b_pods_s = b_pods // eff_g
                    pods_ord = b_pods_s[order]

                    def core_case(_o):
                        K = jnp.minimum(
                            n_active,
                            jnp.maximum(k_ub + _CORE_PAD, _CORE_MIN))
                        if use_pallas:
                            ccosts = jnp.where(jnp.arange(B) < K,
                                               c_sorted, inf)
                            dp, _bits = pallas_cover(pods_ord, ccosts)
                            return dp[eff_res]
                        return cover_values(pods_ord, c_sorted, K,
                                            eff_res)

                    core_ub = lax.cond(jnp.sum(keep) > _CORE_TRIGGER,
                                       core_case, lambda _o: inf, None)
                    keep = jnp.where(
                        core_ub < ub,
                        (bcosts + lp) <= rmul(core_ub, 1.0 + 1e-12) + 1e-9,
                        keep)

                    # kept-first stable permutation preserves market bundle
                    # order within the kept prefix — the decode order the
                    # backtracker's tie-breaking contract depends on.
                    # Built from two exact integer cumsums + one scatter
                    # instead of a second stable argsort (~0.5 ms/row at
                    # B=2048 on CPU)
                    ki = jnp.cumsum(keep.astype(jnp.int64))
                    ni = jnp.cumsum((~keep).astype(jnp.int64))
                    kept_n = ki[B - 1]
                    pos = jnp.where(keep, ki - 1, kept_n + ni - 1)
                    perm = jnp.zeros(B, jnp.int64).at[pos].set(
                        jnp.arange(B, dtype=jnp.int64))
                    kp = b_pods_s[perm]
                    kc = jnp.where(keep[perm], bcosts[perm], inf)

                    def decode(KB):
                        # bits buffer sized to a kept-bound rung, not B:
                        # the decode working set mirrors the host's
                        # (kept_n, residual)-sized bits rows
                        def run(_o):
                            kpk = kp[:KB]
                            if use_pallas:
                                _dp, bits = pallas_cover(kp, kc)
                            else:
                                bits = cover_bits_scan(
                                    kpk, kc[:KB], kept_n, KB)

                            def bt_body(st):
                                i, j, take = st
                                bit = bits[i, j]
                                take = take.at[i].set(bit)
                                j = jnp.where(
                                    bit, jnp.maximum(j - kpk[i], 0), j)
                                return i - 1, j, take

                            _i, _j, take = lax.while_loop(
                                lambda st: (st[0] >= 0) & (st[1] > 0),
                                bt_body,
                                (kept_n - 1, eff_res,
                                 jnp.zeros(KB, dtype=bool)))
                            return sat.at[b_item[perm[:KB]]].add(
                                jnp.where(take, b_copies[perm[:KB]],
                                          i64(0)))
                        return run

                    if use_pallas or B <= 256:
                        counts = decode(B)(None)
                    else:
                        counts = lax.cond(kept_n <= 256,
                                          decode(256), decode(B), None)
                    return counts, jnp.bool_(True)

                return dp_case

            def after_sat(_):
                # route the row to the narrowest tier wider than its
                # *effective* (coarsened) residual; lax.map preserves real
                # branching, so a row pays only its own tier's vector width
                t_idx = jnp.searchsorted(
                    jnp.asarray(tiers), eff_res, side="right")
                t_idx = jnp.minimum(t_idx, len(tiers) - 1)
                return lax.cond(
                    capacity < residual,
                    lambda _o: (sat, jnp.bool_(False)),
                    lambda _o: lax.switch(
                        t_idx, [make_dp_case(t) for t in tier_tools], _o),
                    _)

            return lax.cond(residual == 0,
                            lambda _o: (sat, jnp.bool_(True)),
                            after_sat, None)

        # -- row batching ----------------------------------------------------
        def solve_rows(coefs, actives, reqs):
            """Solve a stack of engine rows sequentially (``lax.fori_loop``
            writing into preallocated outputs — measured ~12% faster than
            ``lax.map``'s scan plumbing).  Sequential, not vmapped, so real
            ``lax.cond``/``lax.switch`` branching survives (the saturation
            fast path and the residual-tier ladder) and every while_loop
            carry stays un-batched, letting XLA update the dp/bits buffers
            in place.  A vmapped row solver was measured ~200x slower here:
            batching the dynamic-trip while_loops forces a masking
            ``select`` copy of the (lanes, B, RC) bits carry on every
            iteration."""
            D = coefs.shape[0]
            def body(i, out):
                cnts, feas = out
                c, f = solve_row(coefs[i], actives[i], reqs[i])
                return cnts.at[i].set(c), feas.at[i].set(f)
            return lax.fori_loop(
                0, D, body,
                (jnp.zeros((D, N), jnp.int64), jnp.zeros(D, bool)))

        return rmul, prep, solve_row, solve_rows, score

    # -- fused programs ------------------------------------------------------
    def _prescan_compiled(self, N, B, RC, D, G):
        key = ("prescan", N, B, RC, D, G) + self._fused_flags()
        fn = self._fused_cache.get(key)
        if fn is None:
            jax, jnp = self._jax, self._jnp
            lax = jax.lax
            use_pallas, on_cpu = self._fused_flags()

            def run(md, reqs, excl, alphas, z, thr, maxr, gran):
                rmul, prep, _row, solve_rows, _score = self._solver_core(
                    md, z, N, B, RC, use_pallas, on_cpu,
                    coarse=(thr, maxr, gran))
                pn, qn, active = prep(excl)
                di = jnp.arange(D * G) // G
                a = alphas[jnp.arange(D * G) % G][:, None]
                coefs = rmul(-a, pn[di]) + rmul(1.0 - a, qn[di])
                counts, feas = solve_rows(coefs, active[di], reqs[di])
                return counts.reshape(D, G, N), feas.reshape(D, G)

            fn = jax.jit(run)
            self._fused_cache[key] = fn
            self.program_builds += 1
        return fn

    def _golden_compiled(self, N, B, RC, D, MAXR):
        key = ("golden", N, B, RC, D, MAXR) + self._fused_flags()
        fn = self._fused_cache.get(key)
        if fn is None:
            jax, jnp = self._jax, self._jnp
            lax = jax.lax
            use_pallas, on_cpu = self._fused_flags()
            ME = MAXR + 2

            def run(md, reqs, excl, a0, b0, tol, z, thr, maxr, gran):
                rmul, prep, _row, solve_rows, score = self._solver_core(
                    md, z, N, B, RC, use_pallas, on_cpu,
                    coarse=(thr, maxr, gran))
                pn, qn, active = prep(excl)
                reqf = reqs.astype(jnp.float64)
                dn = jnp.arange(D)

                def solve_vec(alphas, reqv):
                    coefs = (rmul(-alphas[:, None], pn)
                             + rmul(1.0 - alphas[:, None], qn))
                    return solve_rows(coefs, active, reqv)

                def spec(counts, feas):
                    s = score(counts.astype(jnp.float64), reqf)
                    return jnp.where(feas, s, -jnp.inf)

                # bracket init: exactly the host's x1/x2 update formulas
                # (rmul keeps PHI*(b-a) rounded before the subtract/add)
                w0 = rmul(jnp.float64(_PHI), b0 - a0)
                x1 = b0 - w0
                x2 = a0 + w0
                c1, fe1 = solve_vec(x1, reqs)
                c2, fe2 = solve_vec(x2, reqs)
                f1 = spec(c1, fe1)
                f2 = spec(c2, fe2)

                ev_a = (jnp.zeros((D, ME))
                        .at[:, 0].set(x1).at[:, 1].set(x2))
                ev_c = (jnp.zeros((D, ME, N), dtype=jnp.int64)
                        .at[:, 0, :].set(c1).at[:, 1, :].set(c2))
                ev_f = (jnp.zeros((D, ME), dtype=bool)
                        .at[:, 0].set(fe1).at[:, 1].set(fe2))
                evn = jnp.full((D,), 2, dtype=jnp.int64)

                def cond(st):
                    return (st[0] < MAXR) & jnp.any((st[2] - st[1]) > tol)

                def body(st):
                    (r, a, b, x1, x2, f1, f2,
                     ev_a, ev_c, ev_f, evn) = st
                    act = (b - a) > tol
                    right = (f1 >= f2) & act     # shrink from the right
                    left = act & ~(f1 >= f2)     # shrink from the left
                    nb = jnp.where(right, x2, b)
                    na = jnp.where(left, x1, a)
                    w = rmul(jnp.float64(_PHI), nb - na)
                    nx1 = jnp.where(right, nb - w, jnp.where(left, x2, x1))
                    nx2 = jnp.where(left, na + w, jnp.where(right, x1, x2))
                    pf1 = jnp.where(left, f2, f1)
                    pf2 = jnp.where(right, f1, f2)
                    probe = jnp.where(right, nx1,
                                      jnp.where(left, nx2, 0.0))
                    # inactive decisions re-solve req=0 (the cheap
                    # saturation fast path) instead of a full row
                    reqv = jnp.where(act, reqs, jnp.int64(0))
                    cp, fep = solve_vec(probe, reqv)
                    fp = spec(cp, fep)
                    nf1 = jnp.where(right, fp, pf1)
                    nf2 = jnp.where(left, fp, pf2)
                    ev_a = ev_a.at[dn, evn].set(
                        jnp.where(act, probe, ev_a[dn, evn]))
                    ev_c = ev_c.at[dn, evn, :].set(
                        jnp.where(act[:, None], cp, ev_c[dn, evn, :]))
                    ev_f = ev_f.at[dn, evn].set(
                        jnp.where(act, fep, ev_f[dn, evn]))
                    evn = evn + act.astype(jnp.int64)
                    return (r + 1, na, nb, nx1, nx2, nf1, nf2,
                            ev_a, ev_c, ev_f, evn)

                st = lax.while_loop(cond, body, (
                    jnp.int64(0), a0, b0, x1, x2, f1, f2,
                    ev_a, ev_c, ev_f, evn))
                return st[7], st[8], st[9], st[10]

            fn = jax.jit(run)
            self._fused_cache[key] = fn
            self.program_builds += 1
        return fn

    # -- host-side drivers ---------------------------------------------------
    def _shape_key(self, market, reqs, n_dec, coarsening=None):
        N = _bucket(max(market.n, 1), self._N_STEPS)
        B = _bucket(max(market.n_bundles, 1), self._BF_STEPS)
        width = max(max(reqs, default=1), 1)
        if (coarsening is not None and coarsening.enabled
                and width > coarsening.threshold
                and market.pods_gcd > 1):
            # gcd-coarsened rows need ceil(req/g) DP rows; rows whose
            # residual stays below the threshold need the threshold width
            width = max(coarsening.threshold,
                        -(-width // market.pods_gcd))
        RC = _bucket(width, self._RF_STEPS) + 1
        D = _bucket(max(n_dec, 1), self._D_STEPS)
        return N, B, RC, D

    def _coarse_scalars(self, market, coarsening):
        """The ``(threshold, max_rows, gcd)`` int64 triple handed to the
        compiled programs as *traced* scalars (config or market changes
        never force a recompile).  Coarsening off → an unreachable
        threshold, so every row takes the exact path."""
        if coarsening is None or not coarsening.enabled:
            return np.int64(2 ** 62), np.int64(1), np.int64(1)
        return (np.int64(coarsening.threshold),
                np.int64(coarsening.max_rows),
                np.int64(max(market.pods_gcd, 1)))

    def _pad_decisions(self, market, reqs, excludes, N, D):
        rq = np.zeros(D, np.int64)
        rq[:len(reqs)] = reqs
        ex = np.zeros((D, N), bool)
        for d, mask in enumerate(excludes):
            if mask is not None:
                ex[d, :market.n] = mask
        return rq, ex

    def _run_prescan(self, market, reqs, excludes, grid, coarsening=None):
        Dr, G = len(reqs), len(grid)
        N, B, RC, D = self._shape_key(market, reqs, Dr, coarsening)
        md = self._device_market(market, N, B)
        rq, ex = self._pad_decisions(market, reqs, excludes, N, D)
        thr, maxr, gran = self._coarse_scalars(market, coarsening)
        fn = self._prescan_compiled(N, B, RC, D, G)
        counts, feas = fn(md, rq, ex, np.asarray(grid, np.float64),
                          np.int64(0), thr, maxr, gran)
        return (np.asarray(counts)[:Dr, :, :market.n],
                np.asarray(feas)[:Dr])

    def _run_golden(self, market, reqs, excludes, a_list, b_list,
                    tolerance, coarsening=None):
        Dr = len(reqs)
        N, B, RC, D = self._shape_key(market, reqs, Dr, coarsening)
        md = self._device_market(market, N, B)
        rq, ex = self._pad_decisions(market, reqs, excludes, N, D)
        thr, maxr, gran = self._coarse_scalars(market, coarsening)
        # round budget: any bracket is <= 1 wide and shrinks by PHI per
        # round, so ceil(log(tol)/log(PHI)) rounds suffice (+2 slack)
        MAXR = (int(math.ceil(math.log(tolerance) / math.log(_PHI))) + 2
                if 0.0 < tolerance < 1.0 else 3)
        a0 = np.zeros(D)
        a0[:Dr] = a_list
        b0 = np.zeros(D)
        b0[:Dr] = b_list
        fn = self._golden_compiled(N, B, RC, D, MAXR)
        ev_a, ev_c, ev_f, evn = fn(md, rq, ex, a0, b0,
                                   np.float64(tolerance), np.int64(0),
                                   thr, maxr, gran)
        return (np.asarray(ev_a)[:Dr], np.asarray(ev_c)[:Dr, :, :market.n],
                np.asarray(ev_f)[:Dr], np.asarray(evn)[:Dr])

    # -- rounding self-check + record entry point ----------------------------
    def _fused_ok(self) -> bool:
        """One-time probe that this XLA build's rmul-guarded products are
        bitwise the host's (the FMA-contraction defense holds)."""
        if self._selfcheck_ok is None:
            try:
                self._selfcheck_ok = self._run_selfcheck()
            except Exception as exc:   # pragma: no cover - defensive
                events_log.warn_once(
                    "backend_fused_disabled",
                    "fused jax decision plane disabled (self-check raised "
                    f"{exc!r}); falling back to per-round dispatch",
                    RuntimeWarning)
                self._selfcheck_ok = False
        return self._selfcheck_ok

    def _run_selfcheck(self) -> bool:
        jax, jnp = self._jax, self._jnp
        lax = jax.lax
        rng = np.random.default_rng(0)
        pn = rng.uniform(0.5, 4.0, 64)
        qn = rng.uniform(0.5, 4.0, 64)
        alphas = rng.uniform(0.0, 1.0, 16)

        def dev(a, p, q, z):
            def rm(x, y):
                t = x * y
                return lax.bitcast_convert_type(
                    lax.bitcast_convert_type(t, jnp.int64) ^ z,
                    jnp.float64)
            coef = (rm(-a[:, None], p[None, :])
                    + rm(1.0 - a[:, None], q[None, :]))
            thr = rm(coef, 1.0 + 1e-12) + 1e-9
            w = a[:, None] - rm(jnp.float64(_PHI), coef)
            return coef, thr, w

        coef_d, thr_d, w_d = jax.jit(dev)(
            jnp.asarray(alphas), jnp.asarray(pn), jnp.asarray(qn),
            np.int64(0))
        a2 = alphas[:, None]
        coef_h = -a2 * pn[None, :] + (1.0 - a2) * qn[None, :]
        thr_h = coef_h * (1.0 + 1e-12) + 1e-9
        w_h = a2 - _PHI * coef_h
        ok = (np.asarray(coef_d).tobytes() == coef_h.tobytes()
              and np.asarray(thr_d).tobytes() == thr_h.tobytes()
              and np.asarray(w_d).tobytes() == w_h.tobytes())
        if not ok:   # pragma: no cover - depends on XLA build
            events_log.warn_once(
                "backend_fused_disabled",
                "fused jax decision plane disabled: device float products "
                "do not match host rounding on this XLA build; falling "
                "back to per-round dispatch", RuntimeWarning)
        return ok

    def fused_gss_record(self, items, market, reqs, excludes, grid,
                         tolerance,
                         coarsening=None) -> Optional["_FusedGssRecord"]:
        """Run the device-resident prescan for a ``bracketed_gss_many``
        batch and return the replay record, or None to decline (empty
        market, failed self-check, a device error, or a batch whose
        coarsening ladder would need the approx tier — the device plane
        only implements the exact and gcd modes, so approx-regime batches
        stay on the host engine)."""
        if market.n == 0 or market.n_bundles == 0:
            return None
        cfg = DEFAULT_COARSENING if coarsening is None else coarsening
        max_req = max((int(r) for r in reqs), default=0)
        if cfg.enabled and max_req > cfg.threshold:
            g = market.pods_gcd
            if not (g > 1 and -(-max_req // g) <= cfg.max_rows):
                return None
        if not self._fused_ok():
            return None
        try:
            rec = _FusedGssRecord(self, items, market, reqs, excludes,
                                  grid, tolerance, cfg)
        except _PrescanMismatch:
            # the sampled host cross-check failed: device counts cannot be
            # trusted on this build — disable the fused path for the
            # process (already warned in _verify_sample)
            self._selfcheck_ok = False
            return None
        except Exception as exc:
            events_log.warn_once(
                "backend_fused_record_fallback",
                f"fused GSS device path failed ({exc!r}); falling back "
                "to per-round dispatch", RuntimeWarning)
            return None
        self.fused_records += 1
        return rec


class _PrescanMismatch(RuntimeError):
    """Device prescan counts failed the sampled host cross-check."""


class _FusedGssRecord:
    """Replay record binding one device-resident GSS batch to its host
    control loop (DESIGN.md §13).

    Construction runs the fused prescan; :meth:`run_golden` runs the fused
    golden program once the host has chosen brackets.  Both fill an
    exact-bitwise α → counts lookup per decision.  The host replay
    (``bracketed_gss_many``) then re-executes the sequential control flow
    with exact host floats and resolves every probe through
    :meth:`solve_many`: device-recorded counts on a hit, a counted NumPy
    engine solve on a miss (device/host control divergence) — so a
    speculation mismatch can only cost time, never change a selection.
    """

    def __init__(self, backend, items, market, reqs, excludes, grid,
                 tolerance, coarsening=None):
        self._backend = backend
        self._items = list(items)
        self._market = market
        self._reqs = [int(r) for r in reqs]
        self._excludes = list(excludes)
        self._tolerance = float(tolerance)
        self._coarsening = coarsening
        counts, feas = backend._run_prescan(market, self._reqs,
                                            self._excludes, list(grid),
                                            coarsening=coarsening)
        self.prescan = [
            [list(map(int, counts[d, g])) if feas[d, g] else None
             for g in range(len(grid))]
            for d in range(len(self._reqs))]
        self._lookup: List[dict] = [{} for _ in self._reqs]
        for d, row in enumerate(self.prescan):
            for a, c in zip(grid, row):
                self._lookup[d].setdefault(float(a), c)
        self._verify_sample(list(grid))

    def _verify_sample(self, grid: List[float]) -> None:
        """Prescan fail-safe, mirroring the golden phase's lookup-miss
        host solve: before the record is trusted, one sampled
        (decision, α) row per batch — rotated through decisions and grid
        points by the backend's ``verify_solves`` counter — is re-solved
        on the NumPy engine and compared exactly.  Any divergence (an
        XLA build or lowering whose numerics the rmul/Pallas self-checks
        did not anticipate) raises :class:`_PrescanMismatch`, which
        permanently disables the fused path — a warned, counted event —
        instead of silently changing selections."""
        if not self._reqs or not grid:
            return
        be = self._backend
        d = be.verify_solves % len(self._reqs)
        g = be.verify_solves % len(grid)
        be.verify_solves += 1
        from .ilp import solve_ilp_many   # deferred: no import cycle
        ref = solve_ilp_many(
            self._items, [self._reqs[d]], [[float(grid[g])]],
            market=self._market, excludes=[self._excludes[d]],
            backend=be._host_fallback,
            coarsening=self._coarsening)[0][0]
        if ref != self.prescan[d][g]:
            events_log.warn_once(
                "backend_fused_prescan_mismatch",
                "fused jax decision plane disabled: device prescan counts "
                f"diverged from the host engine (decision {d}, alpha "
                f"{float(grid[g])!r}); falling back to per-round dispatch",
                RuntimeWarning)
            raise _PrescanMismatch(
                f"prescan verification mismatch at decision {d}, "
                f"alpha {float(grid[g])!r}")

    def run_golden(self, a_list, b_list) -> None:
        ev_a, ev_c, ev_f, evn = self._backend._run_golden(
            self._market, self._reqs, self._excludes,
            [float(a) for a in a_list], [float(b) for b in b_list],
            self._tolerance, coarsening=self._coarsening)
        for d in range(len(self._reqs)):
            lut = self._lookup[d]
            for s in range(int(evn[d])):
                cnt = (list(map(int, ev_c[d, s])) if ev_f[d, s] else None)
                lut.setdefault(float(ev_a[d, s]), cnt)

    def solve_many(self, idxs, alpha_lists):
        """``solve_ilp_many``-shaped resolution of a golden round's probes:
        one counts-or-None list per (decision index, α list) pair."""
        out = [[None] * len(al) for al in alpha_lists]
        miss_pos: List[Tuple[int, List[int]]] = []
        miss_reqs: List[int] = []
        miss_alphas: List[List[float]] = []
        miss_excl: List[Optional[np.ndarray]] = []
        for k, (d, alist) in enumerate(zip(idxs, alpha_lists)):
            lut = self._lookup[d]
            missing = []
            for j, a in enumerate(alist):
                hit = lut.get(float(a), _MISS)
                if hit is _MISS:
                    missing.append(j)
                else:
                    out[k][j] = hit
            if missing:
                miss_pos.append((k, missing))
                miss_reqs.append(self._reqs[d])
                miss_alphas.append([alist[j] for j in missing])
                miss_excl.append(self._excludes[d])
        if miss_pos:
            self._backend.fallback_solves += sum(
                len(js) for _k, js in miss_pos)
            from .ilp import solve_ilp_many   # deferred: no import cycle
            solved = solve_ilp_many(
                self._items, miss_reqs, miss_alphas, market=self._market,
                excludes=miss_excl, backend=self._backend._host_fallback,
                coarsening=self._coarsening)
            for (k, js), counts_d in zip(miss_pos, solved):
                for j, c in zip(js, counts_d):
                    out[k][j] = c
                    self._lookup[idxs[k]].setdefault(
                        float(alpha_lists[k][j]), c)
        return out


# ---------------------------------------------------------------------------
# Default-backend registry (env-overridable, numpy fallback with a warning)
# ---------------------------------------------------------------------------

_DEFAULT: Optional[SolverBackend] = None


def jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


def make_backend(spec: str) -> SolverBackend:
    """Build a backend from a spec string: ``numpy`` | ``jax`` |
    ``jax:pallas`` | ``jax:fused`` | ``jax:fused:pallas``.  A jax spec
    without jax installed warns once (counted in
    ``repro.core.events_log``) and returns the numpy backend (the solver
    path treats jax as optional)."""
    if spec == "numpy":
        return NumpyBackend()
    if spec in ("jax", "jax:pallas", "jax:fused", "jax:fused:pallas"):
        try:
            if spec.startswith("jax:fused"):
                return FusedJaxBackend(pallas=spec.endswith(":pallas"))
            return JaxBackend(pallas=spec.endswith(":pallas"))
        except ImportError:
            events_log.warn_once(
                "backend_numpy_fallback",
                "KubePACS solver backend %r requested but jax is not "
                "installed; falling back to the NumPy backend (install "
                "jax, or set KUBEPACS_SOLVER_BACKEND=numpy to silence "
                "this)" % spec, RuntimeWarning, stacklevel=2)
            return NumpyBackend()
    raise ValueError(f"unknown solver backend spec {spec!r} "
                     "(expected numpy | jax | jax:pallas | jax:fused | "
                     "jax:fused:pallas)")


def get_backend() -> SolverBackend:
    """The process-default backend: ``KUBEPACS_SOLVER_BACKEND`` if set,
    else numpy (selections are backend-invariant; numpy keeps the default
    dependency surface of the control plane at exactly numpy)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = make_backend(
            os.environ.get("KUBEPACS_SOLVER_BACKEND", "numpy"))
    return _DEFAULT


def set_backend(backend: Optional[SolverBackend | str]) -> SolverBackend:
    """Override the process default (string specs accepted); ``None``
    resets to the environment/default resolution on next use."""
    global _DEFAULT
    if isinstance(backend, str):
        backend = make_backend(backend)
    _DEFAULT = backend
    return get_backend() if backend is None else backend
