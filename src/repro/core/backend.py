"""Pluggable solver backends for the min-plus cover DP (DESIGN.md §12).

The ILP engine reduces every solve — single-α, a GSS prescan grid, or the
cross-decision batches of ``solve_ilp_many`` — to one primitive: a forward
min-plus value pass over a bundle sequence that also emits *improvement
bits*, the per-(bundle, coverage) booleans the exact backtracker consumes.
This module defines that primitive once, with two interchangeable
implementations:

* :class:`NumpyBackend` — the host path: a Python loop over bundles with
  in-place vectorized row updates.  Always available; the reference for
  the bit-identical-selection contract.
* :class:`JaxBackend` — the accelerator path: the same recurrence as a
  ``jax.lax.scan`` under ``jit``, batched over stacked solve groups with
  bucketed padding so recompilation is bounded.  Optionally (``pallas``
  flag) the inner relaxation step runs as a Pallas kernel — interpreted
  on CPU, lowerable on TPU/GPU — for the jax_pallas north star.

Canonical kernel semantics (both backends, float64):

    dp[0] = 0, dp[j>0] = +inf
    for b in 0..B-1:                       # bundle order is significant
        cand[j] = dp[max(j - pods[b], 0)] + cost[b]      (j >= 1)
        bits[b, j] = cand[j] < dp[j]                     (bits[b, 0] = False)
        dp[j]    = min(dp[j], cand[j])                   (dp[0] pinned at 0)

(The strict ``<`` needs no epsilon: dp values are exact subset-cost sums,
so a strict improvement at (b, j) means every optimal solution of the
bundle prefix uses b — the backtracker's take-rule — and equality means
skipping b is optimal.  The seed solver's 1e-12 guard band protected a
history matrix recomputed along a different float path; here bits and dp
come from the same pass.)

Every arithmetic step is an elementwise float64 op executed in the same
order by both implementations, so the resulting ``dp``/``bits`` are
bit-identical — which is what makes backend choice invisible to selections
(the backtracker's tie-breaking reads only ``bits``).  The ``j``-prefix of
``dp``/``bits`` does not depend on the padded target length, so solve
groups that share (costs, kept bundles) can share one padded row.

JAX is an *optional* dependency of this path: importing this module never
imports ``jax``.  Requesting the jax backend without jax installed warns
once and falls back to :class:`NumpyBackend`
(``KUBEPACS_SOLVER_BACKEND=numpy|jax|jax:pallas`` overrides the default).
"""

from __future__ import annotations

import os
import warnings
from typing import List, Optional, Sequence, Tuple

import numpy as np

#: one (bpods, costs, target) residual covering problem; ``bpods`` int64
#: (all >= 1), ``costs`` float64 (may contain +inf), ``target`` >= 1
CoverGroup = Tuple[np.ndarray, np.ndarray, int]


class SolverBackend:
    """Interface: batched cover-DP value passes with improvement bits."""

    name = "abstract"

    #: engine hint: decode in slices of at most this many DP groups so the
    #: bits arrays of one slice die before the next is computed (the host
    #: path is cache/allocator-sensitive; accelerator backends want the
    #: whole stack in one dispatch and override with a large value)
    max_group_batch = 1 << 30

    def cover_bits(self, groups: Sequence[CoverGroup],
                   ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """For each group return ``(dp, bits)`` — ``dp`` float64 of shape
        ``(target+1,)`` and ``bits`` bool of shape ``(B, target+1)`` — per
        the canonical kernel above.  Implementations may stack groups into
        one padded dispatch; returned arrays are trimmed numpy arrays."""
        raise NotImplementedError

    def cover_values(self, groups: Sequence[CoverGroup]) -> List[np.ndarray]:
        """Value-only variant: just each group's final ``dp`` vector (used
        for the engine's core upper bounds, where bits are never read)."""
        return [dp for dp, _bits in self.cover_bits(groups)]


class NumpyBackend(SolverBackend):
    """Host reference implementation (ragged — no padding waste).

    Runs each group's forward pass with preallocated scratch rows (the
    pass is memory-bandwidth-bound; allocator churn is the only other
    cost worth removing) and skips +inf bundles outright — an inert
    bundle's candidates never beat the running ``dp``, so skipping is
    exact.
    """

    name = "numpy"
    max_group_batch = 8      # keep the live bits working set cache-sized

    def cover_bits(self, groups):
        scratch = np.empty(max((g[2] for g in groups), default=0) + 1)
        return [self._one(bpods, costs, target, scratch)
                for bpods, costs, target in groups]

    def cover_values(self, groups):
        scratch = np.empty(max((g[2] for g in groups), default=0) + 1)
        return [self._values(bpods, costs, target, scratch)
                for bpods, costs, target in groups]

    @staticmethod
    def _values(bpods: np.ndarray, costs: np.ndarray, target: int,
                scratch: Optional[np.ndarray] = None) -> np.ndarray:
        if scratch is None:
            scratch = np.empty(target + 1)
        dp = np.full(target + 1, np.inf)
        dp[0] = 0.0
        for b in range(len(bpods)):
            cb = costs[b]
            if not np.isfinite(cb):
                continue
            pb = int(bpods[b])
            if pb <= target:
                k = target + 1 - pb
                cand = np.add(dp[:k], cb, out=scratch[:k])
                np.minimum(dp[pb:], cand, out=dp[pb:])
                if pb > 1:
                    np.minimum(dp[1:pb], cb, out=dp[1:pb])
            else:
                np.minimum(dp[1:], cb, out=dp[1:])
        return dp

    @staticmethod
    def _one(bpods: np.ndarray, costs: np.ndarray, target: int,
             scratch: Optional[np.ndarray] = None,
             ) -> Tuple[np.ndarray, np.ndarray]:
        B = len(bpods)
        if scratch is None:
            scratch = np.empty(target + 1)
        dp = np.full(target + 1, np.inf)
        dp[0] = 0.0
        # every finite bundle's row is fully written below (j >= 1) and the
        # j = 0 column is blanked at the end, so empty beats zeros here
        bits = np.empty((B, target + 1), dtype=bool)
        for b in range(B):
            cb = costs[b]
            if not np.isfinite(cb):
                bits[b] = False   # cand = x + inf never beats dp
                continue
            pb = int(bpods[b])
            if pb <= target:
                # j in [pb, target]: cand = dp[j - pb] + cb (pre-update dp;
                # the scratch row materializes before the in-place writes)
                k = target + 1 - pb
                cand = np.add(dp[:k], cb, out=scratch[:k])
                np.less(cand, dp[pb:], out=bits[b, pb:])
                np.minimum(dp[pb:], cand, out=dp[pb:])
                if pb > 1:        # j in [1, pb-1]: cand = dp[0] + cb = cb
                    np.less(cb, dp[1:pb], out=bits[b, 1:pb])
                    np.minimum(dp[1:pb], cb, out=dp[1:pb])
            else:                 # pb > target: cand = cb for every j >= 1
                np.less(cb, dp[1:], out=bits[b, 1:])
                np.minimum(dp[1:], cb, out=dp[1:])
        bits[:, 0] = False
        return dp, bits


def _bucket(n: int, steps: Sequence[int]) -> int:
    """Round ``n`` up to the smallest bucket (bounds jit recompilation)."""
    for s in steps:
        if n <= s:
            return s
    step = steps[-1]
    return ((n + step - 1) // step) * step


class JaxBackend(SolverBackend):
    """``jax.lax.scan`` cover-DP, jitted, batched over padded groups.

    Groups are stacked into one ``(G, B_pad, R_pad)`` dispatch per call;
    pad bundles carry ``pods=1, cost=+inf`` (inert), pad target columns are
    never read back (the kernel's ``j``-prefix is padding-independent).
    ``G``/``B``/``R`` are bucketed so the jit cache stays small across the
    varying shapes of a simulation run.  All arithmetic runs in float64
    under a scoped ``enable_x64`` so results are bit-identical to
    :class:`NumpyBackend` without flipping global precision for unrelated
    jax users in the process.

    ``pallas=True`` swaps the inner relaxation step for a Pallas kernel
    (`repro.kernels` idiom); on CPU it runs in interpreter mode — a
    correctness/bring-up path, not a fast one — while TPU/GPU lower it.
    """

    name = "jax"

    #: bucket ladders: fine at small sizes, coarse (multiples of the last
    #: step) beyond, keeping padding waste and recompiles both bounded
    _G_STEPS = (1, 2, 4, 8, 16, 32, 64)
    _B_STEPS = (16, 32, 64, 128, 256, 512)
    _R_STEPS = (256, 512, 1024, 2048)

    def __init__(self, pallas: bool = False):
        import jax  # deferred: jax is optional for the solver path

        self._jax = jax
        self._jnp = jax.numpy
        self.pallas = bool(pallas)
        if pallas:
            self.name = "jax:pallas"
        self._jit_cache: dict = {}

    # -- kernel construction -------------------------------------------------
    def _step_fn(self, interpret: bool):
        jnp = self._jnp
        if not self.pallas:
            def step(dp, xs):
                pb, cb = xs                                  # (G,), (G,)
                jidx = jnp.arange(dp.shape[1])
                idx = jnp.maximum(jidx[None, :] - pb[:, None], 0)
                cand = jnp.take_along_axis(dp, idx, axis=1) + cb[:, None]
                cand = cand.at[:, 0].set(jnp.inf)            # dp[0] pinned
                bit = cand < dp
                return jnp.minimum(dp, cand), bit
            return step

        from jax.experimental import pallas as pl

        def relax_kernel(dp_ref, pb_ref, cb_ref, out_ref, bit_ref):
            dp = dp_ref[...]                                 # (G, R+1)
            pb = pb_ref[...]                                 # (G, 1)
            cb = cb_ref[...]                                 # (G, 1)
            jidx = self._jax.lax.broadcasted_iota(
                jnp.int64, dp.shape, dimension=1)
            idx = jnp.maximum(jidx - pb, 0)
            cand = jnp.take_along_axis(dp, idx, axis=1) + cb
            cand = jnp.where(jidx == 0, jnp.inf, cand)
            bit_ref[...] = cand < dp
            out_ref[...] = jnp.minimum(dp, cand)

        def step(dp, xs):
            pb, cb = xs
            new_dp, bit = pl.pallas_call(
                relax_kernel,
                out_shape=(
                    self._jax.ShapeDtypeStruct(dp.shape, dp.dtype),
                    self._jax.ShapeDtypeStruct(dp.shape, jnp.bool_),
                ),
                interpret=interpret,
            )(dp, pb[:, None], cb[:, None].astype(dp.dtype))
            return new_dp, bit
        return step

    def _compiled(self, G: int, B: int, R: int, with_bits: bool = True):
        key = (G, B, R, with_bits)
        fn = self._jit_cache.get(key)
        if fn is None:
            jax, jnp = self._jax, self._jnp
            interpret = jax.default_backend() == "cpu"
            step = self._step_fn(interpret)

            def run(bpods, costs):                  # (G, B) int64 / float64
                dp0 = jnp.full((G, R + 1), jnp.inf,
                               dtype=jnp.float64).at[:, 0].set(0.0)
                if with_bits:
                    dp, bits = jax.lax.scan(step, dp0, (bpods.T, costs.T))
                    return dp, jnp.moveaxis(bits, 0, 1)      # (G, B, R+1)
                dp, _ = jax.lax.scan(
                    lambda d, xs: (step(d, xs)[0], None), dp0,
                    (bpods.T, costs.T))
                return dp

            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn

    # -- public API ----------------------------------------------------------
    def cover_bits(self, groups):
        return self._dispatch(groups, with_bits=True)

    def cover_values(self, groups):
        return self._dispatch(groups, with_bits=False)

    def _dispatch(self, groups, with_bits: bool):
        if not groups:
            return []
        from jax.experimental import enable_x64

        # partition groups into (B, R) shape buckets so one outlier group
        # does not pad every other dispatch up to its size
        buckets: dict = {}
        for i, (bp, _bc, t) in enumerate(groups):
            key = (_bucket(len(bp), self._B_STEPS),
                   _bucket(t, self._R_STEPS))
            buckets.setdefault(key, []).append(i)
        out: List = [None] * len(groups)
        with enable_x64():
            for (B, R), idxs in buckets.items():
                G = _bucket(len(idxs), self._G_STEPS)
                bpods = np.ones((G, B), dtype=np.int64)
                costs = np.full((G, B), np.inf)
                for g, i in enumerate(idxs):
                    bp, bc, _t = groups[i]
                    bpods[g, :len(bp)] = bp
                    costs[g, :len(bc)] = bc
                res = self._compiled(G, B, R, with_bits)(bpods, costs)
                if with_bits:
                    dp = np.asarray(res[0])
                    bits = np.asarray(res[1])
                    for g, i in enumerate(idxs):
                        bp, _bc, t = groups[i]
                        out[i] = (dp[g, :t + 1], bits[g, :len(bp), :t + 1])
                else:
                    dp = np.asarray(res)
                    for g, i in enumerate(idxs):
                        out[i] = dp[g, :groups[i][2] + 1]
        return out


# ---------------------------------------------------------------------------
# Default-backend registry (env-overridable, numpy fallback with a warning)
# ---------------------------------------------------------------------------

_DEFAULT: Optional[SolverBackend] = None
_WARNED = False


def jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


def make_backend(spec: str) -> SolverBackend:
    """Build a backend from a spec string: ``numpy`` | ``jax`` |
    ``jax:pallas``.  A jax spec without jax installed warns once and
    returns the numpy backend (the solver path treats jax as optional)."""
    global _WARNED
    if spec == "numpy":
        return NumpyBackend()
    if spec in ("jax", "jax:pallas"):
        try:
            return JaxBackend(pallas=spec.endswith(":pallas"))
        except ImportError:
            if not _WARNED:
                warnings.warn(
                    "KubePACS solver backend %r requested but jax is not "
                    "installed; falling back to the NumPy backend (install "
                    "jax, or set KUBEPACS_SOLVER_BACKEND=numpy to silence "
                    "this)" % spec, RuntimeWarning, stacklevel=2)
                _WARNED = True
            return NumpyBackend()
    raise ValueError(f"unknown solver backend spec {spec!r} "
                     "(expected numpy | jax | jax:pallas)")


def get_backend() -> SolverBackend:
    """The process-default backend: ``KUBEPACS_SOLVER_BACKEND`` if set,
    else numpy (selections are backend-invariant; numpy keeps the default
    dependency surface of the control plane at exactly numpy)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = make_backend(
            os.environ.get("KUBEPACS_SOLVER_BACKEND", "numpy"))
    return _DEFAULT


def set_backend(backend: Optional[SolverBackend | str]) -> SolverBackend:
    """Override the process default (string specs accepted); ``None``
    resets to the environment/default resolution on next use."""
    global _DEFAULT
    if isinstance(backend, str):
        backend = make_backend(backend)
    _DEFAULT = backend
    return get_backend() if backend is None else backend
