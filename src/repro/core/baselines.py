"""Baseline provisioners from the paper's evaluation (§5.2, Table 4).

* KubePACS-Greedy — same inputs as KubePACS, naive allocation (ablation).
* SpotVerse-Node / SpotVerse-Pod — price + single-node SPS + IF thresholds.
* SpotKube — NSGA-II genetic algorithm, fixed 4 instances per selected type.
* Karpenter-like — price-capacity-optimized SpotFleet policy (no BS awareness).

All take preprocessed :class:`CandidateItem` lists so every method sees the
identical candidate universe (the paper's controlled comparison).
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .efficiency import CandidateItem, NodePool


def _empty(items: Sequence[CandidateItem]) -> NodePool:
    return NodePool(items=list(items), counts=[0] * len(items))


# ---------------------------------------------------------------------------
# KubePACS-Greedy (ablation, §5.2)
# ---------------------------------------------------------------------------

def kubepacs_greedy(items: Sequence[CandidateItem], req_pods: int) -> NodePool:
    """Rank by per-node performance-per-dollar Perf_i/SP_i; fill under T3."""
    pool = _empty(items)
    if not items:
        return pool.nonzero()
    perf = np.array([it.perf for it in items], dtype=np.float64)
    price = np.array([it.spot_price for it in items], dtype=np.float64)
    order = np.argsort(-perf / price, kind="stable")
    remaining = req_pods
    for i in order:
        if remaining <= 0:
            break
        it = items[int(i)]
        if it.pods <= 0 or it.t3 <= 0:
            continue
        take = min(it.t3, math.ceil(remaining / it.pods))
        pool.counts[int(i)] = take
        remaining -= take * it.pods
    return pool.nonzero()


# ---------------------------------------------------------------------------
# SpotVerse (adapted to pod semantics, §5.2)
# ---------------------------------------------------------------------------

def spotverse(items: Sequence[CandidateItem], req_pods: int,
              mode: str = "node", sps_threshold: int = 3,
              if_threshold: int = 2) -> NodePool:
    """Filter by single-node SPS and IF, then pick the cheapest offering.

    ``mode="node"`` ranks by price per node, ``mode="pod"`` by price per pod.
    No multi-node (T3) bound is applied — the paper's Fig. 5b failure mode of
    concentrating hundreds of nodes on one type is intentional here.
    """
    eligible = [i for i, it in enumerate(items)
                if it.offering.sps_single >= sps_threshold
                and it.offering.interruption_freq <= if_threshold
                and it.pods > 0]
    if not eligible:   # relax the thresholds like SpotVerse's fallback tiers
        eligible = [i for i, it in enumerate(items) if it.pods > 0]
    if not eligible:
        return _empty(items)

    if mode == "node":
        best = min(eligible, key=lambda i: items[i].spot_price)
    elif mode == "pod":
        best = min(eligible, key=lambda i: items[i].spot_price / items[i].pods)
    else:
        raise ValueError(f"unknown SpotVerse mode {mode!r}")

    pool = _empty(items)
    pool.counts[best] = math.ceil(req_pods / items[best].pods)
    return pool.nonzero()


# ---------------------------------------------------------------------------
# SpotKube (NSGA-II, fixed 4 instances per selected type, §5.2)
# ---------------------------------------------------------------------------

def spotkube(items: Sequence[CandidateItem], req_pods: int,
             seed: int = 0, population: int = 48, generations: int = 80,
             per_type_count: int = 4) -> NodePool:
    """NSGA-II over type-inclusion bitmasks; each chosen type gets 4 nodes.

    Objectives: (minimize hourly cost, maximize type/AZ diversity), with
    demand coverage as a feasibility constraint (constrained-domination).
    """
    rng = np.random.default_rng(seed)
    n = len(items)
    if n == 0:
        return _empty(items)
    pods = np.array([max(it.pods, 0) for it in items]) * per_type_count
    cost = np.array([it.spot_price for it in items]) * per_type_count
    azs = np.array([hash(it.offering.az) % 10_000 for it in items])

    def fitness(mask: np.ndarray) -> Tuple[float, float, float]:
        covered = float(pods[mask].sum())
        shortfall = max(0.0, req_pods - covered)
        total_cost = float(cost[mask].sum()) if mask.any() else float("inf")
        diversity = float(mask.sum() + len(np.unique(azs[mask]))) if mask.any() else 0.0
        return shortfall, total_cost, -diversity

    def dominated(f1, f2) -> bool:
        """Constrained domination: feasibility first, then Pareto."""
        if f1[0] != f2[0]:
            return f1[0] > f2[0]
        ge = all(a >= b for a, b in zip(f1[1:], f2[1:]))
        gt = any(a > b for a, b in zip(f1[1:], f2[1:]))
        return ge and gt

    pop = rng.random((population, n)) < (req_pods / max(pods.sum(), 1) * 3.0)
    for _ in range(generations):
        fits = [fitness(ind) for ind in pop]
        children = np.empty_like(pop)
        for c in range(population):
            a, b = rng.integers(0, population, size=2)
            parent1 = pop[a] if not dominated(fits[a], fits[b]) else pop[b]
            a, b = rng.integers(0, population, size=2)
            parent2 = pop[a] if not dominated(fits[a], fits[b]) else pop[b]
            cross = rng.random(n) < 0.5
            child = np.where(cross, parent1, parent2)
            flip = rng.random(n) < (2.0 / n)
            children[c] = child ^ flip
        pop = children

    fits = [fitness(ind) for ind in pop]
    feasible = [i for i, f in enumerate(fits) if f[0] == 0.0]
    pick = (min(feasible, key=lambda i: fits[i][1]) if feasible
            else min(range(population), key=lambda i: fits[i]))
    pool = _empty(items)
    for i in np.nonzero(pop[pick])[0]:
        pool.counts[int(i)] = per_type_count
    return pool.nonzero()


# ---------------------------------------------------------------------------
# Karpenter-like (price-capacity-optimized SpotFleet policy, §5.4)
# ---------------------------------------------------------------------------

def karpenter_like(items: Sequence[CandidateItem], req_pods: int) -> NodePool:
    """AWS price-capacity-optimized: blend price and pool-depth ranks, then
    consolidate onto the winning type.  No benchmark-score awareness, no
    multi-node T3 bound — the paper's Fig. 10 behaviour (few large types)."""
    usable = [i for i, it in enumerate(items) if it.pods > 0]
    if not usable:
        return _empty(items)
    price = np.array([items[i].spot_price / items[i].pods for i in usable])
    depth = np.array([items[i].t3 for i in usable], dtype=np.float64)
    # rank 0 = best: cheap per pod, deep capacity pool, big instance
    price_rank = np.argsort(np.argsort(price))
    depth_rank = np.argsort(np.argsort(-depth))
    size_rank = np.argsort(np.argsort(
        [-items[i].offering.vcpus for i in usable]))
    score = 0.5 * price_rank + 0.35 * depth_rank + 0.15 * size_rank
    best = usable[int(np.argmin(score))]
    pool = _empty(items)
    pool.counts[best] = math.ceil(req_pods / items[best].pods)
    return pool.nonzero()
