"""The full KubePACS pipeline (paper §3 + §4): preprocessing → ILP×GSS →
node pool, plus the reactive spot-interruption handling loop of §4.1.

`KubePACSProvisioner` is the controller-side object the data plane talks to:

    decision = provisioner.provision(request, market.snapshot())
    ...
    events = market.interrupts_for_pool(decision.pool.as_dict())
    replacement = provisioner.handle_interrupts(events, request, market.snapshot())

Interrupted offerings land in the `UnavailableOfferingsCache` (TTL'd) and are
excluded from the next optimization cycle, mirroring the Karpenter-fork
implementation in the paper.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Callable, Dict, Iterable, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np

from .backend import CoarseningConfig, SolverBackend
from .efficiency import (CandidateItem, NodePool, Request, decision_metrics,
                         pods_per_instance)
from .gss import (GssTrace, bracketed_gss, bracketed_gss_many,
                  golden_section_search)
from .ilp import CompiledMarket, compile_market
from .market import InterruptEvent, Offering
from .scaling import build_base_price_index, scaled_benchmark_score


class UnavailableOfferingsCache:
    """TTL cache of interrupted offerings excluded from re-optimization."""

    def __init__(self, ttl_hours: float = 2.0):
        self.ttl = ttl_hours
        self._entries: Dict[str, float] = {}   # offering_id -> expiry time

    def add(self, offering_id: str, now: float) -> None:
        self._entries[offering_id] = now + self.ttl

    def excluded(self, now: float) -> Set[str]:
        self._entries = {k: v for k, v in self._entries.items() if v > now}
        return set(self._entries)

    def __len__(self) -> int:
        return len(self._entries)


@dataclasses.dataclass
class ProvisioningDecision:
    pool: NodePool
    trace: Optional[GssTrace]
    alpha: Optional[float]
    wall_seconds: float
    excluded_offerings: Set[str]
    metrics: Dict[str, float]
    # diagnostic provenance (e.g. {"memo_hit": 1.0} when the pool came from
    # the cross-replica DecisionMemo).  compare=False keeps the fleet ≡
    # standalone decision-equality contract intact: a memoized decision
    # equals the freshly-solved one it was cached from (DESIGN.md §11)
    cache: Dict[str, float] = dataclasses.field(default_factory=dict,
                                                compare=False)


class DecisionMemo:
    """Cross-replica decision memoization (DESIGN.md §11).

    The fleet engine sets :attr:`context` to a token capturing everything
    decision-relevant that lives *outside* the provisioning call — the
    shared market-state index and the policy's internal-state digest —
    before each replica's decision.  The policy/provisioner side then keys
    the solve on ``(context, request shape + pods, excluded offerings)``:
    replicas whose keys coincide share one GSS×ILP solve, turning
    O(replicas · solves) into O(unique · solves).  ``context=None`` (the
    default, and the standalone-``ClusterSim`` state) disables lookups, so
    attaching a memo can never change single-run behavior.

    Correctness rests on the policy determinism contract (DESIGN.md §9):
    a decision is a pure function of (market snapshot, request, excluded
    set, policy state), all of which the key covers.  Stored decisions are
    returned by reference — engine code never mutates a decision's pool,
    trace, or metrics after launch — with only the diagnostic
    ``wall_seconds``/``cache`` fields rewritten per hit.
    """

    def __init__(self) -> None:
        self._store: Dict = {}
        self.context: Optional[Tuple] = None
        self.hits = 0
        self.misses = 0

    def key(self, request: Request, excluded: Set[str]) -> Optional[Tuple]:
        if self.context is None:
            return None
        return (self.context, request.pods, request.cpu_per_pod,
                request.mem_per_pod, request.workload, frozenset(excluded))

    def lookup(self, key) -> Optional[ProvisioningDecision]:
        hit = self._store.get(key)
        if hit is None:
            self.misses += 1
        else:
            self.hits += 1
        return hit

    def fetch(self, key, wall_seconds: float,
              ) -> Optional[ProvisioningDecision]:
        """Lookup plus the per-hit diagnostic stamping every memoized
        provision path shares: a hit comes back with fresh ``wall_seconds``
        and memo provenance in ``cache``.  Only ``cache`` is
        ``compare=False``; ``wall_seconds`` participates in equality, so
        full ``==`` against a standalone decision holds exactly when the
        wall clock is injected (tests use ``clock=lambda: 0.0``) — the
        record-level and field-level equality contracts are
        clock-independent because records never include wall time."""
        hit = self.lookup(key)
        if hit is None:
            return None
        return dataclasses.replace(hit, wall_seconds=wall_seconds,
                                   cache={"memo_hit": 1.0})

    def store(self, key, decision: ProvisioningDecision) -> None:
        self._store[key] = decision

    def count_hit(self) -> None:
        """Record a hit served outside :meth:`fetch` — the collect-then-solve
        batch path counts a duplicate pending key as a memo hit, keeping the
        hit/miss counters identical to the sequential path's
        (DESIGN.md §12)."""
        self.hits += 1

    @property
    def unique_solves(self) -> int:
        return len(self._store)

    def stats(self) -> Dict[str, int]:
        return {"memo_hits": self.hits, "memo_misses": self.misses,
                "memo_unique_solves": self.unique_solves}


class PendingDecision:
    """Placeholder for a decision whose GSS×ILP solve was deferred into a
    :class:`SolveBatch` (the fleet engine's collect-then-solve tick phase,
    DESIGN.md §12).  ``resolve()`` is valid only after the owning batch's
    :meth:`SolveBatch.execute` ran; a *hit* token (duplicate memo key) gets
    the shared decision re-stamped exactly like a sequential memo hit."""

    __slots__ = ("_job", "_hit", "_wall")

    def __init__(self, job: "_SolveJob", hit: bool, wall: float):
        self._job = job
        self._hit = hit
        self._wall = wall

    def resolve(self) -> ProvisioningDecision:
        if self._job.decision is None:
            raise RuntimeError("PendingDecision.resolve() before "
                               "SolveBatch.execute() — the collect phase "
                               "must run the batch before launching")
        if self._hit:
            return dataclasses.replace(self._job.decision,
                                       wall_seconds=self._wall,
                                       cache={"memo_hit": 1.0})
        return self._job.decision


@dataclasses.dataclass
class _SolveJob:
    """One deferred guarded-GSS solve plus its decision-builder."""

    items: List[CandidateItem]
    market: CompiledMarket
    req_pods: int
    exclude: Optional[np.ndarray]
    tolerance: float
    timer: Callable[[], float]
    finish: Callable[[Optional[NodePool], GssTrace], ProvisioningDecision]
    coarsening: Optional[CoarseningConfig] = None
    decision: Optional[ProvisioningDecision] = None


class SolveBatch:
    """Collect-then-solve executor (DESIGN.md §12).

    During a fleet tick's collect phase, provisioners with a batch attached
    enqueue their memo-miss solves here instead of running them inline;
    duplicate memo keys collapse onto the first job (and count as memo
    hits, exactly like the sequential path).  ``execute()`` groups the
    collected jobs by compiled market and runs each group through one
    :func:`~repro.core.gss.bracketed_gss_many` — every decision's pools and
    traces are bit-identical to inline solving because the batched search
    *is* the sequential search at dispatch granularity.
    """

    def __init__(self, backend: Optional[SolverBackend] = None):
        if isinstance(backend, str):
            from .backend import make_backend
            backend = make_backend(backend)
        self.backend = backend
        self._jobs: List[_SolveJob] = []
        self._by_key: Dict = {}

    def __len__(self) -> int:
        return len(self._jobs)

    def pending(self, key, wall: float) -> Optional[PendingDecision]:
        """A hit token for an already-enqueued key, else None."""
        job = self._by_key.get(key)
        if job is None:
            return None
        return PendingDecision(job, hit=True, wall=wall)

    def enqueue(self, key, *, items, market, req_pods, exclude, tolerance,
                timer, finish, coarsening=None) -> PendingDecision:
        job = _SolveJob(items=items, market=market, req_pods=req_pods,
                        exclude=exclude, tolerance=tolerance, timer=timer,
                        finish=finish, coarsening=coarsening)
        self._jobs.append(job)
        if key is not None:
            self._by_key[key] = job
        return PendingDecision(job, hit=False, wall=0.0)

    def execute(self) -> int:
        """Solve every collected job (one batched search per compiled
        market) and build their decisions.  Returns the job count."""
        jobs, self._jobs, self._by_key = self._jobs, [], {}
        groups: Dict = {}
        for job in jobs:
            gkey = (id(job.market), job.tolerance, id(job.timer),
                    job.coarsening)
            groups.setdefault(gkey, []).append(job)
        for group in groups.values():
            results = bracketed_gss_many(
                group[0].items, [j.req_pods for j in group],
                tolerance=group[0].tolerance, market=group[0].market,
                excludes=[j.exclude for j in group], timer=group[0].timer,
                backend=self.backend, coarsening=group[0].coarsening)
            for job, (pool, trace) in zip(group, results):
                job.decision = job.finish(pool, trace)
        return len(jobs)


def exclusion_mask(items: Sequence[CandidateItem], excluded: Set[str],
                   extra: Optional[np.ndarray] = None,
                   ) -> Optional[np.ndarray]:
    """Boolean solver mask over ``items`` for the TTL-cached offering_ids —
    the single definition of exclusion semantics, shared by the KubePACS
    provisioner and every scenario-engine policy.  ``extra`` ORs a
    caller-supplied feasibility mask (e.g. the serving SLO mask of
    DESIGN.md §15) into the same path, so additional hard constraints
    reach ``solve_ilp`` exactly like §4.1 interrupt exclusions."""
    if not excluded and extra is None:
        return None
    mask = np.array([it.offering.offering_id in excluded for it in items],
                    dtype=bool)
    if extra is not None:
        mask |= np.asarray(extra, dtype=bool)
    return mask


def preprocess(catalog: Sequence[Offering], request: Request,
               excluded: Optional[Set[str]] = None) -> List[CandidateItem]:
    """Stage 1 of Algorithm 1 (DatasetPreProcessing, lines 3–6)."""
    excluded = excluded or set()
    base_prices = build_base_price_index(catalog)
    items: List[CandidateItem] = []
    for o in catalog:
        if o.offering_id in excluded or o.spot_price <= 0 or o.t3 <= 0:
            continue
        pods = pods_per_instance(o, request)
        if pods < 1:
            continue
        bs = scaled_benchmark_score(o, set(request.workload), base_prices)
        items.append(CandidateItem(offering=o, pods=pods, bs=bs,
                                   spot_price=o.spot_price, t3=o.t3))
    return items


class KubePACSProvisioner:
    """ILP + GSS provisioning with §4.1 interrupt handling."""

    def __init__(self, tolerance: float = 0.01, ttl_hours: float = 2.0,
                 guarded_gss: bool = True,
                 timer: Callable[[], float] = time.perf_counter,
                 coarsening: Optional[CoarseningConfig] = None,
                 backend: Optional[SolverBackend] = None):
        self.tolerance = tolerance
        self.guarded_gss = guarded_gss   # bracketed prescan (DESIGN.md §7)
        # pinned solver backend for inline solves (None = the process
        # default).  The chaos degradation ladder (DESIGN.md §16) uses
        # this to run per-rung provisioners; the batch path keeps the
        # process backend (batching is fleet-engine-owned).
        self.backend = backend
        # demand-coarsening policy threaded into every solve (None = the
        # process-wide DEFAULT_COARSENING, inert at the paper's scales)
        self.coarsening = coarsening
        self.cache = UnavailableOfferingsCache(ttl_hours)
        self.event_queue: collections.deque[InterruptEvent] = collections.deque()
        self.clock = 0.0   # advanced by the caller (simulator hours)
        # wall timer for the diagnostic wall_seconds stamps; injectable so
        # tests can assert full ProvisioningDecision equality (decision
        # *content* never depends on it)
        self.timer = timer
        # compiled-market cache (DESIGN.md §8): bundle splits / pod / bound
        # arrays depend only on the catalog snapshot and the request's
        # per-pod shape, so re-optimisation against the *same* snapshot
        # object (§4.1 interrupt handling within a market step, demand
        # resizing) skips preprocessing; a fresh snapshot (prices moved)
        # correctly rebuilds.
        self._market_catalog: Optional[Sequence[Offering]] = None
        self._market_shape: Optional[Tuple] = None
        self._market_items: List[CandidateItem] = []
        self._market: Optional[CompiledMarket] = None
        # cross-replica decision memo (attached by the fleet engine; None =
        # standalone operation, memo lookups disabled)
        self.decision_memo: Optional[DecisionMemo] = None
        # collect-then-solve batch (attached by the fleet engine; None =
        # inline solving).  Only the guarded-GSS path batches; the
        # unguarded search solves inline regardless (DESIGN.md §12).
        self.solve_batch: Optional[SolveBatch] = None

    def _compiled(self, request: Request, catalog: Sequence[Offering],
                  precompiled: Optional[Tuple[List[CandidateItem],
                                              CompiledMarket]] = None,
                  ) -> Tuple[List[CandidateItem], CompiledMarket]:
        if precompiled is not None:
            # scenario-engine sharing hook: N replica provisioners solving
            # against the same snapshot reuse one preprocessed candidate set
            # + CompiledMarket (candidate shape ignores request.pods, so a
            # shortfall-sized replacement request shares it too)
            return precompiled
        # the held reference keeps the snapshot alive, so the identity check
        # cannot alias a recycled object id
        shape = (request.cpu_per_pod, request.mem_per_pod, request.workload)
        if catalog is not self._market_catalog or shape != self._market_shape:
            items = preprocess(catalog, request)
            self._market_catalog = catalog
            self._market_shape = shape
            self._market_items = items
            self._market = compile_market(items)
        return self._market_items, self._market

    # -- main optimization cycle -------------------------------------------
    def provision(self, request: Request, catalog: Sequence[Offering],
                  precompiled: Optional[Tuple[List[CandidateItem],
                                              CompiledMarket]] = None,
                  ) -> ProvisioningDecision | PendingDecision:
        """One optimization cycle.  With a :class:`SolveBatch` attached (the
        fleet engine's collect phase) a memo-miss returns a
        :class:`PendingDecision` token instead of solving inline; the
        engine resolves tokens after ``SolveBatch.execute()``."""
        t0 = self.timer()
        excluded = self.cache.excluded(self.clock)
        memo = self.decision_memo
        mkey = memo.key(request, excluded) if memo is not None else None
        batch = self.solve_batch if self.guarded_gss else None
        if mkey is not None:
            if batch is not None:
                tok = batch.pending(mkey, self.timer() - t0)
                if tok is not None:      # same key already collected this
                    memo.count_hit()     # phase: a memo hit, shared solve
                    return tok
            hit = memo.fetch(mkey, self.timer() - t0)
            if hit is not None:
                return hit
        items, market = self._compiled(request, catalog, precompiled)
        exclude = exclusion_mask(items, excluded)
        if batch is not None:
            def finish(pool, trace, _request=request, _excluded=excluded,
                       _mkey=mkey, _t0=t0):
                return self._finalize(_request, _excluded, pool, trace,
                                      _t0, _mkey)
            return batch.enqueue(mkey, items=items, market=market,
                                 req_pods=request.pods, exclude=exclude,
                                 tolerance=self.tolerance, timer=self.timer,
                                 finish=finish, coarsening=self.coarsening)
        search = bracketed_gss if self.guarded_gss else golden_section_search
        pool, trace = search(items, request.pods, tolerance=self.tolerance,
                             market=market, exclude=exclude, timer=self.timer,
                             backend=self.backend,
                             coarsening=self.coarsening)
        return self._finalize(request, excluded, pool, trace, t0, mkey)

    def _finalize(self, request: Request, excluded: Set[str],
                  pool: Optional[NodePool], trace: GssTrace, t0: float,
                  mkey) -> ProvisioningDecision:
        """Post-search decision assembly, shared by the inline path and the
        batch ``finish`` callbacks so both build identical decisions."""
        wall = self.timer() - t0
        if pool is None:   # demand exceeds bounded capacity: surface it
            pool = NodePool(items=[], counts=[], request=request)
            alpha = None
        else:
            pool.request = request
            alpha = pool.alpha
        metrics = decision_metrics(pool, request.pods)
        decision = ProvisioningDecision(pool=pool, trace=trace, alpha=alpha,
                                        wall_seconds=wall,
                                        excluded_offerings=excluded,
                                        metrics=metrics)
        if mkey is not None:
            self.decision_memo.store(mkey, decision)
        return decision

    # -- §4.1 reactive loop ---------------------------------------------------
    def enqueue(self, events: Iterable[InterruptEvent]) -> None:
        """Spot Interrupt Event Messages → Spot Interrupt Event Queue."""
        self.event_queue.extend(events)

    def handle_interrupts(self, request: Request,
                          catalog: Sequence[Offering],
                          surviving_pods: int = 0,
                          precompiled: Optional[Tuple[List[CandidateItem],
                                                      CompiledMarket]] = None,
                          ) -> Optional[ProvisioningDecision | PendingDecision]:
        """Drain the queue, cache interrupted offerings, re-optimize.

        ``surviving_pods`` is the capacity still alive in the cluster; the
        replacement request covers only the shortfall (rapid recovery, §4.1).
        Returns None when the queue was empty or nothing is missing.
        """
        drained = False
        while self.event_queue:
            ev = self.event_queue.popleft()
            self.cache.add(ev.offering_id, self.clock)
            drained = True
        if not drained:
            return None
        shortfall = max(0, request.pods - surviving_pods)
        if shortfall == 0:
            return None
        repl_request = dataclasses.replace(request, pods=shortfall)
        return self.provision(repl_request, catalog, precompiled)


def merge_pools(base: NodePool, extra: NodePool) -> NodePool:
    """Union of two decisions (replacement capacity joins the survivors)."""
    counts: Dict[str, int] = collections.Counter()
    items: Dict[str, CandidateItem] = {}
    for pool in (base, extra):
        for it, c in zip(pool.items, pool.counts):
            counts[it.offering.offering_id] += c
            items[it.offering.offering_id] = it
    merged_items = list(items.values())
    merged_counts = [counts[it.offering.offering_id] for it in merged_items]
    return NodePool(items=merged_items, counts=merged_counts,
                    alpha=base.alpha, request=base.request)
