"""Workload-aware performance scaling (paper §3.3, Eq. 8) — the Karpenter
scaling integration point.

CoreMark can't see network/disk hardware, so for instances whose
specialization matches the declared workload intent the benchmark score is
scaled by the on-demand price ratio to the general-purpose sibling
(symbols as in Table 1 / DESIGN.md):

    BS_i^scaled = BS_i × OP_i / OP_base          (Eq. 8)

where ``OP_base`` is the on-demand price of the general-purpose sibling
``{family}{gen}{vendor}.{size}`` (:meth:`Offering.base_instance_type`,
indexed by :func:`build_base_price_index`).  The rationale: AWS prices the
`n`/`d`/`dn` premium at the value of the specialized hardware, so the
od-price ratio is a market-calibrated proxy for the network/disk
performance CoreMark misses.

Integration with the Karpenter scaling path: this runs inside
DatasetPreProcessing (Alg. 1 lines 3–6, `provisioner.preprocess`) — i.e.
in the same controller pass that Karpenter's provisioner uses to build its
candidate list — *before* the ILP sees the candidates, so the scaled
``BS_i`` flows into ``Perf_i = BS_i·Pod_i`` and hence into both the Eq. 4–5
objective normalization (``Perf_i/Perf_min``) and the Eq. 2 E_PerfCost
score.  Non-matching specializations stay unscaled (the paper's c6id
example); no declared intent ⇒ no scaling.  A wrong intent only
mis-weights specialization; it never breaks feasibility or availability
(paper §3.3 last paragraph).
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence, Set

from .market import Offering

#: specialization kind -> the intents it serves
_SPEC_TO_INTENTS = {
    "general": frozenset(),
    "network": frozenset({"network"}),
    "disk": frozenset({"disk"}),
    "network+disk": frozenset({"network", "disk"}),
}


def build_base_price_index(catalog: Iterable[Offering]) -> Dict[str, float]:
    """Map base_instance_type -> on-demand price of the general-purpose sibling.

    Prices are AZ-independent on AWS; we take the first general offering seen
    for each (family, gen, vendor, size).
    """
    index: Dict[str, float] = {}
    for o in catalog:
        if o.specialization == "general" and o.instance_type not in index:
            index[o.instance_type] = o.od_price
    return index


def matches_intent(offering: Offering, workload: Set[str]) -> bool:
    """Does this offering's specialization serve any declared intent?"""
    serves = _SPEC_TO_INTENTS[offering.specialization]
    return bool(serves & workload)


def scaled_benchmark_score(offering: Offering, workload: Set[str],
                           base_price_index: Dict[str, float]) -> float:
    """Eq. 8 applied per-offering; single-core BS in, scaled BS out."""
    if not workload or not matches_intent(offering, workload):
        return offering.bs_core
    op_base = base_price_index.get(offering.base_instance_type)
    if op_base is None or op_base <= 0:
        # No general sibling in the candidate universe: leave unscaled
        # rather than invent a base price.
        return offering.bs_core
    return offering.bs_core * (offering.od_price / op_base)
