"""Golden Section Search over the cost/performance weight α (paper §3.2, Alg. 1).

GSS maximizes E_Total(α) = E_PerfCost × E_OverPods of the ILP solution at α
over α ∈ [0, 1], shrinking the bracket by φ = (√5−1)/2 ≈ 0.618 per step and
reusing one interior evaluation per iteration (one ILP solve per iteration
after the two initial solves; ≈ 5n+1 iterations for tolerance ε = 10⁻ⁿ,
Eq. 6–7).  The best pool over *all* evaluated α is returned (Alg. 1's S*),
which also guards against mild non-unimodality of the empirical E_Total(α).

Engine wiring (DESIGN.md §8): when running with the default solver, both
searches evaluate against a :class:`~repro.core.ilp.CompiledMarket` built
once per call (or passed in by the provisioner), and ``bracketed_gss``'s
prescan is a single :func:`~repro.core.ilp.solve_ilp_batch` vectorized DP
over the whole α grid instead of ``prescan`` sequential solves.  A custom
``solver`` callable falls back to the seed per-α path unchanged.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .efficiency import (CandidateItem, NodePool, e_total, score_counts_batch)
from .ilp import CompiledMarket, compile_market, solve_ilp, solve_ilp_batch

PHI = (math.sqrt(5.0) - 1.0) / 2.0     # ≈ 0.618


@dataclasses.dataclass
class GssTrace:
    """Every (α, E_Total) the search evaluated — Fig. 6's black lines."""

    alphas: List[float] = dataclasses.field(default_factory=list)
    e_totals: List[float] = dataclasses.field(default_factory=list)
    ilp_solves: int = 0
    wall_seconds: float = 0.0


def expected_iterations(tolerance: float, a: float = 0.0, b: float = 1.0) -> int:
    """Eq. 6: k−1 ≥ ⌈log(ε/(b−a)) / log φ⌉  (≈ 4.784·n for ε=10⁻ⁿ)."""
    return int(math.ceil(math.log(tolerance / (b - a)) / math.log(PHI))) + 1


def _make_evaluator(items: Sequence[CandidateItem], req_pods: int,
                    solver: Callable, market: Optional[CompiledMarket],
                    exclude: Optional[np.ndarray], trace: GssTrace,
                    cache: dict) -> Callable:
    """One (α → (pool, E_Total)) evaluator shared by both searches.

    The engine path solves against the compiled market (memory-flat DP,
    preprocessing already hoisted); a custom ``solver`` keeps the seed
    calling convention for tests and alternative backends.
    """
    use_engine = solver is solve_ilp
    if not use_engine and exclude is not None:
        raise ValueError("exclude masks require the default solve_ilp solver "
                         "(custom solvers have no exclusion channel)")
    if use_engine and market is None:
        market = compile_market(items)

    def evaluate(alpha: float) -> Tuple[Optional[NodePool], float]:
        key = round(alpha, 12)
        if key in cache:
            return cache[key]
        if use_engine:
            counts = solve_ilp(items, req_pods, alpha, market=market,
                               exclude=exclude)
        else:
            counts = solver(items, req_pods, alpha)
        trace.ilp_solves += 1
        if counts is None:
            pool, score = None, float("-inf")
        else:
            pool = NodePool(items=list(items), counts=counts, alpha=alpha)
            score = e_total(pool, req_pods)
        trace.alphas.append(alpha)
        trace.e_totals.append(score if score != float("-inf") else 0.0)
        cache[key] = (pool, score)
        return pool, score

    return evaluate


def golden_section_search(
    items: Sequence[CandidateItem],
    req_pods: int,
    tolerance: float = 0.01,
    alpha_lo: float = 0.0,
    alpha_hi: float = 1.0,
    solver: Callable[[Sequence[CandidateItem], int, float], Optional[List[int]]] = solve_ilp,
    market: Optional[CompiledMarket] = None,
    exclude: Optional[np.ndarray] = None,
    timer: Callable[[], float] = time.perf_counter,
) -> Tuple[Optional[NodePool], GssTrace]:
    """Algorithm 1 (lines 7–27).  Returns (best pool S*, evaluation trace).

    ``timer`` stamps ``GssTrace.wall_seconds``; inject a fake for tests that
    assert full decision equality (wall time is diagnostic, never decision
    content)."""
    trace = GssTrace()
    t0 = timer()
    cache: dict[float, Tuple[Optional[NodePool], float]] = {}
    evaluate = _make_evaluator(items, req_pods, solver, market, exclude,
                               trace, cache)

    a, b = alpha_lo, alpha_hi
    x1 = b - PHI * (b - a)
    x2 = a + PHI * (b - a)
    pool1, f1 = evaluate(x1)
    pool2, f2 = evaluate(x2)
    best_pool, best_f = (pool1, f1) if f1 >= f2 else (pool2, f2)

    while (b - a) > tolerance:
        if f1 >= f2:
            b = x2
            x2, f2, pool2 = x1, f1, pool1
            x1 = b - PHI * (b - a)
            pool1, f1 = evaluate(x1)
            if f1 > best_f:
                best_pool, best_f = pool1, f1
        else:
            a = x1
            x1, f1, pool1 = x2, f2, pool2
            x2 = a + PHI * (b - a)
            pool2, f2 = evaluate(x2)
            if f2 > best_f:
                best_pool, best_f = pool2, f2

    trace.wall_seconds = timer() - t0
    if best_pool is not None:
        best_pool = best_pool.nonzero()
    return best_pool, trace


def bracketed_gss(
    items: Sequence[CandidateItem],
    req_pods: int,
    tolerance: float = 0.01,
    prescan: int = 9,
    solver: Callable[[Sequence[CandidateItem], int, float], Optional[List[int]]] = solve_ilp,
    market: Optional[CompiledMarket] = None,
    exclude: Optional[np.ndarray] = None,
    timer: Callable[[], float] = time.perf_counter,
) -> Tuple[Optional[NodePool], GssTrace]:
    """Guarded GSS (beyond-paper robustness hardening, DESIGN.md §7).

    The paper's Fig. 6 landscapes are empirically unimodal; a synthetic or
    adversarial market can produce secondary bumps that trap pure GSS in the
    wrong bracket.  We first scan ``prescan`` equispaced α (one *batched*
    vectorized DP with the default solver — constant extra ILP solves, a
    single numpy pass), then run Algorithm 1 inside the grid cell bracketing
    the best scan point.  Degrades gracefully to pure GSS quality on
    unimodal landscapes; strictly better on bumpy ones.
    """
    grid = [i / (prescan - 1) for i in range(prescan)]
    use_engine = solver is solve_ilp
    scan_trace = GssTrace()
    t0 = timer()

    if use_engine:
        if market is None:
            market = compile_market(items)
        all_counts = solve_ilp_batch(items, req_pods, grid, market=market,
                                     exclude=exclude)
        scan_trace.ilp_solves += len(grid)
        scores = score_counts_batch(
            items, all_counts, req_pods, none_score=float("-inf"),
            arrays=market.metric_arrays)
        pools = [None if counts is None
                 else NodePool(items=list(items), counts=counts)
                 for counts in all_counts]
    else:
        if exclude is not None:
            raise ValueError("exclude masks require the default solve_ilp "
                             "solver (custom solvers have no exclusion "
                             "channel)")
        scores, pools = [], []
        for alpha in grid:
            counts = solver(items, req_pods, alpha)
            scan_trace.ilp_solves += 1
            if counts is None:
                scores.append(float("-inf"))
                pools.append(None)
            else:
                pool = NodePool(items=list(items), counts=counts, alpha=alpha)
                scores.append(e_total(pool, req_pods))
                pools.append(pool)

    best_pool, best_f, best_idx = None, float("-inf"), 0
    for gi, (alpha, score, pool) in enumerate(zip(grid, scores, pools)):
        if pool is not None:
            pool.alpha = alpha
        scan_trace.alphas.append(alpha)
        scan_trace.e_totals.append(max(score, 0.0))
        if score > best_f:
            best_pool, best_f, best_idx = pool, score, gi

    lo = grid[max(0, best_idx - 1)]
    hi = grid[min(len(grid) - 1, best_idx + 1)]
    pool, trace = golden_section_search(items, req_pods, tolerance=tolerance,
                                        alpha_lo=lo, alpha_hi=hi,
                                        solver=solver, market=market,
                                        exclude=exclude, timer=timer)
    # merge traces and keep the global argmax
    trace.alphas = scan_trace.alphas + trace.alphas
    trace.e_totals = scan_trace.e_totals + trace.e_totals
    trace.ilp_solves += scan_trace.ilp_solves
    trace.wall_seconds = timer() - t0
    inner_f = e_total(pool, req_pods) if pool is not None else float("-inf")
    if best_pool is not None and best_f > inner_f:
        return best_pool.nonzero(), trace
    return pool, trace
