"""Golden Section Search over the cost/performance weight α (paper §3.2, Alg. 1).

GSS maximizes E_Total(α) = E_PerfCost × E_OverPods of the ILP solution at α
over α ∈ [0, 1], shrinking the bracket by φ = (√5−1)/2 ≈ 0.618 per step and
reusing one interior evaluation per iteration (one ILP solve per iteration
after the two initial solves; ≈ 5n+1 iterations for tolerance ε = 10⁻ⁿ,
Eq. 6–7).  The best pool over *all* evaluated α is returned (Alg. 1's S*),
which also guards against mild non-unimodality of the empirical E_Total(α).

Engine wiring (DESIGN.md §8 + §12): with the default solver,
``bracketed_gss`` is the one-decision case of :func:`bracketed_gss_many`,
the *cross-decision batched* search: D decisions (each with its own
demand and §4.1 exclusion mask) advance their prescans and golden-section
brackets in lockstep, and every round's pending α probes across all
decisions go to :func:`~repro.core.ilp.solve_ilp_many` as one stacked
engine invocation (one backend dispatch).  Each decision's (α, E_Total)
evaluation sequence — and therefore its selected pool and trace — is
exactly the sequential algorithm's; batching changes execution, never
content.  A custom ``solver`` callable falls back to the seed per-α path
unchanged.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .backend import CoarseningConfig, SolverBackend
from .efficiency import (CandidateItem, NodePool, e_total,
                         score_counts_batch, score_counts_many)
from .ilp import (CompiledMarket, compile_market, solve_ilp, solve_ilp_many)

PHI = (math.sqrt(5.0) - 1.0) / 2.0     # ≈ 0.618


@dataclasses.dataclass
class GssTrace:
    """Every (α, E_Total) the search evaluated — Fig. 6's black lines."""

    alphas: List[float] = dataclasses.field(default_factory=list)
    e_totals: List[float] = dataclasses.field(default_factory=list)
    ilp_solves: int = 0
    wall_seconds: float = 0.0


@functools.lru_cache(maxsize=256)
def expected_iterations(tolerance: float, a: float = 0.0, b: float = 1.0) -> int:
    """Eq. 6: k−1 ≥ ⌈log(ε/(b−a)) / log φ⌉  (≈ 4.784·n for ε=10⁻ⁿ).

    Cached: the (tolerance, bracket) universe of a run is tiny and callers
    historically re-derived it per provisioning cycle.
    """
    return int(math.ceil(math.log(tolerance / (b - a)) / math.log(PHI))) + 1


def _make_evaluator(items: Sequence[CandidateItem], req_pods: int,
                    solver: Callable, market: Optional[CompiledMarket],
                    exclude: Optional[np.ndarray], trace: GssTrace,
                    cache: dict,
                    backend: Optional[SolverBackend] = None,
                    coarsening: Optional[CoarseningConfig] = None,
                    ) -> Callable:
    """One (α → (pool, E_Total)) evaluator shared by both searches.

    The engine path solves against the compiled market with the objective
    row rebuilt from normalised vectors cached *once* per (market, mask) —
    ``market.norms(exclude)`` — instead of re-deriving the masked
    normalisation on every α probe (bit-identical by construction).  A
    custom ``solver`` keeps the seed calling convention for tests and
    alternative backends.
    """
    use_engine = solver is solve_ilp
    if not use_engine and exclude is not None:
        raise ValueError("exclude masks require the default solve_ilp solver "
                         "(custom solvers have no exclusion channel)")
    if use_engine and market is None:
        market = compile_market(items)
    if use_engine:
        perf_norm, price_norm = market.norms(exclude)

    def evaluate(alpha: float) -> Tuple[Optional[NodePool], float]:
        key = round(alpha, 12)
        if key in cache:
            return cache[key]
        if use_engine:
            coef = -alpha * perf_norm + (1.0 - alpha) * price_norm
            counts = solve_ilp(items, req_pods, alpha, market=market,
                               exclude=exclude, backend=backend, coef=coef,
                               coarsening=coarsening)
        else:
            counts = solver(items, req_pods, alpha)
        trace.ilp_solves += 1
        if counts is None:
            pool, score = None, float("-inf")
        else:
            pool = NodePool(items=list(items), counts=counts, alpha=alpha)
            score = e_total(pool, req_pods)
        trace.alphas.append(alpha)
        trace.e_totals.append(score if score != float("-inf") else 0.0)
        cache[key] = (pool, score)
        return pool, score

    return evaluate


def golden_section_search(
    items: Sequence[CandidateItem],
    req_pods: int,
    tolerance: float = 0.01,
    alpha_lo: float = 0.0,
    alpha_hi: float = 1.0,
    solver: Callable[[Sequence[CandidateItem], int, float], Optional[List[int]]] = solve_ilp,
    market: Optional[CompiledMarket] = None,
    exclude: Optional[np.ndarray] = None,
    timer: Callable[[], float] = time.perf_counter,
    backend: Optional[SolverBackend] = None,
    coarsening: Optional[CoarseningConfig] = None,
) -> Tuple[Optional[NodePool], GssTrace]:
    """Algorithm 1 (lines 7–27).  Returns (best pool S*, evaluation trace).

    ``timer`` stamps ``GssTrace.wall_seconds``; inject a fake for tests that
    assert full decision equality (wall time is diagnostic, never decision
    content)."""
    trace = GssTrace()
    t0 = timer()
    cache: dict[float, Tuple[Optional[NodePool], float]] = {}
    evaluate = _make_evaluator(items, req_pods, solver, market, exclude,
                               trace, cache, backend, coarsening)

    a, b = alpha_lo, alpha_hi
    x1 = b - PHI * (b - a)
    x2 = a + PHI * (b - a)
    pool1, f1 = evaluate(x1)
    pool2, f2 = evaluate(x2)
    best_pool, best_f = (pool1, f1) if f1 >= f2 else (pool2, f2)

    while (b - a) > tolerance:
        if f1 >= f2:
            b = x2
            x2, f2, pool2 = x1, f1, pool1
            x1 = b - PHI * (b - a)
            pool1, f1 = evaluate(x1)
            if f1 > best_f:
                best_pool, best_f = pool1, f1
        else:
            a = x1
            x1, f1, pool1 = x2, f2, pool2
            x2 = a + PHI * (b - a)
            pool2, f2 = evaluate(x2)
            if f2 > best_f:
                best_pool, best_f = pool2, f2

    trace.wall_seconds = timer() - t0
    if best_pool is not None:
        best_pool = best_pool.nonzero()
    return best_pool, trace


def bracketed_gss(
    items: Sequence[CandidateItem],
    req_pods: int,
    tolerance: float = 0.01,
    prescan: int = 9,
    solver: Callable[[Sequence[CandidateItem], int, float], Optional[List[int]]] = solve_ilp,
    market: Optional[CompiledMarket] = None,
    exclude: Optional[np.ndarray] = None,
    timer: Callable[[], float] = time.perf_counter,
    backend: Optional[SolverBackend] = None,
    coarsening: Optional[CoarseningConfig] = None,
) -> Tuple[Optional[NodePool], GssTrace]:
    """Guarded GSS (beyond-paper robustness hardening, DESIGN.md §7).

    The paper's Fig. 6 landscapes are empirically unimodal; a synthetic or
    adversarial market can produce secondary bumps that trap pure GSS in the
    wrong bracket.  We first scan ``prescan`` equispaced α (one batched
    engine invocation with the default solver), then run Algorithm 1 inside
    the grid cell bracketing the best scan point.  Degrades gracefully to
    pure GSS quality on unimodal landscapes; strictly better on bumpy ones.

    With the default solver this *is* :func:`bracketed_gss_many` at
    ``D = 1`` — one implementation, so the batched tick phase of the fleet
    engine and the sequential path can never diverge (DESIGN.md §12).
    """
    if solver is solve_ilp:
        return bracketed_gss_many(
            items, [req_pods], tolerance=tolerance, prescan=prescan,
            market=market, excludes=[exclude], timer=timer,
            backend=backend, coarsening=coarsening)[0]

    # custom-solver fallback: the seed per-α path, unchanged
    if exclude is not None:
        raise ValueError("exclude masks require the default solve_ilp "
                         "solver (custom solvers have no exclusion "
                         "channel)")
    grid = [i / (prescan - 1) for i in range(prescan)]
    scan_trace = GssTrace()
    t0 = timer()
    scores, pools = [], []
    for alpha in grid:
        counts = solver(items, req_pods, alpha)
        scan_trace.ilp_solves += 1
        if counts is None:
            scores.append(float("-inf"))
            pools.append(None)
        else:
            pool = NodePool(items=list(items), counts=counts, alpha=alpha)
            scores.append(e_total(pool, req_pods))
            pools.append(pool)

    best_pool, best_f, best_idx = None, float("-inf"), 0
    for gi, (alpha, score, pool) in enumerate(zip(grid, scores, pools)):
        if pool is not None:
            pool.alpha = alpha
        scan_trace.alphas.append(alpha)
        scan_trace.e_totals.append(max(score, 0.0))
        if score > best_f:
            best_pool, best_f, best_idx = pool, score, gi

    lo = grid[max(0, best_idx - 1)]
    hi = grid[min(len(grid) - 1, best_idx + 1)]
    pool, trace = golden_section_search(items, req_pods, tolerance=tolerance,
                                        alpha_lo=lo, alpha_hi=hi,
                                        solver=solver, market=market,
                                        exclude=exclude, timer=timer)
    # merge traces and keep the global argmax
    trace.alphas = scan_trace.alphas + trace.alphas
    trace.e_totals = scan_trace.e_totals + trace.e_totals
    trace.ilp_solves += scan_trace.ilp_solves
    trace.wall_seconds = timer() - t0
    inner_f = e_total(pool, req_pods) if pool is not None else float("-inf")
    if best_pool is not None and best_f > inner_f:
        return best_pool.nonzero(), trace
    return pool, trace


class _GssState:
    """One decision's sequential-GSS state, advanced in lockstep."""

    __slots__ = ("req", "exclude", "idx", "t0", "scan_trace", "trace",
                 "cache", "scan_pool", "scan_f", "a", "b", "x1", "x2",
                 "f1", "f2", "pool1", "pool2", "best_pool", "best_f",
                 "done")

    def __init__(self, req: int, exclude: Optional[np.ndarray]):
        self.req = req
        self.exclude = exclude
        self.scan_trace = GssTrace()
        self.trace = GssTrace()
        self.cache: dict = {}
        self.scan_pool: Optional[NodePool] = None
        self.scan_f = float("-inf")
        self.best_pool: Optional[NodePool] = None
        self.best_f = float("-inf")
        self.done = False


def bracketed_gss_many(
    items: Sequence[CandidateItem],
    req_pods_list: Sequence[int],
    tolerance: float = 0.01,
    prescan: int = 9,
    market: Optional[CompiledMarket] = None,
    excludes: Optional[Sequence[Optional[np.ndarray]]] = None,
    timer: Callable[[], float] = time.perf_counter,
    backend: Optional[SolverBackend] = None,
    coarsening: Optional[CoarseningConfig] = None,
) -> List[Tuple[Optional[NodePool], GssTrace]]:
    """Cross-decision batched guarded GSS (DESIGN.md §12).

    Runs D guarded searches — one per (demand, exclusion mask) — in
    lockstep: the D prescans form one :func:`solve_ilp_many` invocation,
    and every golden-section round batches the decisions' pending α probes
    into the next one.  Per decision, the evaluation order, cache
    behaviour, trace content, and returned pool are *exactly* those of the
    sequential :func:`bracketed_gss`; only the dispatch granularity
    changes.  Scoring deliberately runs per decision with the same array
    shapes as the sequential path (``score_counts_batch`` over that
    decision's grid, scalar ``e_total`` per golden probe) so every float
    matches bit-for-bit.
    """
    n_dec = len(req_pods_list)
    if excludes is None:
        excludes = [None] * n_dec
    if len(excludes) != n_dec:
        raise ValueError("excludes must match len(req_pods_list)")
    grid = [i / (prescan - 1) for i in range(prescan)]
    if market is None:
        market = compile_market(items)

    states = [_GssState(req, ex) for req, ex in zip(req_pods_list, excludes)]
    for i, st in enumerate(states):
        st.idx = i
        st.t0 = timer()

    # -- fused device plane (DESIGN.md §13): backends that support it run
    # the whole batch (prescan grid + speculative golden rounds) on device
    # and hand back a replay record; the lockstep loop below then consumes
    # recorded counts instead of dispatching per round.  Control flow,
    # scoring, traces, and selections are the sequential path's either way.
    record = None
    if backend is not None and getattr(backend, "supports_fused_gss", False):
        record = backend.fused_gss_record(items, market, list(req_pods_list),
                                          list(excludes), grid, tolerance,
                                          coarsening=coarsening)

    # -- prescan: one stacked engine invocation over every (decision, α) --
    if record is not None:
        all_counts = record.prescan
    else:
        all_counts = solve_ilp_many(items, list(req_pods_list), grid,
                                    market=market, excludes=list(excludes),
                                    backend=backend, coarsening=coarsening)
    all_scores = score_counts_many(items, all_counts, list(req_pods_list),
                                   none_score=float("-inf"),
                                   arrays=market.metric_arrays)
    for st, counts_d, scores in zip(states, all_counts, all_scores):
        st.scan_trace.ilp_solves += len(grid)
        pools = [None if counts is None
                 else NodePool(items=list(items), counts=counts)
                 for counts in counts_d]
        best_idx = 0
        for gi, (alpha, score, pool) in enumerate(zip(grid, scores, pools)):
            if pool is not None:
                pool.alpha = alpha
            st.scan_trace.alphas.append(alpha)
            st.scan_trace.e_totals.append(max(score, 0.0))
            if score > st.scan_f:
                st.scan_pool, st.scan_f, best_idx = pool, score, gi
        st.a = grid[max(0, best_idx - 1)]
        st.b = grid[min(len(grid) - 1, best_idx + 1)]
        st.x1 = st.b - PHI * (st.b - st.a)
        st.x2 = st.a + PHI * (st.b - st.a)

    if record is not None:
        # speculative device golden rounds over the chosen brackets; the
        # probe α sequence is re-derived exactly below, so every cache
        # miss resolves from the record (host solve only on divergence)
        record.run_golden([st.a for st in states], [st.b for st in states])

    # -- lockstep golden-section refinement --------------------------------
    def eval_round(requests: List[Tuple[_GssState, List[float]]]) -> None:
        """Evaluate each state's pending α list with sequential-evaluate
        semantics (cache first, one engine row per miss, per-state append
        order), batching all misses into one solve_ilp_many call."""
        miss_states: List[_GssState] = []
        miss_reqs: List[int] = []
        miss_alphas: List[List[float]] = []
        miss_excludes: List[Optional[np.ndarray]] = []
        for st, alist in requests:
            pending: List[float] = []
            seen = set()
            for alpha in alist:
                key = round(alpha, 12)
                if key not in st.cache and key not in seen:
                    seen.add(key)
                    pending.append(alpha)
            if pending:
                miss_states.append(st)
                miss_reqs.append(st.req)
                miss_alphas.append(pending)
                miss_excludes.append(st.exclude)
        if not miss_states:
            return
        if record is not None:
            solved = record.solve_many([st.idx for st in miss_states],
                                       miss_alphas)
        else:
            solved = solve_ilp_many(items, miss_reqs, miss_alphas,
                                    market=market, excludes=miss_excludes,
                                    backend=backend, coarsening=coarsening)
        for st, alphas_d, counts_d in zip(miss_states, miss_alphas, solved):
            for alpha, counts in zip(alphas_d, counts_d):
                st.trace.ilp_solves += 1
                if counts is None:
                    pool, score = None, float("-inf")
                else:
                    pool = NodePool(items=list(items), counts=counts,
                                    alpha=alpha)
                    score = e_total(pool, st.req)
                st.trace.alphas.append(alpha)
                st.trace.e_totals.append(
                    score if score != float("-inf") else 0.0)
                st.cache[round(alpha, 12)] = (pool, score)

    eval_round([(st, [st.x1, st.x2]) for st in states])
    for st in states:
        st.pool1, st.f1 = st.cache[round(st.x1, 12)]
        st.pool2, st.f2 = st.cache[round(st.x2, 12)]
        if st.f1 >= st.f2:
            st.best_pool, st.best_f = st.pool1, st.f1
        else:
            st.best_pool, st.best_f = st.pool2, st.f2

    while True:
        active = [st for st in states
                  if not st.done and (st.b - st.a) > tolerance]
        for st in states:
            if not st.done and (st.b - st.a) <= tolerance:
                st.done = True
        if not active:
            break
        probes: List[Tuple[_GssState, List[float]]] = []
        for st in active:
            if st.f1 >= st.f2:
                st.b = st.x2
                st.x2, st.f2, st.pool2 = st.x1, st.f1, st.pool1
                st.x1 = st.b - PHI * (st.b - st.a)
                probes.append((st, [st.x1]))
            else:
                st.a = st.x1
                st.x1, st.f1, st.pool1 = st.x2, st.f2, st.pool2
                st.x2 = st.a + PHI * (st.b - st.a)
                probes.append((st, [st.x2]))
        eval_round(probes)
        for st, alist in probes:
            pool, f = st.cache[round(alist[0], 12)]
            if alist[0] == st.x1:
                st.pool1, st.f1 = pool, f
                if f > st.best_f:
                    st.best_pool, st.best_f = pool, f
            else:
                st.pool2, st.f2 = pool, f
                if f > st.best_f:
                    st.best_pool, st.best_f = pool, f

    # -- per-decision finish: exactly the sequential epilogue --------------
    out: List[Tuple[Optional[NodePool], GssTrace]] = []
    for st in states:
        inner_pool = st.best_pool
        if inner_pool is not None:
            inner_pool = inner_pool.nonzero()
        trace = st.trace
        trace.alphas = st.scan_trace.alphas + trace.alphas
        trace.e_totals = st.scan_trace.e_totals + trace.e_totals
        trace.ilp_solves += st.scan_trace.ilp_solves
        trace.wall_seconds = timer() - st.t0
        inner_f = (e_total(inner_pool, st.req)
                   if inner_pool is not None else float("-inf"))
        if st.scan_pool is not None and st.scan_f > inner_f:
            out.append((st.scan_pool.nonzero(), trace))
        else:
            out.append((inner_pool, trace))
    return out
