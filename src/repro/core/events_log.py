"""Process-wide degradation-event registry (DESIGN.md §16).

The solver stack degrades in several deliberate ways — the NumPy fallback
when a jax backend is requested without jax, the process-wide x64 flip,
the Pallas kernel self-check disabling the kernel, the fused plane's
prescan cross-check disabling the device path, and the chaos guard's
ladder descents.  Each of those used to announce itself with a one-time
``warnings.warn`` and nothing else, which makes degradation invisible in
a fleet run's results: stderr is not a metrics channel.

This module centralizes those events into a tiny counter registry:

* every occurrence is **counted** (``count``), whether or not it warns;
* ``warn_once`` keeps the existing one-warning-per-process contract for
  human eyes while still counting every occurrence;
* the sim engines snapshot the registry at run start and merge the
  *delta* into ``SimResult.cache_stats`` under ``event_*`` keys, so a
  fleet sweep reports "the jax backend silently fell back to NumPy" as
  data, not as a line lost in CI logs.

Counters are process-global and monotonically increasing (like the
warning flags they replace).  They are deliberately **not** part of any
decision, trace record, or metric dict — the determinism contract
(DESIGN.md §9) is untouched; ``cache_stats`` is already exempt from
trace/equality comparisons.  ``reset`` exists for test isolation only.
"""

from __future__ import annotations

import threading
import warnings
from typing import Dict

_lock = threading.Lock()
_counters: Dict[str, int] = {}
_warned_keys = set()


def count(name: str, n: int = 1) -> int:
    """Increment counter ``name`` by ``n``; returns the new value."""
    with _lock:
        value = _counters.get(name, 0) + int(n)
        _counters[name] = value
        return value


def warn_once(name: str, message: str, category=RuntimeWarning,
              stacklevel: int = 2) -> bool:
    """Count this occurrence and emit ``message`` the first time only.

    Returns True when the warning was actually emitted (first occurrence
    for this key in the process), False on every repeat — the same
    contract the module-level ``_WARNED`` flags used to provide, minus
    the scattering.
    """
    count(name)
    with _lock:
        if name in _warned_keys:
            return False
        _warned_keys.add(name)
    warnings.warn(message, category, stacklevel=stacklevel + 1)
    return True


def counters() -> Dict[str, int]:
    """A point-in-time copy of every counter."""
    with _lock:
        return dict(_counters)


def snapshot() -> Dict[str, int]:
    """Alias of :func:`counters` that reads as intent at call sites that
    later diff against it with :func:`delta_since`."""
    return counters()


def delta_since(snap: Dict[str, int]) -> Dict[str, int]:
    """Counters that moved since ``snap`` (only non-zero deltas)."""
    now = counters()
    out = {}
    for name, value in now.items():
        moved = value - snap.get(name, 0)
        if moved:
            out[name] = moved
    return out


def reset() -> None:
    """Clear all counters and warn-once keys (test isolation only)."""
    with _lock:
        _counters.clear()
        _warned_keys.clear()


__all__ = ["count", "counters", "delta_since", "reset", "snapshot",
           "warn_once"]
