"""KubePACS control plane: the paper's contribution as a composable library."""

from . import events_log
from .market import (Offering, InterruptEvent, SpotMarketSimulator,
                     generate_catalog, restrict, snapshot_with,
                     pressure_interrupt_probability,
                     pressure_interrupt_probability_batch)
from .efficiency import (Request, CandidateItem, NodePool, pods_per_instance,
                         e_perf_cost, e_over_pods, e_total, e_total_batch,
                         decision_metrics, pool_metric_arrays,
                         reweight_items, score_counts_batch,
                         score_counts_many)
from .scaling import scaled_benchmark_score, build_base_price_index, matches_intent
from .backend import (DEFAULT_COARSENING, CoarseningConfig, JaxBackend,
                      NumpyBackend, SolverBackend, get_backend,
                      jax_available, make_backend, set_backend)
from .ilp import (solve_ilp, solve_ilp_batch, solve_ilp_many, solve_ilp_pulp,
                  solve_ilp_reference, objective_coefficients,
                  CompiledMarket, compile_market, reweight_market)
from .gss import (golden_section_search, bracketed_gss, bracketed_gss_many,
                  expected_iterations, GssTrace, PHI)
from .baselines import kubepacs_greedy, spotverse, spotkube, karpenter_like
from .provisioner import (DecisionMemo, KubePACSProvisioner, PendingDecision,
                          ProvisioningDecision, SolveBatch,
                          UnavailableOfferingsCache, preprocess, merge_pools)

__all__ = [
    "Offering", "InterruptEvent", "SpotMarketSimulator", "generate_catalog",
    "restrict", "Request", "CandidateItem", "NodePool", "pods_per_instance",
    "e_perf_cost", "e_over_pods", "e_total", "e_total_batch",
    "pool_metric_arrays", "score_counts_batch", "scaled_benchmark_score",
    "build_base_price_index", "matches_intent", "solve_ilp",
    "solve_ilp_batch", "solve_ilp_pulp", "solve_ilp_reference",
    "objective_coefficients", "CompiledMarket", "compile_market",
    "golden_section_search", "bracketed_gss", "expected_iterations",
    "GssTrace", "PHI", "kubepacs_greedy", "spotverse", "spotkube",
    "karpenter_like", "KubePACSProvisioner", "ProvisioningDecision",
    "UnavailableOfferingsCache", "preprocess", "merge_pools",
    "snapshot_with", "pressure_interrupt_probability",
    "pressure_interrupt_probability_batch", "decision_metrics",
    "reweight_items", "reweight_market", "DecisionMemo",
    "solve_ilp_many", "bracketed_gss_many", "score_counts_many",
    "SolveBatch", "PendingDecision",
    "SolverBackend", "NumpyBackend", "JaxBackend", "get_backend",
    "set_backend", "make_backend", "jax_available",
    "CoarseningConfig", "DEFAULT_COARSENING", "events_log",
]
