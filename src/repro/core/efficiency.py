"""Request/candidate data types and the paper's efficiency metrics (Eq. 1–3)."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .market import Offering


@dataclasses.dataclass(frozen=True)
class Request:
    """The user's workload requirement ``Req`` (Table 1) + workload intent."""

    pods: int                    # Req_pod
    cpu_per_pod: float           # Req_cpu  (vCPUs)
    mem_per_pod: float           # Req_mem  (GiB)
    workload: frozenset = frozenset()   # subset of {"network", "disk"} (§3.3)

    def __post_init__(self):
        object.__setattr__(self, "workload", frozenset(self.workload))


@dataclasses.dataclass(frozen=True)
class CandidateItem:
    """One preprocessed offering: the ILP's per-type constants."""

    offering: Offering
    pods: int                    # Pod_i  (Eq. 1)
    bs: float                    # BS_i, possibly workload-scaled (Eq. 8)
    spot_price: float            # SP_i
    t3: int                      # T3_i  (upper bound on x_i)

    @property
    def perf(self) -> float:     # Perf_i = BS_i * Pod_i
        return self.bs * self.pods


@dataclasses.dataclass
class NodePool:
    """A provisioning decision: counts per candidate (only x_i > 0 kept)."""

    items: List[CandidateItem]
    counts: List[int]
    alpha: Optional[float] = None        # the α that produced this pool
    request: Optional[Request] = None

    def as_dict(self) -> Dict[str, int]:
        return {it.offering.offering_id: c for it, c in zip(self.items, self.counts)}

    @property
    def total_nodes(self) -> int:
        return int(sum(self.counts))

    @property
    def total_pods(self) -> int:
        return int(sum(it.pods * c for it, c in zip(self.items, self.counts)))

    @property
    def hourly_cost(self) -> float:
        return float(sum(it.spot_price * c for it, c in zip(self.items, self.counts)))

    def nonzero(self) -> "NodePool":
        keep = [(it, c) for it, c in zip(self.items, self.counts) if c > 0]
        return NodePool(items=[it for it, _ in keep], counts=[c for _, c in keep],
                        alpha=self.alpha, request=self.request)


def pods_per_instance(offering: Offering, req: Request) -> int:
    """Eq. 1: Pod_i = min(floor(CPU_i/Req_cpu), floor(Mem_i/Req_mem))."""
    if req.cpu_per_pod <= 0 or req.mem_per_pod <= 0:
        raise ValueError("per-pod resources must be positive")
    return int(min(offering.vcpus // req.cpu_per_pod,
                   offering.mem_gib // req.mem_per_pod))


def e_perf_cost(pool: NodePool) -> float:
    """Eq. 2 left: cumulative performance-per-dollar of the selected pool,
    implemented as  Σ_i Perf_i·x_i  /  Σ_i SP_i·x_i .

    Interpretation note (recorded in DESIGN.md §7).  Read literally, Eq. 2
    sums per-node ratios BS_i·x_i/SP_i, which (a) grows linearly in node
    count so splitting capacity across ever-smaller nodes dominates — the
    SpotVerse-Node policy would be provably optimal, contradicting Fig. 5a —
    and (b) cannot reproduce Table 2's collapse to ~1e-4 under α=1
    over-provisioning.  The aggregate-performance-per-aggregate-dollar
    reading reproduces both, and matches the text ("cumulative
    performance-per-dollar of selected instances").  Perf_i = BS_i·Pod_i is
    the instance-level contribution (Table 1), consistent with Eq. 5.
    """
    perf = sum(it.perf * c for it, c in zip(pool.items, pool.counts) if c > 0)
    cost = sum(it.spot_price * c for it, c in zip(pool.items, pool.counts) if c > 0)
    if cost <= 0:
        return 0.0
    return float(perf) / float(cost)


def e_over_pods(pool: NodePool, req_pods: int) -> float:
    """Eq. 2 right: Req_pod / Σ_i Pod_i·x_i  (over-provisioning penalty)."""
    allocated = pool.total_pods
    if allocated <= 0:
        return 0.0
    return float(req_pods) / float(allocated)


def e_total(pool: NodePool, req_pods: int) -> float:
    """Eq. 3: E_Total = E_PerfCost × E_OverPods (0 for infeasible pools)."""
    if pool.total_pods < req_pods:
        return 0.0   # unmet demand: not a valid provisioning decision
    return e_perf_cost(pool) * e_over_pods(pool, req_pods)
