"""Request/candidate data types and the paper's efficiency metrics (Eq. 1–3).

Implements, with the symbol names used throughout DESIGN.md and Table 1:

* **Eq. 1** — :func:`pods_per_instance`:
  ``Pod_i = min(⌊CPU_i/Req_cpu⌋, ⌊Mem_i/Req_mem⌋)``, the per-instance pod
  capacity that converts a node-selection problem into pod coverage.
* **Eq. 2 (left), E_PerfCost** — :func:`e_perf_cost`: cumulative
  performance-per-dollar of the selected pool,
  ``Σ_i Perf_i·x_i / Σ_i SP_i·x_i`` with ``Perf_i = BS_i·Pod_i``
  (aggregate/aggregate — see the interpretation note on the function and
  DESIGN.md §7 for why the literal per-node-ratio reading is rejected).
* **Eq. 2 (right), E_OverPods** — :func:`e_over_pods`:
  ``Req_pod / Σ_i Pod_i·x_i``, the over-provisioning penalty that
  normalizes performance-per-dollar by how much capacity exceeds demand.
* **Eq. 3, E_Total** — :func:`e_total`: ``E_PerfCost × E_OverPods``,
  0 for pools that underfill the demand — the objective GSS maximizes
  over α (Alg. 1) and the metric every figure/table reports.

The E_perf/E_cost *normalization* of the ILP objective itself
(``-α·Perf_i/Perf_min + (1−α)·SP_i/SP_min``, Eq. 4–5) lives in
:func:`repro.core.ilp.objective_coefficients`; this module only scores
completed pools.  Batch variants (:func:`e_total_batch`,
:func:`score_counts_batch`) score (n_pools × n_items) count matrices in
one vectorized pass for the batched GSS prescan (DESIGN.md §8) and the
scenario engine's sweeps (DESIGN.md §9).

This module is the *authoritative* scorer: the fused device plane
(DESIGN.md §13) re-implements Eq. 3 on device only to steer its
speculative bracket control — every score a decision, trace, or metric
dict actually reports is recomputed here on host floats, so a device
scoring discrepancy can cost a fallback solve but never change a
selection.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .market import Offering


@dataclasses.dataclass(frozen=True)
class Request:
    """The user's workload requirement ``Req`` (Table 1) + workload intent."""

    pods: int                    # Req_pod
    cpu_per_pod: float           # Req_cpu  (vCPUs)
    mem_per_pod: float           # Req_mem  (GiB)
    workload: frozenset = frozenset()   # subset of {"network", "disk"} (§3.3)

    def __post_init__(self):
        object.__setattr__(self, "workload", frozenset(self.workload))


@dataclasses.dataclass(frozen=True)
class CandidateItem:
    """One preprocessed offering: the ILP's per-type constants."""

    offering: Offering
    pods: int                    # Pod_i  (Eq. 1)
    bs: float                    # BS_i, possibly workload-scaled (Eq. 8)
    spot_price: float            # SP_i
    t3: int                      # T3_i  (upper bound on x_i)

    @property
    def perf(self) -> float:     # Perf_i = BS_i * Pod_i
        return self.bs * self.pods


@dataclasses.dataclass
class NodePool:
    """A provisioning decision: counts per candidate (only x_i > 0 kept)."""

    items: List[CandidateItem]
    counts: List[int]
    alpha: Optional[float] = None        # the α that produced this pool
    request: Optional[Request] = None

    def as_dict(self) -> Dict[str, int]:
        return {it.offering.offering_id: c for it, c in zip(self.items, self.counts)}

    @property
    def total_nodes(self) -> int:
        return int(sum(self.counts))

    @property
    def total_pods(self) -> int:
        return int(sum(it.pods * c for it, c in zip(self.items, self.counts)))

    @property
    def hourly_cost(self) -> float:
        return float(sum(it.spot_price * c for it, c in zip(self.items, self.counts)))

    @property
    def perf_rate(self) -> float:
        """Σ_i Perf_i·x_i — aggregate benchmark throughput per hour, the
        numerator of Eq. 2 and the rate the scenario engine integrates into
        delivered perf-hours (DESIGN.md §10 backtest accounting)."""
        return float(sum(it.perf * c for it, c in zip(self.items, self.counts)))

    def nonzero(self) -> "NodePool":
        keep = [(it, c) for it, c in zip(self.items, self.counts) if c > 0]
        return NodePool(items=[it for it, _ in keep], counts=[c for _, c in keep],
                        alpha=self.alpha, request=self.request)


def pods_per_instance(offering: Offering, req: Request) -> int:
    """Eq. 1: Pod_i = min(floor(CPU_i/Req_cpu), floor(Mem_i/Req_mem))."""
    if req.cpu_per_pod <= 0 or req.mem_per_pod <= 0:
        raise ValueError("per-pod resources must be positive")
    return int(min(offering.vcpus // req.cpu_per_pod,
                   offering.mem_gib // req.mem_per_pod))


def e_perf_cost(pool: NodePool) -> float:
    """Eq. 2 left: cumulative performance-per-dollar of the selected pool,
    implemented as  Σ_i Perf_i·x_i  /  Σ_i SP_i·x_i .

    Interpretation note (recorded in DESIGN.md §7).  Read literally, Eq. 2
    sums per-node ratios BS_i·x_i/SP_i, which (a) grows linearly in node
    count so splitting capacity across ever-smaller nodes dominates — the
    SpotVerse-Node policy would be provably optimal, contradicting Fig. 5a —
    and (b) cannot reproduce Table 2's collapse to ~1e-4 under α=1
    over-provisioning.  The aggregate-performance-per-aggregate-dollar
    reading reproduces both, and matches the text ("cumulative
    performance-per-dollar of selected instances").  Perf_i = BS_i·Pod_i is
    the instance-level contribution (Table 1), consistent with Eq. 5.
    """
    perf = sum(it.perf * c for it, c in zip(pool.items, pool.counts) if c > 0)
    cost = sum(it.spot_price * c for it, c in zip(pool.items, pool.counts) if c > 0)
    if cost <= 0:
        return 0.0
    return float(perf) / float(cost)


def e_over_pods(pool: NodePool, req_pods: int) -> float:
    """Eq. 2 right: Req_pod / Σ_i Pod_i·x_i  (over-provisioning penalty)."""
    allocated = pool.total_pods
    if allocated <= 0:
        return 0.0
    return float(req_pods) / float(allocated)


def e_total(pool: NodePool, req_pods: int) -> float:
    """Eq. 3: E_Total = E_PerfCost × E_OverPods (0 for infeasible pools)."""
    if pool.total_pods < req_pods:
        return 0.0   # unmet demand: not a valid provisioning decision
    return e_perf_cost(pool) * e_over_pods(pool, req_pods)


def decision_metrics(pool: NodePool, req_pods: int) -> Dict[str, float]:
    """The canonical metric dict attached to every ProvisioningDecision —
    one schema across the KubePACS provisioner and every scenario-engine
    policy (trace consumers index these keys unconditionally).  An empty
    (infeasible) pool scores 0 everywhere rather than dropping keys."""
    return {
        "e_total": e_total(pool, req_pods),
        "e_perf_cost": e_perf_cost(pool),
        "e_over_pods": e_over_pods(pool, req_pods),
        "hourly_cost": pool.hourly_cost,
        "nodes": float(pool.total_nodes),
        "pods": float(pool.total_pods),
    }


def pool_capacity_rate(pool: NodePool,
                       rate_per_pod: Dict[str, float]) -> float:
    """Σ_i rate(o_i)·Pod_i·x_i — a pool's aggregate rate under a per-pod
    rate table (e.g. QPS/pod from the serving perf model, DESIGN.md §15).
    The serving analogue of :attr:`NodePool.perf_rate`: offerings missing
    from the table contribute nothing rather than raising, so a rate table
    built from one market snapshot stays usable on later pools."""
    return float(sum(rate_per_pod.get(it.offering.offering_id, 0.0)
                     * it.pods * c
                     for it, c in zip(pool.items, pool.counts)))


def reweight_items(items: Sequence[CandidateItem], perf: np.ndarray,
                   price: np.ndarray) -> List[CandidateItem]:
    """Array-adjustment entry point: the same candidates with substituted
    (Perf_i, SP_i) vectors.

    The risk subsystem (``repro.risk.objective``) optimizes a *risk-adjusted*
    efficiency by handing GSS + the ILP engine candidates whose performance
    is discounted by expected uptime and whose price carries expected
    re-provisioning cost — the solvers are reused verbatim because only
    these two vectors enter the objective.  ``Pod_i``/``T3_i`` (the
    constraint structure) are untouched, so a :class:`CompiledMarket` can be
    reweighted without re-splitting bundles (``repro.core.ilp.reweight_market``).
    Since ``Perf_i = BS_i·Pod_i``, the adjusted BS is ``perf_i / Pod_i``.
    """
    if len(perf) != len(items) or len(price) != len(items):
        raise ValueError("perf/price vectors must match the candidate count")
    return [dataclasses.replace(it, bs=float(p) / it.pods, spot_price=float(sp))
            for it, p, sp in zip(items, perf, price)]


def pool_metric_arrays(items: Sequence[CandidateItem],
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(Perf_i, SP_i, Pod_i) as float64 vectors for batch scoring."""
    perf = np.array([it.perf for it in items], dtype=np.float64)
    price = np.array([it.spot_price for it in items], dtype=np.float64)
    pods = np.array([it.pods for it in items], dtype=np.float64)
    return perf, price, pods


def e_total_batch(perf: np.ndarray, price: np.ndarray, pods: np.ndarray,
                  counts: np.ndarray, req_pods: int) -> np.ndarray:
    """Eq. 3 over a batch of count-vectors: counts is (n_pools, n_items).

    Vectorized equivalent of scoring each row with :func:`e_total`; rows
    that underfill the demand (or cost nothing) score 0, matching the
    scalar path.  Used by the batched GSS prescan and the benchmarks.

    Backend note (DESIGN.md §12): inputs are coerced with ``np.asarray``
    so accelerator-backend outputs (e.g. jax device arrays) score without
    copy ceremony, but the reductions themselves deliberately stay on the
    host BLAS path — scores feed GSS bracket *comparisons*, and the
    batched search promises bit-identical decisions to the sequential
    one, which pins the summation shapes (see :func:`score_counts_many`).
    """
    perf = np.asarray(perf, dtype=np.float64)
    price = np.asarray(price, dtype=np.float64)
    pods = np.asarray(pods, dtype=np.float64)
    counts = np.asarray(counts, dtype=np.float64)
    perf_sum = counts @ perf
    cost_sum = counts @ price
    pods_sum = counts @ pods
    with np.errstate(divide="ignore", invalid="ignore"):
        score = (perf_sum / cost_sum) * (req_pods / pods_sum)
    score[(pods_sum < req_pods) | (cost_sum <= 0) | (pods_sum <= 0)] = 0.0
    return score


def score_counts_batch(items: Sequence[CandidateItem],
                       counts_list: Sequence[Optional[Sequence[int]]],
                       req_pods: int, none_score: float = 0.0,
                       arrays: Optional[tuple] = None) -> List[float]:
    """Score per-α solver outputs (``None`` = infeasible) in one batch.

    The canonical consumer of :func:`solve_ilp_batch` results: feasible
    rows are scored with one :func:`e_total_batch` call and reassembled in
    order; infeasible rows get ``none_score``.  ``arrays`` accepts a
    precomputed (perf, price, pods) triple (e.g. from a CompiledMarket) to
    skip the per-item rebuild.
    """
    feasible = [c for c in counts_list if c is not None]
    if not feasible:
        return [none_score] * len(counts_list)
    perf, price, pods = (arrays if arrays is not None
                         else pool_metric_arrays(items))
    scores = e_total_batch(perf, price, pods, np.array(feasible), req_pods)
    out: List[float] = []
    fi = 0
    for c in counts_list:
        if c is None:
            out.append(none_score)
        else:
            out.append(float(scores[fi]))
            fi += 1
    return out


def score_counts_many(items: Sequence[CandidateItem],
                      counts_lists: Sequence[Sequence[Optional[Sequence[int]]]],
                      req_pods_list: Sequence[int],
                      none_score: float = 0.0,
                      arrays: Optional[tuple] = None) -> List[List[float]]:
    """Score the stacked per-decision outputs of ``solve_ilp_many``.

    Deliberately one :func:`score_counts_batch` call *per decision* (not
    one flattened matmul): BLAS reduction order can depend on operand
    shape, and the cross-decision batched GSS (DESIGN.md §12) promises
    every decision the bit-identical scores the sequential path computes
    — so each decision is scored with exactly the sequential call shape.
    """
    return [score_counts_batch(items, counts_d, req, none_score=none_score,
                               arrays=arrays)
            for counts_d, req in zip(counts_lists, req_pods_list)]
