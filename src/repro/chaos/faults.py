"""Deterministic control-plane fault models (DESIGN.md §16).

The scenario engine's determinism contract (DESIGN.md §9) — same seed ⇒
byte-identical trace, replay RNG-free — must survive fault injection, so
every fault here is a *pure function* of scenario-declared parameters and
the refresh/decision coordinates at which it fires.  No fault consumes an
RNG stream: where a fault needs randomness (per-field drift, corrupted-row
choice), it builds a fresh ``np.random.default_rng`` keyed on
``(fault.seed, state_idx, fault_index)`` — the same stream-free idiom as
``Scenario.effective_pods`` — so the standalone engine, the fleet engine,
and trace replay all derive bit-identical fault effects from the same
coordinates.

Fault taxonomy (``Fault.kind``):

``feed_outage``
    The control plane's market feed freezes: the controller keeps seeing
    the last pre-fault ``(spot, t3)`` snapshot, optionally with per-field
    multiplicative drift of amplitude ``magnitude`` (stale caches decay).
    The *world* (interrupt hazards, billing) keeps moving — the engine
    splits the true snapshot from the observed one.
``corrupt_price``
    A ``rate`` fraction of matching rows reports ``spot × magnitude``
    (magnitude < 1 understates — the dangerous direction: the optimizer
    chases phantom bargains billed at true prices; > 1 spikes).
``corrupt_nan``
    A ``rate`` fraction of matching rows reports NaN spot — rows that must
    be quarantined, not solved over (NaN poisons every normalized
    objective coefficient downstream).
``ice``
    Insufficient-capacity errors at launch: each matching offering grants
    at most ``floor(requested × (1 − magnitude))`` nodes of any request
    (offering-level capacity caps; magnitude 1.0 = full rejection).
``solver_error``
    The first ``int(magnitude)`` solve attempts of any decision inside the
    window raise (injected backend exceptions).
``solver_deadline``
    Every solve attempt inside the window overruns by ``magnitude``
    *simulated* seconds, charged against the guard's decision deadline.
``region_brownout``
    A whole region degrades at once (DESIGN.md §17): the regional market
    overlay thins the region's TRUE T3 capacity by ``magnitude`` and
    spikes its spot prices, while launches into the region grant at most
    ``floor(requested × (1 − magnitude))`` nodes.  The feed stays
    truthful — policies *see* the brownout.  ``selector`` is the exact
    region name.
``region_outage``
    The region is gone: TRUE T3 drops to zero region-wide (the overlay's
    doing — candidates vanish from ``preprocess`` for every policy) and
    launches into the region grant nothing.
``region_partition``
    The control plane is partitioned *from* the region: the observed feed
    freezes at the last pre-window values for the region's rows (the
    snapshot is tainted) and launches grant zero, but the TRUE world keeps
    moving — the feed looks healthy while every launch fails, the trap the
    hardened policy's region rung exists for.

Fault windows are half-open ``[time, time + duration)`` and should be
aligned to scenario tick boundaries (the storm factories use multiples of
the step); windows covering t = 0 cannot freeze a feed that was never
fresh — the first refresh is always treated as fresh.

This module deliberately imports nothing from ``repro.sim`` (the scenario
layer imports *us*); the controller reports fault activation transitions
as plain tuples and the engine wraps them in trace records.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_EPS = 1e-9

#: kinds that correlate failure across a whole region's offerings
#: (DESIGN.md §17); ``selector`` is the exact region name for these
REGION_KINDS = ("region_brownout", "region_outage", "region_partition")

FAULT_KINDS = ("feed_outage", "corrupt_price", "corrupt_nan", "ice",
               "solver_error", "solver_deadline") + REGION_KINDS

#: kinds that taint the controller's view of the market feed (the guard's
#: healthy-path test): everything except launch-time ICE and solver faults
FEED_KINDS = ("feed_outage", "corrupt_price", "corrupt_nan")
SOLVER_KINDS = ("solver_error", "solver_deadline")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One declarative fault window (see module doc for kind semantics)."""

    kind: str
    time: float
    duration: float
    selector: str = ""        # substring match on offering_id ("" = all)
    magnitude: float = 1.0
    rate: float = 1.0         # fraction of matching rows hit per refresh
    seed: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {FAULT_KINDS})")
        if self.kind in REGION_KINDS and not self.selector:
            raise ValueError(f"{self.kind} faults need a region selector")
        # float-normalize so Scenario round-trips through JSON byte-exactly
        object.__setattr__(self, "time", float(self.time))
        object.__setattr__(self, "duration", float(self.duration))
        object.__setattr__(self, "magnitude", float(self.magnitude))
        object.__setattr__(self, "rate", float(self.rate))
        object.__setattr__(self, "seed", int(self.seed))
        object.__setattr__(self, "selector", str(self.selector))

    def active(self, time: float) -> bool:
        """Half-open activation window ``[time, time + duration)``."""
        return (self.time - _EPS) <= time < (self.time + self.duration
                                             - _EPS)


class ChaosController:
    """Deterministic fault oracle for one simulation run.

    One controller is built per run from ``scenario.faults`` and driven by
    the engine in exact refresh order — the identical call sequence in
    ``ClusterSim``, ``FleetSim``, and replay is what makes fault effects
    reproduce bit-exactly everywhere.  The controller is the *injection*
    side only; the hardened response lives in :mod:`repro.chaos.guard`
    (which reads, never mutates, the controller).

    State: the last *fresh* ``(spot, t3)`` pair and its timestamp (for
    feed outages), and the previously-active fault set (for activation
    transition records).  No RNG stream is held.
    """

    def __init__(self, faults: Sequence[Fault],
                 catalog: Sequence) -> None:
        self.faults: Tuple[Fault, ...] = tuple(faults)
        ids = [o.offering_id for o in catalog]
        self._ids = ids
        # per-fault row selectors are static (the catalog is)
        self._sel: Dict[int, np.ndarray] = {
            i: np.array([f.selector in oid for oid in ids], dtype=bool)
            for i, f in enumerate(self.faults)
            if f.kind in ("corrupt_price", "corrupt_nan")}
        regions = [getattr(o, "region", "") for o in catalog]
        self._region_of: Dict[str, str] = dict(zip(ids, regions))
        # region faults match on the exact region tag, not the id substring
        self._rsel: Dict[int, np.ndarray] = {
            i: np.array([r == f.selector for r in regions], dtype=bool)
            for i, f in enumerate(self.faults)
            if f.kind in REGION_KINDS}
        #: any region-kind fault *declared* (not necessarily active) — the
        #: static gate that keeps the hardened policy's region rung
        #: bit-inert on scenarios without regional faults (DESIGN.md §17)
        self.has_region_faults = any(f.kind in REGION_KINDS
                                     for f in self.faults)
        self._last_spot: Optional[np.ndarray] = None
        self._last_t3: Optional[np.ndarray] = None
        self._last_fresh_time = 0.0
        self._active_prev: frozenset = frozenset()
        #: hours since the observed snapshot was last fresh (0 = fresh)
        self.stale_age = 0.0
        #: True when the *current* observed snapshot went through any
        #: feed-affecting fault window (outage or corruption) — the guard's
        #: "can I trust what I'm looking at" bit, exact w.r.t. the last
        #: ``observe`` call rather than re-derived from window arithmetic
        self.snapshot_tainted = False

    # -- feed path -----------------------------------------------------------
    def observe(self, state_idx: int, time: float, spot: np.ndarray,
                t3: np.ndarray,
                ) -> Tuple[np.ndarray, np.ndarray,
                           List[Tuple[str, str, int]]]:
        """One market refresh seen through the fault plane.

        Returns ``(spot_obs, t3_obs, transitions)`` — the controller-visible
        arrays (the true inputs are never mutated; unfaulted refreshes
        return them by reference) and the fault activation transitions
        ``(kind, phase, fault_index)`` that occurred at this refresh, in
        fault-declaration order, for the engine to trace.
        """
        transitions: List[Tuple[str, str, int]] = []
        act = frozenset(i for i, f in enumerate(self.faults)
                        if f.active(time))
        for i, f in enumerate(self.faults):
            if i in act and i not in self._active_prev:
                transitions.append((f.kind, "begin", i))
            elif i not in act and i in self._active_prev:
                transitions.append((f.kind, "end", i))
        self._active_prev = act

        # the pre-refresh last-fresh feed: region partitions freeze their
        # rows at these values for the whole window (a partition that opens
        # before the first refresh cannot freeze a never-seen feed)
        prev_spot, prev_t3 = self._last_spot, self._last_t3
        partitions = [(i, self.faults[i]) for i in sorted(act)
                      if self.faults[i].kind == "region_partition"]

        outages = [self.faults[i] for i in sorted(act)
                   if self.faults[i].kind == "feed_outage"]
        if outages and self._last_spot is not None:
            f = outages[0]
            spot_obs = self._last_spot.copy()
            t3_obs = self._last_t3.copy()
            if f.magnitude > 0.0:
                rng = np.random.default_rng((f.seed & 0xFFFFFFFF,
                                             int(state_idx), 0xFEED))
                drift = 1.0 + f.magnitude * (2.0 * rng.random(len(spot_obs))
                                             - 1.0)
                spot_obs = np.maximum(spot_obs * drift, 1e-12)
            self.stale_age = time - self._last_fresh_time
            tainted = True
        else:
            # fresh refresh (or an outage window starting before the first
            # refresh, which cannot freeze a never-seen feed)
            self._last_spot = np.array(spot, dtype=np.float64, copy=True)
            self._last_t3 = np.array(t3, copy=True)
            self._last_fresh_time = time
            self.stale_age = 0.0
            spot_obs, t3_obs = spot, t3
            tainted = False
            if partitions and prev_spot is not None:
                # partitioned rows never refresh: pin their last-fresh
                # values at the pre-window feed so the frozen view does
                # not silently advance during the window
                for i, _ in partitions:
                    mask = self._rsel[i]
                    self._last_spot[mask] = prev_spot[mask]
                    self._last_t3[mask] = prev_t3[mask]

        for i in sorted(act):
            f = self.faults[i]
            if f.kind not in ("corrupt_price", "corrupt_nan"):
                continue
            tainted = True
            rng = np.random.default_rng((f.seed & 0xFFFFFFFF,
                                         int(state_idx), i))
            pick = self._sel[i] & (rng.random(len(self._ids)) < f.rate)
            if not pick.any():
                continue
            if spot_obs is spot:        # copy-on-write: never mutate truth
                spot_obs = np.array(spot, dtype=np.float64, copy=True)
            if f.kind == "corrupt_price":
                spot_obs[pick] = spot_obs[pick] * f.magnitude
            else:
                spot_obs[pick] = np.nan
        if partitions and prev_spot is not None:
            for i, _ in partitions:
                mask = self._rsel[i]
                if not mask.any():
                    continue
                tainted = True
                if spot_obs is spot:    # copy-on-write, as above
                    spot_obs = np.array(spot, dtype=np.float64, copy=True)
                if t3_obs is t3:
                    t3_obs = np.array(t3, copy=True)
                spot_obs[mask] = self._last_spot[mask]
                t3_obs[mask] = self._last_t3[mask]
        self.snapshot_tainted = tainted
        return spot_obs, t3_obs, transitions

    # -- launch path ---------------------------------------------------------
    def ice_caps(self, time: float,
                 requested: Dict[str, int]) -> Optional[Dict[str, int]]:
        """Offering-level grant caps for a launch at ``time`` under active
        ICE faults, or None when no ICE window is active.  Caps are a pure
        function of the *requested* counts, so re-applying them to already
        clipped grants is the identity — which is what keeps replayed
        fulfillment records byte-identical.  Region faults correlate the
        launch failure across every offering of the selected region:
        brownouts thin grants by ``magnitude``, outages and partitions
        grant nothing."""
        active = [f for f in self.faults if f.active(time)
                  and f.kind in ("ice",) + REGION_KINDS]
        if not active:
            return None
        caps: Dict[str, int] = {}
        for oid, c in requested.items():
            cap = int(c)
            for f in active:
                if f.kind == "ice":
                    if f.selector in oid:
                        cap = min(cap,
                                  int(math.floor(c * (1.0 - f.magnitude))))
                elif self._region_of.get(oid, "") == f.selector:
                    if f.kind == "region_brownout":
                        cap = min(cap,
                                  int(math.floor(c * (1.0 - f.magnitude))))
                    else:            # outage / partition: region is dark
                        cap = 0
            caps[oid] = max(cap, 0)
        return caps

    # -- region path ---------------------------------------------------------
    def region_fault_regions(self, time: float) -> Tuple[str, ...]:
        """Regions under an *active* region-kind fault at ``time``, sorted —
        the quarantine set the hardened policy's region rung excludes and
        re-weights demand away from (DESIGN.md §17).  Reading the
        controller here is the same precedent as ``snapshot_tainted`` /
        ``solver_faulted``: the guard reads the injection oracle's state,
        never mutates it."""
        return tuple(sorted({f.selector for f in self.faults
                             if f.kind in REGION_KINDS and f.active(time)}))

    # -- solver path ---------------------------------------------------------
    def solver_faulted(self, time: float) -> Optional[Fault]:
        """The first active solver fault at ``time`` (declaration order)."""
        for f in self.faults:
            if f.kind in SOLVER_KINDS and f.active(time):
                return f
        return None

    def attempt_outcome(self, time: float, attempt_index: int) -> str:
        """What happens to solve attempt ``attempt_index`` (0-based, counted
        across the whole decision) at ``time``: ``"ok"``, ``"error"``
        (injected exception), or ``"overrun"`` (deadline blowout of
        :meth:`attempt_cost_s` simulated seconds)."""
        f = self.solver_faulted(time)
        if f is None:
            return "ok"
        if f.kind == "solver_error":
            return "error" if attempt_index < int(f.magnitude) else "ok"
        return "overrun"

    def attempt_cost_s(self, time: float) -> float:
        """Simulated seconds a solve attempt costs beyond the solve itself
        (non-zero only inside a ``solver_deadline`` window)."""
        f = self.solver_faulted(time)
        if f is not None and f.kind == "solver_deadline":
            return f.magnitude
        return 0.0


def fault_storm(name: str, scale: float = 1.0) -> Tuple[Fault, ...]:
    """Named fault-storm presets, laid out for a 48 h / 3 h-step horizon
    (``scale`` compresses or stretches every window; keep windows aligned
    to tick boundaries).  These are the storms ``bench_chaos`` sweeps and
    ``examples/run_scenario.py --faults`` exposes:

    * ``feed``     — understatement corruption, then a feed outage, then a
      NaN burst: the full price-feed failure surface.
    * ``ice``      — a long partial-fulfillment window.
    * ``solver``   — injected solve errors, then deadline overruns.
    * ``combined`` — all of the above (the acceptance-gate storm).
    """
    def s(t: float) -> float:
        return t * scale

    feed = (
        Fault(kind="corrupt_price", time=s(6.0), duration=s(9.0),
              magnitude=0.01, rate=0.5, seed=101),
        Fault(kind="feed_outage", time=s(18.0), duration=s(9.0),
              magnitude=0.02, seed=102),
        Fault(kind="corrupt_nan", time=s(30.0), duration=s(6.0),
              rate=0.4, seed=103),
    )
    ice = (
        Fault(kind="ice", time=s(9.0), duration=s(15.0), magnitude=0.7,
              seed=104),
    )
    solver = (
        Fault(kind="solver_error", time=s(36.0), duration=s(6.0),
              magnitude=3.0, seed=105),
        Fault(kind="solver_deadline", time=s(42.0), duration=s(3.0),
              magnitude=10.0, seed=106),
    )
    storms = {"feed": feed, "ice": ice, "solver": solver,
              "combined": feed + ice + solver}
    if name not in storms:
        raise ValueError(f"unknown fault storm {name!r} "
                         f"(expected one of {sorted(storms)})")
    return storms[name]


def region_storm(region: str, scale: float = 1.0) -> Tuple[Fault, ...]:
    """The correlated regional failure sequence ``bench_region`` sweeps,
    laid out for a 48 h / 3 h-step horizon like :func:`fault_storm`: the
    selected region browns out (thinned capacity, spiked prices, partial
    grants), then goes dark entirely, then partitions away from the
    control plane while its feed keeps showing the last pre-partition
    snapshot."""
    def s(t: float) -> float:
        return t * scale

    return (
        Fault(kind="region_brownout", time=s(6.0), duration=s(9.0),
              magnitude=0.6, selector=region, seed=107),
        Fault(kind="region_outage", time=s(18.0), duration=s(9.0),
              magnitude=1.0, selector=region, seed=108),
        Fault(kind="region_partition", time=s(33.0), duration=s(9.0),
              magnitude=1.0, selector=region, seed=109),
    )


__all__ = ["FAULT_KINDS", "FEED_KINDS", "REGION_KINDS", "SOLVER_KINDS",
           "ChaosController", "Fault", "fault_storm", "region_storm"]
