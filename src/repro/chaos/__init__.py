"""ChaosPlane: deterministic fault injection + degraded-mode provisioning.

Two halves (DESIGN.md §16):

- :mod:`repro.chaos.faults` — the fault models and the
  :class:`ChaosController` that applies them to observed market feeds.
  Import-light (numpy only) so the sim layer can depend on it freely.
- :mod:`repro.chaos.guard` — the hardened policy / degradation ladder.
  Imported lazily (PEP 562) because it depends on :mod:`repro.sim.policy`,
  which itself reaches back to :mod:`repro.chaos.faults` via the scenario
  schema.
"""

from .faults import (FAULT_KINDS, FEED_KINDS, REGION_KINDS, SOLVER_KINDS,
                     ChaosController, Fault, fault_storm, region_storm)

_GUARD_SYMBOLS = ("DEFAULT_LADDER", "GuardConfig", "HardenedPolicy",
                  "backoff_schedule", "check_decision",
                  "decision_available", "quarantine_mask", "safe_pool")

__all__ = ["FAULT_KINDS", "FEED_KINDS", "REGION_KINDS", "SOLVER_KINDS",
           "ChaosController", "Fault", "fault_storm", "region_storm",
           *_GUARD_SYMBOLS]


def __getattr__(name):
    if name in _GUARD_SYMBOLS:
        from . import guard
        return getattr(guard, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
