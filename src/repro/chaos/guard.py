"""Degraded-mode provisioning: the hardened control plane (DESIGN.md §16).

:class:`HardenedPolicy` wraps the paper's :class:`KubePACSProvisioner`
with the reliability machinery a real control plane needs when its own
inputs fail — and is **inert when healthy**: with no
:class:`~repro.chaos.faults.ChaosController` bound (or no fault touching
the current decision), ``provision``/``on_interrupts`` literally delegate
to the contained provisioner, so decisions are bit-identical to the
``kubepacs`` policy by construction, not by tolerance.

Under a fault, a decision descends a ladder until something valid comes
out:

1. **Quarantine** — rows whose observed ``spot``/``t3`` fail sanity bands
   (NaN/non-finite, below ``floor_od_factor × od`` or above
   ``spike_od_factor × od``, T3 out of the market's [1, 50] band) are ORed
   into the §4.1 exclusion mask.  Detection-based: the guard never peeks
   at which rows the fault actually hit.
2. **Staleness penalty** — a frozen feed of age ``a`` hours still solves,
   but with Perf discounted by ``1 / (1 + λ·a)`` through the O(n)
   ``reweight_items``/``reweight_market`` path (the same entry point as
   the risk objective), and the solved pool mapped back onto real items.
   Beyond ``max_stale_hours`` the guard refuses to solve on the zombie
   snapshot at all and falls through to the memo rung.
3. **Solver rungs** — one bounded-retry loop per ladder backend spec
   (default ``("default", "numpy")``; a jax deployment would run
   ``("jax:fused", "jax", "numpy")`` — all rungs produce bit-identical
   selections per the DESIGN §12 backend contract, which is what makes
   descending *safe*).  Retries wait out a deterministic decorrelated-
   jitter backoff schedule (:func:`backoff_schedule`) whose delays are
   charged against the decision deadline in *simulated* seconds — the
   guard never sleeps, and the schedule is a pure function of
   ``(seed, decision time, attempt)``.
4. **Memo rung** — the last good solved pool for this exact request shape
   (the PR-4 ``DecisionMemo`` idea turned into a per-policy last-good
   store), re-scored against the current demand.
5. **Safe rung** — a solver-free, availability-first minimum-viable pool:
   greedy over sanitized rows by (interruption_freq, od-price per pod),
   the "just keep the lights on" answer when nothing else worked.

Every decision — healthy or degraded — passes the invariant monitor
(:func:`check_decision`): counts within T3 bounds, finite spot prices,
hourly cost sane relative to the on-demand bill.  A monitor reject
descends the ladder like a solve failure.  Per-rung counters surface
through ``SimResult.cache_stats`` (``chaos_*`` keys).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from ..core.backend import SolverBackend, make_backend
from ..core.efficiency import (NodePool, Request, decision_metrics,
                               pool_metric_arrays, reweight_items)
from ..core.gss import bracketed_gss
from ..core.ilp import reweight_market
from ..core.provisioner import (KubePACSProvisioner, ProvisioningDecision,
                                exclusion_mask)
from ..region.config import RegionConfig
from ..region.solver import solve_with_regions
from ..sim.policy import Policy
from .faults import ChaosController

#: default degradation ladder: the ambient backend, then the host engine.
#: "default" = inherit the process backend (None); every other entry is a
#: ``make_backend`` spec.
DEFAULT_LADDER = ("default", "numpy")


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Hardening knobs (all deterministic; see module doc)."""

    attempts_per_rung: int = 2
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 1.0
    backoff_seed: int = 0
    #: simulated wall-seconds a decision may spend on solver attempts +
    #: backoff waits before dropping to the memo/safe rungs
    deadline_s: float = 4.0
    #: beyond this snapshot age (hours) the guard stops solving on the
    #: stale feed entirely (the penalty rung covers 0 < age ≤ max)
    max_stale_hours: float = 4.0
    #: λ of the staleness discount 1 / (1 + λ·age_hours)
    stale_penalty_per_hour: float = 0.1
    #: spot sanity band relative to od_price (the market clips real spot
    #: into [0.03·od, 1.0·od]; DESIGN §16 quarantine detection bands sit
    #: just outside it)
    floor_od_factor: float = 0.02
    spike_od_factor: float = 1.05
    #: a fulfillment round granting less than this fraction of an
    #: offering's requested nodes TTL-excludes the offering (ICE response)
    ice_exclude_below: float = 0.5
    #: ceiling on the 1/grant-ratio over-request factor the guard applies
    #: while fulfillment rounds come back *uniformly* short (market-wide
    #: ICE: diversifying away is pure loss, so compensate instead)
    ice_inflate_cap: float = 4.0
    #: learned quarantine band (§10 → §16): rows whose *online-estimated*
    #: interrupt hazard λ_i (interrupts per node-hour, from the risk
    #: subsystem's estimators) exceeds this rate are quarantined like a
    #: failed sanity band.  0.0 = off — no estimators are constructed and
    #: the guard is bit-identical to the fixed-bands-only build.
    hazard_quarantine_rate: float = 0.0


def backoff_schedule(seed: int, now: float, attempts: int,
                     base_s: float = 0.05, cap_s: float = 1.0,
                     ) -> Tuple[float, ...]:
    """Decorrelated-jitter backoff delays for one decision's retry loop.

    ``delays[0]`` is 0 (the first attempt fires immediately);
    ``delays[k] = min(cap, U(base, 3·delays[k-1]))`` with each draw from a
    fresh generator keyed on ``(seed, decision-time, k)`` — a pure
    function of its arguments, so the schedule is identical across
    engines and replay (determinism contract, DESIGN §9/§16)."""
    delays = [0.0]
    prev = base_s
    for k in range(1, max(int(attempts), 1)):
        rng = np.random.default_rng((int(seed) & 0xFFFFFFFF,
                                     int(round(now * 3600.0)), k))
        d = min(cap_s, float(rng.uniform(base_s, 3.0 * prev)))
        delays.append(d)
        prev = d
    return tuple(delays[:max(int(attempts), 1)])


def quarantine_mask(items: Sequence, config: GuardConfig,
                    hazard: Optional[np.ndarray] = None,
                    ) -> Optional[np.ndarray]:
    """Detection-based row quarantine: True where an item's *observed*
    market fields fail the sanity bands.  Returns None when every row is
    sane (so the exclusion path stays byte-identical to the unguarded
    one on clean feeds).

    ``hazard`` optionally carries the §10 estimators' per-item interrupt
    hazard rate; with ``config.hazard_quarantine_rate > 0`` rows whose
    estimated λ exceeds the rate join the quarantine — the learned band
    the fixed thresholds cannot express.  Absent/off, the mask is exactly
    the fixed-bands mask."""
    flags = np.zeros(len(items), dtype=bool)
    for i, it in enumerate(items):
        od = it.offering.od_price
        sp = it.spot_price
        flags[i] = (not math.isfinite(sp)
                    or sp <= config.floor_od_factor * od
                    or sp > config.spike_od_factor * od
                    or not (0 < it.t3 <= 50))
    if hazard is not None and config.hazard_quarantine_rate > 0.0:
        flags |= np.asarray(hazard, dtype=np.float64) \
            > config.hazard_quarantine_rate
    return flags if flags.any() else None


def check_decision(pool: Optional[NodePool], request: Request,
                   config: GuardConfig) -> bool:
    """The invariant monitor: feasibility/budget sanity of one decision.

    Checks (all cheap, all deterministic): non-negative counts within each
    item's T3 bound, finite positive spot prices, finite non-negative
    hourly cost, and cost no higher than the equivalent on-demand bill
    (spot is clipped at od by the market; paying above it means the
    decision trusted a spiked row)."""
    if pool is None:
        return False
    od_cost = 0.0
    for it, c in zip(pool.items, pool.counts):
        if c < 0 or c > it.t3:
            return False
        if not math.isfinite(it.spot_price) or it.spot_price <= 0:
            return False
        od_cost += it.offering.od_price * c
    cost = pool.hourly_cost
    if not math.isfinite(cost) or cost < 0:
        return False
    return cost <= config.spike_od_factor * od_cost + 1e-9


def safe_pool(items: Sequence, exclude: Optional[np.ndarray],
              request: Request) -> NodePool:
    """The ladder's bottom solver-free rung: a minimum-viable pool that
    greedily covers the demand from sanitized rows, most-reliable first
    (interruption_freq, then od-price per pod — od because observed spot
    is exactly what can no longer be trusted down here)."""
    order = sorted(
        range(len(items)),
        key=lambda i: (items[i].offering.interruption_freq,
                       items[i].offering.od_price / items[i].pods,
                       items[i].offering.offering_id))
    chosen, counts = [], []
    remaining = int(request.pods)
    for i in order:
        if remaining <= 0:
            break
        if exclude is not None and exclude[i]:
            continue
        it = items[i]
        if not math.isfinite(it.spot_price) or it.spot_price <= 0 \
                or it.t3 <= 0:
            continue
        take = min(int(it.t3), math.ceil(remaining / it.pods))
        if take <= 0:
            continue
        chosen.append(it)
        counts.append(take)
        remaining -= take * it.pods
    return NodePool(items=chosen, counts=counts, alpha=None,
                    request=request)


def decision_available(decision: Optional[ProvisioningDecision]) -> bool:
    """Did this decision cycle produce usable capacity?  (The bench's
    decision-availability numerator: failed/blocked cycles and empty
    pools count as unavailable.)"""
    if decision is None or not isinstance(decision, ProvisioningDecision):
        return False
    if decision.metrics.get("decision_failed"):
        return False
    return decision.pool.total_pods > 0


class HardenedPolicy(Policy):
    """The ``hardened`` policy spec: KubePACS + the degradation ladder.

    ``chaos_hardened`` marks the policy to the engine: under an active
    solver fault the engine fails *unhardened* policies' decision cycles
    outright, while hardened policies get called and handle the fault
    through the retry/ladder machinery themselves.
    """

    name = "hardened"
    chaos_hardened = True

    #: the solver-rung count is ``len(ladder)``; metrics' ``chaos_rung``
    #: uses indices 0..L-1 for solver rungs, L for memo, L+1 for safe
    def __init__(self, tolerance: float = 0.01, ttl_hours: float = 2.0,
                 clock: Callable[[], float] = time.perf_counter,
                 config: Optional[GuardConfig] = None,
                 ladder: Sequence[str] = DEFAULT_LADDER,
                 region: Optional[RegionConfig] = None) -> None:
        self.provisioner = KubePACSProvisioner(tolerance=tolerance,
                                               ttl_hours=ttl_hours,
                                               timer=clock)
        self.config = config or GuardConfig()
        self.ladder = tuple(ladder)
        #: scenario RegionConfig (None outside a regional scenario); the
        #: §17 failover rung prices egress / honors caps through it
        self.region = region
        #: §10 estimators for the learned quarantine band — constructed in
        #: :meth:`bind` only when ``hazard_quarantine_rate`` is enabled
        self.estimators = None
        self.chaos: Optional[ChaosController] = None
        self._backends: Dict[str, Optional[SolverBackend]] = {}
        # last-good solved pools keyed by exact request shape (pods
        # included: a pool sized for 100 pods cannot serve 300)
        self._last_good: Dict[Tuple, Tuple[NodePool, Optional[float]]] = {}
        self._lg_digest = ""
        # observed grant ratio of the latest uniformly-short fulfillment
        # round (1.0 = market granting in full; see observe_fulfillment)
        self._grant_ratio = 1.0
        self.counters: Dict[str, int] = {}

    # -- protocol hooks ------------------------------------------------------
    def bind(self, catalog) -> None:
        if self.config.hazard_quarantine_rate > 0.0:
            from ..risk.estimators import RiskEstimators
            self.estimators = RiskEstimators(catalog)

    def bind_chaos(self, chaos: Optional[ChaosController]) -> None:
        self.chaos = chaos

    def observe_market(self, time, spot, t3):
        if self.estimators is not None:
            self.estimators.on_market_state(time, spot, t3)

    def observe_interrupts(self, time, dt, pool, notices):
        if self.estimators is not None:
            self.estimators.on_interrupts(time, dt, pool, notices)

    def set_decision_memo(self, memo):
        self.decision_memo = memo
        self.provisioner.decision_memo = memo

    def set_solve_batch(self, batch):
        """Deliberately a no-op: the guard solves inline so every attempt
        is individually retryable/deadline-checkable.  Correct under the
        batching contract (batching changes execution, never content)."""

    def memo_digest(self) -> Optional[str]:
        # without chaos the guard is stateless beyond the TTL cache the
        # memo key already covers (inert-path parity with "kubepacs");
        # with chaos, degraded decisions additionally depend on the
        # last-good store, which this digest pins conservatively (equal
        # histories ⇒ equal digests; a differing history never shares)
        if self.chaos is None and self.estimators is None:
            return None
        lg = f"guard:{self._lg_digest}"
        if self.estimators is not None:
            # learned quarantine band: decisions depend on estimator state
            lg += f":{self.estimators.digest()}"
        return lg

    def chaos_stats(self) -> Dict[str, int]:
        """Per-rung/diagnostic counters (``cache_stats``' ``chaos_*``)."""
        return dict(self.counters)

    def _count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def _backend(self, spec: str) -> Optional[SolverBackend]:
        if spec not in self._backends:
            self._backends[spec] = (None if spec == "default"
                                    else make_backend(spec))
        return self._backends[spec]

    # -- last-good store -----------------------------------------------------
    @staticmethod
    def _shape_key(request: Request) -> Tuple:
        return (request.pods, request.cpu_per_pod, request.mem_per_pod,
                request.workload)

    def _lookup_last_good(self, request: Request
                          ) -> Optional[Tuple[NodePool, Optional[float]]]:
        """Exact shape first; otherwise the smallest remembered pool of
        the same (cpu, mem, workload) that covers at least the requested
        pods, trimmed down to the shortfall keeping the cheapest pods.
        Shortfall re-provisions carry pod counts the exact-match store
        has never seen, and dropping those to the safe rung buys the
        most expensive (availability-first) pods in the catalog."""
        shape = self._shape_key(request)
        hit = self._last_good.get(shape)
        if hit is not None:
            return hit
        best = None
        for key, val in self._last_good.items():
            if key[1:] == shape[1:] and key[0] >= request.pods \
                    and (best is None or key[0] < best[0]):
                best = (key[0], val)
        if best is None:
            return None
        pool, alpha = best[1]
        order = sorted(range(len(pool.items)),
                       key=lambda i: (pool.items[i].spot_price
                                      / pool.items[i].pods,
                                      pool.items[i].offering.offering_id))
        remaining = request.pods
        items, counts = [], []
        for i in order:
            if remaining <= 0:
                break
            it = pool.items[i]
            take = min(int(pool.counts[i]), math.ceil(remaining / it.pods))
            if take <= 0:
                continue
            items.append(it)
            counts.append(take)
            remaining -= take * it.pods
        if not items:
            return None
        self._count("memo_trimmed")
        return (NodePool(items=items, counts=counts, alpha=alpha,
                         request=request), alpha)

    def _remember(self, request: Request,
                  decision: ProvisioningDecision) -> None:
        if not isinstance(decision, ProvisioningDecision):
            return                      # PendingDecision (batched healthy)
        if decision.pool.total_pods <= 0:
            return
        self._last_good[self._shape_key(request)] = (decision.pool,
                                                     decision.alpha)
        h = hashlib.blake2s(digest_size=8)
        h.update(self._lg_digest.encode())
        h.update(repr((self._shape_key(request),
                       sorted(decision.pool.as_dict().items()),
                       decision.alpha)).encode())
        self._lg_digest = h.hexdigest()

    # -- the policy interface ------------------------------------------------
    def provision(self, request, snapshot, now, precompiled=None):
        self.provisioner.clock = now
        chaos = self.chaos
        if chaos is None:
            return self.provisioner.provision(request, snapshot,
                                              precompiled)
        if chaos.has_region_faults:
            # §17 failover rung — sits above the ladder; bit-inert unless
            # the scenario actually declares region-kind faults
            qregions = chaos.region_fault_regions(now)
            if qregions:
                d = self._region_failover(request, snapshot, now,
                                          precompiled, qregions)
                if d is not None:
                    self._remember(request, d)
                    return self._inflate(request, d)
        healthy = (not chaos.snapshot_tainted
                   and chaos.solver_faulted(now) is None)
        if healthy:
            d = self.provisioner.provision(request, snapshot, precompiled)
            if not isinstance(d, ProvisioningDecision) \
                    or check_decision(d.pool, request, self.config):
                self._count("healthy")
                self._remember(request, d)
                return self._inflate(request, d)
            self._count("monitor_rejects")      # pragma: no cover
        return self._inflate(request, self._degraded(request, snapshot,
                                                     now, precompiled))

    def on_interrupts(self, notices, request, snapshot, surviving_pods,
                      now, precompiled=None):
        self.provisioner.clock = now
        if self.chaos is None:
            self.provisioner.enqueue([n.to_core() for n in notices])
            return self.provisioner.handle_interrupts(
                request, snapshot, surviving_pods=surviving_pods,
                precompiled=precompiled)
        if not notices:
            return None
        for n in notices:
            self.provisioner.cache.add(n.offering_id, now)
        shortfall = max(0, request.pods - surviving_pods)
        if shortfall == 0:
            return None
        repl = dataclasses.replace(request, pods=shortfall)
        return self.provision(repl, snapshot, now, precompiled)

    def observe_fulfillment(self, time, requested, grants):
        """ICE response, split by shortfall shape.

        *Offering-specific* (some offerings granted in full, others far
        short): the short offerings join the §4.1 TTL exclusion cache —
        the SpotKube-style diversification answer to capacity errors.

        *Market-wide* (every requested offering short, or uniformly
        partial): diversifying away from everything is pure loss, so the
        guard instead records the observed grant ratio and subsequent
        decisions over-request by ``1/ratio`` (T3-clipped, capped at
        ``ice_inflate_cap``; see :meth:`_inflate`) until a round is
        granted in full again.  Over-requesting under a cap is free:
        grants — and therefore billing — never exceed what the market
        actually yields."""
        if self.estimators is not None:
            self.estimators.on_fulfillment(time, requested, grants)
        if self.chaos is None:
            return
        cfg = self.config
        pos = {oid: c for oid, c in requested.items() if c > 0}
        if not pos:
            return
        short = [oid for oid, c in pos.items()
                 if grants.get(oid, 0) < cfg.ice_exclude_below * c]
        if short and len(short) < len(pos):
            self._grant_ratio = 1.0
            for oid in short:
                self.provisioner.cache.add(oid, time)
                self._count("ice_excluded")
            return
        got = sum(grants.get(oid, 0) for oid in pos)
        ratio = got / sum(pos.values())
        if ratio >= 1.0:
            self._grant_ratio = 1.0
        else:
            self._grant_ratio = max(ratio, 1.0 / cfg.ice_inflate_cap)
            self._count("ice_market_wide")

    def _inflate(self, request, decision):
        """Market-wide ICE compensation: while fulfillment rounds come
        back uniformly short, scale each item's requested count by the
        observed grant ratio (clipped to its T3 bound) so the post-cap
        grants land near the solved pool instead of ``ratio ×`` it."""
        if self._grant_ratio >= 1.0 \
                or not isinstance(decision, ProvisioningDecision) \
                or decision.pool.total_pods <= 0:
            return decision
        pool = decision.pool
        counts = [min(int(it.t3), math.ceil(c / self._grant_ratio))
                  if c > 0 else int(c)
                  for it, c in zip(pool.items, pool.counts)]
        if counts == [int(c) for c in pool.counts]:
            return decision
        self._count("ice_inflated")
        new_pool = NodePool(items=list(pool.items), counts=counts,
                            alpha=pool.alpha, request=pool.request)
        metrics = decision_metrics(new_pool, request.pods)
        metrics.update({k: v for k, v in decision.metrics.items()
                        if k.startswith("chaos_")})
        metrics["chaos_ice_inflate"] = round(1.0 / self._grant_ratio, 4)
        return dataclasses.replace(decision, pool=new_pool,
                                   metrics=metrics)

    # -- the §17 region failover rung ----------------------------------------
    def _hazard_rows(self, items) -> Optional[np.ndarray]:
        """Per-item estimated hazard for the learned quarantine band, or
        None when the band is off (the default — bit-inert)."""
        est = self.estimators
        if est is None or self.config.hazard_quarantine_rate <= 0.0:
            return None
        lam = est.hazard()
        return lam[est.gather([it.offering.offering_id for it in items])]

    def _region_failover(self, request, snapshot, now, precompiled,
                         qregions) -> Optional[ProvisioningDecision]:
        """Quarantine every row of the actively-faulted regions and
        re-solve the full demand into the survivors with the scenario
        RegionConfig's side-constraints (egress priced into the objective,
        caps, minimum spread).  Detection is declaration-based but
        row-blind: the guard reads *which regions* are under an active
        fault window from the controller — the operator signal a real
        control plane gets from health probes — never which rows the
        fault actually corrupted.  Returns None when the survivors cannot
        cover demand (or the monitor rejects), and the decision falls
        through to the healthy/degraded paths."""
        prov = self.provisioner
        cfg = self.config
        t0 = prov.timer()
        excluded = prov.cache.excluded(now)
        items, market = prov._compiled(request, snapshot, precompiled)
        qset = set(qregions)
        rmask = np.array([getattr(it.offering, "region", "") in qset
                          for it in items], dtype=bool)
        # rmask may be empty — e.g. an outage already blanked the region's
        # rows out of the frozen observed feed.  The quarantine is vacuous
        # then, but the side-constrained re-solve below is still the §17
        # response: min-spread/caps/egress matter *most* mid-outage, and
        # the plain degraded ladder applies none of them
        if rmask.any():
            self._count("region_quarantined_rows", int(rmask.sum()))
        qmask = quarantine_mask(items, cfg, hazard=self._hazard_rows(items))
        extra = rmask if qmask is None else (rmask | qmask)
        exclude = exclusion_mask(items, excluded, extra=extra)
        if exclude is not None and bool(exclude.all()):
            return None     # no survivors — let the ladder cope
        rcfg = self.region if self.region is not None else RegionConfig()
        pool, trace, info = solve_with_regions(
            items, request.pods, rcfg, market=market,
            tolerance=prov.tolerance, exclude=exclude, timer=prov.timer,
            coarsening=prov.coarsening)
        if pool is None or not check_decision(pool, request, cfg):
            self._count("region_failover_failed")
            return None
        self._count("region_failover")
        if info["egress_reweighted"]:
            self._count("region_egress_solves")
        if info["cap_repairs"]:
            self._count("region_cap_repairs", info["cap_repairs"])
        if info["spread_forced"]:
            self._count("region_spread_forced", info["spread_forced"])
        metrics = decision_metrics(pool, request.pods)
        metrics["chaos_rung"] = -1.0    # above solver rung 0
        metrics["chaos_region_failover"] = float(len(qregions))
        return ProvisioningDecision(
            pool=pool, trace=trace, alpha=pool.alpha,
            wall_seconds=prov.timer() - t0,
            excluded_offerings=excluded, metrics=metrics)

    # -- the degraded path ---------------------------------------------------
    def _degraded(self, request, snapshot, now, precompiled):
        prov = self.provisioner
        cfg = self.config
        chaos = self.chaos
        timer = prov.timer
        t0 = timer()
        excluded = prov.cache.excluded(now)
        memo = self.decision_memo
        mkey = memo.key(request, excluded) if memo is not None else None
        if mkey is not None:
            hit = memo.fetch(mkey, timer() - t0)
            if hit is not None:
                return hit
        items, market = prov._compiled(request, snapshot, precompiled)
        qmask = quarantine_mask(items, cfg, hazard=self._hazard_rows(items))
        nq = int(qmask.sum()) if qmask is not None else 0
        if nq:
            self._count("quarantined_rows", nq)
        exclude = exclusion_mask(items, excluded, extra=qmask)
        age = chaos.stale_age

        decision = None
        total_attempts = cfg.attempts_per_rung * len(self.ladder)
        schedule = backoff_schedule(cfg.backoff_seed, now, total_attempts,
                                    cfg.backoff_base_s, cfg.backoff_cap_s)
        budget = cfg.deadline_s
        attempt = 0
        if age > cfg.max_stale_hours:
            self._count("stale_beyond_ttl")
        else:
            # staleness penalty through the O(n) reweighting path
            items_s, market_s = items, market
            if age > 0.0:
                perf, price, _ = pool_metric_arrays(items)
                pen = 1.0 / (1.0 + cfg.stale_penalty_per_hour * age)
                items_s = reweight_items(items, perf * pen, price)
                market_s = reweight_market(market, perf * pen, price,
                                           items=items_s)
            infeasible = False
            for ri, rung in enumerate(self.ladder):
                solved = None
                for _ in range(cfg.attempts_per_rung):
                    if attempt > 0:   # simulated backoff wait (no sleep)
                        budget -= schedule[min(attempt,
                                               len(schedule) - 1)]
                    if budget <= 0.0:
                        self._count("deadline_exhausted")
                        break
                    outcome = chaos.attempt_outcome(now, attempt)
                    attempt += 1
                    if outcome == "error":
                        self._count("solve_errors")
                        continue
                    if outcome == "overrun":
                        budget -= chaos.attempt_cost_s(now)
                        self._count("solve_overruns")
                        continue
                    solved = bracketed_gss(
                        items_s, request.pods, tolerance=prov.tolerance,
                        market=market_s, exclude=exclude, timer=timer,
                        backend=self._backend(rung),
                        coarsening=prov.coarsening)
                    break
                if solved is not None:
                    pool, trace = solved
                    if pool is None:
                        # genuinely infeasible on sanitized inputs — the
                        # backend contract makes every rung agree, so go
                        # straight to the memo rung
                        self._count("infeasible_solves")
                        infeasible = True
                        break
                    if age > 0.0:
                        # map penalized counts back onto real items so
                        # cost accrual uses observed market numbers
                        real = {it.offering.offering_id: it
                                for it in items}
                        pool = NodePool(
                            items=[real[it.offering.offering_id]
                                   for it in pool.items],
                            counts=list(pool.counts), alpha=pool.alpha,
                            request=request)
                    if check_decision(pool, request, cfg):
                        self._count(f"solver_rung_{ri}")
                        decision = self._build(
                            request, excluded, pool, trace, pool.alpha,
                            t0, float(ri), age, nq, attempt, mkey)
                        self._remember(request, decision)
                        break
                    self._count("monitor_rejects")
                if infeasible or budget <= 0.0:
                    break

        if decision is None:
            lg = self._lookup_last_good(request)
            if lg is not None:
                pool, alpha = lg
                # shallow copy: never mutate a previously returned pool
                pool = NodePool(items=list(pool.items),
                                counts=list(pool.counts), alpha=alpha,
                                request=request)
                self._count("memo_rung")
                decision = self._build(request, excluded, pool, None,
                                       alpha, t0, float(len(self.ladder)),
                                       age, nq, attempt, mkey)
            else:
                pool = safe_pool(items, exclude, request)
                self._count("safe_rung" if pool.total_pods > 0
                            else "no_decision")
                decision = self._build(request, excluded, pool, None,
                                       None, t0,
                                       float(len(self.ladder) + 1),
                                       age, nq, attempt, mkey)
        return decision

    def _build(self, request, excluded, pool, trace, alpha, t0, rung,
               age, nq, attempts, mkey):
        metrics = decision_metrics(pool, request.pods)
        metrics["chaos_rung"] = rung
        metrics["chaos_attempts"] = float(attempts)
        if age > 0.0:
            metrics["chaos_stale_hours"] = age
        if nq:
            metrics["chaos_quarantined"] = float(nq)
        decision = ProvisioningDecision(
            pool=pool, trace=trace, alpha=alpha,
            wall_seconds=self.provisioner.timer() - t0,
            excluded_offerings=excluded, metrics=metrics)
        if mkey is not None:
            self.decision_memo.store(mkey, decision)
        return decision


__all__ = ["DEFAULT_LADDER", "GuardConfig", "HardenedPolicy",
           "backoff_schedule", "check_decision", "decision_available",
           "quarantine_mask", "safe_pool"]
