"""ServeSim: SLO-driven serving co-simulation (DESIGN.md §15).

Connects the ML stack (roofline perf model over ``serving.py`` decode
cells) to the provisioning plane (ClusterSim + the ``serving_slo``
policy): deterministic request-rate traces, per-offering QPS/latency
tables, square-root-staffed pod demand, latency-SLO feasibility masks,
and interruption → recovery QPS accounting.

Import layering: ``workload`` and ``perf_model`` are leaf modules
(``repro.sim`` imports them), while ``sim`` imports ``repro.sim`` — the
runner names below are therefore exposed lazily via ``__getattr__`` to
keep the package importable from either direction without a cycle.
"""

from __future__ import annotations

from .perf_model import (ServingProfile, ServingTable, analytic_token_s,
                         cache_stats, clear_caches, default_profile,
                         default_slo_ms, reference_qps_per_pod,
                         reference_token_s, serving_table)
from .workload import (DEFAULT_STAFFING_BETA, WorkloadSpec,
                       demand_schedule_from_trace, staffed_pods,
                       trace_digest)

_SIM_NAMES = ("DEFAULT_RECOVERY_HOURS", "PoolTimeline", "ServeReport",
              "ServeScenario", "build_serve_scenario", "evaluate_serving",
              "run_serving")


def __getattr__(name: str):
    if name in _SIM_NAMES:
        from . import sim
        return getattr(sim, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "DEFAULT_RECOVERY_HOURS", "DEFAULT_STAFFING_BETA", "PoolTimeline",
    "ServeReport", "ServeScenario", "ServingProfile", "ServingTable",
    "WorkloadSpec", "analytic_token_s", "build_serve_scenario",
    "cache_stats", "clear_caches", "default_profile", "default_slo_ms",
    "demand_schedule_from_trace", "evaluate_serving",
    "reference_qps_per_pod", "reference_token_s", "run_serving",
    "serving_table", "staffed_pods", "trace_digest",
]
