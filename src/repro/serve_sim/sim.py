"""The serving co-simulation: traffic × provisioning × recovery accounting.

This is the layer that closes the loop between the ML stack and the
decision plane (DESIGN.md §15).  A :class:`ServeScenario` pairs a
deterministic request-rate trace (:mod:`repro.serve_sim.workload`) with a
provisioning :class:`~repro.sim.scenario.Scenario` whose pod-demand
schedule is staffed from that trace; :func:`run_serving` drives the
unchanged ``ClusterSim`` engine and then *re-reads the run as a serving
system*:

* a :class:`PoolTimeline` observer captures the pool composition at every
  change (launches, interruption losses) through the engine's
  ``observe_pool`` hook — the piecewise-constant capacity function;
* each pool segment is converted to served QPS via the perf model's
  per-offering QPS/pod table: ``served(t) = min(λ(t), C(t))``, and to
  SLO-served QPS with capacity restricted to SLO-feasible offerings
  (``request_ms ≤ slo_ms``) — cheap slow nodes serve traffic but not
  *within* the SLO, which is exactly the karpenter-baseline failure mode;
* **recovery accounting** (the elastic-reconfiguration charge): capacity
  *added* after an interruption or demand change spends
  ``recovery_hours`` warming up — node boot, image pull, weight load,
  cache re-shard (the runtime/elastic.py re-step path) — during which its
  QPS is charged as lost.  The initial t=0 provisioning is exempt (the
  service is assumed warm at the start of the horizon).

The resulting :class:`ServeReport` carries the headline production
metrics: SLO attainment and served-QPS-hours per dollar.  Everything is
deterministic: the trace, the table, and the integration are pure
functions of (spec, profile, scenario); the only randomness is the
engine's own seeded market/interrupt streams.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .perf_model import (ServingProfile, ServingTable, default_profile,
                         default_slo_ms, serving_table)
from .workload import WorkloadSpec, trace_digest

_EPS = 1e-9

#: default elastic-reconfiguration window (hours): time for a replacement
#: node to boot, pull the serving image, load weights, and rejoin the
#: decode mesh — newly added capacity serves nothing for this long
DEFAULT_RECOVERY_HOURS = 0.25


class PoolTimeline:
    """Engine observer recording (time, reason, pool composition) at every
    pool change — the capacity step function the report integrates.  Pure
    recorder: adding it to ``observers=`` cannot perturb decisions."""

    def __init__(self) -> None:
        self.events: List[Tuple[float, str, Tuple[Tuple[str, int, int], ...]]] = []

    # observer protocol (only the pool hook does anything)
    def observe_market(self, time, spot, t3) -> None:
        pass

    def observe_interrupts(self, time, dt, pool, notices) -> None:
        pass

    def observe_fulfillment(self, time, requested, grants) -> None:
        pass

    def observe_pool(self, time, pool, reason) -> None:
        alloc = tuple((it.offering.offering_id, int(c), int(it.pods))
                      for it, c in zip(pool.items, pool.counts) if c > 0)
        self.events.append((float(time), str(reason), alloc))


@dataclasses.dataclass(frozen=True)
class ServeScenario:
    """A workload trace + the provisioning scenario staffed from it."""

    workload: WorkloadSpec
    scenario: "object"                   # repro.sim.Scenario
    profile: ServingProfile
    slo_ms: float
    recovery_hours: float = DEFAULT_RECOVERY_HOURS


def build_serve_scenario(workload: str = "diurnal", *,
                         policy: str = "serving_slo",
                         base_qps: float = 1000.0, seed: int = 11,
                         profile: Optional[ServingProfile] = None,
                         slo_ms: Optional[float] = None,
                         recovery_hours: float = DEFAULT_RECOVERY_HOURS,
                         duration_hours: float = 24.0,
                         step_hours: float = 1.0,
                         **overrides) -> ServeScenario:
    """The serving counterpart of the ``*_scenario()`` factories: one call
    yields the workload spec, the staffed :class:`Scenario`, and the SLO —
    everything :func:`run_serving` needs.  ``profile=None`` resolves
    :func:`default_profile` (env-overridable mode), which is also what the
    ``serving_slo`` policy resolves internally, so the policy and the
    report always price capacity with the same table."""
    from ..sim.scenario import serving_scenario
    if profile is None:
        profile = default_profile()
    spec = WorkloadSpec(kind=workload, base_qps=base_qps, seed=seed,
                        duration_hours=duration_hours,
                        step_hours=step_hours)
    scenario = serving_scenario(workload, base_qps=base_qps, seed=seed,
                                policy=policy,
                                duration_hours=duration_hours,
                                step_hours=step_hours, profile=profile,
                                **overrides)
    return ServeScenario(
        workload=spec, scenario=scenario, profile=profile,
        slo_ms=float(slo_ms) if slo_ms is not None
        else default_slo_ms(profile),
        recovery_hours=float(recovery_hours))


@dataclasses.dataclass
class ServeReport:
    """Serving-side reading of one simulation run."""

    policy: str
    workload_kind: str
    workload_digest: str                 # trace determinism pin
    perf_mode: str                       # "roofline" | "analytic"
    slo_ms: float
    total_cost: float
    offered_qps_hours: float             # ∫ λ dt
    served_qps_hours: float              # ∫ min(λ, C_warm) dt
    slo_served_qps_hours: float          # ∫ min(λ, C_slo,warm) dt
    nominal_served_qps_hours: float      # ∫ min(λ, C) dt (no warm-up charge)
    recovery_lost_qps_hours: float       # nominal − served (warm-up losses)
    interrupted_nodes: int
    decisions: int
    infeasible_decisions: int            # SLO mask left no feasible pool

    @property
    def slo_attainment(self) -> float:
        """Fraction of offered traffic served within the latency SLO."""
        return (self.slo_served_qps_hours / self.offered_qps_hours
                if self.offered_qps_hours > 0 else 0.0)

    @property
    def served_fraction(self) -> float:
        return (self.served_qps_hours / self.offered_qps_hours
                if self.offered_qps_hours > 0 else 0.0)

    @property
    def qps_hours_per_dollar(self) -> float:
        return (self.served_qps_hours / self.total_cost
                if self.total_cost > 0 else 0.0)

    @property
    def slo_qps_hours_per_dollar(self) -> float:
        """The headline: served QPS-hours *under SLO* per dollar spent."""
        return (self.slo_served_qps_hours / self.total_cost
                if self.total_cost > 0 else 0.0)

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(slo_attainment=self.slo_attainment,
                 served_fraction=self.served_fraction,
                 qps_hours_per_dollar=self.qps_hours_per_dollar,
                 slo_qps_hours_per_dollar=self.slo_qps_hours_per_dollar)
        return d


def _segment_capacity(alloc: Sequence[Tuple[str, int, int]],
                      table: ServingTable, slo_ms: float,
                      ) -> Tuple[float, float, Dict[str, float]]:
    """(total QPS, SLO-feasible QPS, per-offering QPS) of one pool."""
    idx = table.index
    total, slo_total = 0.0, 0.0
    per: Dict[str, float] = {}
    for oid, nodes, pods in alloc:
        k = idx.get(oid)
        if k is None:
            continue
        qps = nodes * pods * float(table.qps_per_pod[k])
        per[oid] = per.get(oid, 0.0) + qps
        total += qps
        if float(table.request_ms[k]) <= slo_ms + _EPS:
            slo_total += qps
    return total, slo_total, per


def evaluate_serving(ss: ServeScenario, table: ServingTable,
                     timeline: PoolTimeline, result) -> ServeReport:
    """Integrate λ(t) against the capacity timeline → :class:`ServeReport`.

    Capacity is piecewise constant between pool events; λ is piecewise
    constant per workload interval; warm-up adjustments subtract newly
    added per-offering QPS over ``[t_event, t_event + recovery_hours)``.
    The integration grid is the union of all three breakpoint families,
    so every sub-interval has constant integrand and the result is exact
    (no quadrature error to drift across platforms)."""
    spec = ss.workload
    lam = spec.trace()
    horizon = spec.n_steps * spec.step_hours

    events = sorted(timeline.events, key=lambda e: e[0])
    # per-event capacity + warm-up windows for capacity *added* after t=0
    seg: List[Tuple[float, float, float]] = []      # (start, C, C_slo)
    warm: List[Tuple[float, float, float, float]] = []  # (a, b, dC, dC_slo)
    prev_per: Dict[str, float] = {}
    for t, reason, alloc in events:
        total, slo_total, per = _segment_capacity(alloc, table, ss.slo_ms)
        seg.append((t, total, slo_total))
        if t > _EPS and ss.recovery_hours > 0:
            added = 0.0
            added_slo = 0.0
            idx = table.index
            for oid, qps in per.items():
                delta = qps - prev_per.get(oid, 0.0)
                if delta > _EPS:
                    added += delta
                    k = idx.get(oid)
                    if k is not None and \
                            float(table.request_ms[k]) <= ss.slo_ms + _EPS:
                        added_slo += delta
            if added > 0:
                warm.append((t, min(t + ss.recovery_hours, horizon),
                             added, added_slo))
        prev_per = per
    if not seg or seg[0][0] > _EPS:
        seg.insert(0, (0.0, 0.0, 0.0))              # empty pool until t=0+

    cuts = {0.0, horizon}
    cuts.update(t for t, _, _ in seg if t < horizon)
    cuts.update(x for a, b, _, _ in warm for x in (a, b) if x < horizon)
    cuts.update(float(k * spec.step_hours) for k in range(1, spec.n_steps))
    grid = sorted(cuts)

    offered = served = slo_served = nominal = 0.0
    si = 0
    for a, b in zip(grid, grid[1:]):
        dt = b - a
        if dt <= _EPS:
            continue
        while si + 1 < len(seg) and seg[si + 1][0] <= a + _EPS:
            si += 1
        _, cap, cap_slo = seg[si]
        warming = sum(d for (wa, wb, d, _) in warm if wa <= a + _EPS < wb)
        warming_slo = sum(d for (wa, wb, _, d) in warm
                          if wa <= a + _EPS < wb)
        k = min(int((a + _EPS) / spec.step_hours), spec.n_steps - 1)
        rate = float(lam[k])
        offered += rate * dt
        nominal += min(rate, cap) * dt
        served += min(rate, max(cap - warming, 0.0)) * dt
        slo_served += min(rate, max(cap_slo - warming_slo, 0.0)) * dt

    metrics_list = [d.metrics for _, d in result.decisions]
    infeasible = sum(1 for m in metrics_list
                     if m.get("serve_infeasible", 0.0) > 0
                     or (m.get("pods", 0.0) <= 0 and m.get("nodes", 0) <= 0))
    return ServeReport(
        policy=ss.scenario.policy, workload_kind=spec.kind,
        workload_digest=trace_digest(spec), perf_mode=table.mode,
        slo_ms=ss.slo_ms, total_cost=float(result.total_cost),
        offered_qps_hours=offered, served_qps_hours=served,
        slo_served_qps_hours=slo_served, nominal_served_qps_hours=nominal,
        recovery_lost_qps_hours=max(nominal - served, 0.0),
        interrupted_nodes=int(result.interrupted_nodes),
        decisions=len(result.decisions),
        infeasible_decisions=int(infeasible))


def run_serving(ss: ServeScenario, *, catalog=None,
                clock=None) -> ServeReport:
    """Run the provisioning simulation and read it back as a serving
    system.  The engine, policies, and trace format are untouched — the
    co-simulation is an observer plus a post-pass."""
    from ..sim.engine import ClusterSim
    timeline = PoolTimeline()
    kwargs = {} if clock is None else {"clock": clock}
    sim = ClusterSim(ss.scenario, catalog=catalog, observers=[timeline],
                     **kwargs)
    result = sim.run()
    table = serving_table(ss.profile, sim.catalog)
    return evaluate_serving(ss, table, timeline, result)


__all__ = ["DEFAULT_RECOVERY_HOURS", "PoolTimeline", "ServeReport",
           "ServeScenario", "build_serve_scenario", "evaluate_serving",
           "run_serving"]
