"""Per-offering serving throughput / latency from the roofline model.

This module replaces the scalar ``perf = BS_i · Pod_i`` score with serving
quantities for the co-simulation (DESIGN.md §15): every offering gets a
**QPS per pod** (throughput the ILP should buy) and a **per-request
latency** (what the SLO mask filters on), derived from the ML stack
instead of CoreMark alone.

Derivation (two modes, identical *ranking* by construction):

* ``roofline`` — lower + compile a reduced decode cell through
  :func:`repro.serving.make_sharded_decode` on a 1×1 ``("data","model")``
  mesh (the launch/dryrun.py recipe, without its XLA_FLAGS side effects),
  walk the partitioned HLO with :func:`repro.roofline.analyze_hlo`, and
  turn ``Roofline.step_s`` into a measured *efficiency factor* — compiled
  step time over the ideal weight-stream bound on the same cell — that
  rescales the analytic full-model bound (both roofline terms are linear
  in N, so the factor transfers; it captures what the analytic bound
  misses: KV-cache traffic, bookkeeping fusions, layout copies).
* ``analytic`` — the ``model_flops`` fallback, jax-free: a decode step
  over B concurrent rows on a D-device pod moves the active weights once
  plus the KV cache of B rows at the pinned context length
  (``memory_s = (2·N + B·S·kv_bytes)/(HBM_BW·D)``, bf16) and computes
  ``2·N`` FLOPs per row (``compute_s = 2·N·B/(PEAK_FLOPS·D)``);
  ``step_s = max`` of the two.  At the default profile the KV term
  dominates — decode at 32 k context is cache-bound, which is exactly
  what the compiled twin's HLO walk shows too.

Either way ``token_s_ref`` is the per-token seconds of the *reference*
machine (a gen-6 intel core, ``GEN6_CORE_SCORE``).  Offerings scale it by
their CoreMark ratio ``s_i = BS_i / GEN6_CORE_SCORE`` — one multiplicative
speed factor per offering, which is exactly why the two modes can never
disagree on ranking, only on absolute seconds (the property the
deterministic twin of the jax-gated ranking test pins).

Both the step time and the per-market table are cached by a (config,
shape, offering-set) digest — recompiling a decode cell per provisioning
decision would dwarf the solver.  ``cache_stats()`` exposes hit/miss
counters for the invalidation tests.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import warnings
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro import roofline
from repro.core.market import GEN6_CORE_SCORE

#: env override for the default perf-model mode (CI pins the analytic
#: fallback on the no-jax leg implicitly; set ``KUBEPACS_SERVE_PERF=analytic``
#: to force it even with jax installed)
ENV_MODE = "KUBEPACS_SERVE_PERF"

_MODES = ("auto", "roofline", "analytic")


@dataclasses.dataclass(frozen=True)
class ServingProfile:
    """What is being served: the (config, shape) half of the cache key.

    ``active_params`` is pinned rather than recomputed so the analytic
    fallback never imports jax and both modes rescale to the same
    full-model anchor (qwen2.5-14b dense ≈ 14.8e9 parameters)."""

    arch: str = "qwen2.5-14b"
    shape: str = "decode_32k"
    active_params: float = 14.8e9     # full-model params touched per token
    kv_bytes_per_token: float = 1.97e5   # bf16 K+V bytes cached per token
    context_len: int = 32768          # KV length each stream decodes against
    devices_per_pod: int = 8          # chips a pod shards the replica over
    batch_per_pod: int = 32           # concurrent decode streams per pod
    tokens_per_request: int = 128     # decoded tokens per request
    mode: str = "auto"                # "auto" | "roofline" | "analytic"

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"unknown perf-model mode {self.mode!r}; "
                             f"choose from {_MODES}")
        for field in ("active_params", "kv_bytes_per_token"):
            object.__setattr__(self, field, float(getattr(self, field)))
        for field in ("context_len", "devices_per_pod", "batch_per_pod",
                      "tokens_per_request"):
            object.__setattr__(self, field, int(getattr(self, field)))

    def resolved_mode(self) -> str:
        if self.mode != "auto":
            return self.mode
        from repro.core import jax_available
        return "roofline" if jax_available() else "analytic"

    @property
    def digest(self) -> str:
        """Config+shape digest (mode-inclusive): the table cache key half
        that invalidates when any serving assumption changes."""
        h = hashlib.blake2b(digest_size=16)
        h.update(repr(dataclasses.astuple(self)).encode())
        return h.hexdigest()


def default_profile() -> ServingProfile:
    """The profile serving scenarios use unless told otherwise; honours
    the ``KUBEPACS_SERVE_PERF`` mode override."""
    mode = os.environ.get(ENV_MODE, "auto").strip() or "auto"
    return ServingProfile(mode=mode)


# --------------------------------------------------------------------------
# reference step time (per-token seconds on the gen-6 intel anchor)
# --------------------------------------------------------------------------

def analytic_token_s(profile: ServingProfile) -> float:
    """Pure-analytic decode-step roofline (no jax): max of the compute
    term and the memory term (active weights streamed once + KV cache of
    every concurrent row at the pinned context) over a ``devices_per_pod``
    pod.  One new token per row per step ⇒ per-token seconds = step
    seconds.  Default profile: ≈ 36 ms/token, cache-bound."""
    n = profile.active_params
    b = float(profile.batch_per_pod)
    d = float(profile.devices_per_pod)
    kv_bytes = b * profile.context_len * profile.kv_bytes_per_token
    compute_s = 2.0 * n * b / (roofline.PEAK_FLOPS * d)
    memory_s = (2.0 * n + kv_bytes) / (roofline.HBM_BW * d)
    return max(compute_s, memory_s)


def _roofline_token_s(profile: ServingProfile) -> float:
    """Compile a reduced decode cell (smoke twin, capped batch/seq so CI
    compiles in seconds), walk its HLO, and rescale the analytic
    full-model bound by the cell's measured efficiency factor
    (``analyze_hlo`` step time / ideal weight-stream bound)."""
    import jax
    import jax.numpy as jnp

    from repro import serving, sharding
    from repro.configs.base import SHAPES, InputShape, get_config
    from repro.data.pipeline import batch_pspecs, batch_specs
    from repro.models import transformer

    cfg = get_config(profile.arch, smoke=True)
    full = SHAPES[profile.shape]
    if full.kind != "decode":
        raise ValueError(f"serving profile needs a decode shape, got "
                         f"{profile.shape!r} ({full.kind})")
    cell = InputShape("serve_cell", seq_len=min(full.seq_len, 2048),
                      global_batch=min(profile.batch_per_pod, 8),
                      kind="decode")
    rules = sharding.single_pod_rules()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding.mesh_context(mesh, rules):
        aparams = transformer.abstract_params(cfg)
        acache = transformer.abstract_cache(cfg, cell.global_batch,
                                            cell.seq_len)
        bspecs = batch_specs(cfg, cell)
        bpspecs = batch_pspecs(cfg, cell, rules)
        step = serving.make_sharded_decode(cfg, rules, bpspecs, donate=False)
        # decode position indexes dynamic_update_slice next to literal-int
        # indices, which canonicalize to int64 once a solver backend has
        # flipped jax_enable_x64 process-wide — pin the *current* default
        # int dtype instead of int32 so the cell compiles in either regime
        pos = jax.ShapeDtypeStruct((), jnp.asarray(0).dtype)
        compiled = step.lower(aparams, acache, bspecs, pos).compile()
    hc = roofline.analyze_hlo(compiled.as_text(), 1)
    rl = roofline.Roofline(flops_per_device=hc.flops,
                           bytes_per_device=hc.bytes,
                           wire_bytes_per_device=hc.wire_bytes,
                           n_devices=1)
    # efficiency factor: measured HLO roofline over the *same cell's*
    # ideal bound (weights + its actual abstract-cache bytes) — transfers
    # to the full model because both roofline terms are linear in the
    # streamed bytes; it captures what the ideal bound misses (layout
    # copies, bookkeeping fusions, non-cache intermediates)
    smoke_active = float(transformer.active_params(cfg))
    cache_bytes = float(sum(
        int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(acache)))
    ideal_s = max(2.0 * smoke_active * cell.global_batch
                  / roofline.PEAK_FLOPS,
                  (2.0 * smoke_active + cache_bytes) / roofline.HBM_BW)
    eff = rl.step_s / max(ideal_s, 1e-30)
    return analytic_token_s(profile) * eff


#: step cache: (arch, shape, active_params, batch_per_pod, resolved mode)
#: → reference per-token seconds.  Module-level so every policy / bench /
#: replica run in a process shares one compile.
_STEP_CACHE: Dict[Tuple, float] = {}
_TABLE_CACHE: Dict[Tuple[str, Tuple], "ServingTable"] = {}
_STATS = {"step_hits": 0, "step_misses": 0,
          "table_hits": 0, "table_misses": 0}


def cache_stats() -> Dict[str, int]:
    return dict(_STATS)


def clear_caches() -> None:
    _STEP_CACHE.clear()
    _TABLE_CACHE.clear()
    for k in _STATS:
        _STATS[k] = 0


def reference_token_s(profile: ServingProfile) -> Tuple[float, str]:
    """(per-token seconds at speed factor 1.0, resolved mode), cached.
    ``auto`` degrades roofline → analytic with a warning if the compile
    path fails (broken jax install ≠ broken co-simulation); an explicit
    ``mode="roofline"`` propagates the error."""
    mode = profile.resolved_mode()
    key = (profile.arch, profile.shape, profile.active_params,
           profile.kv_bytes_per_token, profile.context_len,
           profile.devices_per_pod, profile.batch_per_pod, mode)
    if key in _STEP_CACHE:
        _STATS["step_hits"] += 1
        return _STEP_CACHE[key], mode
    _STATS["step_misses"] += 1
    if mode == "roofline":
        try:
            token_s = _roofline_token_s(profile)
        except Exception as exc:                      # pragma: no cover
            if profile.mode == "roofline":
                raise
            warnings.warn(f"serve_sim: roofline perf model unavailable "
                          f"({exc!r}); falling back to analytic")
            mode = "analytic"
            key = key[:-1] + (mode,)
            token_s = analytic_token_s(profile)
    else:
        token_s = analytic_token_s(profile)
    _STEP_CACHE[key] = token_s
    return token_s, mode


# --------------------------------------------------------------------------
# per-market serving table
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingTable:
    """Vectorized serving quantities for one offering set under one
    profile — the co-simulation's replacement for scalar perf scores."""

    profile_digest: str
    mode: str                        # resolved: "roofline" | "analytic"
    token_s_ref: float               # per-token s at speed factor 1.0
    offering_ids: Tuple[str, ...]
    speed: np.ndarray                # s_i = BS_i / GEN6_CORE_SCORE
    qps_per_pod: np.ndarray          # requests/s one pod of i sustains
    request_ms: np.ndarray           # per-request decode latency on i

    @property
    def index(self) -> Dict[str, int]:
        return {oid: k for k, oid in enumerate(self.offering_ids)}

    def slo_mask(self, slo_ms: float) -> Optional[np.ndarray]:
        """Boolean mask (True = SLO-infeasible, exclude from the ILP) in
        :func:`repro.core.provisioner.exclusion_mask` convention; ``None``
        when every offering meets the SLO."""
        mask = self.request_ms > float(slo_ms)
        return mask if bool(mask.any()) else None

    def qps_map(self) -> Dict[str, float]:
        """offering_id → QPS/pod (the recovery-accounting rate table)."""
        return {oid: float(q)
                for oid, q in zip(self.offering_ids, self.qps_per_pod)}


def serving_table(profile: ServingProfile,
                  offerings: Sequence) -> ServingTable:
    """Build (or fetch) the serving table for ``offerings`` — anything
    with ``offering_id``/``bs_core`` attributes (market offerings or the
    ``.offering`` of solver candidates)."""
    offs = [getattr(o, "offering", o) for o in offerings]
    market_key = tuple((o.offering_id, float(o.bs_core)) for o in offs)
    cache_key = (profile.digest, market_key)
    hit = _TABLE_CACHE.get(cache_key)
    if hit is not None:
        _STATS["table_hits"] += 1
        return hit
    _STATS["table_misses"] += 1
    token_s, mode = reference_token_s(profile)
    speed = np.array([bs / GEN6_CORE_SCORE for _, bs in market_key],
                     dtype=np.float64)
    token_s_i = token_s / np.maximum(speed, 1e-12)
    request_ms = profile.tokens_per_request * token_s_i * 1e3
    qps_per_pod = profile.batch_per_pod / (profile.tokens_per_request
                                           * token_s_i)
    table = ServingTable(
        profile_digest=profile.digest, mode=mode, token_s_ref=token_s,
        offering_ids=tuple(oid for oid, _ in market_key),
        speed=speed, qps_per_pod=qps_per_pod, request_ms=request_ms)
    _TABLE_CACHE[cache_key] = table
    return table


def reference_qps_per_pod(profile: ServingProfile) -> float:
    """QPS/pod of the speed-factor-1.0 anchor under the profile's
    resolved step time.  Staffing, SLO, and capacity all derive from the
    same ``token_s_ref``, which makes the co-simulation *scale-invariant*
    in it: pod counts and absolute latencies shift between modes, but
    mask fractions, attainment, and policy rankings do not — the property
    the analytic-≡-roofline ranking test pins."""
    token_s, _ = reference_token_s(profile)
    return profile.batch_per_pod / (profile.tokens_per_request * token_s)


def default_slo_ms(profile: ServingProfile,
                   slack: float = 1.05) -> float:
    """Default latency SLO: ``slack`` × the reference request latency —
    a request may decode 5 % slower than on the gen-6 intel anchor.  With
    the catalog's CoreMark spread (speed factors ≈ 0.79–1.23) this masks
    the slow quarter of the market (old generations, low-score vendors):
    SLO-infeasibility is a *speed-factor* threshold (``s_i < 1/slack``),
    identical in both perf-model modes."""
    token_s, _ = reference_token_s(profile)
    return slack * profile.tokens_per_request * token_s * 1e3


__all__ = ["ENV_MODE", "ServingProfile", "ServingTable", "analytic_token_s",
           "cache_stats", "clear_caches", "default_profile", "default_slo_ms",
           "reference_qps_per_pod", "reference_token_s", "serving_table"]
