"""Deterministic request-rate traces + queueing-theoretic capacity staffing.

The serving co-simulation (DESIGN.md §15) is driven by a per-interval
arrival-rate trace λ(t) in requests/second — the aggregate of millions of
users, each a sparse Poisson source, so λ is the only statistic that
matters (the per-user streams never need simulating).  Three canonical
shapes cover the production regimes the provisioning plane must absorb:

* ``diurnal`` — the 24 h sinusoidal day/night cycle every consumer
  service shows, plus small seeded per-interval noise;
* ``bursty``  — the diurnal base with seeded lognormal bursts landing on
  random intervals (push notifications, batch retries);
* ``flash``   — the diurnal base with one flash-crowd window (a launch,
  an outage elsewhere) multiplying demand for a few hours.

Determinism contract (same as DESIGN.md §9): a trace is a *pure function*
of its :class:`WorkloadSpec` — every draw comes from a fresh
``np.random.default_rng`` seeded by the spec's fields, so the same spec
produces byte-identical float64 arrays in any process, any call order.
:func:`trace_digest` pins that as a checkable hash.

Capacity staffing implements the square-root safety rule (the Halfin-Whitt
regime of M/M/c): to keep queueing delay negligible at offered load
ρ = λ/μ, provision ``c = ⌈ρ + β·√ρ⌉`` servers, not ⌈ρ⌉ — the √ρ headroom
is what absorbs stochastic arrival bursts within an interval, and β ≈ 1–2
corresponds to a ≲ few-% delay probability.  This is the "queueing-delay
headroom term" through which the ILP provisions *capacity*, not raw pods.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math

import numpy as np

#: workload kind → seed-stream tag (keeps kinds on disjoint RNG streams
#: even at equal seeds)
_KIND_TAG = {"diurnal": 1, "bursty": 2, "flash": 3}

#: default square-root staffing safety factor β (≈1 % delay probability)
DEFAULT_STAFFING_BETA = 1.5


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One request-rate trace, fully determined by its fields."""

    kind: str = "diurnal"            # "diurnal" | "bursty" | "flash"
    base_qps: float = 1000.0         # trough-level arrival rate (req/s)
    peak_factor: float = 2.5         # diurnal peak / trough ratio
    duration_hours: float = 24.0
    step_hours: float = 1.0          # trace granularity (≙ sim tick)
    seed: int = 0
    noise: float = 0.03              # per-interval multiplicative jitter
    burst_factor: float = 2.0        # bursty: burst multiplier scale
    burst_rate: float = 0.15         # bursty: P(burst) per interval
    flash_factor: float = 4.0        # flash: crowd multiplier
    flash_hours: float = 2.0         # flash: crowd window length

    def __post_init__(self):
        if self.kind not in _KIND_TAG:
            raise ValueError(f"unknown workload kind {self.kind!r}; "
                             f"choose from {sorted(_KIND_TAG)}")
        for field in ("base_qps", "peak_factor", "duration_hours",
                      "step_hours", "noise", "burst_factor", "burst_rate",
                      "flash_factor", "flash_hours"):
            object.__setattr__(self, field, float(getattr(self, field)))
        object.__setattr__(self, "seed", int(self.seed))

    @property
    def n_steps(self) -> int:
        return max(1, int(math.ceil(self.duration_hours / self.step_hours
                                    - 1e-9)))

    def times(self) -> np.ndarray:
        """Interval start times (hours): λ[k] holds on [times[k], times[k+1])."""
        return np.arange(self.n_steps, dtype=np.float64) * self.step_hours

    def _rng(self) -> np.random.Generator:
        # stream-free determinism: a fresh generator per call, seeded only
        # by spec fields — the trace is a pure function of the spec
        return np.random.default_rng(
            (self.seed & 0xFFFFFFFF, _KIND_TAG[self.kind], self.n_steps))

    def trace(self) -> np.ndarray:
        """λ(t) per interval (req/s), float64, byte-identical per spec."""
        rng = self._rng()
        t = self.times()
        # diurnal base: trough at base_qps, peak at base·peak_factor,
        # peak mid-afternoon (hour 15 of each day)
        amp = 0.5 * (self.peak_factor - 1.0)
        phase = 2.0 * np.pi * (t % 24.0 - 15.0) / 24.0
        lam = self.base_qps * (1.0 + amp * (1.0 + np.cos(phase)))
        if self.noise > 0:
            lam = lam * (1.0 + self.noise
                         * (2.0 * rng.random(self.n_steps) - 1.0))
        if self.kind == "bursty":
            hit = rng.random(self.n_steps) < self.burst_rate
            mult = 1.0 + (self.burst_factor - 1.0) * rng.random(self.n_steps)
            lam = np.where(hit, lam * mult, lam)
        elif self.kind == "flash":
            n_flash = max(1, int(round(self.flash_hours / self.step_hours)))
            hi = max(1, self.n_steps - n_flash)
            start = int(rng.integers(self.n_steps // 4, max(hi,
                                                            self.n_steps // 4
                                                            + 1)))
            lam[start:start + n_flash] *= self.flash_factor
        return np.ascontiguousarray(lam, dtype=np.float64)


def trace_digest(spec: WorkloadSpec) -> str:
    """blake2b over the spec repr + raw trace bytes — the determinism
    contract as a comparable string (bench_serve verifies same seed ⇒
    identical digest before timing anything)."""
    h = hashlib.blake2b(digest_size=16)
    h.update(repr(spec).encode())
    h.update(spec.trace().tobytes())
    return h.hexdigest()


def staffed_pods(lam_qps: float, qps_per_pod: float,
                 beta: float = DEFAULT_STAFFING_BETA) -> int:
    """Square-root staffing: pods needed to serve ``lam_qps`` with
    queueing-delay headroom.

    M/M/c with per-server rate μ = ``qps_per_pod`` and offered load
    ρ = λ/μ: ``c = ⌈ρ + β·√ρ⌉`` keeps the delay probability small and
    roughly constant as λ scales (Halfin-Whitt).  β = 0 degrades to the
    bare capacity floor ⌈ρ⌉."""
    if lam_qps <= 0:
        return 1
    if qps_per_pod <= 0:
        raise ValueError("qps_per_pod must be positive")
    rho = float(lam_qps) / float(qps_per_pod)
    return max(1, int(math.ceil(rho + float(beta) * math.sqrt(rho) - 1e-9)))


def demand_schedule_from_trace(spec: WorkloadSpec, qps_per_pod: float,
                               beta: float = DEFAULT_STAFFING_BETA,
                               ) -> tuple:
    """(initial_pods, ((time, pods), ...)) — the workload trace converted
    into the scenario engine's pod-demand schedule via square-root
    staffing.  Consecutive equal staffing levels are merged so the
    schedule only carries genuine capacity changes.  Policy-independent by
    construction: every compared policy provisions the same pod demand and
    differs only in *which* offerings provide it (DESIGN.md §15)."""
    lam = spec.trace()
    times = spec.times()
    staff = [staffed_pods(l, qps_per_pod, beta) for l in lam]
    initial = staff[0]
    schedule = []
    prev = initial
    for t, pods in zip(times[1:], staff[1:]):
        if pods != prev:
            schedule.append((float(t), int(pods)))
            prev = pods
    return initial, tuple(schedule)


__all__ = ["DEFAULT_STAFFING_BETA", "WorkloadSpec",
           "demand_schedule_from_trace", "staffed_pods", "trace_digest"]
