"""Correlated regional market physics (DESIGN.md §17).

The overlay turns the scenario's single OU market into K regional markets
driven by a one-factor correlation model: each refresh at hour ``t``
draws a shared shock ``z0`` and one idiosyncratic shock ``z_r`` per
region, all *pure functions of* ``(shock_seed, region, t)`` — a fresh
``np.random.default_rng`` keyed on those coordinates, never a consumed
stream, the same idiom as ``Scenario.effective_pods``.  The region's
log-price factor is

    g_r(t) = vol · (√rho · z0(t)  +  √(1 − rho) · z_r(t))

applied multiplicatively to the region's spot rows and clipped to the
market simulator's own ``[0.03·od, od]`` band.  Because the draws are
coordinate-pure, the standalone engine, the fleet engine's shared market
path, and RNG-free replay all see bit-identical regional prices — the §9
determinism contract holds verbatim with correlation active.

The overlay is a *view transform*: the underlying ``SpotMarketSimulator``
state is never touched, so the OU mean-reversion never feeds back on the
regional factor.  World-side region fault effects (brownout capacity
thinning + price spikes, outage blackouts) live here too — they modify
TRUE state, while the observed-side effects (partition feed freezes, ICE
caps) stay in :class:`repro.chaos.ChaosController`.
"""

from __future__ import annotations

import hashlib
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..chaos.faults import Fault, REGION_KINDS
from .config import RegionConfig

#: spot clip band, identical to SpotMarketSimulator's step clamp
_SPOT_FLOOR_OD = 0.03
_SPOT_CEIL_OD = 1.0


def _tag_coord(tag: str) -> int:
    """Stable 32-bit coordinate for a region tag (process-independent)."""
    return int.from_bytes(hashlib.blake2s(tag.encode(), digest_size=4)
                          .digest(), "big")


def region_shock(seed: int, tag: str, t: float) -> float:
    """One standard-normal draw, a pure function of ``(seed, tag, t)``.

    The time coordinate is ``int(round(t * 3600))`` — exact for any tick
    grid down to one second, like the engine's other coordinate-pure
    draws."""
    rng = np.random.default_rng((int(seed) & 0xFFFFFFFF, _tag_coord(tag),
                                 int(round(float(t) * 3600.0))))
    return float(rng.standard_normal())


def regional_price_factors(cfg: RegionConfig, regions: Sequence[str],
                           t: float) -> Dict[str, float]:
    """The multiplicative price factor ``exp(g_r(t))`` per region."""
    if cfg.vol == 0.0:
        return {r: 1.0 for r in regions}
    z0 = region_shock(cfg.shock_seed, "__shared__", t)
    w_shared = math.sqrt(cfg.rho)
    w_own = math.sqrt(1.0 - cfg.rho)
    out: Dict[str, float] = {}
    for r in regions:
        g = cfg.vol * (w_shared * z0
                       + w_own * region_shock(cfg.shock_seed, r, t))
        out[r] = math.exp(g)
    return out


class RegionalMarketOverlay:
    """Pure per-refresh transform of the TRUE ``(spot, t3)`` arrays.

    Built once per run from the (static) catalog, the region config, and
    the scenario's declared region-kind fault windows; :meth:`apply` is a
    pure function of its arguments and the refresh time.  When nothing
    applies at ``t`` the inputs are returned *by reference* — the
    engine-side identity checks (and the inertness contract) rely on
    that."""

    def __init__(self, cfg: RegionConfig, catalog: Sequence,
                 faults: Sequence[Fault] = ()) -> None:
        self.cfg = cfg
        regions = [getattr(o, "region", "") for o in catalog]
        #: region tags present in the catalog, sorted for a stable
        #: factor-evaluation order
        self.regions: Tuple[str, ...] = tuple(sorted(set(regions)))
        self._rows: Dict[str, np.ndarray] = {
            r: np.array([x == r for x in regions], dtype=bool)
            for r in self.regions}
        self._od = np.array([o.od_price for o in catalog], dtype=np.float64)
        # world-side region faults only; partitions are observed-side
        self._faults: List[Fault] = [
            f for f in faults
            if f.kind in ("region_brownout", "region_outage")]

    def apply(self, spot: np.ndarray, t3: np.ndarray, t: float,
              ) -> Tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        active = [f for f in self._faults if f.active(t)]
        if cfg.vol == 0.0 and not active:
            return spot, t3          # bit-inert: same objects out
        spot2 = np.array(spot, dtype=np.float64, copy=True)
        t32 = np.array(t3, copy=True)
        if cfg.vol != 0.0:
            factors = regional_price_factors(cfg, self.regions, t)
            for r in self.regions:
                f = factors[r]
                if f != 1.0:
                    rows = self._rows[r]
                    spot2[rows] = spot2[rows] * f
        for f in active:
            rows = self._rows.get(f.selector)
            if rows is None or not rows.any():
                continue
            if f.kind == "region_brownout":
                # thinned capacity + scarcity-spiked prices, feed truthful
                t32[rows] = np.floor(
                    t32[rows].astype(np.float64) * (1.0 - f.magnitude)
                ).astype(t32.dtype)
                spot2[rows] = spot2[rows] * (1.0 + f.magnitude)
            else:                    # region_outage: the region is dark
                t32[rows] = 0
        np.clip(spot2, _SPOT_FLOOR_OD * self._od,
                _SPOT_CEIL_OD * self._od, out=spot2)
        return spot2, t32


def make_overlay(cfg: Optional[RegionConfig], catalog: Sequence,
                 faults: Sequence[Fault] = (),
                 ) -> Optional[RegionalMarketOverlay]:
    """The engines' one overlay-construction rule: an overlay exists iff
    the scenario declares a region config *or* any region-kind fault
    (whose world-side effects live here even without a config).  None
    means the market path is untouched — the inert case costs nothing."""
    has_region_faults = any(f.kind in REGION_KINDS for f in faults)
    if cfg is None and not has_region_faults:
        return None
    return RegionalMarketOverlay(cfg if cfg is not None else RegionConfig(),
                                 catalog, faults)


# -- hazard regimes ----------------------------------------------------------
def hazard_scale_rows(cfg: Optional[RegionConfig],
                      catalog: Sequence) -> Optional[np.ndarray]:
    """Per-offering hazard-scale vector aligned to catalog order, or None
    when the config is absent or every scale is exactly 1 (the law must
    stay bitwise untouched then — ``x ** 1.0`` is not a guaranteed
    no-op)."""
    if cfg is None or not cfg.hazard_scale or cfg.hazard_inert:
        return None
    return np.array([cfg.hazard_of(getattr(o, "region", ""))
                     for o in catalog], dtype=np.float64)


def apply_hazard_scale(p: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """``p' = 1 − (1 − p)**scale`` — the one definition of the regional
    hazard regime, shared by the standalone model and the fleet engine's
    batched path so the two stay bitwise identical."""
    return 1.0 - (1.0 - p) ** scale


# -- data gravity ------------------------------------------------------------
def egress_row_costs(cfg: Optional[RegionConfig],
                     items: Sequence) -> Optional[np.ndarray]:
    """Per-item egress $/node-hour (rate × pods-per-node for every item
    outside the home region), or None when egress is off."""
    if cfg is None or cfg.egress_per_pod_hour == 0.0:
        return None
    home = cfg.home
    return np.array([0.0 if getattr(it.offering, "region", "") == home
                     else cfg.egress_per_pod_hour * it.pods
                     for it in items], dtype=np.float64)


def pool_egress_rate(cfg: RegionConfig, pool) -> float:
    """Egress $/hour a pool accrues: allocated pods placed outside the
    home region, at ``egress_per_pod_hour``."""
    if pool is None or cfg.egress_per_pod_hour == 0.0:
        return 0.0
    home = cfg.home
    total = 0.0
    for it, c in zip(pool.items, pool.counts):
        if c > 0 and getattr(it.offering, "region", "") != home:
            total += cfg.egress_per_pod_hour * it.pods * c
    return total


def region_pool_shares(pool) -> Dict[str, int]:
    """Nodes per region in a pool (empty dict for an empty pool)."""
    shares: Dict[str, int] = {}
    if pool is None:
        return shares
    for it, c in zip(pool.items, pool.counts):
        if c > 0:
            r = getattr(it.offering, "region", "")
            shares[r] = shares.get(r, 0) + int(c)
    return shares


__all__ = ["RegionalMarketOverlay", "apply_hazard_scale", "egress_row_costs",
           "hazard_scale_rows", "make_overlay", "pool_egress_rate",
           "region_pool_shares", "region_shock", "regional_price_factors"]
