"""Region side-constraints over the unchanged GSS × ILP stack (§17).

Three constraints enter ``solve_ilp`` *without touching the solver*:

- **Data-gravity / egress costs** ride the O(n) objective-reweight path
  (``reweight_items`` + ``reweight_market``): every candidate outside the
  home region is priced at ``SP_i + egress_per_pod_hour · Pod_i`` for the
  solve, and the returned counts are mapped back onto the true-priced
  items (the risk subsystem's pattern) so billing stays on TRUE prices.
- **Per-region capacity caps** are a deterministic post-solve repair: a
  violating region is trimmed to its cap (best perf-per-dollar nodes
  kept), joins the at-cap set, and the residual demand is re-solved with
  the at-cap regions' rows OR-ed into the §4.1 exclusion mask.  Regions
  only ever *enter* the at-cap set, so the loop terminates in ≤ K rounds.
- **Minimum region spread** (N+1 redundancy) force-places one
  availability-first node (lowest IF, then cheapest per pod — the safe
  rung's ordering) in each missing region after the solve.

Because the side-constraints wrap the solve rather than extend it, the
fused device backend is reused unchanged for the inner solves — and
region-aware policies deliberately solve *inline* (``set_solve_batch`` is
a no-op for them), so the cross-decision fused batch path never sees a
side-constrained solve: the host handles them, mirroring the PR 7
approx-tier split.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.efficiency import NodePool, pool_metric_arrays, reweight_items
from ..core.gss import bracketed_gss
from ..core.ilp import CompiledMarket, compile_market, reweight_market
from ..core.provisioner import merge_pools
from .config import RegionConfig
from .market import egress_row_costs, region_pool_shares


def _region_of(item) -> str:
    return getattr(item.offering, "region", "")


def _real_pool(pool: Optional[NodePool],
               items: Sequence) -> Optional[NodePool]:
    """Map a pool solved over reweighted items back onto the true-priced
    candidates (counts are positional over offering_id)."""
    if pool is None:
        return None
    real = {it.offering.offering_id: it for it in items}
    return NodePool(items=[real[it.offering.offering_id]
                           for it in pool.items],
                    counts=list(pool.counts), alpha=pool.alpha,
                    request=pool.request)


def _region_rows(items: Sequence, regions) -> np.ndarray:
    rs = set(regions)
    return np.array([_region_of(it) in rs for it in items], dtype=bool)


def _or_mask(base: Optional[np.ndarray],
             extra: np.ndarray) -> Optional[np.ndarray]:
    if not extra.any():
        return base
    return extra if base is None else (base | extra)


def solve_with_regions(items: Sequence, req_pods: int, cfg: RegionConfig,
                       *, market: Optional[CompiledMarket] = None,
                       tolerance: float = 0.01,
                       exclude: Optional[np.ndarray] = None,
                       timer: Callable[[], float] = time.perf_counter,
                       backend=None, coarsening=None,
                       ) -> Tuple[Optional[NodePool], object, Dict]:
    """Guarded GSS with the region side-constraints applied around it.

    Returns ``(pool, gss_trace, info)`` where ``info`` counts the repair
    work (``cap_repairs``, ``spread_forced``, ``egress_reweighted``).
    With a solver-inert config this is exactly ``bracketed_gss`` — same
    arguments, same result."""
    info: Dict = {"cap_repairs": 0, "spread_forced": 0,
                  "egress_reweighted": False}
    items = list(items)
    if market is None:
        market = compile_market(items)

    solve_items, solve_market = items, market
    egress = egress_row_costs(cfg, items)
    if egress is not None and egress.any():
        perf, price, _ = pool_metric_arrays(items)
        priced = price + egress
        solve_items = reweight_items(items, perf, priced)
        solve_market = reweight_market(market, perf, priced,
                                       items=solve_items)
        info["egress_reweighted"] = True

    def _solve(pods: int, mask: Optional[np.ndarray]):
        pool, trace = bracketed_gss(solve_items, pods, tolerance,
                                    market=solve_market, exclude=mask,
                                    timer=timer, backend=backend,
                                    coarsening=coarsening)
        return _real_pool(pool, items), trace

    pool, trace = _solve(int(req_pods), exclude)
    if pool is None:
        return None, trace, info

    if cfg.caps:
        pool = _repair_caps(pool, items, req_pods, cfg, exclude, _solve,
                            info)
    if cfg.min_spread > 1:
        pool = _force_spread(pool, items, cfg, exclude, info)
    return pool, trace, info


def _repair_caps(pool: NodePool, items: Sequence, req_pods: int,
                 cfg: RegionConfig, exclude: Optional[np.ndarray],
                 solve: Callable, info: Dict) -> NodePool:
    at_cap: set = set()
    for _ in range(len(cfg.caps) + 1):
        shares = region_pool_shares(pool)
        viol = [(r, c) for r, c in cfg.caps if shares.get(r, 0) > c]
        if not viol:
            break
        region, cap = viol[0]        # caps declaration order: deterministic
        info["cap_repairs"] += 1
        at_cap.add(region)
        # trim the region to its cap, best perf-per-dollar nodes first
        entries = [(i, it, c) for i, (it, c)
                   in enumerate(zip(pool.items, pool.counts))
                   if c > 0 and _region_of(it) == region]
        entries.sort(key=lambda e: (-(e[1].perf / e[1].spot_price),
                                    e[1].offering.offering_id))
        counts = list(pool.counts)
        budget = cap
        for i, it, c in entries:
            take = min(int(c), budget)
            counts[i] = take
            budget -= take
        pool = NodePool(items=list(pool.items), counts=counts,
                        alpha=pool.alpha, request=pool.request)
        deficit = int(req_pods) - pool.total_pods
        if deficit > 0:
            mask = _or_mask(exclude, _region_rows(items, at_cap))
            extra, _ = solve(deficit, mask)
            if extra is not None:
                pool = merge_pools(pool, extra)
    return pool


def _force_spread(pool: NodePool, items: Sequence, cfg: RegionConfig,
                  exclude: Optional[np.ndarray], info: Dict) -> NodePool:
    shares = region_pool_shares(pool)
    used = {r for r, n in shares.items() if n > 0}
    rows_ok = (np.ones(len(items), dtype=bool) if exclude is None
               else ~np.asarray(exclude, dtype=bool))
    available = sorted({_region_of(it) for i, it in enumerate(items)
                        if rows_ok[i]})
    for region in available:
        if len(used) >= cfg.min_spread:
            break
        if region in used:
            continue
        cap = cfg.cap_of(region)
        if cap is not None and shares.get(region, 0) + 1 > cap:
            continue
        cands = [it for i, it in enumerate(items)
                 if rows_ok[i] and _region_of(it) == region and it.t3 >= 1]
        if not cands:
            continue
        best = min(cands, key=lambda it: (it.offering.interruption_freq,
                                          it.spot_price / it.pods,
                                          it.offering.offering_id))
        pool = merge_pools(pool, NodePool(items=[best], counts=[1],
                                          alpha=pool.alpha,
                                          request=pool.request))
        used.add(region)
        info["spread_forced"] += 1
    return pool


__all__ = ["solve_with_regions"]
