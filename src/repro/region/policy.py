"""Region-aware provisioning policies (DESIGN.md §17).

Both policies ride :class:`repro.sim.policy._BaselinePolicy`'s §4.1
plumbing (TTL exclusion cache, shortfall protocol, decision-memo hooks)
and solve *inline*: ``set_solve_batch`` stays the base-class no-op, so
the fleet engine's cross-decision fused batches never see a
side-constrained solve — the host declines them by construction,
mirroring the PR 7 approx-tier split.

``kubepacs_region``
    The KubePACS objective with the scenario ``RegionConfig``'s
    side-constraints (per-region caps, minimum spread, egress pricing)
    applied through :func:`repro.region.solver.solve_with_regions`.
``region_pinned:<R>``
    The single-market strawman — only region R's offerings are feasible
    (their complement is ORed into the §4.1 exclusion mask).  This is
    the comparator ``bench_region`` measures the hardened policy's
    cross-region failover against.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.efficiency import CandidateItem, NodePool
from ..core.gss import bracketed_gss
from ..sim.policy import Precompiled, _BaselinePolicy
from .config import RegionConfig
from .solver import solve_with_regions


class RegionPinnedPolicy(_BaselinePolicy):
    """Provision exclusively inside one region."""

    def __init__(self, pin_region: str, tolerance: float = 0.01,
                 ttl_hours: float = 2.0,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        super().__init__(ttl_hours, clock)
        self.pin_region = str(pin_region)
        self.tolerance = float(tolerance)
        self.name = f"region_pinned:{self.pin_region}"

    def _extra_mask(self, items: List[CandidateItem]) -> Optional[np.ndarray]:
        mask = np.array([getattr(it.offering, "region", "")
                         != self.pin_region for it in items], dtype=bool)
        return mask if mask.any() else None

    def _solve(self, items, req_pods, exclude, precompiled):
        market = precompiled[1] if precompiled is not None else None
        pool, _ = bracketed_gss(items, req_pods, self.tolerance,
                                market=market, exclude=exclude,
                                timer=self.clock)
        if pool is None:         # the pinned region cannot cover demand
            return NodePool(items=[], counts=[]), None
        return pool, pool.alpha


class RegionAwarePolicy(_BaselinePolicy):
    """KubePACS objective + RegionConfig side-constraints, solved inline."""

    name = "kubepacs_region"

    def __init__(self, region: Optional[RegionConfig],
                 tolerance: float = 0.01, ttl_hours: float = 2.0,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        super().__init__(ttl_hours, clock)
        # without a config the policy degrades to plain guarded GSS — a
        # solver-inert config and no config decide identically
        self.region = region if region is not None else RegionConfig()
        self.tolerance = float(tolerance)
        #: cumulative side-constraint repair work, for the examples /
        #: benches to report (``region_*`` keys, like the guard's
        #: ``chaos_*`` counters)
        self.stats: Dict[str, int] = {"region_cap_repairs": 0,
                                      "region_spread_forced": 0,
                                      "region_egress_solves": 0}

    def _solve(self, items, req_pods, exclude, precompiled):
        market = precompiled[1] if precompiled is not None else None
        pool, _, info = solve_with_regions(
            items, req_pods, self.region, market=market,
            tolerance=self.tolerance, exclude=exclude, timer=self.clock)
        self.stats["region_cap_repairs"] += info["cap_repairs"]
        self.stats["region_spread_forced"] += info["spread_forced"]
        self.stats["region_egress_solves"] += int(info["egress_reweighted"])
        if pool is None:
            return NodePool(items=[], counts=[]), None
        return pool, pool.alpha


__all__ = ["RegionAwarePolicy", "RegionPinnedPolicy"]
