"""The RegionPlane's declarative knob set (DESIGN.md §17).

A :class:`RegionConfig` attached to a ``Scenario`` turns the single-market
run into one control plane provisioning across K simultaneous regional
markets.  Every field defaults to the *identity*: a config with
``vol=0.0``, no caps, no spread floor, no egress, and unit hazard scales
changes nothing anywhere — that is the single-region-inertness contract
the tests and ``bench_region`` prove bit-exactly.

This module imports only the standard library so the scenario schema can
depend on it without cycles (``region.market`` / ``region.solver`` carry
the numpy machinery).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class RegionConfig:
    """Multi-region provisioning knobs; all defaults are bit-inert.

    ``regions``              region tags the scenario's catalog is
                             restricted to (declaration order; ``()`` =
                             full catalog).  ``regions[0]`` is the home
                             region unless ``home_region`` overrides it.
    ``rho``                  shared-factor correlation of the per-region
                             price shocks in [0, 1]: 1 = every region
                             moves together (the dangerous regime), 0 =
                             independent markets.
    ``vol``                  log-volatility of the per-refresh regional
                             shock; 0.0 disables the price overlay
                             entirely (bitwise).
    ``shock_seed``           seed of the pure ``(seed, region, t)`` shock
                             draws — the axis ``run_fleet_paths`` sweeps.
    ``hazard_scale``         per-region interruption-hazard multipliers
                             ``((region, scale), ...)``; the per-node law
                             becomes ``1 − (1 − p)**scale``.  Unit scales
                             are skipped entirely.
    ``caps``                 per-region node caps ``((region, nodes), ...)``
                             entering the solver as post-solve repair via
                             the exclusion-mask path.
    ``min_spread``           minimum number of distinct regions any pool
                             must span (N+1 redundancy); 0 disables.
    ``home_region``          where the data lives; egress is charged on
                             pods placed anywhere else ("" = regions[0]).
    ``egress_per_pod_hour``  data-gravity cost in $ per pod-hour outside
                             the home region, charged via ``reweight_items``
                             at solve time and accrued into billing.
    """

    regions: Tuple[str, ...] = ()
    rho: float = 0.6
    vol: float = 0.0
    shock_seed: int = 0
    hazard_scale: Tuple[Tuple[str, float], ...] = ()
    caps: Tuple[Tuple[str, int], ...] = ()
    min_spread: int = 0
    home_region: str = ""
    egress_per_pod_hour: float = 0.0

    def __post_init__(self):
        # normalize so Scenario round-trips through JSON byte-exactly
        object.__setattr__(self, "regions",
                           tuple(str(r) for r in self.regions))
        object.__setattr__(self, "rho", float(self.rho))
        object.__setattr__(self, "vol", float(self.vol))
        object.__setattr__(self, "shock_seed", int(self.shock_seed))
        object.__setattr__(self, "hazard_scale", tuple(
            (str(r), float(s)) for r, s in self.hazard_scale))
        object.__setattr__(self, "caps", tuple(
            (str(r), int(c)) for r, c in self.caps))
        object.__setattr__(self, "min_spread", int(self.min_spread))
        object.__setattr__(self, "home_region", str(self.home_region))
        object.__setattr__(self, "egress_per_pod_hour",
                           float(self.egress_per_pod_hour))
        if not 0.0 <= self.rho <= 1.0:
            raise ValueError(f"rho must be in [0, 1], got {self.rho}")
        if self.vol < 0.0:
            raise ValueError(f"vol must be >= 0, got {self.vol}")

    # -- identity probes (each mechanism gates on its own knob) --------------
    @property
    def price_inert(self) -> bool:
        """True when the correlated price overlay is disabled bitwise."""
        return self.vol == 0.0

    @property
    def hazard_inert(self) -> bool:
        """True when every hazard scale is exactly 1 (law untouched)."""
        return all(s == 1.0 for _, s in self.hazard_scale)

    @property
    def solver_inert(self) -> bool:
        """True when no side-constraint enters the solve path."""
        return (not self.caps and self.min_spread <= 1
                and self.egress_per_pod_hour == 0.0)

    # -- accessors -----------------------------------------------------------
    @property
    def home(self) -> str:
        return self.home_region or (self.regions[0] if self.regions else "")

    def cap_of(self, region: str) -> Optional[int]:
        for r, c in self.caps:
            if r == region:
                return c
        return None

    def hazard_of(self, region: str) -> float:
        for r, s in self.hazard_scale:
            if r == region:
                return s
        return 1.0

    # -- serialization (mirrors Scenario.to_dict / from_dict) ----------------
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["regions"] = list(self.regions)
        d["hazard_scale"] = [list(p) for p in self.hazard_scale]
        d["caps"] = [list(p) for p in self.caps]
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "RegionConfig":
        d = dict(d)
        d["regions"] = tuple(d.get("regions", ()))
        d["hazard_scale"] = tuple(
            (r, s) for r, s in d.get("hazard_scale", ()))
        d["caps"] = tuple((r, c) for r, c in d.get("caps", ()))
        return cls(**d)


__all__ = ["RegionConfig"]
