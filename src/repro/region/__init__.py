"""RegionPlane: multi-region fleet arbitration (DESIGN.md §17).

Layered to stay cycle-free with the rest of the package:

- :mod:`repro.region.config` — the declarative :class:`RegionConfig`
  (standard library only; the scenario schema imports it).
- :mod:`repro.region.market` — the correlated shock overlay, hazard
  regimes, and data-gravity helpers (core + chaos.faults only).
- :mod:`repro.region.solver` — region side-constraints wrapped around the
  unchanged GSS × ILP stack.
- :mod:`repro.region.policy` — the region-aware policies; imported lazily
  (PEP 562) because it depends on :mod:`repro.sim.policy`, which reaches
  back here via the scenario schema.
"""

from .config import RegionConfig
from .market import (RegionalMarketOverlay, apply_hazard_scale,
                     egress_row_costs, hazard_scale_rows, make_overlay,
                     pool_egress_rate, region_pool_shares, region_shock,
                     regional_price_factors)
from .solver import solve_with_regions

_POLICY_SYMBOLS = ("RegionAwarePolicy", "RegionPinnedPolicy")

__all__ = ["RegionConfig", "RegionalMarketOverlay", "apply_hazard_scale",
           "egress_row_costs", "hazard_scale_rows", "make_overlay",
           "pool_egress_rate", "region_pool_shares", "region_shock",
           "regional_price_factors", "solve_with_regions", *_POLICY_SYMBOLS]


def __getattr__(name):
    if name in _POLICY_SYMBOLS:
        from . import policy
        return getattr(policy, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
