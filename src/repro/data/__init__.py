from .pipeline import DataConfig, make_batch, batch_specs, batch_pspecs

__all__ = ["DataConfig", "make_batch", "batch_specs", "batch_pspecs"]
