"""Deterministic, resumable, shard-aware data pipeline.

Batches are a pure function of (seed, step, shard_id, world) — resuming from
a checkpoint at step k regenerates exactly the stream a failed worker would
have seen, and elastic rescale (world change) re-partitions rows without
coordination.  The synthetic LM task mixes a Zipf unigram stream with
copy/induction spans so small models show real loss decrease in examples.

`batch_specs` produces the ShapeDtypeStructs the multi-pod dry-run lowers
against (same structures, no allocation).
"""

from __future__ import annotations

import dataclasses
import threading
import queue as _queue
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    copy_frac: float = 0.5        # fraction of each row that is a copy span


def _row(rng: np.random.Generator, vocab: int, seq: int,
         copy_frac: float) -> np.ndarray:
    zipf = np.minimum(rng.zipf(1.3, size=seq + 1), vocab - 1)
    span = int(seq * copy_frac / 2)
    if span > 1:
        start = rng.integers(0, seq - 2 * span)
        zipf[start + span: start + 2 * span] = zipf[start: start + span]
    return zipf.astype(np.int32)


def make_batch(cfg: ModelConfig, dcfg: DataConfig, *, step: int,
               shard: int = 0, world: int = 1, batch: int = 8,
               seq: int = 128) -> Dict[str, Any]:
    """Batch for this worker's shard at this step (numpy, host-side)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([dcfg.seed, step, shard, world]))
    v = cfg.vocab_size
    if cfg.input_mode == "audio_codes":
        k = cfg.n_codebooks
        rows = np.stack([[_row(rng, v, seq, dcfg.copy_frac)
                          for _ in range(k)] for _ in range(batch)])
        return {"codes": rows[:, :, :seq],
                "targets": rows[:, :, 1:seq + 1]}
    if cfg.input_mode == "vlm":
        p = cfg.vision_prefix
        st = seq - p
        rows = np.stack([_row(rng, v, st, dcfg.copy_frac)
                         for _ in range(batch)])
        emb = rng.normal(0, 1, size=(batch, p, cfg.d_model)).astype(np.float32)
        return {"tokens": rows[:, :st], "targets": rows[:, 1:st + 1],
                "vision_embeds": emb}
    rows = np.stack([_row(rng, v, seq, dcfg.copy_frac) for _ in range(batch)])
    return {"tokens": rows[:, :seq], "targets": rows[:, 1:seq + 1]}


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run §2)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        if cfg.input_mode == "audio_codes":
            return {"codes": jax.ShapeDtypeStruct((b, cfg.n_codebooks, 1), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.input_mode == "audio_codes":
        return {"codes": jax.ShapeDtypeStruct((b, cfg.n_codebooks, s), i32),
                "targets": jax.ShapeDtypeStruct((b, cfg.n_codebooks, s), i32)}
    if cfg.input_mode == "vlm":
        st = s - cfg.vision_prefix
        return {"tokens": jax.ShapeDtypeStruct((b, st), i32),
                "targets": jax.ShapeDtypeStruct((b, st), i32),
                "vision_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.vision_prefix, cfg.d_model), jnp.float32)}
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32)}


def batch_pspecs(cfg: ModelConfig, shape: InputShape, rules) -> Dict[str, Any]:
    """PartitionSpecs for the batch dict (batch dim over data axes; the
    long-context decode keeps batch=1 replicated)."""
    from jax.sharding import PartitionSpec as P
    long_ctx = shape.name == "long_500k"
    b_ax = None if long_ctx else (rules.batch if rules.batch else None)
    specs = batch_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        out[k] = P(b_ax, *([None] * (len(v.shape) - 1)))
    return out


class Prefetcher:
    """Background-thread prefetch (depth-k) over a host batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: _queue.Queue = _queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
