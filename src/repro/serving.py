"""Serving step factories: batched prefill and decode under explicit shardings.

``decode_32k`` / ``long_500k`` dry-run cells lower these (one new token
against a seq_len-sized KV/SSM cache), per the assignment: decode shapes
exercise ``serve_step``, not ``train_step``.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import sharding
from .configs.base import ModelConfig
from .models import transformer


def prefill_fn(params, batch, *, cfg: ModelConfig, max_len: int):
    return transformer.prefill(params, cfg, batch, max_len=max_len)


def decode_fn(params, caches, tokens, pos, *, cfg: ModelConfig):
    return transformer.decode_step(params, cfg, caches, tokens, pos)


def make_sharded_prefill(cfg: ModelConfig, rules: sharding.MeshRules,
                         batch_pspecs, max_len: int):
    pspecs = transformer.param_pspecs(cfg, rules)
    cache_specs = transformer.cache_pspecs(cfg, rules, long_context=False)
    logits_spec = (P(rules.batch or None, None, rules.model)
                   if cfg.input_mode != "audio_codes"
                   else P(rules.batch or None, None, None, rules.model))
    fn = functools.partial(prefill_fn, cfg=cfg, max_len=max_len)
    return jax.jit(fn,
                   in_shardings=sharding.as_shardings((pspecs, batch_pspecs)),
                   out_shardings=sharding.as_shardings(
                       (logits_spec, cache_specs)))


def make_sharded_decode(cfg: ModelConfig, rules: sharding.MeshRules,
                        batch_pspecs, long_context: bool = False,
                        donate: bool = True):
    pspecs = transformer.param_pspecs(cfg, rules)
    cache_specs = transformer.cache_pspecs(cfg, rules,
                                           long_context=long_context)
    b_ax = None if long_context else (rules.batch or None)
    logits_spec = (P(b_ax, None, rules.model)
                   if cfg.input_mode != "audio_codes"
                   else P(b_ax, None, None, rules.model))
    fn = functools.partial(decode_fn, cfg=cfg)
    return jax.jit(fn,
                   in_shardings=sharding.as_shardings(
                       (pspecs, cache_specs, batch_pspecs, P())),
                   out_shardings=sharding.as_shardings(
                       (logits_spec, cache_specs)),
                   donate_argnums=(1,) if donate else ())
