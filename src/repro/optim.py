"""AdamW + warmup-cosine schedule + global-norm clipping, pure JAX.

Optimizer moments are fp32 trees with the *same structure and sharding* as
the parameters (ZeRO-style: whatever shards the weight shards its moments —
the pspec tree for (m, v) is the param pspec tree).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params: PyTree) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params: PyTree, grads: PyTree, state: Dict[str, Any],
                 cfg: OptConfig) -> Tuple[PyTree, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"]
    lr = schedule(step, cfg)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1)
    c2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                       # decoupled wd, matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step + 1}
    metrics = {"lr": lr, "grad_norm": gn}
    return jax.tree.unflatten(treedef, new_p), new_state, metrics
