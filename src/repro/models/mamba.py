"""Mamba-1 block: causal depthwise conv + selective scan (+ decode state).

Parallel (train/prefill) path runs the chunked selective scan through
``kernels.ops.selective_scan`` (Pallas on TPU, chunked associative scan on
CPU).  Decode is a single recurrence step on (h, conv) state.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import sharding
from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import ParamDef


def mamba_schema(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    rank, kc = cfg.dt_rank, cfg.ssm_conv
    wscale = 0.02 / (2 * cfg.n_layers) ** 0.5
    return {
        "in_proj": ParamDef((d, 2 * di), ("embed", "inner")),
        "conv_w": ParamDef((kc, di), (None, "inner"), ("normal", 0.1)),
        "conv_b": ParamDef((di,), ("inner",), ("zeros",)),
        "x_proj": ParamDef((di, rank + 2 * n), ("inner", None)),
        "dt_w": ParamDef((rank, di), (None, "inner")),
        "dt_b": ParamDef((di,), ("inner",), ("dt_bias",)),
        "a_log": ParamDef((di, n), ("inner", None), ("a_log",)),
        "d_skip": ParamDef((di,), ("inner",), ("ones",)),
        "out_proj": ParamDef((di, d), ("inner", "embed"), ("normal", wscale)),
    }


def _split_xz(p, x, cfg):
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)                      # (B,S,2*di)
    return jnp.split(xz, 2, axis=-1)


def _ssm_params(p, xh, cfg):
    dt_ = xh.dtype
    n, rank = cfg.ssm_state, cfg.dt_rank
    bcdt = xh @ p["x_proj"].astype(dt_)                   # (B,S,rank+2N)
    dt_raw, bmat, cmat = jnp.split(bcdt, [rank, rank + n], axis=-1)
    dt = jax.nn.softplus(
        dt_raw @ p["dt_w"].astype(dt_) + p["dt_b"].astype(dt_))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    return dt, A, bmat, cmat


def mamba_apply(p, x: jax.Array, cfg: ModelConfig, *,
                state: Optional[Dict[str, jax.Array]] = None,
                make_cache: bool = False):
    """x: (B,S,d).  state: {"h": (B,di,N), "conv": (B,kc-1,di)} for decode."""
    if state is not None and x.shape[1] == 1:
        return _mamba_decode(p, x, cfg, state)

    b, s, d = x.shape
    dt_ = x.dtype
    kc = cfg.ssm_conv
    xh, z = _split_xz(p, x, cfg)
    xh = sharding.constrain(xh, sharding.mamba_conv_state_spec())

    # causal depthwise conv over S (kernel kc)
    pad = jnp.zeros((b, kc - 1, cfg.d_inner), dt_)
    xp = jnp.concatenate([pad, xh], axis=1)               # (B,S+kc-1,di)
    conv_w = p["conv_w"].astype(dt_)
    xc = sum(xp[:, i:i + s] * conv_w[i] for i in range(kc)) \
        + p["conv_b"].astype(dt_)
    xc = jax.nn.silu(xc)

    dt, A, bmat, cmat = _ssm_params(p, xc, cfg)
    y, h = ops.selective_scan(xc, dt, A, bmat, cmat,
                              p["d_skip"].astype(jnp.float32),
                              impl=cfg.attention_impl if cfg.attention_impl
                              in ("naive",) else "auto",
                              chunk=cfg.mamba_chunk)
    y = (y * jax.nn.silu(z)).astype(dt_)
    out = y @ p["out_proj"].astype(dt_)

    new_state = None
    if make_cache:
        new_state = {"h": h.astype(jnp.float32),
                     "conv": xp[:, -(kc - 1):, :] if kc > 1 else
                     jnp.zeros((b, 0, cfg.d_inner), dt_)}
    return out, new_state


def _mamba_decode(p, x, cfg, state):
    """Single-token recurrence step."""
    b, _, d = x.shape
    dt_ = x.dtype
    kc = cfg.ssm_conv
    xh, z = _split_xz(p, x, cfg)                          # (B,1,di) each
    conv_in = jnp.concatenate([state["conv"].astype(dt_), xh], axis=1)
    conv_w = p["conv_w"].astype(dt_)
    xc = sum(conv_in[:, i:i + 1] * conv_w[i] for i in range(kc)) \
        + p["conv_b"].astype(dt_)
    xc = jax.nn.silu(xc)                                  # (B,1,di)

    dt, A, bmat, cmat = _ssm_params(p, xc, cfg)
    dtf = dt[:, 0].astype(jnp.float32)                    # (B,di)
    xf = xc[:, 0].astype(jnp.float32)
    h = state["h"].astype(jnp.float32)                    # (B,di,N)
    decay = jnp.exp(dtf[..., None] * A[None])
    h = decay * h + (dtf * xf)[..., None] * bmat[:, 0].astype(jnp.float32)[:, None, :]
    y = (h * cmat[:, 0].astype(jnp.float32)[:, None, :]).sum(-1) \
        + p["d_skip"].astype(jnp.float32) * xf            # (B,di)
    y = (y[:, None, :] * jax.nn.silu(z).astype(jnp.float32)).astype(dt_)
    out = y @ p["out_proj"].astype(dt_)
    new_state = {"h": h, "conv": conv_in[:, 1:, :]}
    return out, new_state


def mamba_state_def(cfg: ModelConfig, batch: int):
    di, n, kc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {"h": ParamDef((batch, di, n), ("batch", "inner", "state"),
                          ("zeros",)),
            "conv": ParamDef((batch, kc - 1, di), ("batch", "convk", "inner"),
                             ("zeros",))}
