"""Pure-JAX model zoo: heterogeneous attention/Mamba/MoE decoder stacks."""

from .transformer import (init_params, abstract_params, param_pspecs,
                          loss_fn, forward, prefill, decode_step,
                          init_cache, abstract_cache, cache_pspecs,
                          count_params, active_params)

__all__ = ["init_params", "abstract_params", "param_pspecs", "loss_fn",
           "forward", "prefill", "decode_step", "init_cache",
           "abstract_cache", "cache_pspecs", "count_params", "active_params"]
