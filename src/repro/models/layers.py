"""Shared layers: norms, rotary embeddings, GQA attention, SwiGLU MLP.

Parameters are plain dicts; each module exposes ``*_schema(cfg)`` (shapes +
logical sharding axes + init spec) and ``*_apply(params, ...)``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import sharding
from ..configs.base import ModelConfig
from ..kernels import ops


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: Tuple = ("normal", 0.02)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_schema(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = {"scale": ParamDef((cfg.d_model,), (None,), ("ones",))}
    if cfg.norm == "layernorm":
        d["bias"] = ParamDef((cfg.d_model,), (None,), ("zeros",))
    return d


def norm_apply(p, x, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (partial rotary supported — stablelm)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float, pct: float) -> jax.Array:
    """x: (B,S,H,hd); positions: (S,) absolute positions."""
    hd = x.shape[-1]
    rot = int(hd * pct)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]   # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = xr[..., :half], xr[..., half:]
    xr = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([xr.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attn_schema(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qd, kvd = cfg.n_heads * hd, cfg.n_kv_heads * hd
    wscale = 0.02 / (2 * cfg.n_layers) ** 0.5
    s = {
        "wq": ParamDef((d, qd), ("embed", "q")),
        "wk": ParamDef((d, kvd), ("embed", "kv")),
        "wv": ParamDef((d, kvd), ("embed", "kv")),
        "wo": ParamDef((qd, d), ("q", "embed"), ("normal", wscale)),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamDef((qd,), ("q",), ("zeros",))
        s["bk"] = ParamDef((kvd,), ("kv",), ("zeros",))
        s["bv"] = ParamDef((kvd,), ("kv",), ("zeros",))
    return s


def attn_apply(p, x: jax.Array, cfg: ModelConfig, *,
               cache: Optional[Dict[str, jax.Array]] = None,
               pos: Optional[jax.Array] = None,
               make_cache: bool = False):
    """Pre-normed input -> attention output.

    Modes: train/no-cache (causal self-attn), prefill (make_cache=True,
    returns populated cache), decode (cache given, x is the new token(s),
    pos is the current cache length).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype

    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, kv, hd)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, kv, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt).reshape(h, hd)
        k = k + p["bk"].astype(dt).reshape(kv, hd)
        v = v + p["bv"].astype(dt).reshape(kv, hd)

    offset = jnp.asarray(0, jnp.int32) if pos is None else pos
    positions = offset + jnp.arange(s)
    q = rope(q, positions, cfg.rope_theta, cfg.rope_pct)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_pct)

    new_cache = None
    if cache is not None:           # decode: append to cache, attend over it
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, offset, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, offset, 0, 0))
        new_cache = {"k": kc, "v": vc}
        out = ops.flash_attention(
            q, kc.astype(dt), vc.astype(dt), causal=True, q_offset=offset,
            kv_len=offset + s,
            impl="naive" if s == 1 else _impl(cfg),
            q_chunk=cfg.attn_chunk_q, kv_chunk=cfg.attn_chunk_kv)
    else:                           # train / prefill: causal self-attention
        out = ops.flash_attention(
            q, k, v, causal=True, impl=_impl(cfg),
            q_chunk=cfg.attn_chunk_q, kv_chunk=cfg.attn_chunk_kv,
            causal_skip=cfg.attn_causal_skip)
        if make_cache:
            new_cache = {"k": k, "v": v}

    out = out.reshape(b, s, h * hd)
    return out @ p["wo"].astype(dt), new_cache


def _impl(cfg: ModelConfig) -> str:
    if cfg.attention_impl != "auto":
        return cfg.attention_impl
    return "auto"


def attn_cache_def(cfg: ModelConfig, batch: int, max_len: int):
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    axes = ("batch", "seq", "kv_heads", "head_dim")
    return {"k": ParamDef(shape, axes, ("zeros",)),
            "v": ParamDef(shape, axes, ("zeros",))}


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_schema(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    wscale = 0.02 / (2 * cfg.n_layers) ** 0.5
    return {
        "wg": ParamDef((d, f), ("embed", "ff")),
        "wu": ParamDef((d, f), ("embed", "ff")),
        "wd": ParamDef((f, d), ("ff", "embed"), ("normal", wscale)),
    }


def mlp_apply(p, x: jax.Array) -> jax.Array:
    dt = x.dtype
    gate = jax.nn.silu(x @ p["wg"].astype(dt))
    up = x @ p["wu"].astype(dt)
    return (gate * up) @ p["wd"].astype(dt)
