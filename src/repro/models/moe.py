"""Token-choice MoE with capacity-grouped expert matmuls.

Dispatch is sort-based (no (T,E,C) one-hot): within each *group* (= one
batch row, so groups are data-shard-local) tokens are ranked per expert by a
stable sort and dropped beyond capacity C = ceil(S·k/E·cf) — the standard
dropping formulation production JAX MoEs use.  Expert buffers are laid out
(G, E, C, d) with G on the data axes and E on the model axis
(expert parallelism), so the expert einsum partitions cleanly and the
dispatch/combine scatter carries the all-to-all.

Aux: switch-style load-balance loss (mean over layers, weighted by
``cfg.router_aux_weight``).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .. import sharding
from ..configs.base import ModelConfig
from .layers import ParamDef


def moe_schema(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, fe, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    wscale = 0.02 / (2 * cfg.n_layers) ** 0.5
    s = {
        "router": ParamDef((d, e), ("embed", None)),
        "wg": ParamDef((e, d, fe), ("experts", "expert_in", "expert_ff")),
        "wu": ParamDef((e, d, fe), ("experts", "expert_in", "expert_ff")),
        "wd": ParamDef((e, fe, d), ("experts", "expert_ff", "expert_in"),
                       ("normal", wscale)),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        s["shared_wg"] = ParamDef((d, fs), ("embed", "ff"))
        s["shared_wu"] = ParamDef((d, fs), ("embed", "ff"))
        s["shared_wd"] = ParamDef((fs, d), ("ff", "embed"), ("normal", wscale))
    return s


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = -(-tokens_per_group * cfg.n_experts_active * cfg.capacity_factor
          // cfg.n_experts)            # ceil
    return max(int(c), 1)


def moe_apply(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).  B rows are the dispatch groups."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    c = capacity(cfg, s)
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)    # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                  # renormalize

    # ---- load-balance aux (Switch): E * Σ_e fraction_e * prob_e ----
    me = probs.mean(axis=(0, 1))                                 # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones((b * s * k,), jnp.float32)) / (b * s * k)
    aux = e * jnp.sum(me * ce)

    ctx = sharding.active()
    if (ctx is not None and ctx[1].ep_shard_map and cfg.expert_parallel
            and ctx[1].model is not None
            and e % ctx[0].shape[ctx[1].model] == 0):
        y = _moe_shard_map(p, x, gate_idx, gate_vals, cfg, c)
        if cfg.n_shared_experts:
            sg = jax.nn.silu(x @ p["shared_wg"].astype(dt))
            su = x @ p["shared_wu"].astype(dt)
            y = y + (sg * su) @ p["shared_wd"].astype(dt)
        return y, aux

    # ---- sort-based dispatch (per group) ----
    def dispatch_group(xg, idxg, gateg):
        # xg: (S,d)  idxg/gateg: (S,k)
        flat_e = idxg.reshape(-1)                                # (S*k,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
        rank = jnp.arange(s * k) - starts[sorted_e]              # pos in expert
        keep = rank < c
        slot = jnp.where(keep, sorted_e * c + rank, e * c)       # drop -> pad row
        src = order // k
        buf = jnp.zeros((e * c + 1, d), dt).at[slot].add(xg[src])
        buf = buf[:-1].reshape(e, c, d)
        # combine metadata: for each (token,choice) its slot (or pad)
        inv = jnp.zeros((s * k,), jnp.int32).at[order].set(slot)
        return buf, inv

    bufs, invs = jax.vmap(dispatch_group)(x, gate_idx, gate_vals)
    bufs = sharding.constrain(bufs, sharding.moe_group_spec())   # (B,E,C,d)

    # ---- expert computation: SwiGLU per expert ----
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", bufs, p["wg"].astype(dt)))
    up = jnp.einsum("gecd,edf->gecf", bufs, p["wu"].astype(dt))
    out_buf = jnp.einsum("gecf,efd->gecd", gate * up, p["wd"].astype(dt))
    out_buf = sharding.constrain(out_buf, sharding.moe_group_spec())

    # ---- combine ----
    def combine_group(out_b, inv, gateg):
        flat = jnp.concatenate(
            [out_b.reshape(e * c, d), jnp.zeros((1, d), dt)], axis=0)
        picked = flat[jnp.minimum(inv, e * c)]                   # (S*k,d)
        w = gateg.reshape(-1, 1).astype(dt)
        y = jnp.zeros((s, d), dt).at[
            jnp.arange(s * k) // k].add(picked * w)
        return y

    y = jax.vmap(combine_group)(out_buf, invs, gate_vals)

    if cfg.n_shared_experts:
        sg = jax.nn.silu(x @ p["shared_wg"].astype(dt))
        su = x @ p["shared_wu"].astype(dt)
        y = y + (sg * su) @ p["shared_wd"].astype(dt)
    return y, aux


# ---------------------------------------------------------------------------
# Explicit expert parallelism (beyond-baseline §Perf lever)
# ---------------------------------------------------------------------------

def _moe_shard_map(p, x, gate_idx, gate_vals, cfg: ModelConfig, c: int):
    """shard_map expert parallelism: every (data, model) device processes its
    data-shard's tokens against its model-shard's experts, then one
    activation-sized psum over the model axis combines partial outputs.

    GSPMD cannot infer this pattern from the sort-based gather/scatter (it
    lowers them as full all-gathers/all-reduces of the 10x-inflated (E,C,d)
    capacity buffers — measured ~125 GB/layer/device on kimi-k2); the
    explicit formulation moves only ~2·|activations| per layer.
    Token-drop semantics are identical: each expert lives on exactly one
    shard, so its per-group capacity ranking is shard-local already.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh, rules = sharding.active()
    model_ax = rules.model
    msize = mesh.shape[model_ax]
    e, k = cfg.n_experts, cfg.n_experts_active
    e_local = e // msize
    d = x.shape[-1]
    dt = x.dtype
    batch_ax = rules.batch if rules.batch else None
    b_global = x.shape[0]
    bspec = batch_ax if (batch_ax and b_global % _axes_size(mesh, batch_ax) == 0) \
        else None

    def local_fn(xl, idxl, gatel, wg, wu, wd):
        # xl: (B_l, S, d) — full tokens of this data shard (replicated over
        # model); wg/wu/wd: (E_local, d, f) — this model shard's experts
        shard = jax.lax.axis_index(model_ax)
        e0 = shard * e_local
        s = xl.shape[1]

        def group(xg, idxg, gg):
            flat_e = idxg.reshape(-1)                       # (S*k,) global ids
            order = jnp.argsort(flat_e, stable=True)
            sorted_e = flat_e[order]
            starts = jnp.searchsorted(sorted_e, e0 + jnp.arange(e_local),
                                      side="left")
            local_id = sorted_e - e0                        # may be off-range
            in_range = (local_id >= 0) & (local_id < e_local)
            rank = jnp.arange(s * k) - jnp.where(
                in_range, starts[jnp.clip(local_id, 0, e_local - 1)], 0)
            keep = in_range & (rank < c)
            slot = jnp.where(keep, local_id * c + rank, e_local * c)
            src = order // k
            buf = jnp.zeros((e_local * c + 1, d), dt).at[slot].add(xg[src])
            buf = buf[:-1].reshape(e_local, c, d)
            gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt)))
            up = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
            out = jnp.einsum("ecf,efd->ecd", gate * up, wd.astype(dt))
            flat = jnp.concatenate(
                [out.reshape(e_local * c, d), jnp.zeros((1, d), dt)], axis=0)
            inv = jnp.zeros((s * k,), jnp.int32).at[order].set(slot)
            picked = flat[jnp.minimum(inv, e_local * c)]
            w = gg.reshape(-1, 1).astype(dt)
            return jnp.zeros((s, d), dt).at[jnp.arange(s * k) // k].add(
                picked * w)

        y_partial = jax.vmap(group)(xl, idxl, gatel)
        return jax.lax.psum(y_partial, model_ax)

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, None, None),
                  P(bspec, None, None),
                  P(model_ax, None, None), P(model_ax, None, None),
                  P(model_ax, None, None)),
        out_specs=P(bspec, None, None),
        check_rep=False,
    )(x, gate_idx, gate_vals.astype(dt), p["wg"], p["wu"], p["wd"])


def _axes_size(mesh, axes) -> int:
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return size
