"""Heterogeneous decoder stacks: schema → params/pspecs, forward/loss/decode.

Layer stacking: ``cfg.prefix_layers`` unrolled layers, then a repeating
period of ``cfg.scan_period`` layers scanned ``cfg.n_periods`` times with
per-position stacked parameters — HLO size is O(period), independent of
depth (jamba-72L and kimi-61L compile as 8- and 1-layer bodies).

Three execution modes share one code path:
  * train   — causal LM loss, optional remat, no caches
  * prefill — same forward, emits decode caches preallocated to ``max_len``
  * decode  — single-token step against the caches (KV or SSM state)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import sharding
from ..configs.base import LayerSpec, ModelConfig
from . import layers, mamba, moe
from .layers import ParamDef

PyTree = Any


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def _layer_schema(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, Any]:
    s: Dict[str, Any] = {"mixer_norm": layers.norm_schema(cfg)}
    s["mixer"] = (layers.attn_schema(cfg) if spec.mixer == "attn"
                  else mamba.mamba_schema(cfg))
    if spec.ffn != "none":
        s["ffn_norm"] = layers.norm_schema(cfg)
        s["ffn"] = (layers.mlp_schema(cfg) if spec.ffn == "mlp"
                    else moe.moe_schema(cfg))
    return s


def _stack(defn: ParamDef, n: int) -> ParamDef:
    return ParamDef((n,) + defn.shape, ("layers",) + defn.axes, defn.init)


def model_schema(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    s: Dict[str, Any] = {}
    if cfg.input_mode == "audio_codes":
        s["embed"] = {"tok": ParamDef((cfg.n_codebooks, v, d),
                                      (None, "vocab", "embed"))}
    else:
        s["embed"] = {"tok": ParamDef((v, d), ("vocab", "embed"))}
    s["prefix"] = {str(i): _layer_schema(cfg, cfg.layout[i])
                   for i in range(cfg.prefix_layers)}
    period = cfg.period_layout()
    s["body"] = {str(j): jax.tree.map(
        lambda pd: _stack(pd, cfg.n_periods), _layer_schema(cfg, spec),
        is_leaf=lambda x: isinstance(x, ParamDef))
        for j, spec in enumerate(period)}
    s["final_norm"] = layers.norm_schema(cfg)
    if not cfg.tie_embeddings:
        out_v = v * cfg.n_codebooks if cfg.input_mode == "audio_codes" else v
        s["unembed"] = {"w": ParamDef((d, out_v), ("embed", "vocab"))}
    return s


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def abstract_params(cfg: ModelConfig) -> PyTree:
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, jnp.dtype(cfg.param_dtype)),
        model_schema(cfg), is_leaf=_is_def)


def param_pspecs(cfg: ModelConfig, rules: sharding.MeshRules) -> PyTree:
    return jax.tree.map(
        lambda pd: sharding.logical_to_pspec(pd.axes, rules,
                                             cfg.expert_parallel),
        model_schema(cfg), is_leaf=_is_def)


def _init_leaf(pd: ParamDef, key, dtype):
    kind = pd.init[0]
    if kind == "zeros":
        return jnp.zeros(pd.shape, dtype)
    if kind == "ones":
        return jnp.ones(pd.shape, dtype)
    if kind == "normal":
        return (jax.random.normal(key, pd.shape, jnp.float32)
                * pd.init[1]).astype(dtype)
    if kind == "a_log":       # mamba: A_log = log(1..N) per state column
        n = pd.shape[-1]
        base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, pd.shape).astype(dtype)
    if kind == "dt_bias":     # softplus^-1 of dt0 ~ 0.01
        return jnp.full(pd.shape, -4.6, dtype)
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    schema = model_schema(cfg)
    flat, treedef = jax.tree.flatten(schema, is_leaf=_is_def)
    keys = jax.random.split(key, len(flat))
    dtype = jnp.dtype(cfg.param_dtype)
    leaves = [_init_leaf(pd, k, dtype) for pd, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, leaves)


def count_params(cfg: ModelConfig) -> int:
    flat, _ = jax.tree.flatten(model_schema(cfg), is_leaf=_is_def)
    return int(sum(int(np.prod(pd.shape)) for pd in flat))


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top-k + shared experts only)."""
    if cfg.n_experts == 0:
        return count_params(cfg)
    total = 0
    flat_with_path = jax.tree_util.tree_flatten_with_path(
        model_schema(cfg), is_leaf=_is_def)[0]
    frac = cfg.n_experts_active / cfg.n_experts
    for path, pd in flat_with_path:
        n = int(np.prod(pd.shape))
        is_expert = "experts" in pd.axes
        total += int(n * frac) if is_expert else n
    return total


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _layer_cache_def(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int):
    if spec.mixer == "attn":
        return layers.attn_cache_def(cfg, batch, max_len)
    return mamba.mamba_state_def(cfg, batch)


def cache_schema(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    s: Dict[str, Any] = {}
    s["prefix"] = {str(i): _layer_cache_def(cfg, cfg.layout[i], batch, max_len)
                   for i in range(cfg.prefix_layers)}
    period = cfg.period_layout()
    s["body"] = {str(j): jax.tree.map(
        lambda pd: _stack(pd, cfg.n_periods),
        _layer_cache_def(cfg, spec, batch, max_len), is_leaf=_is_def)
        for j, spec in enumerate(period)}
    return s


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(lambda pd: jnp.zeros(pd.shape, dt),
                        cache_schema(cfg, batch, max_len), is_leaf=_is_def)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(lambda pd: jax.ShapeDtypeStruct(pd.shape, dt),
                        cache_schema(cfg, batch, max_len), is_leaf=_is_def)


def cache_pspecs(cfg: ModelConfig, rules: sharding.MeshRules,
                 long_context: bool = False) -> PyTree:
    """PartitionSpecs matching cache_schema's structure."""
    hd = cfg.resolved_head_dim

    def leaf_spec(pd: ParamDef, stacked: bool):
        if "seq" in pd.axes:            # (B, T, KV, hd) attention cache
            base = _kv_spec(cfg, rules, long_context)
        elif "state" in pd.axes:        # (B, di, N) mamba h state
            base = jax.sharding.PartitionSpec(
                _batch(rules, long_context), rules.model, None)
        else:                           # (B, kc-1, di) conv state
            base = jax.sharding.PartitionSpec(
                _batch(rules, long_context), None, rules.model)
        if stacked:
            return jax.sharding.PartitionSpec(None, *base)
        return base

    schema = cache_schema(cfg, batch=1, max_len=1)   # structure only
    out: Dict[str, Any] = {"prefix": {}, "body": {}}
    for i, sub in schema["prefix"].items():
        out["prefix"][i] = jax.tree.map(lambda pd: leaf_spec(pd, False), sub,
                                        is_leaf=_is_def)
    for j, sub in schema["body"].items():
        out["body"][j] = jax.tree.map(lambda pd: leaf_spec(pd, True), sub,
                                      is_leaf=_is_def)
    return out


def _batch(rules: sharding.MeshRules, long_context: bool):
    if long_context:
        return None          # batch=1: replicate batch, shard sequence
    return rules.batch if rules.batch else None


def _kv_spec(cfg, rules, long_context):
    from jax.sharding import PartitionSpec as P
    msize = 1
    ctx = sharding.active()
    if ctx is not None and rules.model is not None:
        msize = ctx[0].shape[rules.model]
    h_ax = d_ax = None
    if msize > 1:
        if cfg.n_kv_heads % msize == 0:
            h_ax = rules.model
        elif cfg.resolved_head_dim % msize == 0:
            d_ax = rules.model
    if long_context and rules.seq:
        return P(None, rules.seq, h_ax, d_ax)
    return P(_batch(rules, long_context), None, h_ax, d_ax)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    dt = jnp.dtype(cfg.dtype)
    emb = params["embed"]["tok"]
    if cfg.input_mode == "audio_codes":
        codes = batch["codes"]                       # (B, K, S)
        x = sum(jnp.take(emb[k], codes[:, k], axis=0)
                for k in range(cfg.n_codebooks))
    elif cfg.input_mode == "vlm" and "vision_embeds" in batch:
        tok = jnp.take(emb, batch["tokens"], axis=0)
        x = jnp.concatenate([batch["vision_embeds"].astype(tok.dtype), tok],
                            axis=1)
    else:
        x = jnp.take(emb, batch["tokens"], axis=0)
    return sharding.constrain(x.astype(dt),
                              sharding.act_spec_btd(x.shape[1]))


def _apply_layer(p, spec: LayerSpec, x, cfg: ModelConfig, *,
                 cache=None, pos=None, make_cache=False):
    aux = jnp.zeros((), jnp.float32)
    h = layers.norm_apply(p["mixer_norm"], x, cfg.norm)
    if spec.mixer == "attn":
        mix, new_cache = layers.attn_apply(p["mixer"], h, cfg, cache=cache,
                                           pos=pos, make_cache=make_cache)
    else:
        mix, new_cache = mamba.mamba_apply(p["mixer"], h, cfg,
                                           state=cache, make_cache=make_cache)
    x = x + mix
    if spec.ffn != "none":
        h = layers.norm_apply(p["ffn_norm"], x, cfg.norm)
        if spec.ffn == "mlp":
            x = x + layers.mlp_apply(p["ffn"], h)
        else:
            y, aux = moe.moe_apply(p["ffn"], h, cfg)
            x = x + y
    x = sharding.constrain(x, sharding.act_spec_btd(x.shape[1]))
    return x, new_cache, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(cfg.remat_policy)


def _make_cache_holder(cfg, spec, make_cache):
    """Attention prefill caches are written into max_len buffers later."""
    return None


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            mode: str = "train", caches: Optional[PyTree] = None,
            pos: Optional[jax.Array] = None, max_len: Optional[int] = None,
            ) -> Tuple[jax.Array, jax.Array, Optional[PyTree]]:
    """Returns (logits, moe_aux_mean, caches_out or None)."""
    assert mode in ("train", "prefill", "decode")
    make_cache = mode == "prefill"
    x = _embed_inputs(params, cfg, batch)
    period = cfg.period_layout()
    aux_total = jnp.zeros((), jnp.float32)
    n_moe = max(1, sum(1 for l in cfg.layout if l.ffn == "moe"))

    # ---- prefix (unrolled) ----
    new_prefix_caches: Dict[str, Any] = {}
    for i in range(cfg.prefix_layers):
        c = caches["prefix"][str(i)] if caches is not None else None
        x, nc, aux = _apply_layer(params["prefix"][str(i)], cfg.layout[i], x,
                                  cfg, cache=c, pos=pos, make_cache=make_cache)
        aux_total += aux
        if nc is not None:
            new_prefix_caches[str(i)] = nc

    # ---- scanned body ----
    def body(carry, xs):
        x, aux_total = carry
        bparams, bcaches = xs
        new_caches = {}
        for j, spec in enumerate(period):
            c = bcaches[str(j)] if bcaches is not None else None
            x, nc, aux = _apply_layer(bparams[str(j)], spec, x, cfg,
                                      cache=c, pos=pos, make_cache=make_cache)
            aux_total += aux
            new_caches[str(j)] = nc if nc is not None else jnp.zeros((),
                                                                     x.dtype)
        return (x, aux_total), new_caches

    body_caches = caches["body"] if caches is not None else None
    xs = (params["body"], body_caches)
    if body_caches is None:
        # scan needs a concrete pytree; use a per-period dummy
        xs = (params["body"],
              {str(j): jnp.zeros((cfg.n_periods,), jnp.float32)
               for j in range(len(period))})

        def body_nocache(carry, xs):
            bparams, _ = xs
            return body(carry, (bparams, None))
        scan_fn = _remat(body_nocache, cfg) if mode == "train" else body_nocache
    else:
        scan_fn = body

    (x, aux_total), ys = jax.lax.scan(scan_fn, (x, aux_total), xs)
    new_body_caches = ys if caches is not None or make_cache else None

    # ---- head ----
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    dt = x.dtype
    if cfg.tie_embeddings:
        emb = params["embed"]["tok"].astype(dt)
        logits = jnp.einsum("bsd,vd->bsv", x, emb)
    else:
        logits = x @ params["unembed"]["w"].astype(dt)
    if cfg.input_mode == "audio_codes":
        b, s, _ = logits.shape
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
    logits = sharding.constrain(
        logits, sharding.logits_spec() if cfg.input_mode != "audio_codes"
        else jax.sharding.PartitionSpec(sharding.batch_axes(), None, None,
                                        sharding.rules_or_default().model))

    caches_out = None
    if make_cache or caches is not None:
        caches_out = {"prefix": new_prefix_caches, "body": new_body_caches}
        if make_cache and max_len is not None:
            caches_out = _pad_caches(caches_out, cfg, max_len)
    return logits, aux_total / n_moe, caches_out


def _pad_caches(caches, cfg: ModelConfig, max_len: int):
    """Grow prefill KV buffers (B,S,kv,hd) to (B,max_len,kv,hd)."""
    def pad(leaf):
        if leaf.ndim >= 4 and leaf.shape[-1] == cfg.resolved_head_dim:
            t_axis = leaf.ndim - 3
            pad_len = max_len - leaf.shape[t_axis]
            if pad_len > 0:
                widths = [(0, 0)] * leaf.ndim
                widths[t_axis] = (0, pad_len)
                return jnp.pad(leaf, widths)
        return leaf
    return jax.tree.map(pad, caches)


# ---------------------------------------------------------------------------
# Losses and steps
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            z_loss_weight: float = 1e-4):
    logits, aux, _ = forward(params, cfg, batch, mode="train")
    logits = logits.astype(jnp.float32)
    targets = batch["targets"]
    if cfg.input_mode == "audio_codes":
        targets = jnp.moveaxis(targets, 1, 2)        # (B,K,S) -> (B,S,K)
    if cfg.input_mode == "vlm":
        pad = -jnp.ones(targets.shape[:1] + (cfg.vision_prefix,), targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)
    mask = (targets >= 0).astype(jnp.float32)
    safe_t = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, safe_t[..., None],
                                     axis=-1)[..., 0]
    ce = (lse - true_logit) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ce.sum() / denom
    zl = z_loss_weight * ((lse * mask) ** 2).sum() / denom
    total = loss + zl + cfg.router_aux_weight * aux
    metrics = {"loss": loss, "z_loss": zl, "moe_aux": aux, "tokens": denom}
    return total, metrics


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            max_len: int):
    """Causal forward that also returns decode caches sized to max_len."""
    logits, aux, caches = forward(params, cfg, batch, mode="prefill",
                                  max_len=max_len,
                                  pos=jnp.zeros((), jnp.int32))
    return logits, caches


def decode_step(params, cfg: ModelConfig, caches: PyTree,
                tokens: Dict[str, jax.Array], pos: jax.Array):
    """One new token against the caches.  pos = current cache length."""
    logits, _, caches = forward(params, cfg, tokens, mode="decode",
                                caches=caches, pos=pos)
    return logits, caches
