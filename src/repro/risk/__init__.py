"""Availability forecasting and risk-adjusted provisioning (DESIGN.md §10).

A learning layer between market data and the solver: online estimators
(:mod:`~repro.risk.estimators`) turn the scenario engine's event stream
into per-offering hazard / price-drift / fulfillment-shortfall signals; a
survival model (:mod:`~repro.risk.survival`) converts hazard into expected
uptime over a provisioning horizon; and the risk-adjusted objective
(:mod:`~repro.risk.objective`) folds both into adjusted (Perf̂, SP̂)
vectors that the unchanged PR 1 GSS × ILP stack consumes — the
``kubepacs_risk[:horizon]`` policy in ``repro.sim.policy``.

:mod:`~repro.risk.backtest` replays recorded traces to score forecast
calibration and compare risk-aware vs static provisioning on perf-per-
dollar net of interruption losses.  (Import it as ``repro.risk.backtest``;
it depends on ``repro.sim``, which itself imports the modules above, so the
package root stays cycle-free by not re-exporting it.)
"""

from .estimators import RiskEstimators, RiskParams, replay_observations
from .objective import (RiskAdjustment, e_risk, reweight_candidates,
                        risk_adjustment)
from .survival import (expected_interrupted_nodes, expected_uptime_fraction,
                       interrupt_probability, survival_curve)

__all__ = [
    "RiskEstimators", "RiskParams", "replay_observations",
    "RiskAdjustment", "risk_adjustment", "reweight_candidates", "e_risk",
    "survival_curve", "interrupt_probability", "expected_uptime_fraction",
    "expected_interrupted_nodes",
]
