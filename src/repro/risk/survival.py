"""Batched exponential survival model over per-offering hazard rates.

Under a constant hazard λ_i (events per node-hour, from
:class:`repro.risk.estimators.RiskEstimators`), a node of offering i
survives h hours with probability ``S_i(h) = exp(−λ_i·h)``.  Everything the
risk-adjusted objective needs follows in closed form and vectorizes over
the whole catalog:

* survival curves ``S_i(h)`` over a horizon grid — (n, H) in one call,
* interrupt probability over a horizon ``P_i(H) = 1 − exp(−λ_i·H)``,
* expected-uptime fraction
  ``U_i(H) = (1/H)·∫₀ᴴ S_i(t) dt = (1 − exp(−λ_i·H)) / (λ_i·H)``,
  the factor E_risk multiplies into Perf_i (→ 1 as λ·H → 0).

All functions use ``−expm1(−x)`` for 1 − e^(−x) and switch to the exact
limit below ``_SMALL`` so the hazard → 0 / horizon → 0 reductions of
DESIGN.md §10 hold bitwise, not just approximately.
"""

from __future__ import annotations

import numpy as np

_SMALL = 1e-12


def survival_curve(hazard: np.ndarray, hours: np.ndarray) -> np.ndarray:
    """S_i(h) = exp(−λ_i·h) as an (n_offerings, n_hours) matrix."""
    hazard = np.asarray(hazard, dtype=np.float64).reshape(-1, 1)
    hours = np.asarray(hours, dtype=np.float64).reshape(1, -1)
    return np.exp(-hazard * hours)


def interrupt_probability(hazard: np.ndarray, horizon: float) -> np.ndarray:
    """P_i(H) = 1 − exp(−λ_i·H): chance a node is reclaimed within H hours."""
    hazard = np.asarray(hazard, dtype=np.float64)
    if horizon <= 0:
        return np.zeros_like(hazard)
    return -np.expm1(-hazard * horizon)


def expected_uptime_fraction(hazard: np.ndarray,
                             horizon: float) -> np.ndarray:
    """U_i(H) = (1 − exp(−λ_i·H)) / (λ_i·H), exactly 1 in the λ·H → 0 limit.

    The fraction of the next ``horizon`` hours a freshly-launched node of
    offering i is expected to be alive — the uptime discount E_risk applies
    to Perf_i.
    """
    hazard = np.asarray(hazard, dtype=np.float64)
    if horizon <= 0:
        return np.ones_like(hazard)
    x = hazard * horizon
    with np.errstate(divide="ignore", invalid="ignore"):
        u = -np.expm1(-x) / x
    return np.where(x < _SMALL, 1.0, u)


def expected_interrupted_nodes(hazard: np.ndarray, counts: np.ndarray,
                               hours: float) -> np.ndarray:
    """E[nodes lost] = x_i·(1 − exp(−λ_i·h)) — the calibration forecast the
    backtest compares against realized interrupt counts."""
    return np.asarray(counts, dtype=np.float64) * interrupt_probability(
        hazard, hours)
