"""The risk-adjusted provisioning objective E_risk (DESIGN.md §10).

KubePACS maximizes ``E_Total = E_PerfCost × E_OverPods`` over static
(Perf_i, SP_i).  E_risk is the same functional over *adjusted* vectors:

    Perf̂_i = Perf_i · U_i(H) · (1 − s_i)           (uptime & fulfillment)
    SP̂_i   = SP_i · max(1 + clip(d_i)·H/2, floor)   (drifted mean price)
             + SP_i · c · P_i(H) / H                 (re-provision charge)

where ``U_i(H)`` is the expected-uptime fraction and ``P_i(H)`` the
interrupt probability from :mod:`repro.risk.survival`, ``s_i`` the
fulfillment-shortfall rate, ``d_i`` the clipped EWMA price drift, and
``c = reprovision_hours`` the node-hours of spend one interruption wastes
(checkpoint restore + replacement startup, amortized per hour of horizon).

Because the adjustment only substitutes the two objective vectors — the
constraint structure (Pod_i, T3_i) is untouched — the existing batched
solver stack is reused verbatim: :func:`reweight_candidates` produces
adjusted ``CandidateItem``s for GSS scoring and a reweighted
``CompiledMarket`` for the ILP via the PR 1 entry points
(:func:`repro.core.efficiency.reweight_items`,
:func:`repro.core.ilp.reweight_market`).

Exact reductions (property-tested): with horizon ≤ 0 the adjustment is the
identity, and with zero hazard, zero drift, and zero shortfall it is the
identity at any horizon — so E_risk degrades to E_Total exactly when the
estimators carry no risk signal.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.efficiency import (CandidateItem, NodePool, e_total,
                               reweight_items)
from ..core.ilp import CompiledMarket, reweight_market
from .estimators import RiskEstimators, RiskParams
from .survival import expected_uptime_fraction, interrupt_probability

#: lowest multiple of SP_i the drift term may produce — a crashing price
#: must not drive the effective price to zero (the ILP needs SP̂ > 0)
_PRICE_FLOOR = 0.05


@dataclasses.dataclass(frozen=True)
class RiskAdjustment:
    """The adjusted (Perf̂, SP̂) vectors for one candidate set + horizon."""

    perf: np.ndarray          # (m,) uptime/fulfillment-discounted Perf_i
    price: np.ndarray         # (m,) drift + re-provision adjusted SP_i
    hazard: np.ndarray        # (m,) per-item hazard used (diagnostics)
    horizon: float


def risk_adjustment(items: Sequence[CandidateItem],
                    estimators: RiskEstimators, horizon: float,
                    ) -> RiskAdjustment:
    """Compute (Perf̂_i, SP̂_i) for preprocessed candidates in one pass."""
    perf = np.array([it.perf for it in items], dtype=np.float64)
    price = np.array([it.spot_price for it in items], dtype=np.float64)
    if horizon <= 0 or not items:
        return RiskAdjustment(perf=perf, price=price,
                              hazard=np.zeros(len(items)), horizon=horizon)
    p: RiskParams = estimators.params
    idx = estimators.gather([it.offering.offering_id for it in items])
    hazard = estimators.hazard()[idx]
    drift = np.clip(estimators.drift()[idx], -p.drift_clip, p.drift_clip)
    short = estimators.shortfall()[idx]

    uptime = expected_uptime_fraction(hazard, horizon)
    p_int = interrupt_probability(hazard, horizon)
    perf_adj = perf * uptime * (1.0 - short)
    price_adj = (price * np.maximum(1.0 + 0.5 * drift * horizon, _PRICE_FLOOR)
                 + price * p.reprovision_hours * p_int / horizon)
    return RiskAdjustment(perf=perf_adj, price=price_adj, hazard=hazard,
                          horizon=horizon)


def serving_risk_adjustment(adj: RiskAdjustment, serve_perf: np.ndarray,
                            base_perf: np.ndarray) -> RiskAdjustment:
    """SLO-aware reweighting hook (DESIGN.md §15): carry a risk
    adjustment's multiplicative perf discount — uptime × fulfillment,
    i.e. ``adj.perf / base_perf`` — over to a *serving-rate* objective
    vector (QPS/pod · Pod_i from the serving perf model), keeping the
    price adjustment as-is.  The serving policy then optimizes expected
    *served* QPS per risk-adjusted dollar through the unchanged solver
    stack.  Exact reduction: at horizon ≤ 0 (or no risk signal)
    ``adj.perf == base_perf``, so the result is exactly ``serve_perf`` —
    pure serving reweighting with no risk term."""
    serve_perf = np.asarray(serve_perf, dtype=np.float64)
    base_perf = np.asarray(base_perf, dtype=np.float64)
    if serve_perf.shape != base_perf.shape or \
            serve_perf.shape != adj.perf.shape:
        raise ValueError("serve_perf/base_perf must match the adjustment")
    factor = np.where(base_perf > 0,
                      adj.perf / np.maximum(base_perf, 1e-300), 0.0)
    return dataclasses.replace(adj, perf=serve_perf * factor)


def reweight_candidates(items: Sequence[CandidateItem],
                        adj: RiskAdjustment,
                        market: Optional[CompiledMarket] = None,
                        ) -> Tuple[List[CandidateItem],
                                   Optional[CompiledMarket]]:
    """Adjusted candidates (+ reweighted compiled market when one is given)
    ready for the unchanged GSS × ILP stack."""
    items_adj = reweight_items(items, adj.perf, adj.price)
    market_adj = (None if market is None
                  else reweight_market(market, adj.perf, adj.price,
                                       items=items_adj))
    return items_adj, market_adj


def e_risk(pool: NodePool, req_pods: int, items_adj: Sequence[CandidateItem],
           ) -> float:
    """E_risk of a pool expressed over the *real* items: score its counts
    against the adjusted candidates (same order/filtering as the solve)."""
    by_id = {it.offering.offering_id: it for it in items_adj}
    mapped = NodePool(items=[by_id[it.offering.offering_id]
                             for it in pool.items],
                      counts=list(pool.counts))
    return e_total(mapped, req_pods)
