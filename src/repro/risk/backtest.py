"""Trace-driven backtesting of the risk subsystem (DESIGN.md §10).

Two questions, answered offline from the scenario engine:

1. **Is the forecast calibrated?**  :func:`calibration_report` replays a
   recorded trace with a :class:`CalibrationObserver` attached — an
   estimator stack that, at every tick, *first* predicts this tick's
   interrupt outcome for the live pool from its current hazard state and
   *then* updates on what actually happened.  Scores: Brier score of the
   per-(tick, offering) any-interrupt probability, and predicted vs
   realized interrupted-node totals.

2. **Does risk-adjusted provisioning pay?**  :func:`compare_policies` runs
   the same scenario under multiple policies × interruption seeds and
   scores each run on perf-per-dollar *net of interruption losses*:

       net_ppd = (perf_hours − c·Σ lost_perf) / total_cost

   where ``perf_hours = ∫ pool perf_rate dt`` is the work the cluster
   delivered — already net of the expected half-tick of downtime the
   engine charges per reclaimed node — ``Σ lost_perf`` the aggregate
   Perf_i of reclaimed nodes, and ``c = RECOVERY_OVERHEAD_HOURS`` the
   *additional* node-hours one interruption destroys beyond downtime
   (emergency checkpoint, restore, lost step work).  The policy-side
   ``RiskParams.reprovision_hours`` internalizes the sum of both, so the
   objective and the scoreboard agree on what an interruption costs.

The module also ships the two standard stress scenarios
(:func:`interrupt_storm_scenario`, :func:`price_shock_scenario`) shared by
``benchmarks/bench_risk.py`` and the test suite.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.market import Offering
from ..sim.engine import ClusterSim, SimResult
from ..sim.fleet import FleetSim, run_fleet
from ..sim.scenario import Scenario, Shock
from .estimators import RiskEstimators, RiskParams
from .survival import interrupt_probability


# ---------------------------------------------------------------------------
# Standard stress scenarios
# ---------------------------------------------------------------------------

def interrupt_storm_scenario(**overrides) -> Scenario:
    """Bid-crossing interrupt storm: a market-wide price spike (then a
    regional aftershock) drives live spot past the 1.15× bid for much of
    the pool, reclaiming capacity wholesale behind 2 h rebalance warnings —
    the same storm shape as the PR 2 ``run_scenario`` example.  Crossing is
    deterministic given the market path, so the backtest comparison is
    RNG-noise-free: policy deltas are pure selection differences."""
    base = dict(
        name="risk_interrupt_storm", duration_hours=48.0, step_hours=6.0,
        pods=160, cpu_per_pod=2.0, mem_per_pod=2.0,
        interrupt_model="rebalance:2:price_crossing:1.15",
        shocks=(Shock(time=12.0, kind="price", factor=1.6),
                Shock(time=30.0, kind="price", factor=1.6,
                      selector="us-east-1")),
        policy="kubepacs", catalog_seed=11, max_offerings=200,
        market_seed=11, interrupt_seed=11)
    base.update(overrides)
    return Scenario(**base)


def price_shock_scenario(**overrides) -> Scenario:
    """Bid-crossing interrupts under regional price spikes: offerings whose
    spot runs past the bid get reclaimed wholesale, so drift/hazard state
    should steer re-provisioning away from the spiking regions."""
    base = dict(
        name="risk_price_shock", duration_hours=48.0, step_hours=6.0,
        pods=80, cpu_per_pod=2.0, mem_per_pod=2.0,
        interrupt_model="price_crossing:1.15",
        shocks=(Shock(time=12.0, kind="price", factor=1.8,
                      selector="us-east-1"),
                Shock(time=24.0, kind="price", factor=1.6,
                      selector="eu-west-1")),
        policy="kubepacs", catalog_seed=13, max_offerings=200,
        market_seed=13, interrupt_seed=13)
    base.update(overrides)
    return Scenario(**base)


def pressure_crunch_scenario(**overrides) -> Scenario:
    """Pressure-sampled interrupts over a capacity-crunched market: a T3
    crunch pushes allocations toward their pools' capacity.  The pressure
    law's per-offering base rate is the IF band the hazard prior already
    encodes, so this scenario measures what risk adjustment costs when
    there is little *extra* signal to learn (reported for honesty; the
    headline comparisons are the storm and price-shock scenarios)."""
    base = dict(
        name="risk_pressure_crunch", duration_hours=48.0, step_hours=6.0,
        pods=80, cpu_per_pod=2.0, mem_per_pod=2.0,
        interrupt_model="pressure",
        shocks=(Shock(time=12.0, kind="capacity", factor=0.5),),
        policy="kubepacs", catalog_seed=11, max_offerings=200,
        market_seed=11, interrupt_seed=11)
    base.update(overrides)
    return Scenario(**base)


# ---------------------------------------------------------------------------
# Forecast calibration
# ---------------------------------------------------------------------------

class CalibrationObserver:
    """Predict-then-update probe over the engine's event stream.

    At each tick it forecasts, for every offering with live allocation,
    the probability of *any* interrupt over the tick
    (``1 − exp(−λ_i·x_i·Δt)``, the exact union of x_i independent
    exponential clocks) and the expected interrupted-node count
    (``x_i·(1 − exp(−λ_i·Δt))``), records the realized outcome, and only
    then folds the tick into its estimators — predictions are always
    out-of-sample.
    """

    def __init__(self, catalog: Sequence[Offering],
                 params: Optional[RiskParams] = None):
        self.estimators = RiskEstimators(catalog, params)
        self.brier_terms: List[float] = []
        self.predicted_nodes = 0.0
        self.realized_nodes = 0
        self.ticks = 0

    def observe_market(self, time, spot, t3):
        self.estimators.on_market_state(time, spot, t3)

    def observe_interrupts(self, time, dt, pool, notices):
        if dt > 0:
            hazard = self.estimators.hazard()
            hit = {}
            for n in notices:
                hit[n.offering_id] = hit.get(n.offering_id, 0) + n.count
            for oid, count in pool.items():
                i = self.estimators.index.get(oid)
                if i is None or count <= 0:
                    continue
                p_any = float(interrupt_probability(
                    np.array([hazard[i] * count]), dt)[0])
                y = 1.0 if hit.get(oid, 0) > 0 else 0.0
                self.brier_terms.append((p_any - y) ** 2)
                self.predicted_nodes += count * float(interrupt_probability(
                    np.array([hazard[i]]), dt)[0])
            self.realized_nodes += sum(n.count for n in notices)
            self.ticks += 1
        self.estimators.on_interrupts(time, dt, pool, notices)

    def observe_fulfillment(self, time, requested, grants):
        self.estimators.on_fulfillment(time, requested, grants)

    def observe_pool(self, time, pool, reason):
        """Formal observer protocol (DESIGN.md §9): calibration scores
        interrupt forecasts, not capacity timelines — nothing to do."""

    def report(self) -> Dict:
        n = len(self.brier_terms)
        return {
            "ticks": self.ticks,
            "allocations_scored": n,
            "brier": float(np.mean(self.brier_terms)) if n else None,
            "predicted_interrupted_nodes": round(self.predicted_nodes, 3),
            "realized_interrupted_nodes": int(self.realized_nodes),
            "forecast_ratio": (round(self.predicted_nodes
                                     / self.realized_nodes, 3)
                               if self.realized_nodes else None),
        }


def calibration_report(records: Sequence[Dict], *,
                       catalog: Optional[Sequence[Offering]] = None,
                       params: Optional[RiskParams] = None) -> Dict:
    """Replay a recorded trace and score the hazard forecast against it."""
    records = list(records)
    if catalog is None:
        catalog = Scenario.from_dict(records[0]["scenario"]).build_catalog()
    probe = CalibrationObserver(catalog, params)
    ClusterSim.replay(records, catalog=catalog, observers=[probe]).run()
    return probe.report()


def fleet_calibration(scenario: Scenario, seeds: Sequence[int], *,
                      catalog: Optional[Sequence[Offering]] = None,
                      params: Optional[RiskParams] = None) -> Dict:
    """Calibration over a whole interruption-seed fleet (DESIGN.md §11).

    One predict-then-update :class:`CalibrationObserver` rides each fleet
    replica — fed the identical event stream a standalone run would feed
    it — so the Brier score and forecast ratio are estimated over
    ``len(seeds)`` independent interrupt realizations of one market path
    instead of a single draw.  Returns the pooled score (every
    (tick, offering, seed) Brier term weighted equally), the summed
    predicted/realized node counts, and the per-seed reports.
    """
    probes: List[CalibrationObserver] = []

    def factory(cat):
        probe = CalibrationObserver(cat, params)
        probes.append(probe)
        return [probe]

    FleetSim(scenario, seeds, catalog=catalog,
             observer_factory=factory).run()
    reports = [p.report() for p in probes]
    terms = [t for p in probes for t in p.brier_terms]
    predicted = float(sum(p.predicted_nodes for p in probes))
    realized = int(sum(p.realized_nodes for p in probes))
    return {
        "seeds": [int(s) for s in seeds],
        "allocations_scored": len(terms),
        "brier": float(np.mean(terms)) if terms else None,
        "predicted_interrupted_nodes": round(predicted, 3),
        "realized_interrupted_nodes": realized,
        "forecast_ratio": (round(predicted / realized, 3)
                           if realized else None),
        "per_seed": reports,
    }


# ---------------------------------------------------------------------------
# Policy comparison on perf-per-dollar net of interruption losses
# ---------------------------------------------------------------------------

#: node-hours of work destroyed per interruption beyond the engine's
#: half-tick downtime charge (emergency checkpoint + restore + lost steps)
RECOVERY_OVERHEAD_HOURS = 0.25


def net_perf_per_dollar(result: SimResult,
                        recovery_overhead_hours: float = RECOVERY_OVERHEAD_HOURS,
                        ) -> float:
    """(delivered perf-hours − c·Σ lost Perf_i) / total cost."""
    if result.total_cost <= 0:
        return 0.0
    net = (result.total_perf_hours
           - recovery_overhead_hours * result.lost_perf_total)
    return float(net) / float(result.total_cost)


def _run_metrics(result: SimResult, recovery_overhead_hours: float) -> Dict:
    return {
        "interrupt_seed": result.scenario.interrupt_seed,
        "total_cost": round(result.total_cost, 4),
        "perf_hours": round(result.total_perf_hours, 1),
        "lost_perf": round(result.lost_perf_total, 1),
        "interrupted_nodes": result.interrupted_nodes,
        "decisions": len(result.decisions),
        "net_ppd": round(net_perf_per_dollar(result,
                                             recovery_overhead_hours), 1),
        "raw_ppd": round(result.total_perf_hours / result.total_cost, 1)
        if result.total_cost > 0 else 0.0,
    }


def compare_policies(scenario: Scenario,
                     policies: Sequence[str] = ("kubepacs",
                                                "kubepacs_risk:24"),
                     seeds: Sequence[int] = (0, 1, 2),
                     recovery_overhead_hours: float = RECOVERY_OVERHEAD_HOURS,
                     ) -> Dict:
    """Backtest ``policies`` on one scenario across interruption seeds.

    Every (policy, seed) run shares the scenario's market path seeds, so
    differences are pure policy differences plus the interrupt draws their
    distinct pools induce.  Returns per-policy per-seed metrics and
    seed-mean summaries keyed by policy spec.

    Runs ride the fleet engine (one :class:`FleetSim` per policy over all
    seeds — DESIGN.md §11), which produces per-seed results identical to
    standalone ``ClusterSim`` runs; ``apply_fulfillment`` scenarios, which
    the fleet cannot script, fall back to the per-seed path.
    """
    c = recovery_overhead_hours
    runs: Dict[str, List[Dict]] = {}
    for spec in policies:
        sc = dataclasses.replace(scenario, policy=spec)
        if scenario.apply_fulfillment:
            results = [ClusterSim(dataclasses.replace(
                sc, interrupt_seed=int(seed))).run() for seed in seeds]
        else:
            results = run_fleet(sc, seeds)
        runs[spec] = [_run_metrics(r, c) for r in results]
    summary = {}
    for spec, rows in runs.items():
        summary[spec] = {
            "mean_net_ppd": round(float(np.mean([r["net_ppd"]
                                                 for r in rows])), 1),
            "mean_raw_ppd": round(float(np.mean([r["raw_ppd"]
                                                 for r in rows])), 1),
            "mean_cost": round(float(np.mean([r["total_cost"]
                                              for r in rows])), 4),
            "mean_interrupted_nodes": round(float(np.mean(
                [r["interrupted_nodes"] for r in rows])), 2),
            "mean_lost_perf": round(float(np.mean([r["lost_perf"]
                                                   for r in rows])), 1),
        }
    return {
        "scenario": scenario.name,
        "seeds": [int(s) for s in seeds],
        "recovery_overhead_hours": c,
        "runs": runs,
        "summary": summary,
    }
