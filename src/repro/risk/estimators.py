"""Vectorized online estimators over the offering catalog (DESIGN.md §10).

Three signals, each one flat numpy vector indexed by catalog position and
updated from the scenario engine's event stream (live run, trace replay, or
the offline :mod:`repro.risk.backtest` record walker — all three feed the
identical observation sequence, which is what makes risk-aware decisions
replayable):

* **spot-price drift** — EWMA of the per-hour relative price change of each
  offering, time-decayed with constant ``tau_price`` hours:
  ``d_i ← β·d_i + (1−β)·(p_t/p_{t−Δ} − 1)/Δ`` with ``β = exp(−Δ/τ_p)``.
* **interrupt hazard** — per-offering exponential hazard rate λ_i (events
  per node-hour), the ratio of two exponentially-forgotten accumulators:
  discounted interrupt counts over discounted node-hours of exposure.  The
  prior is the SpotLake pressure law at zero pressure
  (``0.01 + 0.015·IF_i`` per hour, see
  :func:`repro.core.market.pressure_interrupt_probability`) carried by
  ``prior_exposure_hours`` pseudo node-hours, so a cold-start estimator
  reproduces the static IF-band signal and observed interrupts sharpen it
  per offering.
* **fulfillment shortfall** — exponentially-forgotten requested/granted
  node counts from fulfillment grants; ``shortfall_i = 1 − granted/requested``.

Determinism contract: estimator state is a pure function of the observed
event sequence — no RNG, no wall clock.  Updating from a live run and from
replaying its trace yields bit-identical state because trace floats
round-trip exactly (DESIGN.md §9) and numpy arithmetic is deterministic.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.market import Offering

#: hazard prior at zero pool pressure — the pressure law's intercept + IF term
_HAZARD_BASE = 0.01
_HAZARD_PER_IF = 0.015


@dataclasses.dataclass(frozen=True)
class RiskParams:
    """Tuning constants of the estimators and the E_risk objective.

    All defaults are deliberately mild: the subsystem should refine the
    static KubePACS inputs, not overwhelm them.
    """

    tau_price_hours: float = 12.0      # price/drift EWMA time constant
    tau_hazard_hours: float = 72.0     # hazard accumulator forgetting constant
    prior_exposure_hours: float = 8.0  # pseudo node-hours carrying the prior
    fulfillment_decay: float = 0.8     # per-event forgetting of grant counts
    prior_requests: float = 4.0        # pseudo requested=granted nodes
    drift_clip: float = 0.25           # |per-hour drift| cap in E_risk
    # node-hours of work one interruption destroys: half a market step of
    # expected mid-interval downtime (the engine's delivered-work accounting
    # at the default 6 h step) plus recovery/restart overhead
    reprovision_hours: float = 3.25


class RiskEstimators:
    """Online (drift, hazard, shortfall) state over one offering catalog."""

    def __init__(self, catalog: Sequence[Offering],
                 params: Optional[RiskParams] = None):
        self.params = params or RiskParams()
        self.catalog = list(catalog)
        self.index: Dict[str, int] = {o.offering_id: i
                                      for i, o in enumerate(self.catalog)}
        n = len(self.catalog)
        p = self.params
        # price drift
        self._prev_spot = np.array([o.spot_price for o in self.catalog],
                                   dtype=np.float64)
        self._drift = np.zeros(n, dtype=np.float64)
        self._last_market_time: Optional[float] = None
        # hazard: exponentially-forgotten events over exposure, seeded with
        # the IF-band prior so hazard(0 data) == the static pressure law
        if_band = np.array([o.interruption_freq for o in self.catalog],
                           dtype=np.float64)
        self._hazard_prior = _HAZARD_BASE + _HAZARD_PER_IF * if_band
        self._exposure = np.full(n, p.prior_exposure_hours, dtype=np.float64)
        self._events = self._hazard_prior * self._exposure
        # fulfillment shortfall
        self._requested = np.full(n, p.prior_requests, dtype=np.float64)
        self._granted = np.full(n, p.prior_requests, dtype=np.float64)

    # -- observation hooks (the engine's observer protocol) -----------------
    def on_market_state(self, time: float, spot: np.ndarray,
                        t3: np.ndarray) -> None:
        """EWMA drift update from a live (spot, t3) refresh.

        A refresh at unchanged simulation time (the t=0 initial state, a
        same-instant shock) only re-anchors the price level: attributing an
        instantaneous jump to a *rate* would divide by Δt = 0.
        """
        del t3  # capacity enters via hazard exposure, not price drift
        spot = np.asarray(spot, dtype=np.float64)
        if self._last_market_time is not None:
            dt = time - self._last_market_time
            if dt > 0:
                with np.errstate(divide="ignore", invalid="ignore"):
                    rate = (spot / self._prev_spot - 1.0) / dt
                rate = np.where(np.isfinite(rate), rate, 0.0)
                beta = math.exp(-dt / self.params.tau_price_hours)
                self._drift = beta * self._drift + (1.0 - beta) * rate
        self._prev_spot = spot.copy()
        self._last_market_time = time

    def on_interrupts(self, time: float, dt: float, pool: Dict[str, int],
                      notices: Sequence) -> None:
        """Hazard update: decay, then add this tick's exposure and events.

        ``pool`` is the allocation that was exposed over the last ``dt``
        hours (pre-loss); ``notices`` are the sampled interrupt notices
        (advisory rebalance recommendations included — they are reclaims,
        just announced early).
        """
        del time
        if dt > 0:
            gamma = math.exp(-dt / self.params.tau_hazard_hours)
            self._exposure *= gamma
            self._events *= gamma
            # forgetting must not decay below the prior's evidence weight,
            # or a long calm run would drift hazard toward 0/0
            floor = self.params.prior_exposure_hours
            thin = self._exposure < floor
            if np.any(thin):
                self._events[thin] += self._hazard_prior[thin] * (
                    floor - self._exposure[thin])
                self._exposure[thin] = floor
            for oid, count in pool.items():
                i = self.index.get(oid)
                if i is not None and count > 0:
                    self._exposure[i] += count * dt
        for n in notices:
            i = self.index.get(n.offering_id)
            if i is not None:
                self._events[i] += n.count

    def on_fulfillment(self, time: float, requested: Dict[str, int],
                       grants: Dict[str, int]) -> None:
        """Shortfall update from one fulfillment round (requested vs granted)."""
        del time
        rho = self.params.fulfillment_decay
        for oid, req in requested.items():
            i = self.index.get(oid)
            if i is None or req <= 0:
                continue
            self._requested[i] = rho * self._requested[i] + req
            self._granted[i] = rho * self._granted[i] + min(
                req, grants.get(oid, 0))

    # -- estimates ----------------------------------------------------------
    def hazard(self) -> np.ndarray:
        """Per-offering exponential hazard rate λ_i (interrupts/node-hour)."""
        return self._events / self._exposure

    def drift(self) -> np.ndarray:
        """Per-offering EWMA relative price drift (1/hour)."""
        return self._drift.copy()

    def shortfall(self) -> np.ndarray:
        """Per-offering expected fulfillment shortfall fraction ∈ [0, 1)."""
        return np.clip(1.0 - self._granted / self._requested, 0.0, 1.0)

    def gather(self, offering_ids: Sequence[str]) -> np.ndarray:
        """Catalog indices for a list of offering_ids (e.g. candidate items)."""
        return np.array([self.index[oid] for oid in offering_ids],
                        dtype=np.int64)

    def digest(self) -> str:
        """Deterministic fingerprint of the full estimator state, used as
        the risk policy's contribution to the fleet decision-memo key
        (DESIGN.md §11): replicas with bit-identical estimator state (and
        identical market snapshot / request / excluded set) provably share
        one risk-adjusted solve.  Hashes the raw float64 buffers, so any
        single-bit state divergence changes the digest."""
        h = hashlib.blake2b(digest_size=16)
        for arr in (self._prev_spot, self._drift, self._exposure,
                    self._events, self._requested, self._granted):
            h.update(arr.tobytes())
        h.update(repr(self._last_market_time).encode())
        return h.hexdigest()

    # -- (de)serialization — deterministic state snapshots ------------------
    def state_dict(self) -> Dict:
        return {
            "prev_spot": self._prev_spot.tolist(),
            "drift": self._drift.tolist(),
            "exposure": self._exposure.tolist(),
            "events": self._events.tolist(),
            "requested": self._requested.tolist(),
            "granted": self._granted.tolist(),
            "last_market_time": self._last_market_time,
        }

    def load_state_dict(self, state: Dict) -> None:
        self._prev_spot = np.array(state["prev_spot"], dtype=np.float64)
        self._drift = np.array(state["drift"], dtype=np.float64)
        self._exposure = np.array(state["exposure"], dtype=np.float64)
        self._events = np.array(state["events"], dtype=np.float64)
        self._requested = np.array(state["requested"], dtype=np.float64)
        self._granted = np.array(state["granted"], dtype=np.float64)
        self._last_market_time = state["last_market_time"]


def replay_observations(estimators: RiskEstimators,
                        records: Sequence[Dict]) -> RiskEstimators:
    """Drive estimators from raw trace records (offline/backtest path).

    Feeds ``market_state`` and ``fulfillment`` records directly.  Hazard
    exposure needs the live pool, which raw records do not carry — use the
    engine replay with an observer (``repro.risk.backtest``) when hazard
    learning matters; this walker is the light-weight path for price/
    fulfillment state.
    """
    for rec in records:
        if rec["type"] == "market_state":
            estimators.on_market_state(rec["time"],
                                       np.array(rec["spot"]),
                                       np.array(rec["t3"]))
        elif rec["type"] == "fulfillment":
            grants = rec["grants"]
            estimators.on_fulfillment(rec["time"], dict(grants), grants)
    return estimators
