from .elastic import ElasticSpotTrainer, ElasticConfig

__all__ = ["ElasticSpotTrainer", "ElasticConfig"]
