"""Elastic spot-instance training runtime — the paper's §4.1 reactive loop
wired to a real JAX training job.

The KubePACS provisioner owns the node pool; the trainer owns the model;
the **scenario engine owns the event stream**: market time, price ticks,
and interruption notices come from a ``repro.sim.ClusterSim`` (the same
engine behind the figure benchmarks), so every training run is recorded to
the engine's replayable JSONL trace instead of a private market loop.
Each "provisioning epoch":

  provision → train steps → cluster.advance() emits interruption notices →
  emergency checkpoint → cache interrupted offerings → re-optimize
  (ILP × GSS minus the Unavailable Offerings Cache) → merge replacement
  capacity → restore → continue

On this single-host container the *cluster* is simulated (the engine emits
the same event stream AWS would), while the *training* is real JAX:
checkpoint/restore, deterministic data resume, and the data-shard
re-partitioning on world-size change all execute for real.  Straggler
mitigation follows the paper's diversity argument plus a step-time
watchdog: offerings flagged slow are pushed through the same
UnavailableOfferingsCache path as interruptions.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Union

import jax
import numpy as np

from .. import optim
from ..configs.base import ModelConfig
from ..core import (InterruptEvent, KubePACSProvisioner, NodePool, Request,
                    SpotMarketSimulator, merge_pools)
from ..data.pipeline import DataConfig, make_batch
from ..models import transformer
from ..sim import ClusterSim
from ..train import checkpoint as ckpt
from ..train.loop import make_train_step


@dataclasses.dataclass
class ElasticConfig:
    total_steps: int = 50
    ckpt_every: int = 10
    market_check_every: int = 5
    market_hours_per_check: float = 1.0
    batch_rows: int = 8
    seq_len: int = 128
    straggler_t3_floor: int = 2      # offerings whose live T3 sinks below
    #                                  this are treated as stragglers
    keep_checkpoints: int = 3


@dataclasses.dataclass
class EpochLog:
    step: int
    event: str
    detail: Dict[str, Any]


class ElasticSpotTrainer:
    def __init__(self, cfg: ModelConfig, request: Request,
                 market: Union[SpotMarketSimulator, ClusterSim],
                 ckpt_dir: str,
                 ecfg: Optional[ElasticConfig] = None,
                 opt_cfg: Optional[optim.OptConfig] = None,
                 dcfg: Optional[DataConfig] = None, seed: int = 0):
        self.cfg = cfg
        self.request = request
        # a bare market is wrapped into the engine (pressure interrupts on
        # a seed-keyed stream); passing a ClusterSim directly lets callers
        # pick the interruption model and capture the trace
        self.cluster = (market if isinstance(market, ClusterSim)
                        else ClusterSim.from_market(market, name="elastic",
                                                    interrupt_seed=seed))
        self.ckpt_dir = ckpt_dir
        self.ecfg = ecfg or ElasticConfig()
        self.opt_cfg = opt_cfg or optim.OptConfig(warmup_steps=5,
                                                  total_steps=1000)
        self.dcfg = dcfg or DataConfig(seed=seed)
        self.provisioner = KubePACSProvisioner()
        self.pool: Optional[NodePool] = None
        self.world = 1
        self.logs: List[EpochLog] = []
        self.recovery_times: List[float] = []

        key = jax.random.PRNGKey(seed)
        self.params = transformer.init_params(cfg, key)
        self.opt_state = optim.init_opt_state(self.params)
        self.step = 0
        self._train_step = make_train_step(cfg, self.opt_cfg, donate=False)
        self._step_times: List[float] = []

    # ------------------------------------------------------------------
    def provision(self) -> None:
        decision = self.provisioner.provision(self.request,
                                              self.cluster.current_snapshot())
        self.pool = decision.pool
        self.world = max(1, min(self.pool.total_pods, self.request.pods))
        self.logs.append(EpochLog(self.step, "provision", {
            "nodes": self.pool.total_nodes, "pods": self.pool.total_pods,
            "alpha": decision.alpha, "e_total": decision.metrics["e_total"],
            "hourly_cost": self.pool.hourly_cost,
            "wall_s": decision.wall_seconds,
        }))

    def _surviving_pool(self, events: List[InterruptEvent]) -> NodePool:
        lost = {}
        for ev in events:
            lost[ev.offering_id] = lost.get(ev.offering_id, 0) + ev.count
        items, counts = [], []
        for it, c in zip(self.pool.items, self.pool.counts):
            c2 = max(0, c - lost.get(it.offering.offering_id, 0))
            if c2 > 0:
                items.append(it)
                counts.append(c2)
        return NodePool(items=items, counts=counts, alpha=self.pool.alpha,
                        request=self.pool.request)

    def _handle_events(self, events: List[InterruptEvent], kind: str) -> None:
        t0 = time.perf_counter()
        # 1. emergency checkpoint (the 2-minute-notice path)
        ckpt.save_checkpoint(self.ckpt_dir, self.step, self.params,
                             self.opt_state, {"reason": kind},
                             keep=self.ecfg.keep_checkpoints)
        # 2. cache interrupted offerings + re-optimize the shortfall
        self.provisioner.clock = self.cluster.time
        self.provisioner.enqueue(events)
        survivors = self._surviving_pool(events)
        repl = self.provisioner.handle_interrupts(
            self.request, self.cluster.current_snapshot(),
            surviving_pods=survivors.total_pods)
        if repl is not None and repl.pool.total_nodes > 0:
            self.pool = merge_pools(survivors, repl.pool)
        else:
            self.pool = survivors
        old_world = self.world
        self.world = max(1, min(self.pool.total_pods, self.request.pods))
        # 3. replacement workers join: restore from the emergency checkpoint
        self.params, self.opt_state, meta = ckpt.restore_checkpoint(
            self.ckpt_dir, self.params, self.opt_state)
        recovery = time.perf_counter() - t0
        self.recovery_times.append(recovery)
        self.logs.append(EpochLog(self.step, kind, {
            "lost_nodes": int(sum(e.count for e in events)),
            "world": (old_world, self.world),
            "pods_after": self.pool.total_pods,
            "recovery_s": recovery,
        }))

    def _check_stragglers(self) -> List[InterruptEvent]:
        """Paper-consistent straggler policy: pools whose live multi-node
        capacity collapsed are demoted exactly like interrupted offerings."""
        if self.pool is None:
            return []
        snapshot = {o.offering_id: o.t3
                    for o in self.cluster.current_snapshot()}
        events = []
        for it, c in zip(self.pool.items, self.pool.counts):
            oid = it.offering.offering_id
            if c > 0 and snapshot.get(oid, 0) < self.ecfg.straggler_t3_floor:
                events.append(InterruptEvent(time=self.cluster.time,
                                             offering_id=oid, count=c,
                                             reason="straggler"))
        return events

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        steps = steps or self.ecfg.total_steps
        # resume if a checkpoint exists (restart-after-failure path)
        last = ckpt.latest_step(self.ckpt_dir)
        if last is not None:
            self.params, self.opt_state, meta = ckpt.restore_checkpoint(
                self.ckpt_dir, self.params, self.opt_state)
            self.step = int(meta["step"])
            self.logs.append(EpochLog(self.step, "resume", {"from": last}))
        if self.pool is None:
            self.provision()

        losses = []
        while self.step < steps:
            t0 = time.perf_counter()
            # deterministic, shard-aware batch: this host plays worker 0 of
            # `world`; on rescale the shard arithmetic re-partitions rows
            batch = make_batch(self.cfg, self.dcfg, step=self.step,
                               shard=self.step % self.world, world=self.world,
                               batch=self.ecfg.batch_rows,
                               seq=self.ecfg.seq_len)
            self.params, self.opt_state, metrics = self._train_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            self._step_times.append(time.perf_counter() - t0)
            self.step += 1

            if self.step % self.ecfg.ckpt_every == 0:
                ckpt.save_checkpoint(self.ckpt_dir, self.step, self.params,
                                     self.opt_state, {"reason": "periodic"},
                                     keep=self.ecfg.keep_checkpoints)
            if self.step % self.ecfg.market_check_every == 0:
                # the engine advances time, records the tick to its trace,
                # and emits the interruption notices effective now.
                # NOTE: hazard exposure now matches the market step — the
                # pre-engine loop stepped the market market_hours_per_check
                # hours but sampled only 1 h of interrupt hazard, so runs
                # with market_hours_per_check > 1 see proportionally more
                # interrupts than the seed did (intentional consistency fix)
                events = self.cluster.advance(
                    self.ecfg.market_hours_per_check, self.pool.as_dict())
                if events:
                    self._handle_events(events, "interrupt")
                stragglers = self._check_stragglers()
                if stragglers:
                    self._handle_events(stragglers, "straggler")

        return {
            "losses": losses,
            "final_loss": losses[-1] if losses else float("nan"),
            "events": [dataclasses.asdict(l) for l in self.logs],
            "recovery_times": self.recovery_times,
            "interrupts_handled": sum(1 for l in self.logs
                                      if l.event in ("interrupt", "straggler")),
            "steps": self.step,
            "trace_records": len(self.cluster.recorder.records),
        }
