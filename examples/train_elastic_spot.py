"""End-to-end driver: train a ~100M-param LM for a few hundred steps on a
KubePACS-provisioned spot pool, surviving simulated interruptions
(checkpoint -> re-provision -> restore).

    PYTHONPATH=src python examples/train_elastic_spot.py [--steps 300]
"""

import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.configs.base import ModelConfig, dense_layout
from repro.core import Request, SpotMarketSimulator, generate_catalog
from repro.runtime import ElasticConfig, ElasticSpotTrainer


def model_100m() -> ModelConfig:
    """~100M params: 12 layers, d_model 768, GQA 12/4, SwiGLU 2048."""
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32_000,
        layout=dense_layout(12), scan_period=1, remat_policy="none",
    ).validate()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    # single-CPU-core demo defaults (~3 s/step); raise on real hardware
    ap.add_argument("--batch-rows", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_100m()
    from repro.models.transformer import count_params
    print(f"model: {cfg.name}  params={count_params(cfg)/1e6:.1f}M")

    market = SpotMarketSimulator(generate_catalog(seed=7), seed=7)
    request = Request(pods=64, cpu_per_pod=4, mem_per_pod=8)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="kubepacs_ckpt_")
    print(f"checkpoints: {ckpt_dir}")

    trainer = ElasticSpotTrainer(
        cfg, request, market, ckpt_dir,
        ElasticConfig(total_steps=args.steps, ckpt_every=25,
                      market_check_every=10, market_hours_per_check=2.0,
                      batch_rows=args.batch_rows, seq_len=args.seq_len))
    out = trainer.run()

    print(f"\ntrained {out['steps']} steps; "
          f"loss {out['losses'][0]:.3f} -> {out['final_loss']:.3f}")
    print(f"interrupt/straggler events handled: {out['interrupts_handled']}"
          f"  (recovery: {[round(r, 2) for r in out['recovery_times']]} s)")
    for e in out["events"]:
        print(f"  step {e['step']:4d}  {e['event']:10s}  {e['detail']}")


if __name__ == "__main__":
    main()
