"""Quickstart: provision a performant/available/cost-efficient spot node pool
with KubePACS and inspect the decision.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (KubePACSProvisioner, Request, e_total,
                        generate_catalog, kubepacs_greedy, preprocess,
                        spotverse)


def main():
    # 1. market snapshot (offline stand-in for the SpotLake archive)
    catalog = generate_catalog(seed=0)
    print(f"catalog: {len(catalog)} offerings "
          f"({len({o.instance_type for o in catalog})} instance types, "
          f"{len({o.region for o in catalog})} regions)")

    # 2. the workload: 100 pods of 2 vCPU / 2 GiB, network-heavy
    request = Request(pods=100, cpu_per_pod=2, mem_per_pod=2,
                      workload={"network"})

    # 3. KubePACS: preprocessing -> ILP x GSS -> node pool
    provisioner = KubePACSProvisioner(tolerance=0.01)
    decision = provisioner.provision(request, catalog)
    pool = decision.pool
    print(f"\nKubePACS decision (alpha*={decision.alpha:.4f}, "
          f"{decision.trace.ilp_solves} ILP solves, "
          f"{decision.wall_seconds:.2f}s):")
    print(f"  nodes={pool.total_nodes}  pods={pool.total_pods} "
          f"(requested {request.pods})  cost=${pool.hourly_cost:.3f}/h")
    print(f"  E_PerfCost={decision.metrics['e_perf_cost']:.3e}  "
          f"E_OverPods={decision.metrics['e_over_pods']:.3f}  "
          f"E_Total={decision.metrics['e_total']:.3e}")
    for it, c in sorted(zip(pool.items, pool.counts),
                        key=lambda ic: -ic[1])[:8]:
        o = it.offering
        print(f"    {c:3d} x {o.instance_type:<18s} @{o.az}  "
              f"spot=${o.spot_price:.4f}  T3={o.t3}  {o.specialization}")

    # 4. the baselines it beats (Fig. 5)
    items = preprocess(catalog, request)
    for name, p in (("greedy", kubepacs_greedy(items, request.pods)),
                    ("spotverse-node", spotverse(items, request.pods, "node"))):
        print(f"  vs {name:15s}: E_Total ratio "
              f"{e_total(p, request.pods) / decision.metrics['e_total']:.3f}")


if __name__ == "__main__":
    main()
