"""Batched serving: prefill a batch of prompts, then decode new tokens with
the KV/SSM caches — over any assigned architecture (reduced config on CPU).

    PYTHONPATH=src python examples/serve_batched.py --arch jamba-1.5-large-398b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)    # reduced config on CPU
    if cfg.input_mode != "tokens":
        print(f"note: {args.arch} is {cfg.input_mode}; serving its token "
              f"backbone (modality frontend is a stub per the assignment)")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S, N = args.batch, args.prompt_len, args.new_tokens
    max_len = S + N

    if cfg.input_mode == "audio_codes":
        prompt = {"codes": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, cfg.n_codebooks, S)))}
    elif cfg.input_mode == "vlm":
        prompt = {"tokens": jnp.asarray(
                      rng.integers(0, cfg.vocab_size, (B, S))),
                  "vision_embeds": jnp.asarray(
                      rng.normal(size=(B, cfg.vision_prefix, cfg.d_model)),
                      jnp.float32)}
        max_len += cfg.vision_prefix
        S += cfg.vision_prefix
    else:
        prompt = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)))}

    t0 = time.perf_counter()
    logits, caches = jax.jit(
        lambda p, b: prefill(p, cfg, b, max_len=max_len))(params, prompt)
    print(f"prefill: batch={B} len={S} in "
          f"{time.perf_counter() - t0:.2f}s  logits={logits.shape}")

    step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    tokens = []
    nxt = jnp.argmax(logits[:, -1:, ...], axis=-1)
    t0 = time.perf_counter()
    for i in range(N):
        if cfg.input_mode == "audio_codes":
            inp = {"codes": jnp.moveaxis(nxt, 2, 1)}     # (B,K,1)
        else:
            inp = {"tokens": nxt[..., 0] if nxt.ndim == 3 else nxt}
            inp["tokens"] = inp["tokens"].reshape(B, 1)
        logits, caches = step(params, caches, inp, jnp.asarray(S + i))
        nxt = jnp.argmax(logits[:, -1:, ...], axis=-1)
        tokens.append(np.asarray(nxt).reshape(B, -1)[:, 0])
    dt = time.perf_counter() - t0
    print(f"decoded {N} tokens/seq in {dt:.2f}s "
          f"({B * N / dt:.1f} tok/s batched)")
    print("sampled continuations (greedy):")
    arr = np.stack(tokens, axis=1)
    for b in range(B):
        print(f"  seq{b}: {arr[b].tolist()}")


if __name__ == "__main__":
    main()
