"""Long-horizon provisioning session: a KubePACS-managed pool tracked over a
simulated 48-hour spot market with interruptions, re-optimizations, and the
workload-intent heuristic — prints an hour-by-hour operations log.

    PYTHONPATH=src python examples/provision_cluster.py --hours 48
"""

import argparse

from repro.core import (KubePACSProvisioner, Request, SpotMarketSimulator,
                        generate_catalog, merge_pools)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=int, default=48)
    ap.add_argument("--pods", type=int, default=200)
    ap.add_argument("--intent", default="network",
                    choices=["none", "network", "disk", "both"])
    args = ap.parse_args()

    intent = {"none": frozenset(), "network": frozenset({"network"}),
              "disk": frozenset({"disk"}),
              "both": frozenset({"network", "disk"})}[args.intent]
    request = Request(pods=args.pods, cpu_per_pod=2, mem_per_pod=4,
                      workload=intent)
    sim = SpotMarketSimulator(generate_catalog(seed=11), seed=11)
    prov = KubePACSProvisioner(ttl_hours=3.0)

    d = prov.provision(request, sim.snapshot())
    pool = d.pool
    print(f"t=0h  provisioned {pool.total_nodes} nodes / {pool.total_pods} "
          f"pods @ ${pool.hourly_cost:.2f}/h  (alpha*={d.alpha:.3f}, "
          f"{d.wall_seconds:.2f}s)")

    total_cost, interrupts = 0.0, 0
    for h in range(1, args.hours + 1):
        sim.step(1.0)
        prov.clock = sim.time
        total_cost += pool.hourly_cost
        events = sim.interrupts_for_pool(pool.as_dict(), hours=1.0)
        if not events:
            continue
        lost = sum(e.count for e in events)
        interrupts += lost
        prov.enqueue(events)
        lost_pods = sum(
            e.count * next((it.pods for it in pool.items
                            if it.offering.offering_id == e.offering_id), 1)
            for e in events)
        survivors = max(0, pool.total_pods - lost_pods)
        repl = prov.handle_interrupts(request, sim.snapshot(),
                                      surviving_pods=survivors)
        if repl is not None and repl.pool.total_nodes:
            pool = merge_pools(pool, repl.pool)  # survivors + replacements
            print(f"t={h}h  lost {lost} nodes ({lost_pods} pods) -> "
                  f"replaced with {repl.pool.total_nodes} nodes in "
                  f"{repl.wall_seconds:.2f}s; cache={len(prov.cache)} "
                  f"excluded offerings")

    print(f"\n{args.hours}h summary: ${total_cost:.2f} spent, "
          f"{interrupts} node interruptions absorbed, "
          f"final pool {pool.total_nodes} nodes / {pool.total_pods} pods")


if __name__ == "__main__":
    main()
