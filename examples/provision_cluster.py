"""Long-horizon provisioning session: a KubePACS-managed pool tracked over a
simulated spot market with interruptions, re-optimizations, and the
workload-intent heuristic — an hour-by-hour operations log, driven by the
scenario engine (one declarative Scenario instead of a hand-rolled loop).

    PYTHONPATH=src python examples/provision_cluster.py --hours 48
"""

import argparse

from repro.sim import ClusterSim, Scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=int, default=48)
    ap.add_argument("--pods", type=int, default=200)
    ap.add_argument("--intent", default="network",
                    choices=["none", "network", "disk", "both"])
    args = ap.parse_args()

    intent = {"none": (), "network": ("network",), "disk": ("disk",),
              "both": ("network", "disk")}[args.intent]
    scenario = Scenario(
        name="provision_cluster",
        duration_hours=float(args.hours), step_hours=1.0,
        pods=args.pods, cpu_per_pod=2, mem_per_pod=4, workload=intent,
        interrupt_model="pressure", policy="kubepacs", ttl_hours=3.0,
        catalog_seed=11, max_offerings=2000,
        market_seed=11, interrupt_seed=11,
    )
    sim = ClusterSim(scenario)
    res = sim.run()

    _, d0 = res.decisions[0]
    print(f"t=0h  provisioned {d0.pool.total_nodes} nodes / "
          f"{d0.pool.total_pods} pods @ ${d0.pool.hourly_cost:.2f}/h  "
          f"(alpha*={d0.alpha:.3f}, {d0.wall_seconds:.2f}s)")
    for rd in res.rounds:
        if not rd.effective:
            continue
        repl = rd.decision
        if repl is not None and repl.pool.total_nodes:
            print(f"t={rd.time:.0f}h  lost {rd.lost_nodes} nodes "
                  f"({rd.lost_pods} pods) -> replaced with "
                  f"{repl.pool.total_nodes} nodes in "
                  f"{repl.wall_seconds:.2f}s; "
                  f"{len(repl.excluded_offerings)} excluded offerings")

    print(f"\n{args.hours}h summary: ${res.total_cost:.2f} spent, "
          f"{res.interrupted_nodes} node interruptions absorbed, "
          f"final pool {res.pool.total_nodes} nodes / "
          f"{res.pool.total_pods} pods "
          f"({len(res.records)} trace records)")


if __name__ == "__main__":
    main()
