"""Scenario-engine tour: define a scenario declaratively, run it, record the
JSONL trace, replay the trace bit-exactly, and sweep interruption seeds —
with the per-seed runner by default, or the replica-major fleet engine
(one shared market path + cross-replica decision memo, DESIGN.md §11)
when ``--replicas N`` asks for a real Monte-Carlo sweep.

With ``--workload`` the tour switches to the serving co-simulation
(DESIGN.md §15): a deterministic request-rate trace staffs the pod
demand, the chosen policy provisions it, and the run is read back as a
serving system — SLO attainment, served QPS-hours, recovery losses.

    PYTHONPATH=src python examples/run_scenario.py --trace /tmp/storm.jsonl
    PYTHONPATH=src python examples/run_scenario.py --smoke   # small & fast
    PYTHONPATH=src python examples/run_scenario.py --smoke --policy kubepacs_risk:12
    PYTHONPATH=src python examples/run_scenario.py --smoke --replicas 256
    PYTHONPATH=src python examples/run_scenario.py --smoke --workload diurnal
    PYTHONPATH=src python examples/run_scenario.py --workload flash --policy karpenter_like
    PYTHONPATH=src python examples/run_scenario.py --smoke --faults combined --policy hardened
    PYTHONPATH=src python examples/run_scenario.py --faults feed:0.5
    PYTHONPATH=src python examples/run_scenario.py --smoke --regions 3
    PYTHONPATH=src python examples/run_scenario.py --regions 3:0.8 --faults region --policy hardened

With ``--faults`` a named fault storm (DESIGN.md §16: ``feed`` / ``ice``
/ ``solver`` / ``combined``, optionally ``NAME:SCALE`` to compress the
windows) overlays the run; the tour then also reports decision
availability and — under ``--policy hardened`` — the degradation-ladder
rung counters.  The replay assertion runs as usual: fault injection is
part of the deterministic trace contract, not an exception to it.

With ``--regions K[:RHO]`` the run provisions across the first K catalog
regions as correlated markets (DESIGN.md §17: shared-factor shocks at
correlation RHO, data gravity toward the home region); the tour then
also reports per-region pool shares and egress spend.  ``--faults
region`` overlays :func:`repro.chaos.region_storm` on the home region —
try it with ``--policy hardened`` to watch the failover rung counters.
"""

import argparse

import numpy as np

from repro.chaos import fault_storm, region_storm
from repro.chaos.guard import decision_available
from repro.core.market import REGIONS
from repro.region import RegionConfig, region_pool_shares
from repro.sim import (ClusterSim, FleetSim, Scenario, Shock, load_trace,
                       make_policy, run_replicas)


def parse_faults(spec: str, smoke: bool, region=None):
    """``NAME`` or ``NAME:SCALE``.  The storm presets are laid out for a
    48 h horizon; without an explicit scale they are compressed to fit
    the tour's 36 h (or 12 h smoke) run."""
    name, _, scale = spec.partition(":")
    factor = float(scale) if scale else (0.25 if smoke else 0.75)
    if name == "region":
        if region is None:
            raise SystemExit("--faults region needs --regions K "
                             "(the storm targets the home region)")
        return region_storm(region.home, factor)
    return fault_storm(name, factor)


def parse_regions(spec: str) -> RegionConfig:
    """``K`` or ``K:RHO`` — the first K catalog regions as correlated
    markets, home (and data gravity) in the first."""
    k, _, rho = spec.partition(":")
    k = max(1, min(int(k), len(REGIONS)))
    return RegionConfig(regions=REGIONS[:k],
                        rho=float(rho) if rho else 0.6,
                        vol=0.25, shock_seed=11, home_region=REGIONS[0],
                        egress_per_pod_hour=0.002)


def build_scenario(smoke: bool, policy: str = "kubepacs",
                   faults=(), region=None) -> Scenario:
    return Scenario(
        name="interrupt_storm_with_spike",
        duration_hours=12.0 if smoke else 36.0, step_hours=6.0,
        pods=40 if smoke else 150, cpu_per_pod=2, mem_per_pod=2,
        # demand doubles mid-run; a price spike hits us-east-1 at hour 9
        demand_schedule=((9.0, 80 if smoke else 300),),
        shocks=(Shock(time=9.0, kind="price", factor=2.5,
                      selector="us-east-1"),),
        # two-hour rebalance warnings wrapped around bid crossings
        interrupt_model="rebalance:2:price_crossing:1.3",
        policy=policy,
        catalog_seed=7, max_offerings=300 if smoke else 800,
        market_seed=7, interrupt_seed=7,
        faults=tuple(faults), region=region,
    )


def run_serving_workload(kind: str, policy: str, smoke: bool) -> None:
    """The ServeSim tour: provision a staffed request trace, then report
    the run as a serving system (DESIGN.md §15)."""
    from repro.serve_sim import build_serve_scenario, run_serving

    ss = build_serve_scenario(kind, policy=policy,
                              duration_hours=8.0 if smoke else 24.0,
                              max_offerings=120 if smoke else 250)
    rep = run_serving(ss)
    print(f"serving {kind!r}: policy={rep.policy}, "
          f"perf_model={rep.perf_mode}, slo={rep.slo_ms:.0f}ms, "
          f"trace digest {rep.workload_digest[:12]}…")
    print(f"        offered {rep.offered_qps_hours:,.0f} QPS·h -> served "
          f"{rep.served_qps_hours:,.0f} ({rep.served_fraction:.1%}), "
          f"within SLO {rep.slo_served_qps_hours:,.0f} "
          f"(attainment {rep.slo_attainment:.1%})")
    print(f"        recovery losses {rep.recovery_lost_qps_hours:,.1f} "
          f"QPS·h across {rep.interrupted_nodes} interrupted nodes; "
          f"{rep.infeasible_decisions}/{rep.decisions} infeasible decisions")
    print(f"        ${rep.total_cost:.2f} total -> "
          f"{rep.slo_qps_hours_per_dollar:,.1f} SLO-served QPS·h per $")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="/tmp/kubepacs_scenario.jsonl")
    ap.add_argument("--smoke", action="store_true",
                    help="small catalog / short horizon")
    ap.add_argument("--policy", default="kubepacs",
                    help="policy spec, e.g. kubepacs, kubepacs_risk:12, "
                         "karpenter_like, fixed_alpha:0.5, serving_slo")
    ap.add_argument("--replicas", type=int, default=None, metavar="N",
                    help="sweep N interruption seeds with the fleet engine "
                         "(default: 5 seeds via the per-seed runner)")
    ap.add_argument("--workload", default=None, metavar="KIND",
                    choices=("diurnal", "bursty", "flash"),
                    help="run the serving co-simulation on this request-"
                         "trace family instead of the interrupt storm")
    ap.add_argument("--faults", default=None, metavar="STORM[:SCALE]",
                    help="overlay a named fault storm (feed, ice, solver, "
                         "combined; DESIGN.md §16 — or region, §17) — try "
                         "with --policy hardened")
    ap.add_argument("--regions", default=None, metavar="K[:RHO]",
                    help="provision across the first K catalog regions as "
                         "correlated markets (DESIGN.md §17), shared-factor "
                         "correlation RHO (default 0.6)")
    args = ap.parse_args()

    region = parse_regions(args.regions) if args.regions else None
    # validate the spec before building anything
    make_policy(args.policy, region=region)

    if args.workload:
        policy = ("serving_slo" if args.policy == "kubepacs"
                  else args.policy)        # serving default unless chosen
        run_serving_workload(args.workload, policy, args.smoke)
        return

    faults = (parse_faults(args.faults, args.smoke, region)
              if args.faults else ())
    scenario = build_scenario(args.smoke, policy=args.policy,
                              faults=faults, region=region)
    print(f"scenario {scenario.name!r}: {scenario.duration_hours:.0f}h, "
          f"policy={scenario.policy}, interrupts={scenario.interrupt_model}"
          + (f", faults={args.faults} ({len(faults)} windows)"
             if faults else "")
          + (f", regions={'/'.join(region.regions)} (rho={region.rho:g})"
             if region else ""))

    # 1. live run, recorded
    res = ClusterSim(scenario).run()
    res.recorder.dump(args.trace)
    print(f"live:   {len(res.decisions)} decisions, "
          f"{res.interrupted_nodes} nodes interrupted, "
          f"${res.total_cost:.2f} total -> {args.trace} "
          f"({len(res.records)} records)")
    if faults:
        avail = [decision_available(d) for _, d in res.decisions]
        rungs = {k[len("chaos_"):]: v for k, v in res.cache_stats.items()
                 if k.startswith("chaos_")}
        print(f"chaos:  decision availability "
              f"{sum(avail)}/{len(avail)} "
              f"({sum(avail) / max(len(avail), 1):.0%}); ladder rungs "
              + (str(rungs) if rungs
                 else "n/a (unhardened policy — no ladder)"))
    if region is not None:
        shares = region_pool_shares(res.pool) or {"(empty pool)": 0}
        share_s = ", ".join(f"{r}: {n}" for r, n in sorted(shares.items()))
        print(f"region: final pool shares {{{share_s}}}; egress "
              f"${res.total_egress:.2f} of ${res.total_cost:.2f} total"
              + (f"; failover rungs "
                 f"{ {k[len('chaos_'):]: v for k, v in res.cache_stats.items() if k.startswith('chaos_region')} }"
                 if any(k.startswith("chaos_region")
                        for k in res.cache_stats) else ""))

    # 2. replay from the JSONL trace — no RNG, identical decisions
    rep = ClusterSim.replay(load_trace(args.trace)).run()
    identical = rep.decision_records() == res.decision_records()
    byte_equal = rep.recorder.dumps() == res.recorder.dumps()
    print(f"replay: identical decisions={identical}, "
          f"byte-identical trace={byte_equal}")
    assert identical and byte_equal

    # 3. multi-seed sweep over one shared market path + compiled market:
    #    the fleet engine for real Monte-Carlo sizes, the per-seed runner
    #    for the default handful of seeds
    if args.replicas:
        fleet = FleetSim(scenario, list(range(args.replicas)))
        results = fleet.run()
        costs = [r.total_cost for r in results]
        stats = fleet.stats()
        lookups = stats.get("memo_hits", 0) + stats.get("memo_misses", 0)
        print(f"fleet:  {args.replicas} interruption seeds in "
              f"{fleet.wall_seconds:.2f}s "
              f"({args.replicas / fleet.wall_seconds:.0f} replicas/s) -> "
              f"total cost ${np.mean(costs):.2f} ± {np.std(costs):.2f}")
        print(f"        decision memo: {stats.get('memo_unique_solves', 0)} "
              f"unique solves for {lookups} decisions "
              f"(hit rate {stats.get('memo_hits', 0) / max(lookups, 1):.1%})")
    else:
        seeds = list(range(5))
        replicas = run_replicas(scenario, seeds)
        costs = [r.total_cost for r in replicas]
        print(f"sweep:  {len(seeds)} interruption seeds -> total cost "
              f"${np.mean(costs):.2f} ± {np.std(costs):.2f} "
              f"(min {min(costs):.2f}, max {max(costs):.2f})")


if __name__ == "__main__":
    main()
