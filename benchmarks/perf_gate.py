"""Minimal performance regression gate (the ReFrame pattern): re-run the
cheap backend-bench config, compare each metric against the checked-in
reference numbers in ``PERF_REFERENCE.json`` with per-metric tolerance
bands, fail the build on regression, and append the measurement to a
versioned trajectory file (``PERF_trajectory.jsonl``) so drift is
inspectable across commits.

Only *ratio* metrics are gated — speedups of one engine over another
measured interleaved in the same process — because absolute wall times
track the CI machine, not the code.  Correctness flags (selection
equality, zero fused fallbacks) are hard assertions, not bands.

Usage:
  python -m benchmarks.perf_gate            # gate against references
  python -m benchmarks.perf_gate --update   # refresh PERF_REFERENCE.json
  python -m benchmarks.perf_gate --smoke    # fewer decisions (CI)

``make perf-gate`` runs the gate; verify.yml wires it into tier-1.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import platform
import sys
from typing import List, Optional

from benchmarks.bench_backend import bench_tick
from benchmarks.bench_chaos import gate_measurement as chaos_measurement
from benchmarks.bench_region import gate_measurement as region_measurement
from benchmarks.bench_scale import gate_measurement as scale_measurement
from benchmarks.bench_serve import gate_measurement as serve_measurement
from repro.core import jax_available

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REFERENCE_PATH = os.path.join(ROOT, "PERF_REFERENCE.json")
TRAJECTORY_PATH = os.path.join(ROOT, "PERF_trajectory.jsonl")

#: gate config: the FleetSim-shaped fleet tick (100 items × 1 k pods) —
#: cheap enough for CI, and the regime the fused plane is built for
GATE_ITEMS = 100
GATE_PODS = 1000


def measure(n_dec: int, repeat: int = 3) -> dict:
    """One gate measurement: the ratio metrics + correctness flags."""
    rec = bench_tick(GATE_ITEMS, GATE_PODS, n_dec, repeat=repeat)
    metrics = {
        "batched_numpy_speedup_vs_pr1":
            rec["speedups_vs_pr1"]["batched_numpy"],
    }
    checks = {"pr1_equality": rec["equality_checked"]}
    if rec["jax_available"]:
        metrics["fused_vs_batched_numpy"] = rec["fused_vs_batched_numpy"]
        metrics["fused_vs_per_dispatch_jax"] = round(
            rec["batched_jax_wall_s"] / rec["fused_jax_wall_s"], 2)
        checks["fused_selections_equal_numpy"] = \
            rec["fused_jax_selections_equal_numpy"]
        checks["jax_selections_equal_numpy"] = \
            rec["batched_jax_selections_equal_numpy"]
        checks["fused_zero_fallbacks"] = rec["fused_fallback_solves"] == 0
    # demand-coarsening ladder (DESIGN.md §14): the 1M-vs-5k decision-wall
    # ratio is the only lower-is-better metric in the gate (its reference
    # carries a *bounded* upper_tol) and the gcd tier must stay bitwise
    scale = scale_measurement(repeat=repeat)
    metrics["scale_1m_vs_5k_ratio"] = scale["ratio"]
    checks["scale_gcd_tier_bitwise"] = scale["gcd_bitwise_ok"]
    # serving co-simulation (DESIGN.md §15): SLO-served QPS-hours per
    # dollar, serving_slo over karpenter_like, pinned to the analytic
    # perf-model mode so the value is leg-independent.  This one is a
    # *cost-efficiency* ratio, not a timing — it gates the decision
    # quality of the SLO-mask path, and its attainment/infeasibility/
    # determinism flags are hard correctness checks
    serve = serve_measurement(repeat=repeat)
    metrics["serve_qps_per_dollar_ratio"] = serve["serve_qps_per_dollar_ratio"]
    checks["serve_slo_attainment_ok"] = serve["attainment_ok"]
    checks["serve_zero_infeasible"] = serve["infeasible_free"]
    checks["serve_determinism"] = serve["determinism_ok"]
    # chaos hardening (DESIGN.md §16): SLO perf-per-dollar of the hardened
    # plane over the naive plane under the combined fault storm — another
    # cost-efficiency ratio (numpy-deterministic, leg-independent).  Its
    # availability/determinism/inertness flags are hard correctness
    # checks: a hardening layer that drops decision cycles, breaks the
    # trace contract, or perturbs the fault-free path is a bug regardless
    # of the ratio
    chaos = chaos_measurement(repeat=repeat)
    metrics["chaos_hardened_vs_naive_ratio"] = \
        chaos["chaos_hardened_vs_naive_ratio"]
    checks["chaos_availability_ok"] = chaos["availability_ok"]
    checks["chaos_determinism"] = chaos["determinism_ok"]
    checks["chaos_inert_when_healthy"] = chaos["inert_ok"]
    # multi-region failover (DESIGN.md §17): SLO perf-per-dollar of the
    # hardened plane with cross-region failover over the region-pinned
    # strawman through the correlated regional storm.  Its determinism
    # and single-region/identity-config inertness flags are hard checks:
    # a region layer that moves any bit of a region-free (or K=1, or
    # identity-config) run breaks the §9 contract regardless of the ratio
    region = region_measurement(repeat=repeat)
    metrics["region_failover_vs_pinned_ratio"] = \
        region["region_failover_vs_pinned_ratio"]
    checks["region_determinism"] = region["determinism_ok"]
    checks["region_single_region_inert"] = region["single_region_inert"]
    checks["region_identity_config_inert"] = \
        region["identity_config_inert"]
    raw = {k: v for k, v in rec.items()
           if k.endswith(("_wall_s", "_compile_s", "_ms_per_decision"))}
    raw["scale_wall_5k_s"] = scale["wall_5k_s"]
    raw["scale_wall_1m_s"] = scale["wall_1m_s"]
    raw["serve_slo_attainment"] = serve["serving_slo_attainment"]
    raw["chaos_hardened_availability"] = chaos["hardened_availability"]
    raw["region_hardened_demand_coverage"] = \
        region["hardened_demand_coverage"]
    return {"config": {"n_items": GATE_ITEMS, "base_pods": GATE_PODS,
                       "n_decisions": n_dec},
            "metrics": metrics, "checks": checks, "raw": raw}


def gate(measured: dict, reference: dict) -> List[str]:
    """ReFrame-style check: each measured metric must sit inside
    ``ref * (1 - lower_tol) .. ref * (1 + upper_tol)`` (upper_tol null =
    unbounded — being faster is never a regression).  Returns the list of
    failures (empty = pass)."""
    failures: List[str] = []
    for name, ok in measured["checks"].items():
        if not ok:
            failures.append(f"correctness check failed: {name}")
    for name, ref in reference["metrics"].items():
        got = measured["metrics"].get(name)
        if got is None:
            if name.startswith("fused") and not jax_available():
                continue                       # no-jax leg: ratio not run
            failures.append(f"metric missing from measurement: {name}")
            continue
        lo = ref["value"] * (1.0 - ref["lower_tol"])
        hi = (float("inf") if ref.get("upper_tol") is None
              else ref["value"] * (1.0 + ref["upper_tol"]))
        if not (lo <= got <= hi):
            failures.append(
                f"{name}: measured {got} outside "
                f"[{round(lo, 2)}, {round(hi, 2) if hi != float('inf') else 'inf'}] "
                f"(reference {ref['value']} -{ref['lower_tol'] * 100:.0f}%)")
    return failures


#: metrics where *larger* is the regression (everything else is a
#: higher-is-better speedup/efficiency ratio).  Explicit by name — a
#: suffix heuristic broke the moment a higher-is-better ``*_ratio``
#: metric (serve_qps_per_dollar_ratio) joined the gate
LOWER_IS_BETTER = frozenset({"scale_1m_vs_5k_ratio"})


def _default_reference(measured: dict) -> dict:
    """References from a fresh measurement.  Bands are deliberately wide
    (-50 % on every speedup): the gate exists to catch the engine falling
    off a cliff (a lost jit cache, a host round-trip creeping back into the
    golden loop), not to police scheduler noise on shared CI hosts.

    Higher-is-better metrics (speedups, QPS-per-dollar ratios) get
    upper_tol None (being faster/cheaper is never a regression).
    :data:`LOWER_IS_BETTER` metrics (the 1M-vs-5k scale ratio) get a
    *bounded* upper_tol instead — the ratio doubling over its reference
    means the coarsening ladder stopped absorbing the demand scale — and
    an unbounded lower side via lower_tol 1.0 (a cheaper 1M decision is
    never a regression)."""
    return {
        "benchmark": "perf_gate",
        "config": measured["config"],
        "machine": platform.machine(),
        "metrics": {
            name: ({"value": value, "lower_tol": 1.0, "upper_tol": 1.0}
                   if name in LOWER_IS_BETTER
                   else {"value": value, "lower_tol": 0.5,
                         "upper_tol": None})
            for name, value in measured["metrics"].items()
        },
    }


def run(update: bool = False, smoke: bool = False,
        repeat: int = 3) -> int:
    # references are only ever written under an explicit --update: a gate
    # that auto-refreshes on a missing reference is a silent no-op pass in
    # CI (a deleted or unshipped PERF_REFERENCE.json would mask every
    # regression), so gate mode fails fast — before the measurement —
    # when the file is absent
    if not update and not os.path.exists(REFERENCE_PATH):
        print(f"perf_gate: FAILED — reference file missing: "
              f"{REFERENCE_PATH}")
        print("perf_gate: a gate without references cannot detect "
              "regressions; run `python -m benchmarks.perf_gate --update` "
              "and commit the refreshed PERF_REFERENCE.json")
        return 1
    n_dec = 4 if smoke else 8
    measured = measure(n_dec, repeat=repeat)
    entry = {
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "python": platform.python_version(),
        **measured,
    }
    with open(TRAJECTORY_PATH, "a") as f:
        f.write(json.dumps(entry) + "\n")
    if update:
        with open(REFERENCE_PATH, "w") as f:
            json.dump(_default_reference(measured), f, indent=2)
        print(f"perf_gate: reference refreshed → {REFERENCE_PATH}")
        print(json.dumps(measured["metrics"], indent=2))
        return 0
    with open(REFERENCE_PATH) as f:
        reference = json.load(f)
    failures = gate(measured, reference)
    for name, value in sorted(measured["metrics"].items()):
        ref = reference["metrics"].get(name, {}).get("value")
        print(f"perf_gate: {name} = {value} (reference {ref})")
    for name, ok in sorted(measured["checks"].items()):
        print(f"perf_gate: check {name}: {'ok' if ok else 'FAILED'}")
    if failures:
        print("perf_gate: REGRESSION")
        for fail in failures:
            print(f"  - {fail}")
        return 1
    print("perf_gate: pass")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="refresh PERF_REFERENCE.json from this run")
    ap.add_argument("--smoke", action="store_true",
                    help="fewer decisions (CI)")
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args(argv if argv is not None else [])
    return run(update=args.update, smoke=args.smoke, repeat=args.repeat)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
